"""Compile -> save -> enact -> replay: the serving-plan workflow
(DESIGN.md Sec. 15), mirroring ``search_and_enact.py`` for decode.

    PYTHONPATH=src python examples/serve_with_plan.py
    PYTHONPATH=src python examples/serve_with_plan.py --steps 20

Search Phase: ``repro.serving.plan.compile_serving()`` lowers one decode
step into the unified event engine — per-token TP collectives as
dep-coupled jobs, prefill admissions from a seeded synthetic request
trace as a competing traffic class — and drives the mutation-registry
backtracking search over the serving knobs (slots, decode batch,
KV-shard layout, collective algorithm, streams).  The result is a
frozen, schema-versioned :class:`ServingPlan` that ``dryrun
--serve-plan`` can re-price and the cache can round-trip.

Enactment Phase: ``ServingPlan.load()`` round-trips the artifact
(asserted bit-for-bit) and ``ServeEngine(plan=...)`` enacts the searched
slot/batch choices on a real (reduced) model; ``replay`` drives the
engine through the same synthetic trace on a virtual clock and prints
the per-request metrics.  The engine run uses a small trace and slot
overrides so the example stays CI-sized — the plan's searched geometry
is for the production mesh, not this host.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    import argparse

    from repro.cluster import list_presets

    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default="tpu_v5e_pod_16",
                    choices=list_presets())
    ap.add_argument("--steps", type=int, default=None,
                    help="bound the search step count (CI smoke lane)")
    args = ap.parse_args()

    from repro.serving.plan import ServingPlan, compile_serving
    from repro.serving.workload import VirtualClock, Workload, replay

    # ---- Search Phase ----
    print("search phase ...")
    workload = Workload(n_requests=48, rate=32.0, concurrency=32, seed=0)
    plan = compile_serving("tinyllama-1.1b", cluster=args.cluster,
                           workload=workload, unchanged_limit=40,
                           max_steps=args.steps, seed=0)
    path = os.path.join(tempfile.gettempdir(), "disco_serve_plan.json")
    plan.save(path)
    d = plan.describe()
    print(f"  searched serving knobs on {args.cluster}: "
          f"slots={d['slots']} batch={d['decode_batch']} "
          f"kv={d['kv_layout']} algo={d['algo']} streams={d['streams']} "
          f"(predicted {plan.predicted_tokens_per_s:.0f} tok/s, "
          f"ttft p99 {plan.predicted_ttft_p99_s*1e3:.3f} ms, "
          f"{plan.provenance['simulations']} simulations); saved {path}")

    # ---- Enactment Phase ----
    print("enactment phase ...")
    loaded = ServingPlan.load(path)
    assert loaded == plan and loaded.fingerprint() == plan.fingerprint(), \
        "serving plan save/load round-trip drifted"
    print(f"  plan round-trips bit-for-bit [{loaded.fingerprint()}]")

    import jax

    from repro.configs import get_config
    from repro.models import stacked as ST
    from repro.serving.engine import ServeEngine

    cfg = get_config("tinyllama-1.1b").reduced()
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    # enact the plan on a host-sized engine: the searched decode_batch /
    # KV layout carry over, the slot count is clamped to this host
    slots = min(loaded.slots, 4)
    engine = ServeEngine(params, cfg, plan=loaded,
                         max_slots=slots, cache_len=64,
                         decode_batch=min(loaded.decode_batch, 2),
                         clock=VirtualClock())
    trace = Workload(n_requests=6, rate=64.0, concurrency=slots,
                     prompt_lens=(3, 8), new_tokens=(3, 6), seed=1)
    m = replay(engine, trace, step_time=1e-3)
    print(f"  replayed {m['completed']} requests / {m['tokens']} tokens in "
          f"{m['decode_steps']} decode steps on the virtual clock: "
          f"{m['tokens_per_s']:.0f} tok/s, "
          f"ttft p50 {m['ttft_p50_s']*1e3:.1f} ms, "
          f"latency p99 {m['latency_p99_s']*1e3:.1f} ms")
    assert m["completed"] == trace.n_requests, "replay dropped requests"
    print("the searched serving plan is enacted by the real engine")


if __name__ == "__main__":
    main()

"""Quickstart: DisCo in five steps on a real traced model.

    PYTHONPATH=src python examples/quickstart.py

1. build a reduced TinyLlama training step,
2. trace it into the fusion IR (one AllReduce per gradient),
3. cost the paper's baselines with the simulator,
4. run the joint op/tensor-fusion backtracking search,
5. print the strategy and simulated speed-up.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.core import (Simulator, backtracking_search, evaluate_baselines,
                        profile_graph, trace_grad_graph)
from repro.data.pipeline import materialize_batch
from repro.models import model as M


def main():
    import dataclasses

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              n_layers=6)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = materialize_batch(cfg, batch=8, seq=64)

    print("1/5 tracing the training step into the fusion IR ...")
    g = profile_graph(trace_grad_graph(
        lambda p, bt: M.loss_fn(p, cfg, bt), params, batch))
    print(f"    {g.describe()}")

    sim = Simulator(n_devices=256)
    print("2/5 baseline strategies (simulated per-iteration time):")
    base = evaluate_baselines(g, sim)
    for name, t in sorted(base.items(), key=lambda kv: kv[1]):
        print(f"    {name:22s} {t * 1e6:9.1f} us")

    print("3/5 joint op/tensor-fusion backtracking search (Alg. 1) ...")
    res = backtracking_search(g, sim, alpha=1.05, beta=10,
                              unchanged_limit=150, seed=0)
    print(f"    {res.simulations} simulations in {res.wall_time:.1f}s")

    print("4/5 best strategy found:")
    print(f"    {res.best.describe()}")
    r = sim.run(res.best)
    print(f"    compute {r.compute_time * 1e6:.1f} us, comm "
          f"{r.comm_time * 1e6:.1f} us, overlap ratio {r.overlap_ratio:.2f}")

    best_base = min(v for k, v in base.items() if k != "FO")
    print(f"5/5 DisCo {res.best_cost * 1e6:.1f} us vs best baseline "
          f"{best_base * 1e6:.1f} us "
          f"(+{(best_base - res.best_cost) / res.best_cost * 100:.1f}%), "
          f"FO bound {base['FO'] * 1e6:.1f} us")


if __name__ == "__main__":
    main()

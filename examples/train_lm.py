"""End-to-end data-parallel training with a DisCo-searched strategy.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen2-0.5b] [--steps N]

Trains a reduced assigned-architecture LM for a few hundred steps on the
synthetic bigram corpus, with the gradient AllReduce schedule enacted from
the DisCo search (see repro/launch/train.py for the full driver with
checkpoints/resume).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if __name__ == "__main__":
    from repro.launch import train

    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv += ["--arch", "qwen2-0.5b"]
    if not any(a.startswith("--steps") for a in argv):
        argv += ["--steps", "200"]
    sys.argv = [sys.argv[0], "--reduced", "--batch", "16", "--seq", "64",
                "--strategy", "auto", "--log-every", "25"] + argv
    train.main()

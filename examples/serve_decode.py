"""Batched serving: prefill a batch of prompts, then decode new tokens.

    PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-3b]

Exercises the inference substrate the decode_32k / long_500k dry-run shapes
lower: prefill -> warm cache -> jit'd single-token decode steps (greedy).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import materialize_batch
from repro.models import stacked as ST


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    cache_len = args.prompt_len + args.new_tokens
    prompts = materialize_batch(cfg, args.batch, args.prompt_len)["tokens"]

    print(f"prefill {args.batch} prompts of {args.prompt_len} tokens ...")
    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, t: ST.prefill(p, cfg, t, cache_len))
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    print(f"  prefill {time.perf_counter() - t0:.2f}s "
          f"({args.batch * args.prompt_len} tokens)")

    decode = jax.jit(
        lambda p, c, tok, pos: ST.decode_step(p, cfg, c, tok, pos))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.new_tokens):
        pos = jnp.int32(args.prompt_len + i)
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"  decoded {total} tokens in {dt:.2f}s "
          f"({total / dt:.0f} tok/s, {dt / args.new_tokens * 1e3:.1f} "
          f"ms/step)")
    seq = jnp.stack(out_tokens, axis=1)
    print(f"  first sequence continuation: {list(map(int, seq[0][:16]))} ...")


if __name__ == "__main__":
    main()

"""Search -> save -> enact: the full DisCo workflow (paper Sec. 3.1),
through the ``repro.plan`` public API.

    PYTHONPATH=src python examples/search_and_enact.py
    PYTHONPATH=src python examples/search_and_enact.py \
        --cluster a100_nvlink_ib --streams 4

Search Phase: one call — ``repro.plan.compile()`` owns trace -> profile ->
backtracking search and returns a frozen, versioned :class:`repro.plan.
Plan` artifact (op-fusion groups, buckets, per-bucket algo/comm/chunks,
cluster fingerprint, predicted iteration time).  ``plan.save()`` writes the
schema-versioned JSON — the paper's "optimized HLO module" configuration
file, now a first-class value that ``dryrun --plan`` can re-price and any
trainer can load.

Enactment Phase: ``Plan.load()`` round-trips the artifact (asserted
bit-for-bit) and ``plan.grad_sync(params)`` lowers it to the
:class:`GradSyncStrategy` built into the distributed train step; we lower
the per-tensor baseline and the DisCo-bucketed step and show the AllReduce
count in the compiled HLO shrink accordingly — including real per-chunk
collectives when the search picked ``chunks > 1``.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

import repro.plan as RP
from repro.configs import get_config
from repro.data.pipeline import make_batch_specs
from repro.distributed.train_step import (GradSyncStrategy, build_train_step,
                                          jit_train_step)
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import make_mesh_compat
from repro.models import stacked as ST
from repro.optim import adamw


def allreduce_count(cfg, mesh, strategy, params, opt, specs):
    step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strategy)
    jf = jit_train_step(step, cfg, mesh, params, opt, specs)
    compiled = jf.lower(params, opt, specs).compile()
    coll = parse_collectives(compiled.as_text())
    return coll["per_op"].get("all-reduce", {"count": 0})["count"], coll


def main():
    import argparse

    from repro.cluster import list_presets

    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default=None, choices=list_presets(),
                    help="cluster preset to search against; default: "
                         "legacy flat model")
    ap.add_argument("--streams", type=int, default=1,
                    help="search against the N-stream event engine; with "
                         "--cluster the comm kind (AllReduce vs ZeRO-3 "
                         "RS+AG) and chunk count become searched dimensions "
                         "too (the flat default spec is algorithm-blind and "
                         "drops them)")
    ap.add_argument("--steps", type=int, default=None,
                    help="bound the search step count (CI smoke lane)")
    args = ap.parse_args()

    # ---- Search Phase (ENABLE_SEARCH=1 in the paper) ----
    print("search phase ...")
    if args.cluster:
        from repro.cluster import get_preset

        spec = get_preset(args.cluster)
        print(f"  pricing collectives on {spec.name} "
              f"({spec.n_devices} devices, {len(spec.levels)} link levels, "
              f"{args.streams} stream(s))")
    plan = RP.compile("qwen2-0.5b", cluster=args.cluster,
                      streams=args.streams, n_devices=4,
                      unchanged_limit=120, max_steps=args.steps, seed=0)
    path = os.path.join(tempfile.gettempdir(), "disco_plan.json")
    plan.save(path)
    d = plan.describe()
    print(f"  {d['grad_tensors']} gradient tensors -> "
          f"{d['allreduce_buckets']} fused AllReduce buckets "
          f"(predicted {plan.predicted_iteration_time*1e3:.3f} ms, "
          f"{plan.provenance['simulations']} simulations); saved {path}")
    if args.cluster:
        print(f"  searched collective-algorithm mix: {d['bucket_algos']}")
        if args.streams > 1:
            print(f"  searched comm kinds: {d['bucket_comm']}  "
                  f"chunk counts: {d['bucket_chunks']}")

    # ---- Enactment Phase (ENABLE_SEARCH=0) ----
    print("enactment phase ...")
    loaded = RP.Plan.load(path)
    # the artifact is a value: the round trip is exact, identity included
    assert loaded == plan and loaded.fingerprint() == plan.fingerprint(), \
        "plan save/load round-trip drifted"
    print(f"  plan round-trips bit-for-bit [{loaded.fingerprint()}]")

    cfg = get_config("qwen2-0.5b").reduced()
    key = jax.random.PRNGKey(0)
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    params_s = jax.eval_shape(lambda: ST.init_params(key, cfg))
    strat = loaded.grad_sync(params_s)
    init, _ = adamw(1e-3)
    opt_s = jax.eval_shape(lambda: init(jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_s)))
    specs = make_batch_specs(cfg, 8, 64)

    n_pt, _ = allreduce_count(cfg, mesh, GradSyncStrategy.per_tensor(params_s),
                              params_s, opt_s, specs)
    n_disco, coll = allreduce_count(cfg, mesh, strat, params_s, opt_s, specs)
    print(f"  compiled HLO all-reduce count: per-tensor={n_pt}, "
          f"DisCo={n_disco}")
    print(f"  DisCo collective mix: "
          f"{ {k: v['count'] for k, v in coll['per_op'].items()} }")
    assert n_disco <= n_pt
    print("the searched schedule is carried verbatim into the compiled HLO")


if __name__ == "__main__":
    main()

"""Search -> save -> enact: the full DisCo workflow (paper Sec. 3.1).

    PYTHONPATH=src python examples/search_and_enact.py
    PYTHONPATH=src python examples/search_and_enact.py \
        --cluster a100_nvlink_ib

Search Phase: backtracking search over the traced step; the winning tensor-
fusion strategy is written to strategy.json (the paper's "optimized HLO
module" configuration file).  With ``--cluster <preset>`` the search prices
collectives on that topology (see ``repro.cluster.list_presets()``) and
also picks a collective algorithm per bucket; without it, the legacy flat
model is used (bit-identical to the seed).

Enactment Phase: the strategy is loaded and built into the distributed train
step; we lower both the per-tensor baseline and the DisCo-bucketed step and
show the AllReduce count in the compiled HLO shrink accordingly.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Simulator, backtracking_search, profile_graph, \
    trace_grad_graph
from repro.data.pipeline import make_batch_specs, materialize_batch
from repro.distributed.train_step import (GradSyncStrategy, build_train_step,
                                          jit_train_step)
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import make_mesh_compat
from repro.models import stacked as ST
from repro.optim import adamw


def allreduce_count(cfg, mesh, strategy, params, opt, specs):
    step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strategy)
    jf = jit_train_step(step, cfg, mesh, params, opt, specs)
    compiled = jf.lower(params, opt, specs).compile()
    coll = parse_collectives(compiled.as_text())
    return coll["per_op"].get("all-reduce", {"count": 0})["count"], coll


def main():
    import argparse

    from repro.cluster import list_presets

    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default=None, choices=list_presets(),
                    help="cluster preset to search against; default: "
                         "legacy flat model")
    ap.add_argument("--streams", type=int, default=1,
                    help="search against the N-stream event engine; with "
                         "--cluster the comm kind (AllReduce vs ZeRO-3 "
                         "RS+AG) and chunk count become searched dimensions "
                         "too (the flat default spec is algorithm-blind and "
                         "drops them)")
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b").reduced()
    key = jax.random.PRNGKey(0)
    params = ST.init_params(key, cfg)
    batch = materialize_batch(cfg, 8, 64)

    # ---- Search Phase (ENABLE_SEARCH=1 in the paper) ----
    print("search phase ...")
    g = profile_graph(trace_grad_graph(
        lambda p, bt: ST.loss_fn(p, cfg, bt), params, batch))
    if args.cluster:
        from repro.cluster import get_preset

        spec = get_preset(args.cluster)
        print(f"  pricing collectives on {spec.name} "
              f"({spec.n_devices} devices, {len(spec.levels)} link levels, "
              f"{args.streams} stream(s))")
        sim = Simulator(cluster=spec, streams=args.streams)
    else:
        sim = Simulator(n_devices=4, streams=args.streams)
    res = backtracking_search(g, sim, unchanged_limit=120, seed=0)
    strat = GradSyncStrategy.from_fusion_graph(res.best, params)
    path = os.path.join(tempfile.gettempdir(), "disco_strategy.json")
    strat.save(path)
    print(f"  {len(g.buckets)} gradient tensors -> "
          f"{len(strat.buckets)} fused AllReduce buckets; saved {path}")
    if args.cluster:
        d = res.best.describe()
        print(f"  searched collective-algorithm mix: {d['bucket_algos']}")
        if args.streams > 1:
            print(f"  searched comm kinds: {d['bucket_comm']}  "
                  f"chunk counts: {d['bucket_chunks']}")

    # ---- Enactment Phase (ENABLE_SEARCH=0) ----
    print("enactment phase ...")
    loaded = GradSyncStrategy.load(path)
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    params_s = jax.eval_shape(lambda: ST.init_params(key, cfg))
    init, _ = adamw(1e-3)
    opt_s = jax.eval_shape(lambda: init(jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_s)))
    specs = make_batch_specs(cfg, 8, 64)

    n_pt, _ = allreduce_count(cfg, mesh, GradSyncStrategy.per_tensor(params_s),
                              params_s, opt_s, specs)
    n_disco, coll = allreduce_count(cfg, mesh, loaded, params_s, opt_s, specs)
    print(f"  compiled HLO all-reduce count: per-tensor={n_pt}, "
          f"DisCo={n_disco}")
    print(f"  DisCo collective mix: "
          f"{ {k: v['count'] for k, v in coll['per_op'].items()} }")
    assert n_disco <= n_pt
    print("the searched schedule is carried verbatim into the compiled HLO")


if __name__ == "__main__":
    main()

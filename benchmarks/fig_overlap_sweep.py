"""Fig. D (ours): serialized channel vs multi-stream / pipelined / ZeRO-3
communication schedules across the cluster preset zoo.

For each :mod:`repro.cluster` preset, price a family of strategies (XLA
op fusion + bucket thresholds from 512 KB to 30 MB, NCCL-style per-bucket
algorithm auto-tuning, plus a ZeRO-3 reduce-scatter/all-gather variant and
two budget-matched joint backtracking searches) under the serialized
channel (``streams=1``, the seed comm model) and under the phase-level
event engine with 2/4/8 concurrent streams, where hierarchical phases of
different buckets pipeline across link levels with fair-share bandwidth
within a level.

The headline comparison is **best-vs-best**: the cheapest schedule the
serialized channel can express vs the cheapest the multi-stream engine can
express (both sides get the same strategy family and the same search
budget).  The acceptance bar (ISSUE 3): at least one preset where the
multi-stream/pipelined side strictly wins.  The sweep runs in the
comm-bound regime (small batch/seq, model-sized gradients) where the
communication schedule is the critical path — the regime the engine
exists for.

On top of the scheduled-overlap family, the sweep prices the *in-kernel
fused* dimension (ISSUE 8): per-granularity all-fused variants, a joint
search with ``METHOD_FUSED`` active (the preset's calibrated overlap
discount), and the scheduled-search winner with every bucket flipped
fused.  The second headline is fused-best vs scheduled-overlap-best:
``fused_beats_scheduled`` per preset, with the fused side never allowed
to regress (an unfused graph is a point of the fused space).

    PYTHONPATH=src python benchmarks/fig_overlap_sweep.py [--quick]
        [--timeline] [--smoke] [--cache DIR]

``--timeline`` embeds each preset's winning comm schedule as
``(kind, bucket, chunk, traffic_class, algo, level, start, end)`` records —
ring vs tree vs hierarchical phases, RS/AG legs, chunk indices, traffic
classes and the ``fused_``-prefixed phases of in-kernel fused buckets are
distinguishable by construction.  ``--smoke`` is the CI lane: two
calibrated presets, reduced budget, and a hard gate that the fused side
never regresses the scheduled-overlap best.  ``--cache DIR`` runs the
searches through a :class:`repro.plan.PlanCache` (re-runs replay).
Writes ``experiments/perf/overlap_sweep.json`` and prints a CSV block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import arch_graph, csv_row
from repro.cluster import PRESETS
from repro.core import Simulator
from repro.core.baselines import (assign_bucket_algos, assign_bucket_comm,
                                  threshold_tensor_fusion,
                                  xla_post_order_op_fusion)
from repro.plan import compile_plan

OUT = "experiments/perf"

THRESHOLDS = {"512KB": 512 << 10, "1MB": 1 << 20, "2MB": 2 << 20,
              "4MB": 4 << 20, "8MB": 8 << 20, "30MB": 30 << 20}
STREAMS = (1, 2, 4, 8)


def _all_fused(g):
    """Every bucket flipped to the in-kernel fused path."""
    z = g.clone()
    for i in range(len(z.buckets)):
        z.set_bucket_fused(i, True)
    return z


def sweep_one(g0, opfused, name: str, spec, *, unchanged_limit: int,
              max_steps: int, seed: int = 0,
              keep_timeline: bool = False, cache=None) -> dict:
    # strategy family: bucket granularities x stream counts, auto algos
    cands = {
        label: assign_bucket_algos(
            threshold_tensor_fusion(opfused, threshold=thr), spec, "auto")
        for label, thr in THRESHOLDS.items()
    }
    configs = {}
    graphs = {}
    for label, g in cands.items():
        for s in STREAMS:
            r = Simulator(cluster=spec, streams=s).run(g)
            key = f"{label}@s{s}"
            graphs[key] = (g, s)
            configs[key] = {
                "iteration_time_s": r.iteration_time,
                "comm_finish_s": r.comm_finish,
                "comm_busy_s": r.comm_time,
                "buckets": len(g.buckets),
                "streams": s,
            }
    # ZeRO-3 RS+AG split of each granularity on the 4-stream engine, plus
    # the in-kernel fused variant of both comm kinds (every bucket fused
    # under the preset's calibrated overlap discount)
    for label, g in cands.items():
        z = assign_bucket_comm(g, "rs_ag")
        variants = {f"{label}_rs_ag@s4": z,
                    f"{label}_fused@s4": _all_fused(g),
                    f"{label}_rs_ag_fused@s4": _all_fused(z)}
        for key, v in variants.items():
            r = Simulator(cluster=spec, streams=4).run(v)
            graphs[key] = (v, 4)
            configs[key] = {
                "iteration_time_s": r.iteration_time,
                "comm_finish_s": r.comm_finish,
                "comm_busy_s": r.comm_time,
                "buckets": len(v.buckets),
                "streams": 4,
                "fused": "fused" in key,
            }
    # budget-matched joint searches: one against the serialized channel,
    # one against the 4-stream engine with the fused dimension *disabled*
    # (overlap_discount=0 -> METHOD_FUSED drops out: the scheduled-overlap
    # side), one with the preset's calibrated discount (the joint fused
    # search) — all through the compile() facade; the winning strategy
    # comes back as a Plan whose to_graph() reconstructs the graph when
    # the timeline replay needs it
    searches = (("searched@s1", 1, 0.0),
                ("searched@s4", 4, 0.0),
                ("searched_fused@s4", 4, None))
    for tag, s, disc in searches:
        plan = compile_plan(graph=g0, cluster=spec, streams=s,
                            overlap_discount=disc,
                            unchanged_limit=unchanged_limit,
                            max_steps=max_steps, seed=seed, cache=cache)
        d = plan.describe()
        graphs[tag] = (plan.to_graph(g0), s)
        configs[tag] = {
            "iteration_time_s": plan.predicted_iteration_time,
            "buckets": d["allreduce_buckets"],
            "streams": s,
            "bucket_algos": d["bucket_algos"],
            "bucket_comm": d["bucket_comm"],
            "fused": tag.endswith("_fused@s4"),
            "fused_comm_buckets": d["fused_comm_buckets"],
            "simulations": plan.provenance["simulations"],
            "cache_outcome": plan.provenance.get("cache", {}).get("outcome"),
        }
    # the scheduled-search winner with every bucket flipped fused: pins the
    # fused side at <= the scheduled side (an unfused graph is a point of
    # the fused space, and the discount only moves job starts earlier)
    sched_g, _ = graphs["searched@s4"]
    fz = _all_fused(sched_g)
    r = Simulator(cluster=spec, streams=4).run(fz)
    graphs["searched_sched_fused@s4"] = (fz, 4)
    configs["searched_sched_fused@s4"] = {
        "iteration_time_s": r.iteration_time,
        "buckets": len(fz.buckets),
        "streams": 4,
        "fused": True,
    }

    ser = {k: v["iteration_time_s"] for k, v in configs.items()
           if v["streams"] == 1}
    ovl = {k: v["iteration_time_s"] for k, v in configs.items()
           if v["streams"] > 1}
    sched = {k: t for k, t in ovl.items() if not configs[k].get("fused")}
    fusd = {k: t for k, t in ovl.items() if configs[k].get("fused")}
    best_ser = min(ser, key=ser.get)
    best_ovl = min(ovl, key=ovl.get)
    best_sched = min(sched, key=sched.get)
    best_fused = min(fusd, key=fusd.get)
    row = {
        "preset": name,
        "n_devices": spec.n_devices,
        "levels": [l.name for l in spec.levels],
        "overlap_discount": Simulator(cluster=spec,
                                      streams=4).overlap_discount,
        "configs": configs,
        "best_serialized_config": best_ser,
        "best_serialized_s": ser[best_ser],
        "best_overlap_config": best_ovl,
        "best_overlap_s": ovl[best_ovl],
        "overlap_speedup": ser[best_ser] / ovl[best_ovl],
        "multistream_strictly_beats_serialized": ovl[best_ovl] < ser[best_ser],
        "best_scheduled_config": best_sched,
        "best_scheduled_s": sched[best_sched],
        "best_fused_config": best_fused,
        "best_fused_s": fusd[best_fused],
        "fused_speedup": sched[best_sched] / fusd[best_fused],
        "fused_beats_scheduled": fusd[best_fused] < sched[best_sched],
        "fused_regresses": fusd[best_fused] > sched[best_sched] * (1 + 1e-9),
    }
    if keep_timeline:
        win_g, win_s = graphs[best_ovl]
        sim_t = Simulator(cluster=spec, streams=win_s, keep_timeline=True)
        r = sim_t.run(win_g)
        row["timeline"] = [list(e) for e in r.timeline if e[0] != "compute"]
    return row


def run(arch: str = "qwen2-0.5b", unchanged_limit: int = 40,
        max_steps: int = 80, seed: int = 0, verbose: bool = True,
        keep_timeline: bool = False, batch: int = 2, seq: int = 32,
        smoke: bool = False, cache=None) -> dict:
    if isinstance(cache, str):
        from repro.plan import PlanCache

        cache = PlanCache(cache)
    # small batch/seq: gradient volume (comm) is model-sized while compute
    # shrinks with tokens — the comm-bound regime
    g0 = arch_graph(arch, batch=batch, seq=seq)
    opfused = xla_post_order_op_fusion(g0)
    presets = (("a100_nvlink_ib", "cross_dc_2pod") if smoke
               else tuple(PRESETS))
    rows = []
    for name in presets:
        spec = PRESETS[name]
        t0 = time.perf_counter()
        row = sweep_one(g0, opfused, name, spec,
                        unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed,
                        keep_timeline=keep_timeline, cache=cache)
        row["wall_s"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        if verbose:
            print(csv_row(name, spec.n_devices,
                          row["best_serialized_config"],
                          f"{row['best_serialized_s']*1e3:.3f}ms",
                          row["best_scheduled_config"],
                          f"{row['best_scheduled_s']*1e3:.3f}ms",
                          row["best_fused_config"],
                          f"{row['best_fused_s']*1e3:.3f}ms",
                          f"{row['fused_speedup']:.3f}x",
                          row["fused_beats_scheduled"]))
    winners = [r["preset"] for r in rows
               if r["multistream_strictly_beats_serialized"]]
    fused_wins = [r["preset"] for r in rows if r["fused_beats_scheduled"]]
    regressions = [r["preset"] for r in rows if r["fused_regresses"]]
    out = {
        "arch": arch,
        "batch": batch,
        "seq": seq,
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "presets": rows,
        "multistream_beats_serialized_on": winners,
        "fused_beats_scheduled_on": fused_wins,
        "fused_regresses_on": regressions,
    }
    if cache is not None:
        out["cache"] = {"root": cache.root, **cache.stats}
    if verbose:
        print(f"# multi-stream/pipelined schedules strictly beat the "
              f"serialized channel on {len(winners)}/{len(rows)} presets: "
              f"{winners}")
        print(f"# in-kernel fused schedules strictly beat the best "
              f"scheduled overlap on {len(fused_wins)}/{len(rows)} "
              f"presets: {fused_wins}")
        if regressions:
            print(f"# WARNING: fused side regressed on {regressions}")
        if cache is not None:
            print(f"# cache {cache.root}: {cache.stats['hits']} hits, "
                  f"{cache.stats['misses']} misses, "
                  f"{cache.stats['warm_starts']} warm starts")
    if not smoke:
        os.makedirs(OUT, exist_ok=True)
        path = os.path.join(OUT, "overlap_sweep.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        if verbose:
            print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--timeline", action="store_true",
                    help="embed each preset's winning comm schedule as "
                         "(kind, bucket, chunk, traffic_class, algo, level, "
                         "start, end) records")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: two calibrated presets, reduced budget; "
                         "exits non-zero if the fused side regresses the "
                         "scheduled-overlap best anywhere")
    ap.add_argument("--cache", default=None, metavar="DIR",
                    help="compile searches through a PlanCache at DIR "
                         "(re-runs replay from the cache)")
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    out = run(arch=args.arch,
              unchanged_limit=15 if args.smoke else
              (25 if args.quick else 40),
              max_steps=30 if args.smoke else (50 if args.quick else 80),
              keep_timeline=args.timeline,
              smoke=args.smoke, cache=args.cache)
    if args.smoke:
        assert not out["fused_regresses_on"], (
            f"fused side regressed the scheduled-overlap best on "
            f"{out['fused_regresses_on']}")
        assert out["fused_beats_scheduled_on"], (
            "in-kernel fusion beat the scheduled overlap on no smoke "
            "preset — the discount calibration or fused pricing is broken")
        print("# smoke gate passed")

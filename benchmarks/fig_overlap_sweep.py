"""Fig. D (ours): serialized channel vs multi-stream / pipelined / ZeRO-3
communication schedules across the cluster preset zoo.

For each :mod:`repro.cluster` preset, price a family of strategies (XLA
op fusion + bucket thresholds from 512 KB to 30 MB, NCCL-style per-bucket
algorithm auto-tuning, plus a ZeRO-3 reduce-scatter/all-gather variant and
two budget-matched joint backtracking searches) under the serialized
channel (``streams=1``, the seed comm model) and under the phase-level
event engine with 2/4/8 concurrent streams, where hierarchical phases of
different buckets pipeline across link levels with fair-share bandwidth
within a level.

The headline comparison is **best-vs-best**: the cheapest schedule the
serialized channel can express vs the cheapest the multi-stream engine can
express (both sides get the same strategy family and the same search
budget).  The acceptance bar (ISSUE 3): at least one preset where the
multi-stream/pipelined side strictly wins.  The sweep runs in the
comm-bound regime (small batch/seq, model-sized gradients) where the
communication schedule is the critical path — the regime the engine
exists for.

    PYTHONPATH=src python benchmarks/fig_overlap_sweep.py [--quick]
        [--timeline]

``--timeline`` embeds each preset's winning comm schedule as
``(kind, bucket, chunk, traffic_class, algo, level, start, end)`` records —
ring vs tree vs hierarchical phases, RS/AG legs, chunk indices and traffic
classes are distinguishable by construction.
Writes ``experiments/perf/overlap_sweep.json`` and prints a CSV block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import arch_graph, csv_row
from repro.cluster import PRESETS
from repro.core import Simulator
from repro.core.baselines import (assign_bucket_algos, assign_bucket_comm,
                                  threshold_tensor_fusion,
                                  xla_post_order_op_fusion)
from repro.plan import compile_plan

OUT = "experiments/perf"

THRESHOLDS = {"512KB": 512 << 10, "1MB": 1 << 20, "2MB": 2 << 20,
              "4MB": 4 << 20, "8MB": 8 << 20, "30MB": 30 << 20}
STREAMS = (1, 2, 4, 8)


def sweep_one(g0, opfused, name: str, spec, *, unchanged_limit: int,
              max_steps: int, seed: int = 0,
              keep_timeline: bool = False) -> dict:
    # strategy family: bucket granularities x stream counts, auto algos
    cands = {
        label: assign_bucket_algos(
            threshold_tensor_fusion(opfused, threshold=thr), spec, "auto")
        for label, thr in THRESHOLDS.items()
    }
    configs = {}
    graphs = {}
    for label, g in cands.items():
        for s in STREAMS:
            r = Simulator(cluster=spec, streams=s).run(g)
            key = f"{label}@s{s}"
            graphs[key] = (g, s)
            configs[key] = {
                "iteration_time_s": r.iteration_time,
                "comm_finish_s": r.comm_finish,
                "comm_busy_s": r.comm_time,
                "buckets": len(g.buckets),
                "streams": s,
            }
    # ZeRO-3 RS+AG split of each granularity on the 4-stream engine
    for label, g in cands.items():
        z = assign_bucket_comm(g, "rs_ag")
        r = Simulator(cluster=spec, streams=4).run(z)
        key = f"{label}_rs_ag@s4"
        graphs[key] = (z, 4)
        configs[key] = {
            "iteration_time_s": r.iteration_time,
            "comm_finish_s": r.comm_finish,
            "comm_busy_s": r.comm_time,
            "buckets": len(z.buckets),
            "streams": 4,
        }
    # budget-matched joint searches: one against the serialized channel,
    # one against the 4-stream engine (op x tensor x algo [x comm kind]) —
    # both through the compile() facade; the winning strategy comes back
    # as a Plan whose to_graph() reconstructs the graph when the timeline
    # replay needs it
    for tag, s in (("searched@s1", 1), ("searched@s4", 4)):
        plan = compile_plan(graph=g0, cluster=spec, streams=s,
                            unchanged_limit=unchanged_limit,
                            max_steps=max_steps, seed=seed)
        d = plan.describe()
        graphs[tag] = (plan.to_graph(g0), s)
        configs[tag] = {
            "iteration_time_s": plan.predicted_iteration_time,
            "buckets": d["allreduce_buckets"],
            "streams": s,
            "bucket_algos": d["bucket_algos"],
            "bucket_comm": d["bucket_comm"],
            "simulations": plan.provenance["simulations"],
        }

    ser = {k: v["iteration_time_s"] for k, v in configs.items()
           if v["streams"] == 1}
    ovl = {k: v["iteration_time_s"] for k, v in configs.items()
           if v["streams"] > 1}
    best_ser = min(ser, key=ser.get)
    best_ovl = min(ovl, key=ovl.get)
    row = {
        "preset": name,
        "n_devices": spec.n_devices,
        "levels": [l.name for l in spec.levels],
        "configs": configs,
        "best_serialized_config": best_ser,
        "best_serialized_s": ser[best_ser],
        "best_overlap_config": best_ovl,
        "best_overlap_s": ovl[best_ovl],
        "overlap_speedup": ser[best_ser] / ovl[best_ovl],
        "multistream_strictly_beats_serialized": ovl[best_ovl] < ser[best_ser],
    }
    if keep_timeline:
        win_g, win_s = graphs[best_ovl]
        sim_t = Simulator(cluster=spec, streams=win_s, keep_timeline=True)
        r = sim_t.run(win_g)
        row["timeline"] = [list(e) for e in r.timeline if e[0] != "compute"]
    return row


def run(arch: str = "qwen2-0.5b", unchanged_limit: int = 40,
        max_steps: int = 80, seed: int = 0, verbose: bool = True,
        keep_timeline: bool = False, batch: int = 2, seq: int = 32) -> dict:
    # small batch/seq: gradient volume (comm) is model-sized while compute
    # shrinks with tokens — the comm-bound regime
    g0 = arch_graph(arch, batch=batch, seq=seq)
    opfused = xla_post_order_op_fusion(g0)
    rows = []
    for name, spec in PRESETS.items():
        t0 = time.perf_counter()
        row = sweep_one(g0, opfused, name, spec,
                        unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed,
                        keep_timeline=keep_timeline)
        row["wall_s"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        if verbose:
            print(csv_row(name, spec.n_devices,
                          row["best_serialized_config"],
                          f"{row['best_serialized_s']*1e3:.3f}ms",
                          row["best_overlap_config"],
                          f"{row['best_overlap_s']*1e3:.3f}ms",
                          f"{row['overlap_speedup']:.3f}x",
                          row["multistream_strictly_beats_serialized"]))
    winners = [r["preset"] for r in rows
               if r["multistream_strictly_beats_serialized"]]
    out = {
        "arch": arch,
        "batch": batch,
        "seq": seq,
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "presets": rows,
        "multistream_beats_serialized_on": winners,
    }
    if verbose:
        print(f"# multi-stream/pipelined schedules strictly beat the "
              f"serialized channel on {len(winners)}/{len(rows)} presets: "
              f"{winners}")
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "overlap_sweep.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    if verbose:
        print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--timeline", action="store_true",
                    help="embed each preset's winning comm schedule as "
                         "(kind, bucket, chunk, traffic_class, algo, level, "
                         "start, end) records")
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    run(arch=args.arch,
        unchanged_limit=25 if args.quick else 40,
        max_steps=50 if args.quick else 80,
        keep_timeline=args.timeline)

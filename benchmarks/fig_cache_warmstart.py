"""Fig. G (ours): plan-cache warm starts — simulations-to-quality across
the cluster preset zoo (DESIGN.md Sec. 12).

The plan cache's two claims, measured leave-one-out over every
:mod:`repro.cluster` preset:

* **Exact-key replay is free.**  Re-compiling a point that is already in
  the cache returns the stored Plan bit-identically (same strategy
  fingerprint, same predicted price) with zero simulator evaluations —
  the replay wall time is file IO, gated >= 20x faster than the cold
  search by ``perf_search.py --smoke``.
* **Warm starts transfer across topologies.**  For each preset P, the
  search is warm-started from a cache holding the *other* presets' plans
  only (never its own key, so every lookup is a genuine near miss): the
  most similar cached strategy is re-applied onto the trace as the
  backtracking search's start state.  Headline metric:
  **simulations-to-quality** — how many candidate evaluations the warm
  search needs before its best cost is within 2% of the cold search's
  final cost, read off ``plan.provenance['quality_history']``.  The
  acceptance bar (ISSUE 7): within-2% quality at <= 50% of the cold
  search's total simulations on at least 5 of the 7 presets.

    PYTHONPATH=src python benchmarks/fig_cache_warmstart.py [--quick]

Writes ``experiments/perf/cache_warmstart.json`` and prints a CSV block.

``--smoke`` is the nightly CI lane: the same leave-one-out sweep at a
reduced budget that **fails** (exit 1) when fewer than
``--smoke-min-pass`` presets meet the within-2%-at-<=``--max-sims-frac``
floor, when any warm start prices worse than the trivial baseline it
replaced, or when the exact-key replay stops being bit-identical.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import arch_graph, csv_row
from repro.cluster import PRESETS
from repro.core import Simulator
from repro.plan import PlanCache, compile_plan
from repro.plan.cache import cache_features, compile_key, knob_digest

OUT = "experiments/perf"
STREAMS = 4  # multi-stream pricing: algo/comm/chunk dimensions all active
QUALITY_TOL = 0.02  # "within 2% of the cold search's final cost"


def sims_to_quality(quality_history, target: float):
    """First simulation count at which the search's best cost reached
    ``target`` (None if it never did).  ``quality_history`` is the
    provenance list of ``[simulations_so_far, best_cost]`` checkpoints."""
    for s, c in quality_history:
        if c <= target:
            return s
    return None


def cold_compile(g0, spec, *, unchanged_limit, max_steps, seed):
    return compile_plan(graph=g0, cluster=spec, streams=STREAMS,
                        unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed)


def run(arch: str = "qwen2-0.5b", unchanged_limit: int = 80,
        max_steps: int = 150, seed: int = 0, verbose: bool = True,
        smoke: bool = False) -> dict:
    g0 = arch_graph(arch)
    knobs = knob_digest(alpha=1.05, beta=10, unchanged_limit=unchanged_limit,
                        max_steps=max_steps, methods=None, seed=seed)

    # ------------------------------------------------- cold pass (no cache)
    cold: dict[str, dict] = {}
    for name, spec in PRESETS.items():
        t0 = time.perf_counter()
        plan = cold_compile(g0, spec, unchanged_limit=unchanged_limit,
                            max_steps=max_steps, seed=seed)
        sim = Simulator(cluster=spec, streams=STREAMS)
        cold[name] = {
            "plan": plan,
            "key": compile_key(g0, sim, knobs),
            "features": cache_features(g0, sim, arch=arch, knobs=knobs),
            "wall_s": time.perf_counter() - t0,
        }
        if verbose:
            print(f"# cold {name}: "
                  f"{plan.provenance['simulations']} sims, "
                  f"{plan.predicted_iteration_time*1e3:.3f} ms", flush=True)

    # ------------------------------------- leave-one-out warm pass + replay
    rows = []
    for name, spec in PRESETS.items():
        cache = PlanCache(tempfile.mkdtemp(prefix=f"warmstart-{name}-"))
        for other, c in cold.items():
            if other != name:
                cache.put(c["key"], c["plan"], c["features"])

        t0 = time.perf_counter()
        warm = compile_plan(graph=g0, cluster=spec, streams=STREAMS,
                            unchanged_limit=unchanged_limit,
                            max_steps=max_steps, seed=seed, cache=cache)
        warm_wall = time.perf_counter() - t0
        # the warm result was stored back: the same call is now an
        # exact-key hit and must replay bit-identically
        t0 = time.perf_counter()
        replay = compile_plan(graph=g0, cluster=spec, streams=STREAMS,
                              unchanged_limit=unchanged_limit,
                              max_steps=max_steps, seed=seed, cache=cache)
        replay_wall = time.perf_counter() - t0

        cplan = cold[name]["plan"]
        cold_sims = cplan.provenance["simulations"]
        cold_best = cplan.predicted_iteration_time
        target = cold_best * (1.0 + QUALITY_TOL)
        prov = warm.provenance
        stq = sims_to_quality(prov["quality_history"], target)
        row = {
            "preset": name,
            "n_devices": spec.n_devices,
            "cold_simulations": cold_sims,
            "cold_best_s": cold_best,
            "cold_sims_to_quality": sims_to_quality(
                cplan.provenance["quality_history"], target),
            "cold_wall_s": round(cold[name]["wall_s"], 3),
            "warm_outcome": prov["cache"]["outcome"],
            "warm_from": prov["cache"].get("warm_from_cluster"),
            "warm_similarity": prov["cache"].get("warm_similarity"),
            "warm_start_cost_s": prov["cache"].get("warm_start_cost"),
            "warm_simulations": prov["simulations"],
            "warm_best_s": warm.predicted_iteration_time,
            "warm_sims_to_quality": stq,
            "warm_wall_s": round(warm_wall, 3),
            "within_2pct": warm.predicted_iteration_time <= target,
            "sims_frac": (None if stq is None or not cold_sims
                          else stq / cold_sims),
            "replay_bit_identical": (
                replay.provenance["cache"]["outcome"] == "hit"
                and replay.strategy_fingerprint()
                == warm.strategy_fingerprint()
                and replay.predicted_iteration_time
                == warm.predicted_iteration_time),
            "replay_wall_s": round(replay_wall, 4),
        }
        # warm start must never price worse than the trivial baseline it
        # replaced (the facade's ladder discards such states pre-search)
        if row["warm_start_cost_s"] is not None:
            row["warm_start_beats_trivial"] = (
                row["warm_start_cost_s"]
                < Simulator(cluster=spec, streams=STREAMS).cost(g0))
        rows.append(row)
        if verbose:
            frac = "n/a" if row["sims_frac"] is None \
                else f"{row['sims_frac']*100:.0f}%"
            print(csv_row(
                name, row["warm_outcome"], row["warm_from"] or "-",
                f"cold={cold_sims}sims",
                f"warm_to_quality={stq if stq is not None else 'never'}",
                frac, f"within2pct={row['within_2pct']}",
                f"replay={row['replay_wall_s']*1e3:.1f}ms"), flush=True)

    passes = [r["preset"] for r in rows
              if r["within_2pct"] and r["sims_frac"] is not None
              and r["sims_frac"] <= 0.5]
    out = {
        "arch": arch,
        "streams": STREAMS,
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "quality_tolerance": QUALITY_TOL,
        "presets": rows,
        "pass_within2pct_at_half_sims": passes,
        "n_pass": len(passes),
        "n_presets": len(rows),
    }
    if verbose:
        print(f"# warm start reaches within {QUALITY_TOL*100:.0f}% of cold "
              f"quality at <=50% of cold simulations on "
              f"{len(passes)}/{len(rows)} presets: {passes}")
    if not smoke:
        os.makedirs(OUT, exist_ok=True)
        path = os.path.join(OUT, "cache_warmstart.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        if verbose:
            print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="nightly CI lane: reduced budget, exit 1 below "
                         "the warm-start sims-to-quality floor")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke-min-pass", type=int, default=5,
                    help="smoke floor: at least this many presets must "
                         "reach within-2% quality at <= --max-sims-frac of "
                         "the cold search's simulations")
    ap.add_argument("--max-sims-frac", type=float, default=0.5)
    args = ap.parse_args()
    quick = args.quick or args.smoke
    out = run(arch=args.arch,
              unchanged_limit=40 if quick else 80,
              max_steps=80 if quick else 150,
              smoke=args.smoke)
    if args.smoke:
        bad = []
        passes = [r["preset"] for r in out["presets"]
                  if r["within_2pct"] and r["sims_frac"] is not None
                  and r["sims_frac"] <= args.max_sims_frac]
        if len(passes) < args.smoke_min_pass:
            bad.append(f"only {len(passes)}/{out['n_presets']} presets "
                       f"reach within-2% quality at "
                       f"<={args.max_sims_frac*100:.0f}% of cold "
                       f"simulations (floor {args.smoke_min_pass}): "
                       f"{passes}")
        for r in out["presets"]:
            if not r["replay_bit_identical"]:
                bad.append(f"{r['preset']}: exact-key replay not "
                           f"bit-identical")
            if r.get("warm_start_beats_trivial") is False:
                bad.append(f"{r['preset']}: warm start priced worse than "
                           f"the trivial baseline it replaced")
        if bad:
            print(f"SMOKE FAIL: {bad}")
            raise SystemExit(1)
        print(f"smoke OK: {len(passes)}/{out['n_presets']} presets within "
              f"2% at <={args.max_sims_frac*100:.0f}% sims "
              f"(floor {args.smoke_min_pass}); replay bit-identical on all")

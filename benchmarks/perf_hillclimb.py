"""Perf hillclimbing (deliverable g, Sec. Perf of EXPERIMENTS.md).

Three pairs, chosen from the baseline roofline table:
  H1 qwen2-0.5b x train_4k      — most collective-bound (ratio 2.5x)
  H2 deepseek-v2-lite x train_4k — most representative of the paper (DisCo
                                    bucket enactment on the MoE training step)
  H3 stablelm-1.6b x decode_32k  — most memory-bound (ratio 156x)

Each iteration is run in a subprocess (XLA:CPU crash isolation) and records
hypothesis / change / before / after into experiments/perf/<id>.json.

    PYTHONPATH=src python benchmarks/perf_hillclimb.py [--only H1]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

OUT = "experiments/perf"

_COMMON = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
import sys, json, dataclasses
sys.path.insert(0, 'src')
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.compat import cost_analysis_compat
from repro.core.analytic import shape_cost
from repro.core.hw import TPU_V5E
from repro.distributed import sharding as SH
from repro.distributed.train_step import build_train_step, jit_train_step, GradSyncStrategy
from repro.launch.dryrun import parse_collectives, build_dryrun_decode
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import input_specs, GRAD_ACCUM
from repro.models import stacked as ST
from repro.optim import adamw

def measure_train(cfg, arch, layout='tp', zero1=False, strategy=None,
                  accum=None):
    mesh = make_production_mesh()
    params = jax.eval_shape(lambda: ST.init_params(jax.random.PRNGKey(0), cfg))
    init, _ = adamw(3e-4)
    opt = jax.eval_shape(lambda: init(jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)))
    specs = input_specs(cfg, 'train_4k')
    step = build_train_step(cfg, mesh, mode='ddp_tp', layout=layout,
                            strategy=strategy,
                            grad_accum=accum or GRAD_ACCUM.get(arch, 1))
    jf = jit_train_step(step, cfg, mesh, params, opt, specs, layout=layout,
                        zero1=zero1)
    compiled = jf.lower(params, opt, specs).compile()
    coll = parse_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    return {
        'collectives': {k: {'count': v['count'], 'bytes': v['bytes']}
                        for k, v in coll['per_op'].items()},
        'hlo_ici_static': coll['ici_traffic_bytes'],
        'mem_args_gib': ma.argument_size_in_bytes / 2**30,
        'mem_temp_gib': ma.temp_size_in_bytes / 2**30,
        'hlo_flops': cost_analysis_compat(compiled).get('flops'),
    }

def terms(cb):
    hw = TPU_V5E
    return {
        'compute_ms': cb.flops / (hw.peak_flops * hw.efficiency) * 1e3,
        'memory_ms': cb.hbm_bytes / hw.hbm_bw * 1e3,
        'collective_ms': cb.ici_bytes / hw.ici_bw * 1e3,
    }
"""


def run_snippet(code: str, timeout=2400) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _COMMON + code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        return {"error": proc.stderr[-1500:]}
    try:
        return json.loads(proc.stdout.strip().splitlines()[-1])
    except Exception:
        return {"error": "no json", "stdout": proc.stdout[-1500:]}


def h1():
    """qwen2-0.5b x train_4k: TP16 -> pure DP256 (+ bf16-reduce analytic)."""
    steps = []
    steps.append(dict(
        name="baseline ddp_tp (TP=16)",
        hypothesis=("TP=16 for a 0.5B model trades ~427 ms of per-layer "
                    "activation psums for weight memory it does not need; "
                    "collective term dominates compute 2.5x"),
        **run_snippet(r"""
cfg = get_config('qwen2-0.5b')
m = measure_train(cfg, 'qwen2-0.5b', layout='tp')
cb = shape_cost(cfg, 'train_4k', {'data': 16, 'model': 16})
m.update(terms(cb)); print(json.dumps(m))
""")))
    steps.append(dict(
        name="iter1: layout=dp (DP over all 256 devices)",
        hypothesis=("napkin: replicated 0.5B weights = 1 GiB bf16 + 4 GiB "
                    "f32 moments fit easily; collective becomes one f32 "
                    "gradient allreduce 2*(255/256)*4*N = 3.9 GiB -> "
                    "~79 ms at 50 GB/s, 5.4x less than TP's 427 ms; "
                    "compute term unchanged -> compute-bound"),
        **run_snippet(r"""
import numpy as np
cfg = get_config('qwen2-0.5b')
m = measure_train(cfg, 'qwen2-0.5b', layout='dp')
# analytic: pure DP -> no TP collectives, grads over 256
n = cfg.param_count()
cb = shape_cost(cfg, 'train_4k', {'data': 256, 'model': 1})
cb = dataclasses.replace(cb, ici_bytes=n * 4 * 2 * 255 / 256)
m.update(terms(cb)); print(json.dumps(m))
""")))
    steps.append(dict(
        name="iter2: bf16 gradient allreduce (analytic; TPU-only)",
        hypothesis=("reducing gradients in bf16 halves allreduce bytes -> "
                    "~40 ms; REFUTABLE only on real TPU (XLA:CPU miscompiles "
                    "16-bit all-reduce, the f32 upcast in sync_grads is the "
                    "documented workaround), so analytic-only"),
        analytic_only=True,
        collective_ms=39.7,
        note="2*(255/256)*2B*0.494e9 / 50 GB/s",
    ))
    steps.append(dict(
        name="iter3: DisCo bucket fusion on top of dp layout",
        hypothesis=("stacked gradient tree has ~30 leaves -> 30 allreduce "
                    "latencies = 0.3 ms, <1% of the 79 ms bandwidth term; "
                    "expect negligible wall-clock change (bucketing matters "
                    "in the many-small-tensor regime of the paper's per-op "
                    "graphs, not for layer-stacked tensors)"),
        **run_snippet(r"""
cfg = get_config('qwen2-0.5b')
params = jax.eval_shape(lambda: ST.init_params(jax.random.PRNGKey(0), cfg))
strat = GradSyncStrategy.size_capped(params, 64 * 2**20)
m = measure_train(cfg, 'qwen2-0.5b', layout='dp', strategy=strat)
n = cfg.param_count()
cb = shape_cost(cfg, 'train_4k', {'data': 256, 'model': 1})
cb = dataclasses.replace(cb, ici_bytes=n * 4 * 2 * 255 / 256)
m.update(terms(cb))
m['n_buckets'] = len(strat.buckets)
print(json.dumps(m))
""")))
    return steps


def h2():
    """deepseek-v2-lite x train_4k: DisCo bucket enactment + ZeRO-1."""
    steps = []
    steps.append(dict(
        name="baseline: per-tensor gradient AllReduce (JAX default analogue)",
        hypothesis=("one AllReduce per stacked gradient leaf; latency term = "
                    "count x 10 us; bandwidth term fixed by param bytes"),
        **run_snippet(r"""
cfg = get_config('deepseek-v2-lite-16b')
m = measure_train(cfg, 'deepseek-v2-lite-16b', layout='tp')
cb = shape_cost(cfg, 'train_4k', {'data': 16, 'model': 16})
m.update(terms(cb)); print(json.dumps(m))
""")))
    steps.append(dict(
        name="iter1: DisCo single-bucket tensor fusion (paper's method iii)",
        hypothesis=("merging compatible neighbouring buckets cuts AllReduce "
                    "count to ~2 (one per sharding signature); the compiled "
                    "HLO must show the collective count drop — the paper's "
                    "tensor fusion carried verbatim into the program"),
        **run_snippet(r"""
cfg = get_config('deepseek-v2-lite-16b')
params = jax.eval_shape(lambda: ST.init_params(jax.random.PRNGKey(0), cfg))
strat = GradSyncStrategy.size_capped(params, 512 * 2**20)
m = measure_train(cfg, 'deepseek-v2-lite-16b', layout='tp', strategy=strat)
cb = shape_cost(cfg, 'train_4k', {'data': 16, 'model': 16})
m.update(terms(cb))
m['n_buckets'] = len(strat.buckets)
print(json.dumps(m))
""")))
    steps.append(dict(
        name="iter2: + ZeRO-1 optimizer-state sharding",
        hypothesis=("adam moments sharded over data axes: argument bytes "
                    "drop by ~15/16 of the 8 B/param f32 moments "
                    "(~7.4 GiB/dev); XLA inserts slice+allgather around the "
                    "update (collective +~2 B/param)"),
        **run_snippet(r"""
cfg = get_config('deepseek-v2-lite-16b')
params = jax.eval_shape(lambda: ST.init_params(jax.random.PRNGKey(0), cfg))
strat = GradSyncStrategy.size_capped(params, 512 * 2**20)
m = measure_train(cfg, 'deepseek-v2-lite-16b', layout='tp', strategy=strat,
                  zero1=True)
cb = shape_cost(cfg, 'train_4k', {'data': 16, 'model': 16})
m.update(terms(cb))
print(json.dumps(m))
""")))
    return steps


def h3():
    """stablelm-1.6b x decode_32k: int8 KV cache."""
    steps = []
    steps.append(dict(
        name="baseline: bf16 KV cache",
        hypothesis=("decode is HBM-bound on the KV cache: 24L x 32k x 32kv x "
                    "64hd x 2 x 2B x 8 local seqs / 16 TP = ~3.2 GiB read "
                    "per step >> 0.2 GiB weights; memory term ~4.4 ms"),
        **run_snippet(r"""
cfg = get_config('stablelm-1.6b')
mesh = make_production_mesh()
jf, args = build_dryrun_decode(cfg, mesh, 'decode_32k')
compiled = jf.lower(*args).compile()
ma = compiled.memory_analysis()
cb = shape_cost(cfg, 'decode_32k', {'data': 16, 'model': 16})
m = terms(cb)
m['mem_args_gib'] = ma.argument_size_in_bytes / 2**30
m['mem_temp_gib'] = ma.temp_size_in_bytes / 2**30
print(json.dumps(m))
""")))
    steps.append(dict(
        name="iter1: int8 KV cache (+f32 per-head scales)",
        hypothesis=("quantising K/V to int8 halves cache bytes (scale "
                    "overhead 1/64): memory term 4.4 -> ~2.4 ms and cache "
                    "argument bytes halve in the compiled artifact; decode "
                    "logit error ~1.7e-2 (measured on the reduced model) is "
                    "acceptable for serving"),
        **run_snippet(r"""
cfg = dataclasses.replace(get_config('stablelm-1.6b'),
                          kv_cache_dtype='int8')
mesh = make_production_mesh()
jf, args = build_dryrun_decode(cfg, mesh, 'decode_32k')
compiled = jf.lower(*args).compile()
ma = compiled.memory_analysis()
cb = shape_cost(cfg, 'decode_32k', {'data': 16, 'model': 16})
# analytic: cache bytes halve + 1/64 scale overhead
cache_gib = 24 * 32768 * 32 * 64 * 2 * 8 / 16
new_hbm = cb.hbm_bytes - cache_gib * 1.05 + cache_gib * (0.5 + 1 / 64)
cb = dataclasses.replace(cb, hbm_bytes=new_hbm)
m = terms(cb)
m['mem_args_gib'] = ma.argument_size_in_bytes / 2**30
m['mem_temp_gib'] = ma.temp_size_in_bytes / 2**30
print(json.dumps(m))
""")))
    return steps


def h4():
    """Search-engine throughput trajectory: simulations/sec of the
    backtracking search over the course of a run, incremental fusion-graph
    engine vs the seed full-replay engine (in-process; see
    benchmarks/perf_search.py for the engine comparison itself)."""
    import time

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import arch_graph
    from perf_search import SeedPathSimulator
    from repro.core import Simulator, backtracking_search

    steps = []
    for arch in ("transformer-paper", "deepseek-v2-236b"):
        for mode in ("incremental", "seed"):
            sim = (Simulator(n_devices=256, incremental=True)
                   if mode == "incremental" else SeedPathSimulator())
            g = arch_graph(arch)
            traj = []
            t0 = time.perf_counter()
            state = {"sims": 0}

            def on_step(step, best, _t0=t0, _traj=traj, _sim=sim, _st=state):
                if step % 10:
                    return
                if isinstance(_sim, Simulator):
                    sims = sum(_sim.stats.values())
                else:
                    sims = len(_sim._memo)
                wall = time.perf_counter() - _t0
                _traj.append({"step": step, "wall_s": round(wall, 3),
                              "sims": sims,
                              "sims_per_sec": round(sims / wall, 1),
                              "best_cost": best})
                _st["sims"] = sims

            res = backtracking_search(g, sim, unchanged_limit=10**9,
                                      max_steps=150, seed=0, on_step=on_step)
            steps.append(dict(
                name=f"search throughput {arch} [{mode}]",
                hypothesis=("incremental engine sustains >=5x the seed "
                            "engine's simulations/sec as the search "
                            "progresses (ISSUE 1 tentpole)"),
                sims_per_sec=round(res.simulations / res.wall_time, 1),
                wall_s=round(res.wall_time, 3),
                simulations=res.simulations,
                best_cost=res.best_cost,
                trajectory=traj,
            ))
    return steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    campaigns = {"H1": h1, "H2": h2, "H3": h3, "H4": h4}
    for hid, fn in campaigns.items():
        if args.only and hid != args.only:
            continue
        print(f"=== {hid} ===", flush=True)
        steps = fn()
        path = os.path.join(OUT, f"{hid}.json")
        json.dump(steps, open(path, "w"), indent=1, default=str)
        for s in steps:
            keys = {k: v for k, v in s.items()
                    if k in ("collective_ms", "memory_ms", "compute_ms",
                             "mem_args_gib", "mem_temp_gib", "n_buckets",
                             "sims_per_sec", "wall_s", "error")}
            coll = s.get("collectives", {})
            nar = coll.get("all-reduce", {}).get("count")
            print(f"  {s['name']}: {keys} all-reduce-count={nar}", flush=True)


if __name__ == "__main__":
    main()

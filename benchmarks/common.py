"""Shared benchmark substrate: traced per-arch fusion graphs + simulator."""
from __future__ import annotations

import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Simulator, profile_graph, trace_grad_graph
from repro.core.hw import TPU_V5E
from repro.data.pipeline import materialize_batch
from repro.models import stacked as ST

# benchmark model suite: one per arch family (reduced configs so the traced
# graphs stay search-tractable on CPU), mirroring the paper's 6-model suite
BENCH_ARCHS = (
    "tinyllama-1.1b",        # llama dense (the paper's Transformer analogue)
    "qwen2-0.5b",            # GQA dense
    "deepseek-v2-lite-16b",  # MLA + MoE
    "rwkv6-3b",              # attention-free
    "recurrentgemma-9b",     # hybrid
    "seamless-m4t-medium",   # enc-dec
)

N_DEVICES = 256  # single-pod simulation target


@functools.lru_cache(maxsize=None)
def arch_graph(arch: str, batch: int = 8, seq: int = 64, n_layers: int = 6):
    """Traced per-device fusion graph of one training step.

    Uses the *unstacked* (per-layer loop) model so the tracer sees the full
    backward DAG — per-layer gradient production times drive the paper's
    computation/communication overlap trade-off.  (The scanned production
    model hides layers inside one opaque scan node; see DESIGN.md.)
    Layer count is raised from the reduced config's 2 so the BP structure is
    non-trivial, mirroring the paper's whole-model graphs.
    """
    import dataclasses

    from repro.models import model as M

    cfg = get_config(arch).reduced()
    if cfg.recurrent is None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = materialize_batch(cfg, batch, seq, seed=0)

    def loss(p, bt):
        return M.loss_fn(p, cfg, bt)

    g = trace_grad_graph(loss, params, data)
    return profile_graph(g)


def make_sim(n_devices: int = N_DEVICES, estimator=None) -> Simulator:
    return Simulator(estimator=estimator, hw=TPU_V5E, n_devices=n_devices)


def csv_row(*cols) -> str:
    return ",".join(str(c) for c in cols)

"""Fig. 6 + Table 1: per-iteration training time of DisCo vs the five
baselines and the FO bound, per architecture; speed-up over the best
baseline.  Prints CSV: arch, strategy, time_us (+ summary speedups)."""
from __future__ import annotations

from common import BENCH_ARCHS, arch_graph, csv_row, make_sim
from repro.core import backtracking_search, evaluate_baselines
from repro.core.simulator import Simulator


def run(archs=BENCH_ARCHS, unchanged_limit=120, seed=0, verbose=True):
    sim = make_sim()
    rows = []
    summary = []
    for arch in archs:
        g = arch_graph(arch)
        base = evaluate_baselines(g, sim)
        res = backtracking_search(g, sim, alpha=1.05, beta=10,
                                  unchanged_limit=unchanged_limit, seed=seed)
        fo_best = sim.full_overlap_bound(res.best)
        for name, t in base.items():
            if name != "FO":
                rows.append((arch, name, t * 1e6))
        rows.append((arch, "DisCo", res.best_cost * 1e6))
        rows.append((arch, "FO", min(base["FO"], fo_best) * 1e6))
        t_min = min(v for k, v in base.items() if k != "FO")
        speedup = (t_min - res.best_cost) / res.best_cost * 100
        fo_speedup = (t_min - min(base["FO"], fo_best)) / min(
            base["FO"], fo_best) * 100
        summary.append((arch, speedup, fo_speedup, res.steps,
                        res.simulations, res.wall_time))
    if verbose:
        print("arch,strategy,us_per_iter")
        for r in rows:
            print(csv_row(r[0], r[1], f"{r[2]:.2f}"))
        print("\n# Table 1: speed-up vs best baseline (%), FO bound speed-up")
        print("arch,disco_speedup_pct,fo_speedup_pct,steps,sims,search_s")
        for s in summary:
            print(csv_row(s[0], f"{s[1]:.1f}", f"{s[2]:.1f}", s[3], s[4],
                          f"{s[5]:.1f}"))
    return rows, summary


if __name__ == "__main__":
    run()

"""Tables 3 & 4: backtracking hyper-parameters — per-iteration time and
search time for alpha in {1, 1.05, 1.1} (beta=10) and beta in {1, 5, 10, 30}
(alpha=1.05)."""
from __future__ import annotations

from common import BENCH_ARCHS, arch_graph, csv_row, make_sim
from repro.core import backtracking_search


def run(archs=BENCH_ARCHS[:3], unchanged_limit=80, verbose=True):
    sim = make_sim()
    rows = []
    for arch in archs:
        g = arch_graph(arch)
        for alpha in (1.0, 1.05, 1.1):
            r = backtracking_search(g, sim, alpha=alpha, beta=10,
                                    unchanged_limit=unchanged_limit, seed=0)
            rows.append((arch, "alpha", alpha, r.best_cost * 1e6,
                         r.wall_time, r.simulations))
        for beta in (1, 5, 10, 30):
            r = backtracking_search(g, sim, alpha=1.05, beta=beta,
                                    unchanged_limit=unchanged_limit, seed=0)
            rows.append((arch, "beta", beta, r.best_cost * 1e6,
                         r.wall_time, r.simulations))
    if verbose:
        print("arch,param,value,us_per_iter,search_s,simulations")
        for r in rows:
            print(csv_row(r[0], r[1], r[2], f"{r[3]:.2f}", f"{r[4]:.2f}",
                          r[5]))
    return rows


if __name__ == "__main__":
    run()

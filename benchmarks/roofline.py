"""Roofline analysis (deliverable g): per (arch x shape x mesh) compute /
memory / collective terms, dominant bottleneck, MODEL_FLOPS ratio.

Terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI):
    compute    = FLOPs_pd / peak
    memory     = HBM_bytes_pd / bw
    collective = ICI_bytes_pd / link_bw

Sources: dry-run JSONs (experiments/dryrun/*.json, HLO cost analysis +
parsed collective ops) AND the analytic model in repro.core.analytic —
HLO cost analysis counts scan bodies once (see EXPERIMENTS.md), so the
table's terms use the analytic values with the raw HLO numbers alongside.
"""
from __future__ import annotations

import glob
import json
import os
import sys

from common import csv_row
from repro.configs import get_config
from repro.core.analytic import shape_cost
from repro.core.hw import TPU_V5E
from repro.launch.shapes import FSDP_ARCHS, applicability


def load_dryruns(path="experiments/dryrun"):
    out = {}
    for f in glob.glob(os.path.join(path, "*.json")):
        d = json.load(open(f))
        if "error" in d:
            continue
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def analyse(arch: str, shape: str, mesh: str, dry: dict | None):
    hw = TPU_V5E
    cfg0 = get_config(arch)
    ok, reason, cfg = applicability(cfg0, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh, "skip": reason}
    mesh_shape = ({"pod": 2, "data": 16, "model": 16}
                  if mesh == "pod2x16x16" else {"data": 16, "model": 16})
    cb = shape_cost(cfg, shape, mesh_shape, fsdp=arch in FSDP_ARCHS)
    n_dev = 1
    for v in mesh_shape.values():
        n_dev *= v
    t_c = cb.flops / (hw.peak_flops * hw.efficiency)
    t_m = cb.hbm_bytes / hw.hbm_bw
    t_i = cb.ici_bytes / hw.ici_bw
    dom = max((t_c, "compute"), (t_m, "memory"), (t_i, "collective"))[1]
    row = {
        "arch": arch, "shape": shape, "mesh": mesh,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_i,
        "dominant": dom,
        "model_flops": cb.model_flops,
        "useful_ratio": cb.model_flops / max(cb.flops * n_dev, 1.0),
        "analytic_flops_pd": cb.flops,
        "analytic_hbm_pd": cb.hbm_bytes,
        "analytic_ici_pd": cb.ici_bytes,
    }
    if dry:
        row["hlo_flops_raw"] = dry.get("flops")
        row["hlo_bytes_raw"] = dry.get("bytes_accessed")
        row["hlo_ici_static"] = dry.get("collectives", {}).get(
            "ici_traffic_bytes")
        m = dry.get("memory", {})
        row["mem_gib_per_dev"] = (m.get("argument_bytes", 0)
                                  + m.get("temp_bytes", 0)) / 2**30
    return row


def run(verbose=True, path="experiments/dryrun"):
    dry = load_dryruns(path)
    from repro.configs import ARCHS
    from repro.launch.shapes import SHAPES

    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("pod16x16", "pod2x16x16"):
                d = dry.get((arch, shape, mesh))
                rows.append(analyse(arch, shape, mesh, d))
    if verbose:
        print("arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
              "useful_ratio,mem_gib_per_dev")
        for r in rows:
            if "skip" in r:
                print(csv_row(r["arch"], r["shape"], r["mesh"], "SKIP", "",
                              "", "", "", ""))
                continue
            print(csv_row(
                r["arch"], r["shape"], r["mesh"],
                f"{r['compute_s'] * 1e3:.3f}", f"{r['memory_s'] * 1e3:.3f}",
                f"{r['collective_s'] * 1e3:.3f}", r["dominant"],
                f"{r['useful_ratio']:.2f}",
                f"{r.get('mem_gib_per_dev', float('nan')):.2f}"))
    return rows


if __name__ == "__main__":
    run()

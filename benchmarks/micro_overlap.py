"""Calibrate the in-kernel fusion overlap discount per cluster preset.

The pricing layer models a *fused* bucket (``FusionGraph.bucket_fused``,
DESIGN.md Sec. 13) with one scalar per preset: the collective's effective
ready time reaches ``discount x duration`` back into the tail of the
producing compute job.  The ground truth it approximates is the fused
kernel's fine-grained behaviour — gradient chunks stream onto the wire as
they are produced, store-and-forward, long before the producer retires.

This microbenchmark prices both on the same event engine:

* **reference** — the producing compute (duration ``T``) emits ``FINE``
  equal chunks inside ONE collective launch; chunk ``k`` becomes ready at
  ``(k+1)/FINE x T`` and the chunks ``after``-chain store-and-forward down
  the link levels (``chunk_phases`` conserves the (c, d) coefficients:
  in-kernel streaming splits the launch's work, it does not re-launch).
* **model** — one unchunked job of the full volume with ready
  ``T x (1 - discount)``.

``fit_overlap_discount`` grid-fits the discount minimising the relative
finish-time error over a sweep of bucket sizes x compute/comm ratios.
The fitted values are stored in ``repro.cluster.calibrate
.OVERLAP_DISCOUNTS`` beside the per-level alpha/beta coefficients.

A deliberate property of the fit: because the event engine prices every
interval of a single bucket's schedule proportionally to its opaque
``c x nbytes + d`` term, both schedules are *scale-free* — the relative
error depends only on the compute/comm ratio and the streaming
granularity, not on a preset's absolute coefficients — so today every
preset calibrates to the same discount.  The table stays per-preset
keyed: a measured-kernel truth (real TPU profiles instead of the engine's
own fine-grained schedule) slots in per preset without an interface
change.

    PYTHONPATH=src python benchmarks/micro_overlap.py --fit    # print table
    PYTHONPATH=src python benchmarks/micro_overlap.py --check  # vs stored

Writes ``experiments/perf/micro_overlap.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster import PRESETS, comm_coeffs
from repro.cluster.calibrate import (OVERLAP_DISCOUNTS,
                                     fit_overlap_discount,
                                     overlap_discount_for)
from repro.core import CommJob, EventEngine

OUT = "experiments/perf"
FINE = 8           # in-kernel streaming granularity (== max CHUNK_CHOICES)
STREAMS = 4        # the engine configuration the sweep prices fused on
# bucket bytes: small buckets expose the per-chunk latency overhead (and
# the per-level phase structure of hierarchical presets), large ones the
# bandwidth regime
SIZES = (64 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20, 64 << 20)
RATIOS = (0.25, 0.5, 1.0, 2.0, 4.0)              # T_compute / T_comm


def _sweep_points(spec) -> list[tuple[float, float]]:
    """(nbytes, compute_duration) pairs spanning compute- to comm-bound."""
    c, d = comm_coeffs(spec, "ring", "ar")
    pts = []
    for nbytes in SIZES:
        t_comm = c * nbytes + d
        for ratio in RATIOS:
            pts.append((float(nbytes), ratio * t_comm))
    return pts


def reference_finish(spec, nbytes: float, t_compute: float) -> float:
    """Fine-grained truth: FINE store-and-forward chunks of one launch,
    chunk k ready at (k+1)/FINE x t_compute — the fused kernel streams
    chunks onto the wire as the producer writes them."""
    jobs, prev = [], None
    for k in range(FINE):
        jobs.append(CommJob(bucket=0, ready=t_compute * (k + 1) / FINE,
                            nbytes=nbytes / FINE, algo="ring",
                            job_id=100 + k, after=prev, chunk=k,
                            chunks=FINE))
        prev = 100 + k
    _, finish = EventEngine(spec, streams=STREAMS).run(jobs)
    return finish


def model_finish(spec, nbytes: float, t_compute: float,
                 discount: float) -> float:
    """The priced model: one job, ready advanced into the compute tail."""
    job = CommJob(bucket=0, ready=t_compute * (1.0 - discount),
                  nbytes=nbytes, algo="ring")
    _, finish = EventEngine(spec, streams=STREAMS).run([job])
    return finish


def calibrate_preset(name: str, spec) -> dict:
    pts = _sweep_points(spec)
    reference = [reference_finish(spec, b, t) for b, t in pts]

    def model(d):
        return [model_finish(spec, b, t, d) for b, t in pts]

    fitted, rms = fit_overlap_discount(reference, model)
    return {
        "preset": name,
        "n_devices": spec.n_devices,
        "fitted_discount": fitted,
        "rms_rel_err": rms,
        "stored_discount": overlap_discount_for(spec),
        "points": len(pts),
        "fine_chunks": FINE,
        "streams": STREAMS,
    }


def run(check: bool = False, tol: float = 0.05, verbose: bool = True) -> dict:
    rows = [calibrate_preset(name, spec) for name, spec in PRESETS.items()]
    if verbose:
        print(f"{'preset':24s} {'fitted':>8s} {'stored':>8s} {'rms_err':>8s}")
        for r in rows:
            print(f"{r['preset']:24s} {r['fitted_discount']:8.3f} "
                  f"{r['stored_discount']:8.3f} {r['rms_rel_err']:8.3f}")
        print("\n# paste into repro/cluster/calibrate.py:")
        print("OVERLAP_DISCOUNTS: dict[str, float] = {")
        for r in rows:
            print(f'    "{r["preset"]}": {r["fitted_discount"]},')
        print("}")
    out = {"fine_chunks": FINE, "streams": STREAMS,
           "sizes": list(SIZES), "ratios": list(RATIOS), "presets": rows}
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "micro_overlap.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    if verbose:
        print(f"# wrote {path}")
    if check:
        stale = [r["preset"] for r in rows
                 if abs(r["fitted_discount"] - r["stored_discount"]) > tol]
        assert not stale, (
            f"stored OVERLAP_DISCOUNTS drifted beyond {tol} from a fresh "
            f"fit on: {stale} — rerun with --fit and paste the table")
        if verbose:
            print(f"# stored discounts within {tol} of fresh fit "
                  f"on all {len(rows)} presets")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fit", action="store_true",
                    help="fit and print the OVERLAP_DISCOUNTS table")
    ap.add_argument("--check", action="store_true",
                    help="assert stored discounts match a fresh fit")
    args = ap.parse_args()
    run(check=args.check)

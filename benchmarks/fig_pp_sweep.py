"""Fig. F (ours): pipeline-aware joint search vs blind background-traffic
modeling across the cluster preset zoo (DESIGN.md Sec. 11).

PR 4 modeled pipeline-parallel stage-boundary transfers as *periodic
background noise*: recurring ``pp``-class p2p jobs with no dependency
structure.  The unified engine lowers a real 1F1B schedule instead —
stage-boundary transfers are dep-coupled to the fwd/bwd units that produce
and consume them, and gradient buckets wait for the *last backward* of
their provider stages.  That changes when link levels are busy, so a
search pricing against the blind model can pick a different (worse)
strategy than one pricing against the schedule it will actually run under.

For each preset, two budget-matched backtracking searches over the same
comm-bound traced graph (small batch/seq, model-sized gradients):

* ``searched_bg``  — 4-stream engine + periodic pp background jobs,
* ``searched_pp``  — 4-stream engine + the 1F1B lowering
  (``pipeline=PipelineSchedule(S, M)``),

both fed the *same* per-boundary p2p volume (the simulator's activation
estimate), so only the contention *structure* differs.  Headline: on how
many presets the two searches pick different strategies
(``strategy_fingerprint``), and the regret of enacting the blind-model
strategy under the schedule it would actually run on.

    PYTHONPATH=src python benchmarks/fig_pp_sweep.py [--quick] [--smoke]
        [--cache DIR]

``--cache DIR`` routes both searches per preset through a
:class:`repro.plan.PlanCache` there (hit/warm-start counts are reported
and recorded in the JSON) — a re-run of the sweep replays every plan.

``--smoke`` is the CI lane: three presets, a reduced search budget, and a
hard failure (exit 1) when the pipeline pricing goes insane (bubble
fraction outside (0, 1), non-positive iteration) or the two models stop
disagreeing on every smoke preset.  Full runs write
``experiments/perf/pp_sweep.json`` and print a CSV block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import arch_graph, csv_row
from repro.cluster import PRESETS
from repro.core import BackgroundTraffic, PipelineSchedule, Simulator
from repro.plan import compile_plan

OUT = "experiments/perf"

STREAMS = 4
STAGES = 4
MICROBATCHES = 8
SMOKE_PRESETS = ("a100_nvlink_ib", "cross_dc_2pod", "tpu_v5e_pod_256")


def pp_models(g0, spec):
    """The two pricing models under comparison, fed the same p2p volume:
    the blind periodic-background job set and the dep-coupled 1F1B
    lowering.  The volume comes from the simulator's own activation
    estimate (mean stage-cut out_bytes per microbatch) so the models
    differ only in contention structure."""
    sched = PipelineSchedule(n_stages=STAGES, n_microbatches=MICROBATCHES)
    probe = Simulator(cluster=spec, streams=STREAMS, pipeline=sched)
    pi = probe.pipeline_inputs(g0)
    pbytes = pi["p2p_bytes"]
    # fwd activations + bwd activation-gradients per boundary per microbatch
    n = 2 * (STAGES - 1) * MICROBATCHES
    span = sum(pi["stage_busy"])
    bg = BackgroundTraffic("pp", pbytes, period=span / n if n else 0.0,
                           kind="p2p", count=n)
    return sched, bg, pbytes


def sweep_one(g0, name: str, spec, *, unchanged_limit: int, max_steps: int,
              seed: int = 0, cache=None) -> dict:
    sched, bg, pbytes = pp_models(g0, spec)
    plan_bg = compile_plan(graph=g0, cluster=spec, streams=STREAMS,
                           background=(bg,), unchanged_limit=unchanged_limit,
                           max_steps=max_steps, seed=seed, cache=cache)
    plan_pp = compile_plan(graph=g0, cluster=spec, streams=STREAMS,
                           pipeline=sched, unchanged_limit=unchanged_limit,
                           max_steps=max_steps, seed=seed, cache=cache)
    # regret: enact the blind-model strategy under the schedule it would
    # actually run on, and compare against the pipeline-aware pick
    sim_pp = Simulator(cluster=spec, streams=STREAMS, pipeline=sched)
    r_bg_under_pp = sim_pp.run(plan_bg.to_graph(g0))
    r_pp = sim_pp.run(plan_pp.to_graph(g0))
    differ = (plan_bg.strategy_fingerprint()
              != plan_pp.strategy_fingerprint())
    return {
        "preset": name,
        "n_devices": spec.n_devices,
        "levels": [l.name for l in spec.levels],
        "p2p_bytes": pbytes,
        "searched_bg": {
            "strategy_fingerprint": plan_bg.strategy_fingerprint(),
            "predicted_s": plan_bg.predicted_iteration_time,
            "describe": plan_bg.describe(),
            "under_pp_s": r_bg_under_pp.iteration_time,
        },
        "searched_pp": {
            "strategy_fingerprint": plan_pp.strategy_fingerprint(),
            "predicted_s": plan_pp.predicted_iteration_time,
            "describe": plan_pp.describe(),
            "under_pp_s": r_pp.iteration_time,
            "bubble_fraction": r_pp.pipeline["bubble"]["fraction"],
            "p2p_busy_s": r_pp.pipeline["p2p_busy_s"],
        },
        "strategies_differ": differ,
        "bg_regret": (r_bg_under_pp.iteration_time / r_pp.iteration_time
                      if r_pp.iteration_time > 0 else 1.0),
        "cache_outcomes": [
            p.provenance.get("cache", {}).get("outcome")
            for p in (plan_bg, plan_pp)
        ] if cache is not None else None,
    }


def run(arch: str = "qwen2-0.5b", unchanged_limit: int = 40,
        max_steps: int = 80, seed: int = 0, verbose: bool = True,
        batch: int = 2, seq: int = 32, smoke: bool = False,
        cache=None) -> dict:
    if isinstance(cache, str):
        from repro.plan import PlanCache

        cache = PlanCache(cache)
    # comm-bound regime: gradient volume is model-sized while compute
    # shrinks with tokens, so comm-schedule choices dominate the ranking
    g0 = arch_graph(arch, batch=batch, seq=seq)
    presets = SMOKE_PRESETS if smoke else tuple(PRESETS)
    rows = []
    for name in presets:
        spec = PRESETS[name]
        t0 = time.perf_counter()
        row = sweep_one(g0, name, spec, unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed, cache=cache)
        row["wall_s"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        if verbose:
            print(csv_row(
                name, spec.n_devices, row["strategies_differ"],
                f"{row['searched_bg']['under_pp_s']*1e3:.3f}ms",
                f"{row['searched_pp']['under_pp_s']*1e3:.3f}ms",
                f"{row['bg_regret']:.3f}x",
                f"{row['searched_pp']['bubble_fraction']:.3f}"))
    diff = [r["preset"] for r in rows if r["strategies_differ"]]
    out = {
        "arch": arch,
        "batch": batch,
        "seq": seq,
        "streams": STREAMS,
        "n_stages": STAGES,
        "n_microbatches": MICROBATCHES,
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "presets": rows,
        "strategies_differ_on": diff,
    }
    if cache is not None:
        out["cache"] = {"root": cache.root, **cache.stats}
    if verbose:
        print(f"# pipeline-aware search picks a different strategy than "
              f"the background-traffic model on {len(diff)}/{len(rows)} "
              f"presets: {diff}")
        if cache is not None:
            print(f"# cache {cache.root}: {cache.stats['hits']} hits, "
                  f"{cache.stats['misses']} misses, "
                  f"{cache.stats['warm_starts']} warm starts")
    if not smoke:
        os.makedirs(OUT, exist_ok=True)
        path = os.path.join(OUT, "pp_sweep.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        if verbose:
            print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI lane: 3 presets at reduced budget; exit 1 "
                         "when pipeline pricing is insane or the models "
                         "stop disagreeing on every smoke preset")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--cache", default=None, metavar="DIR",
                    help="route compile() through a PlanCache here "
                         "(re-runs replay from the cache)")
    args = ap.parse_args()
    quick = args.quick or args.smoke
    out = run(arch=args.arch,
              unchanged_limit=20 if quick else 40,
              max_steps=40 if quick else 80,
              smoke=args.smoke, cache=args.cache)
    if args.smoke:
        bad = []
        for r in out["presets"]:
            pp = r["searched_pp"]
            if not (0.0 < pp["bubble_fraction"] < 1.0):
                bad.append(f"{r['preset']}: bubble "
                           f"{pp['bubble_fraction']:.3f}")
            if not pp["under_pp_s"] > 0.0:
                bad.append(f"{r['preset']}: non-positive iteration")
        if not out["strategies_differ_on"]:
            bad.append("models agree on every smoke preset")
        if bad:
            print(f"SMOKE FAIL: {bad}")
            raise SystemExit(1)

"""Fig. 10: ablation of the three optimisation methods — add non-duplicate
fusion, duplicate fusion, AllReduce fusion one at a time."""
from __future__ import annotations

from common import BENCH_ARCHS, arch_graph, csv_row, make_sim
from repro.core import backtracking_search

VARIANTS = [
    ("none", ()),
    ("+nondup", ("nondup",)),
    ("+nondup+dup", ("nondup", "dup")),
    ("+nondup+tensor", ("nondup", "tensor")),
    ("all_three", ("nondup", "dup", "tensor")),
]


def run(archs=BENCH_ARCHS[:4], unchanged_limit=100, verbose=True):
    sim = make_sim()
    rows = []
    for arch in archs:
        g = arch_graph(arch)
        for name, methods in VARIANTS:
            if not methods:
                t = sim.cost(g)
            else:
                t = backtracking_search(
                    g, sim, methods=methods,
                    unchanged_limit=unchanged_limit, seed=0).best_cost
            rows.append((arch, name, t * 1e6))
    if verbose:
        print("arch,methods,us_per_iter")
        for r in rows:
            print(csv_row(r[0], r[1], f"{r[2]:.2f}"))
    return rows


if __name__ == "__main__":
    run()

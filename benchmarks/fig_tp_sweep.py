"""Fig. G (ours): TP-aware joint search (dep-coupled activation traffic +
searched pipeline knobs) vs blind background-traffic modeling across the
cluster preset zoo (DESIGN.md Sec. 14).

PR 4 modeled tensor-parallel activation collectives as *periodic
background noise*: recurring ``tp``-class all-reduce jobs at a fixed
cadence with no dependency structure.  The unified engine now lowers them
as first-class per-layer jobs dep-coupled to the compute that produces
and consumes them (``repro.core.tp_traffic``): forward activations gate
downstream compute, backward ones gate gradient readiness.  Together with
the searched pipeline knobs (``pp_split`` / ``pp_microbatch`` /
``pp_interleave``) the search prices candidates under the contention
structure they would actually run under, instead of a horizon-averaged
smear.

For each preset, two backtracking searches over the same comm-bound
traced graph:

* ``blind`` — 4-stream engine + 1F1B pipeline + the legacy periodic
  ``tp``-class background jobs (``TPTraffic.to_background``),
* ``joint`` — the same engine and pipeline with ``tp=TPTraffic(...)``
  (dep-coupled lowering), *seeded* with the blind search's winning
  strategy (``initial=``),

both fed the *same* per-layer activation volume, so only the contention
structure differs.  Because the joint search starts from the blind
winner, its best can never price worse than enacting the blind strategy
under the truthful model — regressions are structurally impossible; the
headline is on how many presets the joint search finds a *strictly*
better strategy.

    PYTHONPATH=src python benchmarks/fig_tp_sweep.py [--quick] [--smoke]

``--smoke`` is the CI lane: three presets at a reduced budget and a hard
failure (exit 1) on any regression (joint strictly worse than enacting
the blind pick — impossible by construction, so firing means the seeding
contract broke) or insane pricing.  Full runs write
``experiments/perf/tp_sweep.json`` and print a CSV block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import arch_graph, csv_row
from repro.cluster import PRESETS
from repro.core import (PipelineSchedule, Simulator, TPTraffic,
                        backtracking_search)

OUT = "experiments/perf"

STREAMS = 4
STAGES = 4
MICROBATCHES = 8
TP_LAYERS = 6  # matches arch_graph's layer count
SMOKE_PRESETS = ("a100_nvlink_ib", "cross_dc_2pod", "tpu_v5e_pod_256")


def tp_models(g0, spec):
    """The two pricing models under comparison, fed the same per-layer
    activation volume: the legacy periodic ``tp``-class background jobs
    and the dep-coupled per-layer lowering.  The volume reuses the
    simulator's own stage-cut activation estimate (one all-reduce of the
    mean boundary activation per layer per direction), so the models
    differ only in contention structure."""
    sched = PipelineSchedule(n_stages=STAGES, n_microbatches=MICROBATCHES)
    probe = Simulator(cluster=spec, streams=STREAMS, pipeline=sched)
    pi = probe.pipeline_inputs(g0)
    tp = TPTraffic(n_layers=TP_LAYERS, fwd_bytes=pi["p2p_bytes"])
    horizon = sum(pi["stage_busy"])
    return sched, tp, horizon


def sweep_one(g0, name: str, spec, *, unchanged_limit: int, max_steps: int,
              seed: int = 0) -> dict:
    sched, tp, horizon = tp_models(g0, spec)
    blind_sim = Simulator(cluster=spec, streams=STREAMS, pipeline=sched,
                          background=tuple(tp.to_background(horizon)))
    joint_sim = Simulator(cluster=spec, streams=STREAMS, pipeline=sched,
                          tp=tp)
    skw = dict(unchanged_limit=unchanged_limit, max_steps=max_steps,
               seed=seed)
    blind = backtracking_search(g0, blind_sim, **skw)
    # seed the joint search with the blind winner: best-vs-best under the
    # truthful model can then never regress (see module docstring)
    joint = backtracking_search(g0, joint_sim, initial=blind.best, **skw)
    blind_under_joint = joint_sim.cost(blind.best)
    r_joint = joint_sim.run(joint.best)
    ratio = (blind_under_joint / joint.best_cost
             if joint.best_cost > 0 else 1.0)
    return {
        "preset": name,
        "n_devices": spec.n_devices,
        "levels": [l.name for l in spec.levels],
        "tp_fwd_bytes": tp.fwd_bytes,
        "tp_total_bytes": tp.total_bytes,
        "blind": {
            "best_cost": blind.best_cost,
            "simulations": blind.simulations,
            "under_joint_s": blind_under_joint,
            "pp_knobs": (None if blind.best.pp_knobs is None
                         else list(blind.best.pp_knobs)),
        },
        "joint": {
            "best_cost": joint.best_cost,
            "simulations": joint.simulations,
            "pp_knobs": (None if joint.best.pp_knobs is None
                         else list(joint.best.pp_knobs)),
            "bubble_fraction": r_joint.pipeline["bubble"]["fraction"],
            "tp_busy_s": (r_joint.tp or {}).get("tp_busy_s"),
        },
        "strategies_differ": (blind.best.signature()
                              != joint.best.signature()),
        "joint_win": ratio,
        "strict_win": blind_under_joint > joint.best_cost * (1 + 1e-12),
        "regression": joint.best_cost > blind_under_joint * (1 + 1e-9),
    }


def run(arch: str = "qwen2-0.5b", unchanged_limit: int = 40,
        max_steps: int = 80, seed: int = 0, verbose: bool = True,
        batch: int = 2, seq: int = 32, smoke: bool = False) -> dict:
    # comm-bound regime (same as fig_pp_sweep): model-sized gradients with
    # shrunk compute, so comm-schedule choices dominate the ranking
    g0 = arch_graph(arch, batch=batch, seq=seq)
    presets = SMOKE_PRESETS if smoke else tuple(PRESETS)
    rows = []
    for name in presets:
        spec = PRESETS[name]
        t0 = time.perf_counter()
        row = sweep_one(g0, name, spec, unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed)
        row["wall_s"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        if verbose:
            print(csv_row(
                name, spec.n_devices, row["strategies_differ"],
                f"{row['blind']['under_joint_s']*1e3:.3f}ms",
                f"{row['joint']['best_cost']*1e3:.3f}ms",
                f"{row['joint_win']:.3f}x",
                "WIN" if row["strict_win"] else "tie"))
    wins = [r["preset"] for r in rows if r["strict_win"]]
    out = {
        "arch": arch,
        "batch": batch,
        "seq": seq,
        "streams": STREAMS,
        "n_stages": STAGES,
        "n_microbatches": MICROBATCHES,
        "tp_layers": TP_LAYERS,
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "presets": rows,
        "strict_wins_on": wins,
        "regressions_on": [r["preset"] for r in rows if r["regression"]],
    }
    if verbose:
        print(f"# TP-aware joint search strictly beats the blind model's "
              f"best-vs-best on {len(wins)}/{len(rows)} presets: {wins}")
    if not smoke:
        os.makedirs(OUT, exist_ok=True)
        path = os.path.join(OUT, "tp_sweep.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        if verbose:
            print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI lane: 3 presets at reduced budget; exit 1 on "
                         "any regression or insane pricing")
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    quick = args.quick or args.smoke
    out = run(arch=args.arch,
              unchanged_limit=20 if quick else 40,
              max_steps=40 if quick else 80,
              smoke=args.smoke)
    if args.smoke:
        bad = []
        for r in out["presets"]:
            if r["regression"]:
                bad.append(f"{r['preset']}: joint regressed vs blind "
                           f"({r['joint_win']:.4f}x)")
            if not (0.0 < r["joint"]["bubble_fraction"] < 1.0):
                bad.append(f"{r['preset']}: bubble "
                           f"{r['joint']['bubble_fraction']:.3f}")
            if not r["joint"]["best_cost"] > 0.0:
                bad.append(f"{r['preset']}: non-positive cost")
        if bad:
            print(f"SMOKE FAIL: {bad}")
            raise SystemExit(1)

"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints CSV blocks per artifact.  The full dry-run sweep (deliverable e/g)
runs separately via ``python -m repro.launch.sweep``; roofline.py consumes
its outputs.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    quick = "--quick" in sys.argv
    lim = 40 if quick else 120
    import fig6_training_time
    import fig7_breakdown
    import fig8_single_device
    import fig9_estimator
    import fig10_ablation
    import fig11_gnn_search
    import fig_cluster_sweep
    import table2_sim_accuracy
    import table34_hparams
    import roofline

    artifacts = [
        ("Fig6+Table1: training time & speedups",
         lambda: fig6_training_time.run(unchanged_limit=lim)),
        ("Fig7: time breakdown",
         lambda: fig7_breakdown.run(unchanged_limit=lim)),
        ("Fig8: single-device op fusion",
         lambda: fig8_single_device.run(unchanged_limit=lim)),
        ("Fig9: GNN estimator error (tier A oracle corpus)",
         lambda: fig9_estimator.run(n_per_arch=80 if quick else 200,
                                    epochs=25 if quick else 50)),
        ("Table2: simulator vs real CPU step time",
         lambda: table2_sim_accuracy.run()),
        ("Fig10: optimization-method ablation",
         lambda: fig10_ablation.run(unchanged_limit=max(lim // 2, 30))),
        ("Tables3+4: alpha/beta hyper-parameters",
         lambda: table34_hparams.run(unchanged_limit=max(lim // 2, 30))),
        ("Fig11 (ours): GNN-in-the-loop search vs oracle search",
         lambda: fig11_gnn_search.run(unchanged_limit=max(lim // 2, 30))),
        ("FigC (ours): cluster-topology sweep of searched strategies",
         lambda: fig_cluster_sweep.run(unchanged_limit=max(lim // 2, 30),
                                       max_steps=lim)),
        ("Roofline: per (arch x shape x mesh) terms",
         lambda: roofline.run()),
    ]
    for title, fn in artifacts:
        print(f"\n{'=' * 72}\n# {title}\n{'=' * 72}")
        t0 = time.perf_counter()
        fn()
        print(f"# [{title.split(':')[0]} done in "
              f"{time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()

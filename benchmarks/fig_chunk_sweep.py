"""Fig. E (ours): chunked intra-bucket pipelining vs whole-bucket
pipelining across the cluster preset zoo (DESIGN.md Sec. 9).

Whole-bucket pipelining (PR 3) overlaps *different* buckets' phases across
link levels; a single large fused bucket still serializes its own phase
sequence.  Chunking splits the bucket into store-and-forward chunks whose
per-chunk phase coefficients sum exactly to the unchunked ones — the win
is pure scheduling: chunk 1's intra-host reduce-scatter runs under chunk
0's inter-host leg.  Ring collectives decompose into a single phase, so
chunking only pays on multi-phase (hierarchical / tree) schedules — the
sweep prices each granularity under both NCCL-auto and forced-hierarchical
algorithm assignments to expose the trade-off.

For each preset, the strategy family is bucket granularity (XLA-combiner
thresholds plus one fully-merged bucket) x collective-algorithm assignment
(auto / hier) x chunk count (1, 2, 4, 8), all priced on the 4-stream event
engine in the comm-bound regime (small batch/seq, model-sized gradients),
plus two budget-matched joint backtracking searches (one with
``METHOD_CHUNK``, one without).  Headline: **best chunked vs best
unchunked** per preset.

    PYTHONPATH=src python benchmarks/fig_chunk_sweep.py [--quick] [--smoke]
        [--cache DIR]

``--smoke`` is the CI lane: two presets, the static family only, and a
hard failure (exit 1) when chunking stops strictly beating whole-bucket
pipelining on at least one of them.  ``--cache DIR`` runs the joint
searches through a :class:`repro.plan.PlanCache` (re-runs replay; each
searched config reports its ``cache_outcome``).  Full runs write
``experiments/perf/chunk_sweep.json`` and print a CSV block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import arch_graph, csv_row
from repro.cluster import PRESETS
from repro.core import Simulator
from repro.core.mutations import ALL_METHODS, METHOD_CHUNK
from repro.core.baselines import (assign_bucket_algos,
                                  threshold_tensor_fusion,
                                  xla_post_order_op_fusion)
from repro.plan import compile_plan

OUT = "experiments/perf"

THRESHOLDS = {"1MB": 1 << 20, "4MB": 4 << 20, "30MB": 30 << 20}
CHUNKS = (1, 2, 4, 8)
STREAMS = 4


def merge_all_buckets(g):
    g = g.clone()
    i = 0
    while i < len(g.buckets) - 1:
        if not g.merge_buckets(i, i + 1):
            i += 1
    return g


def set_all_chunks(g, k: int):
    g = g.clone()
    for i in range(len(g.buckets)):
        g.set_bucket_chunks(i, k)
    return g


def sweep_one(g0, opfused, name: str, spec, *, unchanged_limit: int,
              max_steps: int, seed: int = 0, smoke: bool = False,
              cache=None) -> dict:
    cands = {
        label: threshold_tensor_fusion(opfused, threshold=thr)
        for label, thr in THRESHOLDS.items()
    }
    cands["all"] = merge_all_buckets(opfused)
    sim = Simulator(cluster=spec, streams=STREAMS)
    configs = {}
    for label, g in cands.items():
        for algo in ("auto", "hier"):
            ga = assign_bucket_algos(g, spec, algo)
            for k in CHUNKS:
                gk = set_all_chunks(ga, k) if k > 1 else ga
                r = sim.run(gk)
                configs[f"{label}_{algo}@c{k}"] = {
                    "iteration_time_s": r.iteration_time,
                    "comm_finish_s": r.comm_finish,
                    "buckets": len(gk.buckets),
                    "chunks": k,
                }
    if not smoke:
        # budget-matched joint searches (via the compile() facade): with
        # and without METHOD_CHUNK
        no_chunk = tuple(m for m in ALL_METHODS if m != METHOD_CHUNK)
        for tag, methods in (("searched_chunked", ALL_METHODS),
                             ("searched_whole", no_chunk)):
            plan = compile_plan(
                graph=g0, cluster=spec, streams=STREAMS,
                unchanged_limit=unchanged_limit, max_steps=max_steps,
                seed=seed, methods=methods, cache=cache)
            d = plan.describe()
            configs[tag] = {
                "iteration_time_s": plan.predicted_iteration_time,
                "buckets": d["allreduce_buckets"],
                "chunks": max(plan.bucket_chunks),
                "bucket_chunks": d["bucket_chunks"],
                "bucket_algos": d["bucket_algos"],
                "simulations": plan.provenance["simulations"],
                "cache_outcome": plan.provenance.get("cache",
                                                     {}).get("outcome"),
            }
    whole = {k: v["iteration_time_s"] for k, v in configs.items()
             if v["chunks"] == 1}
    chunked = {k: v["iteration_time_s"] for k, v in configs.items()
               if v["chunks"] > 1}
    best_whole = min(whole, key=whole.get)
    best_chunk = min(chunked, key=chunked.get)
    return {
        "preset": name,
        "n_devices": spec.n_devices,
        "levels": [l.name for l in spec.levels],
        "configs": configs,
        "best_whole_config": best_whole,
        "best_whole_s": whole[best_whole],
        "best_chunked_config": best_chunk,
        "best_chunked_s": chunked[best_chunk],
        "chunk_speedup": whole[best_whole] / chunked[best_chunk],
        "chunked_strictly_beats_whole": chunked[best_chunk] < whole[best_whole],
    }


def run(arch: str = "qwen2-0.5b", unchanged_limit: int = 40,
        max_steps: int = 80, seed: int = 0, verbose: bool = True,
        batch: int = 2, seq: int = 32, smoke: bool = False,
        cache=None) -> dict:
    if isinstance(cache, str):
        from repro.plan import PlanCache

        cache = PlanCache(cache)
    # small batch/seq: gradient volume (comm) is model-sized while compute
    # shrinks with tokens — the comm-bound regime chunking exists for
    g0 = arch_graph(arch, batch=batch, seq=seq)
    opfused = xla_post_order_op_fusion(g0)
    presets = (("a100_nvlink_ib", "cross_dc_2pod") if smoke
               else tuple(PRESETS))
    rows = []
    for name in presets:
        spec = PRESETS[name]
        t0 = time.perf_counter()
        row = sweep_one(g0, opfused, name, spec,
                        unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed, smoke=smoke,
                        cache=cache)
        row["wall_s"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        if verbose:
            print(csv_row(name, spec.n_devices, row["best_whole_config"],
                          f"{row['best_whole_s']*1e3:.3f}ms",
                          row["best_chunked_config"],
                          f"{row['best_chunked_s']*1e3:.3f}ms",
                          f"{row['chunk_speedup']:.3f}x",
                          row["chunked_strictly_beats_whole"]))
    winners = [r["preset"] for r in rows if r["chunked_strictly_beats_whole"]]
    out = {
        "arch": arch,
        "batch": batch,
        "seq": seq,
        "streams": STREAMS,
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "presets": rows,
        "chunked_beats_whole_on": winners,
    }
    if cache is not None:
        out["cache"] = {"root": cache.root, **cache.stats}
    if verbose:
        print(f"# chunked schedules strictly beat whole-bucket pipelining "
              f"on {len(winners)}/{len(rows)} presets: {winners}")
        if cache is not None:
            print(f"# cache {cache.root}: {cache.stats['hits']} hits, "
                  f"{cache.stats['misses']} misses, "
                  f"{cache.stats['warm_starts']} warm starts")
    if not smoke:
        os.makedirs(OUT, exist_ok=True)
        path = os.path.join(OUT, "chunk_sweep.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        if verbose:
            print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI lane: 2 presets, static family only; exit 1 "
                         "unless chunking strictly wins on every smoke "
                         "preset")
    ap.add_argument("--cache", default=None, metavar="DIR",
                    help="compile searches through a PlanCache at DIR "
                         "(re-runs replay from the cache)")
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    out = run(arch=args.arch,
              unchanged_limit=25 if args.quick else 40,
              max_steps=50 if args.quick else 80,
              smoke=args.smoke, cache=args.cache)
    if args.smoke:
        losers = [r["preset"] for r in out["presets"]
                  if not r["chunked_strictly_beats_whole"]]
        if losers:
            print(f"SMOKE FAIL: chunking no longer strictly beats "
                  f"whole-bucket pipelining on {losers}")
            raise SystemExit(1)

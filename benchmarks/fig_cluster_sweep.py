"""Fig. C (ours): searched strategy across the cluster preset zoo.

For each :mod:`repro.cluster` preset (plus the legacy flat model as the
reference point) run the joint op/tensor/algorithm backtracking search —
through the ``repro.plan.compile()`` facade, one cached trace searched per
preset — on the same traced training step and record what wins.  The point of the
exercise (and the acceptance bar of the cluster subsystem): the *winning
strategy changes with topology* — bucket counts, op-fusion shape and the
per-bucket collective algorithm all move, and on inter-host-bottlenecked
presets the hierarchical algorithm beats the flat ring outright.

    PYTHONPATH=src python benchmarks/fig_cluster_sweep.py [--quick]
        [--cache DIR]

``--cache DIR`` routes every ``compile()`` through a
:class:`repro.plan.PlanCache` there: a re-run of the sweep replays every
preset from the cache (the hit/miss/warm-start counts are reported and
recorded in the JSON).  Writes ``experiments/perf/cluster_sweep.json``
and prints a CSV block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import arch_graph, csv_row
from repro.cluster import (COLLECTIVE_ALGOS, ClusterSpec, PRESETS,
                           bucket_time)
from repro.core import Simulator, evaluate_baselines
from repro.core.hw import TPU_V5E
from repro.plan import compile_plan

OUT = "experiments/perf"


def sweep_one(g0, name: str, spec: ClusterSpec, *, unchanged_limit: int,
              max_steps: int, seed: int = 0, cache=None) -> dict:
    base = evaluate_baselines(g0, Simulator(cluster=spec))
    plan = compile_plan(graph=g0, cluster=spec,
                        unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed, cache=cache)
    total_grad = sum(g0.bucket_bytes(b) for b in g0.buckets)
    d = plan.describe()
    prov = plan.provenance
    return {
        "preset": name,
        "n_devices": spec.n_devices,
        "levels": [l.name for l in spec.levels],
        "total_grad_bytes": total_grad,
        # single-collective view: what the whole gradient volume costs
        # under each algorithm on this topology
        "whole_volume_time_s": {
            a: bucket_time(total_grad, spec, a) for a in COLLECTIVE_ALGOS
        },
        "initial_cost": prov["initial_cost"],
        "best_cost": plan.predicted_iteration_time,
        "speedup_vs_initial": prov["initial_cost"]
                              / plan.predicted_iteration_time,
        "baselines": base,
        "speedup_vs_jax_default": base["JAX_default"]
                                  / plan.predicted_iteration_time,
        "steps": prov["steps"],
        "simulations": prov["simulations"],
        "buckets": d["allreduce_buckets"],
        "fused_groups": d["fused_groups"],
        "bucket_algos": d["bucket_algos"],
        # strategy-only fingerprint: the distinct_strategies metric must
        # compare what the search *chose*, not the per-preset pricing
        # context baked into plan.fingerprint()
        "fingerprint": plan.strategy_fingerprint(),
        "cache_outcome": prov.get("cache", {}).get("outcome"),
    }


def run(arch: str = "qwen2-0.5b", unchanged_limit: int = 80,
        max_steps: int = 150, seed: int = 0, verbose: bool = True,
        cache=None) -> dict:
    if isinstance(cache, str):
        from repro.plan import PlanCache

        cache = PlanCache(cache)
    g0 = arch_graph(arch)
    specs = {"flat_tpu_256": ClusterSpec.flat(TPU_V5E, 256), **PRESETS}
    rows = []
    for name, spec in specs.items():
        t0 = time.perf_counter()
        row = sweep_one(g0, name, spec, unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed, cache=cache)
        row["wall_s"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        if verbose:
            algos = ",".join(f"{k}:{v}" for k, v in
                             sorted(row["bucket_algos"].items()))
            print(csv_row(name, spec.n_devices, row["buckets"],
                          row["fused_groups"], algos,
                          f"{row['best_cost']*1e3:.3f}ms",
                          f"{row['speedup_vs_jax_default']:.2f}x",
                          row["fingerprint"]))

    fingerprints = {r["preset"]: r["fingerprint"] for r in rows}
    distinct = len(set(fingerprints.values()))
    # inter-host-bottlenecked presets: hierarchical must beat the flat ring
    hier_wins = {
        r["preset"]: r["whole_volume_time_s"]["ring"]
        / r["whole_volume_time_s"]["hier"]
        for r in rows
        if r["whole_volume_time_s"]["hier"]
        < min(r["whole_volume_time_s"]["ring"],
              r["whole_volume_time_s"]["tree"])
    }
    out = {
        "arch": arch,
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "presets": rows,
        "distinct_strategies": distinct,
        "hier_beats_ring_on": hier_wins,
    }
    if cache is not None:
        out["cache"] = {"root": cache.root, **cache.stats}
    if verbose:
        print(f"# {distinct}/{len(rows)} topologies produced distinct "
              f"winning strategies")
        for k, v in sorted(hier_wins.items()):
            print(f"# hierarchical beats flat ring {v:.1f}x on {k}")
        if cache is not None:
            print(f"# cache {cache.root}: {cache.stats['hits']} hits, "
                  f"{cache.stats['misses']} misses, "
                  f"{cache.stats['warm_starts']} warm starts")
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "cluster_sweep.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    if verbose:
        print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--cache", default=None, metavar="DIR",
                    help="route compile() through a PlanCache here "
                         "(re-runs replay from the cache)")
    args = ap.parse_args()
    run(unchanged_limit=40 if args.quick else 80,
        max_steps=80 if args.quick else 150,
        cache=args.cache)

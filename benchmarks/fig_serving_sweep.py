"""Fig. H (ours): searched decode-serving plans vs the default engine
configuration across the cluster preset zoo (DESIGN.md Sec. 15).

``repro.serving.plan`` points the simulator-driven backtracking search at
the *deployed* schedule: one decode step lowered into the unified event
engine — per-token TP collectives as latency-critical dep-coupled jobs,
prefill admissions from a seeded synthetic request trace as a competing
traffic class — and the serving knobs (slot count, decode dispatch batch,
KV-shard layout, collective algorithm, stream allocation) as the search
space.  For each preset this sweep prices the default ``ServeEngine``
configuration (8 slots, full-width dispatch, replicated KV, ring, one
stream — exactly ``ServingState()``) and a searched plan *under the same
simulator and the same trace*, so only the knobs differ.  The search
starts from the default state, so the searched plan can never price worse
— regressions are structurally impossible; the headline is on how many
presets the search finds a *strictly* higher-throughput plan.

    PYTHONPATH=src python benchmarks/fig_serving_sweep.py [--quick] [--smoke]

``--smoke`` is the CI lane: three presets at a reduced budget, a
replay-from-cache bit-identity check (two ``compile_serving`` calls
through a fresh cache must agree fingerprint-for-fingerprint), and a hard
failure (exit 1) on any regression (searched strictly worse than default —
impossible by construction, so firing means the search start-state
contract broke) or insane pricing.  Full runs write
``experiments/perf/serving_sweep.json`` and print a CSV block.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import csv_row
from repro.cluster import PRESETS
from repro.configs import get_config
from repro.core import backtracking_search
from repro.core.mutations import SERVING_METHODS
from repro.serving.plan import DecodeModel, ServingSimulator, ServingState
from repro.serving.workload import Workload

OUT = "experiments/perf"

ARCH = "tinyllama-1.1b"
WORKLOAD = Workload(n_requests=64, rate=32.0, concurrency=48, seed=0)
SMOKE_PRESETS = ("a100_nvlink_ib", "cross_dc_2pod", "tpu_v5e_pod_256")


def sweep_one(name: str, spec, *, unchanged_limit: int, max_steps: int,
              seed: int = 0) -> dict:
    model = DecodeModel.from_config(get_config(ARCH))
    sim = ServingSimulator(model, WORKLOAD, spec)
    default = ServingState()
    p_def = sim.price(default)
    res = backtracking_search(default, sim, methods=SERVING_METHODS,
                              unchanged_limit=unchanged_limit,
                              max_steps=max_steps, seed=seed)
    p_best = sim.price(res.best)
    speedup = (p_def["seconds_per_token"] / p_best["seconds_per_token"]
               if p_best["seconds_per_token"] > 0 else 1.0)
    return {
        "preset": name,
        "n_devices": spec.n_devices,
        "levels": [l.name for l in spec.levels],
        "tp_degree": sim.tp_degree,
        "default": {
            "tokens_per_s": p_def["tokens_per_s"],
            "seconds_per_token": p_def["seconds_per_token"],
            "ttft_p99_s": p_def["ttft_p99_s"],
            "knobs": list(default.signature()[1:]),
        },
        "searched": {
            "tokens_per_s": p_best["tokens_per_s"],
            "seconds_per_token": p_best["seconds_per_token"],
            "ttft_p99_s": p_best["ttft_p99_s"],
            "knobs": list(res.best.signature()[1:]),
            "simulations": res.simulations,
            "steps": res.steps,
        },
        "speedup": speedup,
        "strict_win": (p_def["seconds_per_token"]
                       > p_best["seconds_per_token"] * (1 + 1e-12)),
        "regression": (p_best["seconds_per_token"]
                       > p_def["seconds_per_token"] * (1 + 1e-9)),
    }


def cache_bit_identity() -> list[str]:
    """Two cold->warm ``compile_serving`` calls through a fresh cache must
    agree bit-for-bit (same fingerprint, warm call a cache hit) — the
    replay-from-cache contract the nightly lane gates on."""
    from repro.serving.plan import compile_serving

    bad = []
    with tempfile.TemporaryDirectory() as d:
        kw = dict(cluster="tpu_v5e_pod_16", workload=WORKLOAD,
                  unchanged_limit=20, max_steps=40, seed=0, cache=d)
        p1 = compile_serving(ARCH, **kw)
        p2 = compile_serving(ARCH, **kw)
        if p1.fingerprint() != p2.fingerprint():
            bad.append(f"cache replay fingerprint drift: "
                       f"{p1.fingerprint()} != {p2.fingerprint()}")
        if p1 != p2:
            bad.append("cache replay plan inequality")
        if p2.provenance.get("cache", {}).get("outcome") != "hit":
            bad.append(f"warm compile was not a cache hit: "
                       f"{p2.provenance.get('cache')}")
    return bad


def run(unchanged_limit: int = 60, max_steps: int = 160, seed: int = 0,
        verbose: bool = True, smoke: bool = False) -> dict:
    presets = SMOKE_PRESETS if smoke else tuple(PRESETS)
    rows = []
    for name in presets:
        spec = PRESETS[name]
        t0 = time.perf_counter()
        row = sweep_one(name, spec, unchanged_limit=unchanged_limit,
                        max_steps=max_steps, seed=seed)
        row["wall_s"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        if verbose:
            print(csv_row(
                name, spec.n_devices,
                f"{row['default']['tokens_per_s']:.0f}tok/s",
                f"{row['searched']['tokens_per_s']:.0f}tok/s",
                f"p99 {row['searched']['ttft_p99_s']*1e3:.2f}ms",
                f"{row['speedup']:.3f}x",
                "WIN" if row["strict_win"] else "tie",
                "/".join(str(k) for k in row["searched"]["knobs"])))
    wins = [r["preset"] for r in rows if r["strict_win"]]
    out = {
        "arch": ARCH,
        "workload": list(WORKLOAD.to_tuple()),
        "workload_digest": WORKLOAD.digest(),
        "unchanged_limit": unchanged_limit,
        "max_steps": max_steps,
        "seed": seed,
        "presets": rows,
        "strict_wins_on": wins,
        "regressions_on": [r["preset"] for r in rows if r["regression"]],
    }
    if verbose:
        print(f"# searched serving plan strictly beats the default engine "
              f"configuration on {len(wins)}/{len(rows)} presets: {wins}")
    if not smoke:
        os.makedirs(OUT, exist_ok=True)
        path = os.path.join(OUT, "serving_sweep.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        if verbose:
            print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI lane: 3 presets at reduced budget + cache "
                         "bit-identity; exit 1 on any regression or "
                         "insane pricing")
    args = ap.parse_args()
    quick = args.quick or args.smoke
    out = run(unchanged_limit=20 if quick else 60,
              max_steps=40 if quick else 160,
              smoke=args.smoke)
    if args.smoke:
        bad = cache_bit_identity()
        for r in out["presets"]:
            if r["regression"]:
                bad.append(f"{r['preset']}: searched regressed vs default "
                           f"({r['speedup']:.4f}x)")
            if not r["searched"]["tokens_per_s"] > 0.0:
                bad.append(f"{r['preset']}: non-positive throughput")
            if not r["searched"]["ttft_p99_s"] >= 0.0:
                bad.append(f"{r['preset']}: negative TTFT")
        if bad:
            print(f"SMOKE FAIL: {bad}")
            raise SystemExit(1)

"""Table 2: end-to-end simulator accuracy — simulated iteration time vs a
*really measured* training-step wall time on this CPU.

The simulator is re-based on a CPU-calibrated Hardware() (microbenchmarked
matmul peak + copy bandwidth + dispatch overhead), then compared against the
measured jit step time of each reduced model.  The paper reports 11-17.5%
error on GPU clusters; the CPU analogue validates the same machinery.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from common import BENCH_ARCHS, csv_row
from repro.configs import get_config
from repro.core import Simulator, profile_graph, trace_grad_graph
from repro.core.profile_cpu import calibrate_cpu_hw
from repro.data.pipeline import materialize_batch
from repro.models import stacked as ST


def run(archs=BENCH_ARCHS, batch=8, seq=64, verbose=True):
    hw = calibrate_cpu_hw()
    if verbose:
        print(f"# calibrated: peak {hw.peak_flops / 1e9:.1f} GFLOP/s, "
              f"bw {hw.hbm_bw / 1e9:.2f} GB/s, "
              f"overhead {hw.launch_overhead * 1e6:.1f} us")
        print("arch,measured_ms,simulated_ms,error_pct")
    rows = []
    for arch in archs:
        cfg = get_config(arch).reduced()
        params = ST.init_params(jax.random.PRNGKey(0), cfg)
        data = materialize_batch(cfg, batch, seq, seed=0)

        def loss(p, bt):
            return ST.loss_fn(p, cfg, bt)

        grad_fn = jax.jit(jax.grad(loss))
        g0 = grad_fn(params, data)
        jax.block_until_ready(g0)
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(grad_fn(params, data))
            best = min(best, time.perf_counter() - t0)

        graph = profile_graph(trace_grad_graph(loss, params, data), hw)
        sim = Simulator(hw=hw, n_devices=1)
        est = sim.run(graph).iteration_time
        err = abs(est - best) / best * 100
        rows.append((arch, best * 1e3, est * 1e3, err))
        if verbose:
            print(csv_row(arch, f"{best * 1e3:.2f}", f"{est * 1e3:.2f}",
                          f"{err:.1f}"))
    return rows


if __name__ == "__main__":
    run()

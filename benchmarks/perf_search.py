"""Search-engine throughput benchmark: incremental fusion-graph engine vs
the seed full-replay engine (ISSUE 1 tentpole acceptance).

Measures, per config:

* **simulations/sec** of candidate cost evaluation under
    - ``seed``: every candidate pays a from-scratch quotient rebuild, an
      O(V log V) sorted-signature memo key and a full schedule replay —
      the seed engine's cost profile, emulated via
      ``FusionGraph._quotient_from_scratch`` + ``signature()`` +
      ``Simulator(incremental=False)``;
    - ``incremental``: maintained quotient + rolling ``fast_signature`` +
      journal-driven delta re-simulation.
* **search wall time** of a max_steps-bounded ``backtracking_search`` under
  both engines (identical trajectories — costs are bit-identical), plus an
  optional ``--workers N`` parallel-evaluation run.
* the ``deepseek-v2-236b`` scale probe: the incremental engine must finish
  its bounded search inside the wall-clock budget that the seed engine
  exhausts.

    PYTHONPATH=src python benchmarks/perf_search.py [--archs a,b]
        [--cands N] [--steps N] [--workers N] [--seed-budget SECONDS]

Writes ``experiments/perf/search_engine.json``.

``--smoke`` is the CI regression lane (nightly workflow): a small bounded
run on ``transformer-paper`` that **fails** (exit 1) when the incremental
engine's candidate-evaluation throughput drops below ``--smoke-min-speedup``
x the seed engine — catching event-engine (or other comm-pass) overhead
creeping onto the search hot path.  It also runs the same bounded search
through the ``repro.plan.compile()`` facade and fails when the facade adds
more than ``--smoke-max-facade-overhead`` (default 5%) over the direct
``backtracking_search`` wall time, or when its plan's predicted cost
drifts from the direct search's best (the facade must be wiring, not a
fork of the pipeline).  Finally it compiles through an empty
``repro.plan.PlanCache``: the cold trajectory must be identical to the
uncached search, and the exact-key replay must be bit-identical and at
least ``--smoke-min-cache-speedup`` (default 20x) faster than the cold
compile it replays.  A final gate offers ``METHOD_FUSED`` to searches on
sims where in-kernel fusion is inapplicable (flat topology, serialized
channel, zero overlap discount) and fails unless the cold trajectories are
bit-identical to runs never offered it — the fused dimension must cost
legacy configs nothing.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))
from common import arch_graph  # noqa: E402

from repro.core import Simulator, backtracking_search  # noqa: E402
from repro.core.search import ALL_METHODS, random_apply  # noqa: E402

OUT = "experiments/perf"
N_DEVICES = 256


class SeedPathSimulator:
    """Seed-engine cost profile behind the ``Simulator.cost`` interface."""

    def __init__(self, n_devices: int = N_DEVICES, cluster=None,
                 streams: int = 1):
        self._sim = Simulator(n_devices=n_devices, incremental=False,
                              cluster=cluster, streams=streams)
        self.cluster = self._sim.cluster
        self.streams = streams
        self.estimator = self._sim.estimator
        self._memo: dict = {}

    def cost(self, g) -> float:
        key = g.signature()  # seed memo key: O(V log V) sort
        c = self._memo.get(key)
        if c is None:
            # seed: `_quotient_cache = None` after every mutation -> full
            # O(membership x degree) rebuild before each simulation.  The
            # result is discarded (not written back): replacing the graph's
            # maintained sets would perturb set iteration order and thereby
            # the RNG-driven mutation stream of a subsequent search.
            g._quotient_from_scratch()
            c = self._sim.cost(g)
            self._memo[key] = c
        return c


def bench_sim_throughput(arch: str, n_cands: int, seed: int = 0,
                         cluster=None, streams: int = 1) -> dict:
    """Evaluate an identical mutation stream under both engines.  With
    ``cluster``/``streams`` the stream includes the multi-stream comm
    dimensions (algo / comm-kind / chunk mutations priced by the event
    engine) so the gate also catches engine overhead on that hot path."""
    out = {}
    costs_by_mode = {}
    for mode in ("seed", "incremental"):
        g0 = arch_graph(arch)
        sim = (SeedPathSimulator(cluster=cluster, streams=streams)
               if mode == "seed"
               else Simulator(n_devices=N_DEVICES, incremental=True,
                              cluster=cluster, streams=streams))
        rng = random.Random(seed)
        current = g0
        elapsed = 0.0
        costs = []
        t0 = time.perf_counter()
        sim.cost(current)
        elapsed += time.perf_counter() - t0
        for _ in range(n_cands):
            child = current.clone()
            for _ in range(rng.randint(1, 2)):
                random_apply(child, rng.choice(ALL_METHODS), 1, rng)
            t0 = time.perf_counter()
            costs.append(sim.cost(child))
            elapsed += time.perf_counter() - t0
            if rng.random() < 0.5:
                current = child
        costs_by_mode[mode] = costs
        out[mode] = {
            "candidates": n_cands,
            "eval_seconds": round(elapsed, 4),
            "sims_per_sec": round((n_cands + 1) / elapsed, 1),
        }
        if mode == "incremental":
            out[mode]["sim_stats"] = dict(sim.stats)
    assert costs_by_mode["seed"] == costs_by_mode["incremental"], \
        f"{arch}: engine mismatch"
    out["speedup"] = round(
        out["incremental"]["sims_per_sec"] / out["seed"]["sims_per_sec"], 2)
    out["bit_identical"] = True
    return out


class _BudgetExceeded(Exception):
    pass


def bench_search(arch: str, max_steps: int, workers: int | None,
                 budget_s: float | None = None, seed: int = 0) -> dict:
    out = {}
    kw = dict(unchanged_limit=10**9, max_steps=max_steps, seed=seed)
    modes: list[tuple[str, object, dict]] = [
        ("incremental", Simulator(n_devices=N_DEVICES, incremental=True), {}),
        ("seed", SeedPathSimulator(), {}),
    ]
    if workers:
        modes.insert(1, ("incremental_workers",
                         Simulator(n_devices=N_DEVICES, incremental=True),
                         {"workers": workers}))
    for mode, sim, extra in modes:
        g = arch_graph(arch)
        t0 = time.perf_counter()
        timed_out = False
        res = None

        def on_step(step, best):
            if budget_s is not None and time.perf_counter() - t0 > budget_s:
                raise _BudgetExceeded

        try:
            res = backtracking_search(g, sim, on_step=on_step, **kw, **extra)
        except _BudgetExceeded:
            timed_out = True
        if timed_out:
            out[mode] = {"timed_out": True,
                         "budget_seconds": budget_s,
                         "wall_seconds": round(time.perf_counter() - t0, 2)}
        else:
            out[mode] = {
                "timed_out": False,
                "wall_seconds": round(res.wall_time, 3),
                "steps": res.steps,
                "simulations": res.simulations,
                "sims_per_sec": round(res.simulations / res.wall_time, 1),
                "best_cost": res.best_cost,
                "initial_cost": res.initial_cost,
            }
    done = [m for m in out.values() if not m["timed_out"]]
    if len(done) > 1:
        assert len({m["best_cost"] for m in done}) == 1, \
            f"{arch}: engines found different best costs"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="transformer-paper,qwen2-0.5b")
    ap.add_argument("--cands", type=int, default=300)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--probe-steps", type=int, default=500,
                    help="max_steps for the deepseek scale probe")
    ap.add_argument("--seed-budget", type=float, default=30.0,
                    help="wall-clock budget for the deepseek scale probe")
    ap.add_argument("--skip-deepseek", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI regression check: bounded run, fail if "
                         "the incremental engine's throughput advantage "
                         "over the seed engine regresses")
    ap.add_argument("--smoke-min-speedup", type=float, default=2.0)
    ap.add_argument("--smoke-min-speedup-chunked", type=float, default=1.2,
                    help="throughput floor for the chunked multi-stream "
                         "smoke config (event-engine comm pass on both "
                         "sides, so the incremental edge is smaller)")
    ap.add_argument("--smoke-min-speedup-unified", type=float, default=3.0,
                    help="throughput floor for the serialized hierarchical "
                         "config: both sides run the unified compute+comm "
                         "dependency engine end-to-end, so the gate catches "
                         "unified-engine overhead on the streams=1 path")
    ap.add_argument("--smoke-max-facade-overhead", type=float, default=0.05,
                    help="ceiling on compile() facade overhead relative to "
                         "the direct backtracking_search wall time")
    ap.add_argument("--smoke-min-cache-speedup", type=float, default=20.0,
                    help="floor on the plan-cache exact-key replay's "
                         "speedup over the cold compile it replays")
    args = ap.parse_args()
    if args.smoke:
        args.archs = "transformer-paper"
        args.cands = min(args.cands, 200)
        args.steps = min(args.steps, 25)
        args.skip_deepseek = True
    os.makedirs(OUT, exist_ok=True)
    report: dict = {}
    for arch in args.archs.split(","):
        print(f"=== {arch} ===", flush=True)
        thr = bench_sim_throughput(arch, args.cands)
        print(f"  sims/sec: seed={thr['seed']['sims_per_sec']} "
              f"incremental={thr['incremental']['sims_per_sec']} "
              f"({thr['speedup']}x, bit-identical)", flush=True)
        srch = bench_search(arch, args.steps, args.workers)
        for mode, m in srch.items():
            print(f"  search[{mode}]: {m['wall_seconds']}s "
                  f"{m.get('simulations')} sims", flush=True)
        report[arch] = {"throughput": thr, "search": srch}
        if args.smoke:
            # chunked multi-stream config: the mutation stream now draws
            # algo/comm/chunk flips and the comm pass is the event engine
            from repro.cluster import get_preset

            thr_ms = bench_sim_throughput(
                arch, args.cands, cluster=get_preset("a100_nvlink_ib"),
                streams=4)
            print(f"  sims/sec[chunked 4-stream]: "
                  f"seed={thr_ms['seed']['sims_per_sec']} "
                  f"incremental={thr_ms['incremental']['sims_per_sec']} "
                  f"({thr_ms['speedup']}x, bit-identical)", flush=True)
            report[arch]["throughput_chunked_multistream"] = thr_ms
            # serialized hierarchical config: the full path builds the
            # unified dependency job graph (compute jobs + dep'd comm
            # jobs) for every candidate while the delta path replays the
            # journal suffix — the floor catches unified-engine overhead
            # regressing either side
            thr_uni = bench_sim_throughput(
                arch, args.cands, cluster=get_preset("a100_nvlink_ib"),
                streams=1)
            print(f"  sims/sec[unified serialized]: "
                  f"seed={thr_uni['seed']['sims_per_sec']} "
                  f"incremental={thr_uni['incremental']['sims_per_sec']} "
                  f"({thr_uni['speedup']}x, bit-identical)", flush=True)
            report[arch]["throughput_unified_serialized"] = thr_uni
            # compile() facade on the same graph/budget: the trajectory is
            # identical to bench_search's direct incremental run, so its
            # wall time isolates the facade's own overhead
            from repro.plan import compile_plan

            plan = compile_plan(graph=arch_graph(arch),
                                unchanged_limit=10**9,
                                max_steps=args.steps, seed=0)
            fac = {
                "facade_wall_seconds": round(
                    plan.provenance["facade_wall_time"], 3),
                "search_wall_seconds": round(
                    plan.provenance["search_wall_time"], 3),
                "overhead": round(
                    plan.provenance["facade_wall_time"]
                    / plan.provenance["search_wall_time"] - 1, 4),
                "best_cost": plan.predicted_iteration_time,
                "direct_best_cost": srch["incremental"]["best_cost"],
            }
            print(f"  compile() facade: search "
                  f"{fac['search_wall_seconds']}s, total "
                  f"{fac['facade_wall_seconds']}s "
                  f"({fac['overhead']*100:.2f}% overhead)", flush=True)
            report[arch]["facade"] = fac
            # plan cache: a cold compile through an empty cache must be
            # trajectory-identical to the direct search (initial=None
            # draws the same RNG stream), and its exact-key replay must
            # be bit-identical and pay file IO only
            import tempfile

            from repro.plan import PlanCache

            pcache = PlanCache(tempfile.mkdtemp(prefix="perf-cache-"))
            t0 = time.perf_counter()
            cold_plan = compile_plan(graph=arch_graph(arch),
                                     unchanged_limit=10**9,
                                     max_steps=args.steps, seed=0,
                                     cache=pcache)
            cold_wall = time.perf_counter() - t0
            # min-of-5: a single replay is a few ms of file IO, small
            # enough for one GC pass over this process's searched-graph
            # heap to dominate a lone sample
            replay_wall = float("inf")
            for _ in range(5):
                t0 = time.perf_counter()
                replay = compile_plan(graph=arch_graph(arch),
                                      unchanged_limit=10**9,
                                      max_steps=args.steps, seed=0,
                                      cache=pcache)
                replay_wall = min(replay_wall, time.perf_counter() - t0)
            crep = {
                "cold_wall_seconds": round(cold_wall, 3),
                "replay_wall_seconds": round(replay_wall, 4),
                "speedup": round(cold_wall / replay_wall, 1),
                "outcome": replay.provenance["cache"]["outcome"],
                "replay_bit_identical": (
                    replay == cold_plan
                    and replay.strategy_fingerprint()
                    == cold_plan.strategy_fingerprint()
                    and replay.predicted_iteration_time
                    == cold_plan.predicted_iteration_time),
                "cold_trajectory_identical": (
                    cold_plan.predicted_iteration_time
                    == srch["incremental"]["best_cost"]),
            }
            print(f"  plan cache: cold {crep['cold_wall_seconds']}s, "
                  f"replay {crep['replay_wall_seconds']}s "
                  f"({crep['speedup']}x, outcome={crep['outcome']})",
                  flush=True)
            report[arch]["plan_cache"] = crep
            # METHOD_FUSED gating: where in-kernel fusion is inapplicable
            # (flat topology / serialized channel / zero discount) the
            # active method set drops it, so a cold search offered the
            # fused method draws the exact pre-fused RNG stream — the
            # trajectory must be bit-identical to one never offered it
            from repro.core.search import (ALL_METHODS, METHOD_FUSED,
                                           backtracking_search)

            gate = {}
            skw = dict(unchanged_limit=10**9, max_steps=args.steps, seed=0)
            for tag, sim in (
                    ("flat", Simulator(n_devices=N_DEVICES)),
                    ("serialized", Simulator(
                        cluster=get_preset("a100_nvlink_ib"), streams=1,
                        overlap_discount=0.525)),
                    ("undiscounted", Simulator(
                        cluster=get_preset("a100_nvlink_ib"), streams=4,
                        overlap_discount=0.0))):
                legacy = backtracking_search(arch_graph(arch), sim,
                                             methods=ALL_METHODS, **skw)
                offered = backtracking_search(
                    arch_graph(arch), sim,
                    methods=ALL_METHODS + (METHOD_FUSED,), **skw)
                gate[tag] = {
                    "identical": (
                        legacy.best_cost == offered.best_cost
                        and legacy.simulations == offered.simulations
                        and legacy.best.signature()
                        == offered.best.signature()
                        and not any(offered.best.bucket_fused)),
                    "best_cost": legacy.best_cost,
                }
            print(f"  fused gating: trajectories unchanged on "
                  f"{[t for t, g_ in gate.items() if g_['identical']]}",
                  flush=True)
            report[arch]["fused_gating"] = gate
            # pp-knob gating: the searched pipeline mutations (pp_split /
            # pp_microbatch / pp_interleave) apply only to pipeline-enabled
            # sims.  On every non-pipeline sim the active method set drops
            # them, so a cold search offered the pp methods draws the exact
            # legacy RNG stream — trajectory bit-identical, knobs untouched.
            from repro.core import (METHOD_PP_INTERLEAVE,
                                    METHOD_PP_MICROBATCH, METHOD_PP_SPLIT)

            ppgate = {}
            pp_methods = (METHOD_PP_SPLIT, METHOD_PP_MICROBATCH,
                          METHOD_PP_INTERLEAVE)
            for tag, sim in (
                    ("flat", Simulator(n_devices=N_DEVICES)),
                    ("serialized", Simulator(
                        cluster=get_preset("a100_nvlink_ib"), streams=1,
                        overlap_discount=0.525)),
                    ("undiscounted", Simulator(
                        cluster=get_preset("a100_nvlink_ib"), streams=4,
                        overlap_discount=0.0))):
                legacy = backtracking_search(
                    arch_graph(arch), sim,
                    methods=ALL_METHODS + (METHOD_FUSED,), **skw)
                offered = backtracking_search(
                    arch_graph(arch), sim,
                    methods=ALL_METHODS + (METHOD_FUSED,) + pp_methods,
                    **skw)
                ppgate[tag] = {
                    "identical": (
                        legacy.best_cost == offered.best_cost
                        and legacy.simulations == offered.simulations
                        and legacy.best.signature()
                        == offered.best.signature()
                        and offered.best.pp_knobs is None),
                    "best_cost": legacy.best_cost,
                }
            print(f"  pp gating: trajectories unchanged on "
                  f"{[t for t, g_ in ppgate.items() if g_['identical']]}",
                  flush=True)
            report[arch]["pp_gating"] = ppgate
    if not args.skip_deepseek:
        arch = "deepseek-v2-236b"
        print(f"=== {arch} (scale probe, budget {args.seed_budget}s) ===",
              flush=True)
        probe = bench_search(arch, args.probe_steps, None,
                             budget_s=args.seed_budget)
        for mode, m in probe.items():
            status = "TIMED OUT" if m["timed_out"] else \
                f"{m['wall_seconds']}s {m['simulations']} sims"
            print(f"  search[{mode}]: {status}", flush=True)
        report[arch] = {"search": probe}
    path = os.path.join(OUT, "search_engine.json")
    json.dump(report, open(path, "w"), indent=1)
    print(f"wrote {path}")
    if args.smoke:
        speedups = {a: r["throughput"]["speedup"] for a, r in report.items()
                    if "throughput" in r}
        bad = {a: s for a, s in speedups.items()
               if s < args.smoke_min_speedup}
        chunked = {a: r["throughput_chunked_multistream"]["speedup"]
                   for a, r in report.items()
                   if "throughput_chunked_multistream" in r}
        bad.update({f"{a}[chunked]": s for a, s in chunked.items()
                    if s < args.smoke_min_speedup_chunked})
        unified = {a: r["throughput_unified_serialized"]["speedup"]
                   for a, r in report.items()
                   if "throughput_unified_serialized" in r}
        bad.update({f"{a}[unified]": s for a, s in unified.items()
                    if s < args.smoke_min_speedup_unified})
        if bad:
            print(f"SMOKE FAIL: incremental/seed throughput below floor: "
                  f"{bad}")
            raise SystemExit(1)
        facades = {a: r["facade"] for a, r in report.items()
                   if "facade" in r}
        for a, fac in facades.items():
            if fac["best_cost"] != fac["direct_best_cost"]:
                print(f"SMOKE FAIL: {a}: compile() facade found "
                      f"{fac['best_cost']} vs direct search "
                      f"{fac['direct_best_cost']} — the facade forked the "
                      f"pipeline")
                raise SystemExit(1)
            if fac["overhead"] > args.smoke_max_facade_overhead:
                print(f"SMOKE FAIL: {a}: compile() facade overhead "
                      f"{fac['overhead']*100:.2f}% exceeds "
                      f"{args.smoke_max_facade_overhead*100:.0f}%")
                raise SystemExit(1)
        caches = {a: r["plan_cache"] for a, r in report.items()
                  if "plan_cache" in r}
        for a, crep in caches.items():
            if crep["outcome"] != "hit" or not crep["replay_bit_identical"]:
                print(f"SMOKE FAIL: {a}: plan-cache replay not a "
                      f"bit-identical exact-key hit ({crep})")
                raise SystemExit(1)
            if not crep["cold_trajectory_identical"]:
                print(f"SMOKE FAIL: {a}: compiling through an empty cache "
                      f"changed the search trajectory ({crep})")
                raise SystemExit(1)
            if crep["speedup"] < args.smoke_min_cache_speedup:
                print(f"SMOKE FAIL: {a}: plan-cache replay speedup "
                      f"{crep['speedup']}x below "
                      f"{args.smoke_min_cache_speedup}x floor")
                raise SystemExit(1)
        for a, r in report.items():
            for tag, g_ in r.get("fused_gating", {}).items():
                if not g_["identical"]:
                    print(f"SMOKE FAIL: {a}[{tag}]: offering METHOD_FUSED "
                          f"on a sim where it is inapplicable changed the "
                          f"cold search trajectory ({g_})")
                    raise SystemExit(1)
            for tag, g_ in r.get("pp_gating", {}).items():
                if not g_["identical"]:
                    print(f"SMOKE FAIL: {a}[{tag}]: offering the pp-knob "
                          f"methods on a non-pipeline sim changed the "
                          f"cold search trajectory ({g_})")
                    raise SystemExit(1)
        print(f"smoke OK: incremental/seed throughput {speedups}, "
              f"chunked multi-stream {chunked}, unified serialized "
              f"{unified} "
              f"(floors {args.smoke_min_speedup}x / "
              f"{args.smoke_min_speedup_chunked}x / "
              f"{args.smoke_min_speedup_unified}x); facade overhead "
              f"{ {a: f['overhead'] for a, f in facades.items()} } "
              f"(ceiling {args.smoke_max_facade_overhead*100:.0f}%); "
              f"cache replay "
              f"{ {a: c['speedup'] for a, c in caches.items()} }x "
              f"(floor {args.smoke_min_cache_speedup}x, bit-identical)")


if __name__ == "__main__":
    main()

"""Fig. 7: per-iteration computation / communication / overlap breakdown for
each strategy (paper reports overlap ratio = (comp+comm)/iteration)."""
from __future__ import annotations

from common import BENCH_ARCHS, arch_graph, csv_row, make_sim
from repro.core import backtracking_search
from repro.core.baselines import BASELINES


def run(archs=BENCH_ARCHS[:4], unchanged_limit=120, verbose=True):
    sim = make_sim()
    rows = []
    for arch in archs:
        g = arch_graph(arch)
        strategies = {name: fn(g) for name, fn in BASELINES.items()}
        strategies["DisCo"] = backtracking_search(
            g, sim, unchanged_limit=unchanged_limit, seed=0).best
        for name, h in strategies.items():
            r = sim.run(h)
            rows.append((arch, name, r.iteration_time * 1e6,
                         r.compute_time * 1e6, r.comm_time * 1e6,
                         r.overlap_ratio))
    if verbose:
        print("arch,strategy,iter_us,compute_us,comm_us,overlap_ratio")
        for r in rows:
            print(csv_row(r[0], r[1], f"{r[2]:.2f}", f"{r[3]:.2f}",
                          f"{r[4]:.2f}", f"{r[5]:.3f}"))
    return rows


if __name__ == "__main__":
    run()

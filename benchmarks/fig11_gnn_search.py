"""(ours) GNN-in-the-loop search — the paper's actual deployment setup.

The paper's Strategy Maker searches with the *GNN estimator* as the cost
model (the oracle is only available offline through profiling).  This
benchmark trains the estimator on oracle-labelled fused ops, then runs the
backtracking search with the GNN as the simulator's estimator, and scores
the found strategy with the ORACLE simulator — measuring how much strategy
quality the learned cost model loses vs searching with the oracle itself.
"""
from __future__ import annotations

import random

from common import BENCH_ARCHS, arch_graph, csv_row, make_sim
from repro.core import Simulator, backtracking_search
from repro.core.gnn import GNNConfig, GNNEstimator, train
from repro.core.profile_cpu import sample_fused_groups


def run(archs=BENCH_ARCHS[:3], n_samples=250, epochs=40,
        unchanged_limit=100, verbose=True):
    rng = random.Random(0)
    rows = []
    for arch in archs:
        g = arch_graph(arch)
        corpus = sample_fused_groups(g, n_samples, rng, max_members=16)
        cfg = GNNConfig(n_layers=2, n_heads=4, head_dim=16, mlp_dim=64)
        params, _ = train(corpus, cfg, epochs=epochs, batch_size=32, seed=0)
        oracle_sim = make_sim()
        gnn_sim = Simulator(estimator=GNNEstimator(params, cfg),
                            n_devices=oracle_sim.n_devices)
        res_oracle = backtracking_search(g, oracle_sim,
                                         unchanged_limit=unchanged_limit,
                                         seed=0)
        res_gnn = backtracking_search(g, gnn_sim,
                                      unchanged_limit=unchanged_limit,
                                      seed=0)
        # score the GNN-found strategy under the oracle (ground truth)
        t_gnn_true = oracle_sim.cost(res_gnn.best)
        t0 = oracle_sim.cost(g)
        rows.append((arch, t0 * 1e6, res_oracle.best_cost * 1e6,
                     t_gnn_true * 1e6,
                     (t_gnn_true / res_oracle.best_cost - 1) * 100))
    if verbose:
        print("arch,initial_us,oracle_search_us,gnn_search_us_true,"
              "gnn_gap_pct")
        for r in rows:
            print(csv_row(r[0], f"{r[1]:.1f}", f"{r[2]:.1f}", f"{r[3]:.1f}",
                          f"{r[4]:.1f}"))
    return rows


if __name__ == "__main__":
    run()

"""Fig. 9: Fused-Op Estimator prediction-error PDF/CDF on *unseen* fused ops.

Two ground-truth tiers (DESIGN.md Sec. 3):
  A (default) — oracle-labelled fused subgraphs sampled from the traced
      arch graphs (the paper's sample generator, Sec. 5.2);
  B (--measured) — synthetic fused ops actually jit-executed and timed on
      this CPU (real measurements, smaller corpus).
"""
from __future__ import annotations

import random
import sys

import numpy as np

from common import BENCH_ARCHS, arch_graph, csv_row
from repro.core.gnn import GNNConfig, predict_times, train
from repro.core.profile_cpu import measured_fused_samples, sample_fused_groups


def run(n_per_arch=250, epochs=60, measured=False, verbose=True, seed=0):
    rng = random.Random(seed)
    if measured:
        samples = measured_fused_samples(120, seed=seed, max_nodes=10,
                                         dim=128)
    else:
        samples = []
        for arch in BENCH_ARCHS:
            g = arch_graph(arch)
            samples += sample_fused_groups(g, n_per_arch, rng,
                                           max_members=16)
    rng.shuffle(samples)
    n = len(samples)
    tr, te = samples[: int(n * 0.85)], samples[int(n * 0.85):]
    cfg = GNNConfig(n_layers=3, n_heads=4, head_dim=16, mlp_dim=64)
    params, losses = train(tr, cfg, epochs=epochs, batch_size=32, lr=3e-3,
                           seed=seed)
    pred = predict_times(params, te)
    true = np.array([s[3] for s in te])
    rel = np.abs(pred - true) / true
    pct = {p: float(np.percentile(rel, p)) for p in (50, 75, 90, 95)}
    if verbose:
        print(f"# corpus {'B (CPU-measured)' if measured else 'A (oracle)'}: "
              f"{len(tr)} train / {len(te)} test fused ops")
        print(f"# final train loss {losses[-1]:.4f}")
        print("percentile,rel_error")
        for p, v in pct.items():
            print(csv_row(p, f"{v:.3f}"))
        within = float(np.mean(rel < 0.14))
        print(f"# fraction within 14% error (paper: >0.90 on GPU): "
              f"{within:.2f}")
    return pct


if __name__ == "__main__":
    run(measured="--measured" in sys.argv)

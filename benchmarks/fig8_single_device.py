"""Fig. 8: single-device comparison — DisCo's op-fusion-only search vs the
rule-based post-order heuristic (communication disabled: n_devices=1, no
AllReduce)."""
from __future__ import annotations

from common import BENCH_ARCHS, arch_graph, csv_row
from repro.core import Simulator, backtracking_search
from repro.core.baselines import xla_post_order_op_fusion


def run(archs=BENCH_ARCHS[:4], unchanged_limit=120, verbose=True):
    sim = Simulator(n_devices=1)   # no communication
    rows = []
    for arch in archs:
        g = arch_graph(arch)
        t_none = sim.cost(g)
        t_rule = sim.cost(xla_post_order_op_fusion(g))
        res = backtracking_search(g, sim, methods=("nondup", "dup"),
                                  unchanged_limit=unchanged_limit, seed=0)
        rows.append((arch, t_none * 1e6, t_rule * 1e6, res.best_cost * 1e6))
    if verbose:
        print("arch,no_fusion_us,rule_based_us,disco_search_us")
        for r in rows:
            print(csv_row(r[0], *[f"{x:.2f}" for x in r[1:]]))
    return rows


if __name__ == "__main__":
    run()

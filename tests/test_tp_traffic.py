"""First-class TP activation traffic + searched pipeline knobs
(DESIGN.md Sec. 14): dep-coupling, byte conservation against the legacy
background model, legacy bit-identity, Plan v3 round-trips, warm-start
resets and per-level chunk conservation."""
import json
import random

import pytest
from _propcheck import given, settings, st

from repro.cluster import (chunk_phases, get_preset, level_chunk_phases,
                           COLLECTIVE_ALGOS)
from repro.core import (BackgroundTraffic, ComputeJob, FusionGraph,
                        PipelineSchedule, Simulator, TPTraffic,
                        balanced_spans, couple_tp, couple_tp_pipeline,
                        resolve_schedule, METHOD_PP_INTERLEAVE,
                        METHOD_PP_MICROBATCH, METHOD_PP_SPLIT,
                        active_methods, random_apply)
from repro.core.events import EventEngine, CommJob, TC_DP, TC_TP
from repro.core.pipeline import lower_schedule

from test_core_graph import chain_graph
from test_simulator import random_dag

SPEC = get_preset("a100_nvlink_ib")


def chained_compute(n=6, dur=1e-3):
    out, prev = [], None
    for i in range(n):
        j = ComputeJob(ref=i, duration=dur, job_id=-(i + 1), key=(i,),
                       deps=() if prev is None else (prev,))
        prev = j.job_id
        out.append(j)
    return out


# ------------------------------------------------------------- dep coupling
def test_tp_jobs_never_start_before_producer():
    """Every TP job's timeline records start at or after its producing
    compute job's finish — forward AND backward."""
    compute = chained_compute(6)
    tp = TPTraffic(n_layers=3, fwd_bytes=1e6, bwd_bytes=5e5)
    ends = balanced_spans([1e-3 * (i + 1) for i in range(6)], 3)
    coupled, fwd, bwd, _ = couple_tp(compute, ends, tp, 100)
    eng = EventEngine(SPEC, streams=4)
    tl: list = []
    eng.run_unified(coupled, fwd + bwd, tl)
    starts: dict = {}
    for rec in tl:
        if rec[3] == TC_TP:
            jid = rec[1]  # bucket holds the span; find the job by id below
    for job in fwd + bwd:
        first = min(r[6] for r in tl
                    if r[3] == TC_TP and r[1] == job.bucket
                    and r[4] == job.algo)
        producer_fin = eng.job_finish[job.deps[0]]
        assert first >= producer_fin - 1e-15


def test_forward_tp_gates_next_span():
    """Forward activations block downstream compute: the next span's first
    compute job cannot start before the previous span's forward TP job
    completes."""
    compute = chained_compute(4, dur=1e-4)
    tp = TPTraffic(n_layers=2, fwd_bytes=5e7, bwd_bytes=0.0)
    coupled, fwd, bwd, _ = couple_tp(compute, [2, 4], tp, 100)
    assert not bwd
    eng = EventEngine(SPEC, streams=4)
    eng.run_unified(coupled, fwd)
    # span 0 = jobs 0,1; span 1 = jobs 2,3; fwd[0] gates job 2
    assert eng.job_finish[coupled[2].job_id] >= \
        eng.job_finish[fwd[0].job_id] + coupled[2].duration - 1e-15
    # and the makespan strictly exceeds the un-TP'd chain
    eng2 = EventEngine(SPEC, streams=4)
    u2 = eng2.run_unified(chained_compute(4, dur=1e-4), [])
    assert eng.job_finish[coupled[-1].job_id] > u2.compute_finish


@settings(max_examples=20, deadline=None)
@given(n_layers=st.integers(1, 8), fwd=st.integers(0, 1 << 22),
       bwd=st.integers(0, 1 << 22))
def test_byte_conservation_across_lowerings(n_layers, fwd, bwd):
    """Span lowering, pipeline-unit lowering and the background fallback
    all move exactly ``total_bytes``."""
    tp = TPTraffic(n_layers=n_layers, fwd_bytes=float(fwd),
                   bwd_bytes=float(bwd))
    # span lowering over a 2*n_layers-unit chain
    compute = chained_compute(2 * n_layers)
    ends = balanced_spans([1e-3 * (i + 1) for i in range(2 * n_layers)],
                          n_layers)
    _, f_jobs, b_jobs, _ = couple_tp(compute, ends, tp, 100)
    assert sum(j.nbytes for j in f_jobs + b_jobs) == \
        pytest.approx(tp.total_bytes)
    # pipeline-unit lowering
    sched = PipelineSchedule(n_stages=2, n_microbatches=4)
    cjobs, _, _, nid = lower_schedule(sched, [1e-3, 1e-3], [1e-3, 1e-3],
                                      0.0, next_id=0)
    _, tp_jobs, _, _ = couple_tp_pipeline(cjobs, sched, tp, nid)
    assert sum(j.nbytes for j in tp_jobs) == pytest.approx(tp.total_bytes)
    # background fallback (count pins the job count)
    horizon = 1.0
    made = []
    for b in tp.to_background(horizon):
        made.extend(b.materialize(horizon, 0))
    assert sum(j.nbytes for j in made) == pytest.approx(tp.total_bytes)


def test_zero_byte_tp_never_emits_jobs():
    """PR 6's skip rule on the TP path: free legs lower to the untouched
    compute chain, never to zero-byte jobs."""
    compute = chained_compute(6)
    tp0 = TPTraffic(n_layers=3, fwd_bytes=0.0, bwd_bytes=0.0)
    coupled, fwd, bwd, nid = couple_tp(compute, [2, 4, 6], tp0, 100)
    assert coupled == compute and not fwd and not bwd and nid == 100
    sched = PipelineSchedule(n_stages=2, n_microbatches=4)
    cjobs, _, _, n0 = lower_schedule(sched, [1e-3] * 2, [1e-3] * 2, 0.0)
    out, tp_jobs, gate, nid = couple_tp_pipeline(cjobs, sched, tp0, n0)
    assert out == cjobs and not tp_jobs and gate is None and nid == n0


def test_zero_byte_tp_sim_bit_identical():
    """Simulator(tp=<all-zero>) prices bit-identically to tp=None and
    emits no tp-class timeline records."""
    g = random_dag(7)
    base = Simulator(cluster=SPEC, streams=4, keep_timeline=True)
    r0 = base.run(g)
    simz = Simulator(cluster=SPEC, streams=4, keep_timeline=True,
                     tp=TPTraffic(n_layers=4, fwd_bytes=0.0, bwd_bytes=0.0))
    rz = simz.run(g)
    assert rz.iteration_time == r0.iteration_time
    assert rz.comm_time == r0.comm_time
    assert not [e for e in (rz.timeline or []) if e[3] == TC_TP]


def test_tp_volume_matches_background_model_tally():
    """On the same graph the dep-coupled sim's tp-class busy time equals
    the background sim's tp-class busy time when volumes match — the
    engine prices identical bytes, only the schedule differs."""
    g = random_dag(3)
    tp = TPTraffic(n_layers=4, fwd_bytes=1e6)
    sim = Simulator(cluster=SPEC, streams=4)
    r = Simulator(cluster=SPEC, streams=4, tp=tp).run(g)
    assert r.tp is not None and r.tp["mode"] == "span"
    horizon = sim.run(g).compute_time
    rb = Simulator(cluster=SPEC, streams=4,
                   background=tp.to_background(horizon)).run(g)
    # both engines moved the same tp bytes through the same phase models
    assert r.tp["tp_busy_s"] == pytest.approx(
        sum(b.nbytes for bt in tp.to_background(horizon)
            for b in bt.materialize(horizon, 0)) / tp.total_bytes
        * r.tp["tp_busy_s"])


# --------------------------------------------------------- quiet-window win
def test_quiet_window_dep_coupling_beats_blind_background():
    """A DP bucket ready at t=0 with all TP traffic actually produced
    *later* (dep-coupled): the blind periodic model (offset 0) contends
    with the bucket immediately and finishes the DP class later than the
    dep-coupled schedule, which knows the early window is quiet."""
    compute = chained_compute(4, dur=5e-3)
    tp = TPTraffic(n_layers=2, fwd_bytes=3e7, bwd_bytes=3e7)
    coupled, fwd, bwd, nid = couple_tp(compute, [2, 4], tp, 100)
    dp = CommJob(bucket=0, ready=0.0, nbytes=3e7, traffic_class=TC_DP)
    eng_aware = EventEngine(SPEC, streams=4)
    eng_aware.run_unified(coupled, [dp] + fwd + bwd)
    aware_fin = eng_aware.class_finish[TC_DP]
    horizon = 4 * 5e-3
    bg = []
    base = nid
    for b in tp.to_background(horizon):
        made = b.materialize(horizon, base)
        base += len(made)
        bg.extend(made)
    eng_blind = EventEngine(SPEC, streams=4)
    eng_blind.run_unified(list(compute), [dp] + bg)
    blind_fin = eng_blind.class_finish[TC_DP]
    assert aware_fin < blind_fin


# ------------------------------------------------- searched pipeline knobs
def test_pp_mutations_gated_by_pipeline():
    """pp_* methods are offered only on pipeline-enabled sims; the default
    method tuple on non-pipeline sims is exactly the legacy one."""
    flat = Simulator(n_devices=64)
    engine = Simulator(cluster=SPEC, streams=4)
    piped = Simulator(cluster=SPEC, streams=4,
                      pipeline=PipelineSchedule(4, 8))
    for sim in (flat, engine):
        ms = active_methods(sim)
        assert METHOD_PP_SPLIT not in ms
        assert METHOD_PP_MICROBATCH not in ms
        assert METHOD_PP_INTERLEAVE not in ms
    ms = active_methods(piped)
    assert {METHOD_PP_SPLIT, METHOD_PP_MICROBATCH,
            METHOD_PP_INTERLEAVE} <= set(ms)


def test_pp_mutations_journaled_and_incremental_consistent():
    """pp journal records on a NON-pipeline sim: incremental re-pricing
    equals full re-pricing (the knobs are inert there), and signatures
    shift."""
    g = chain_graph(n=12, grads=(3, 6, 9))
    sim_inc = Simulator(cluster=SPEC, streams=4, incremental=True)
    sim_full = Simulator(cluster=SPEC, streams=4, incremental=False)
    c0 = sim_inc.cost(g)
    sig0 = g.signature()
    rng = random.Random(0)
    assert random_apply(g, METHOD_PP_SPLIT, 3, rng)
    assert g.signature() != sig0
    assert g.signature()[7] is not None
    assert sim_inc.cost(g) == sim_full.cost(g) == c0
    # reset journals back
    assert g.reset_pp_knobs()
    assert g.signature()[7] is None


def test_pp_knobs_clone_and_from_parts_round_trip():
    g = chain_graph(n=8)
    g.set_pp_knobs(n_stages=2, interleave=2)
    c = g.clone()
    assert c.pp_knobs == (2, None, 2)
    assert c.fast_signature() == g.fast_signature()
    g2 = FusionGraph._from_parts(
        g.prims, g.psuccs, g.ppreds, g.groups, g.provider, g._next_gid,
        g.grad_prim, list(g.buckets), family=g.family_token(),
        pp_knobs=g.pp_knobs)
    assert g2.pp_knobs == (2, None, 2)


def test_resolve_schedule_clamps_and_preserves_base():
    base = PipelineSchedule(n_stages=4, n_microbatches=8)
    assert resolve_schedule(None, (2, 4, 1), 8) is None
    assert resolve_schedule(base, None, 8) is base
    r = resolve_schedule(base, (2, 16, None), 8)
    assert (r.n_stages, r.n_microbatches, r.interleave) == (2, 16, 1)
    assert r.fwd_bwd_ratio == base.fwd_bwd_ratio
    # stage count clamps to the group count
    r = resolve_schedule(base, (8, None, None), 3)
    assert r.n_stages == 3
    # interleave collapses where Megatron divisibility fails (M % S != 0)
    r = resolve_schedule(base, (3, 8, 2), 8)
    assert r.interleave == 1 and r.schedule == "1f1b"
    r = resolve_schedule(base, (4, 8, 2), 8)
    assert r.interleave == 2 and r.schedule == "interleaved_1f1b"
    # no-op overrides return the base object itself
    assert resolve_schedule(base, (4, 8, 1), 8) is base


def test_pp_knobs_change_pipeline_price():
    """A searched stage-count override changes the pipeline pricing (the
    knob is live, not inert, on pipeline-enabled sims)."""
    g = chain_graph(n=16, grads=(3, 7, 11))
    sim = Simulator(cluster=SPEC, streams=4,
                    pipeline=PipelineSchedule(4, 8))
    c_base = sim.cost(g)
    g2 = g.clone()
    g2.set_pp_knobs(n_stages=2)
    assert sim.cost(g2) != c_base
    r = sim.run(g2)
    assert r.pipeline["n_stages"] == 2
    assert r.pipeline["pp_knobs"] == (2, None, None)


# ----------------------------------------------------------------- plan v3
def test_plan_v3_round_trip_pp_knobs_and_tp():
    g = chain_graph(n=12, grads=(3, 6, 9))
    g.set_pp_knobs(n_stages=2, n_microbatches=16)
    tp = TPTraffic(n_layers=4, fwd_bytes=2e6)
    sim = Simulator(cluster=SPEC, streams=4,
                    pipeline=PipelineSchedule(4, 8), tp=tp)
    from repro.plan import Plan

    plan = Plan.from_graph(g, sim=sim)
    assert plan.version == 3
    assert plan.pp_knobs == (2, 16, None)
    assert plan.tp == tp.to_tuple()
    d = json.loads(json.dumps(plan._to_json()))
    plan2 = Plan.from_dict(d)
    assert plan2 == plan
    g2 = plan2.to_graph(chain_graph(n=12, grads=(3, 6, 9)))
    assert g2.pp_knobs == (2, 16, None)
    assert sim.cost(g2) == sim.cost(g)
    sim2 = plan2.simulator()
    assert sim2.tp == tp
    assert sim2.cost(g2) == sim.cost(g)


def test_plan_v1_v2_load_with_defaults():
    """Pre-v3 artifacts load with pp_knobs/tp/level_chunks defaulted and
    re-price exactly."""
    g = chain_graph(n=12, grads=(3, 6, 9))
    sim = Simulator(cluster=SPEC, streams=4)
    from repro.plan import Plan

    plan = Plan.from_graph(g, sim=sim)
    d = json.loads(json.dumps(plan._to_json()))
    for k in ("pp_knobs", "tp", "level_chunks"):
        d.pop(k)
    d["version"] = 2
    p2 = Plan.from_dict(d)
    assert p2.pp_knobs is None and p2.tp is None and not p2.level_chunks
    assert p2.to_graph(chain_graph(n=12, grads=(3, 6, 9))).pp_knobs is None
    assert p2.simulator().cost(g) == sim.cost(g)
    d["version"] = 1
    assert Plan.from_dict(d).pp_knobs is None


def test_plan_strategy_fingerprint_stable_without_pp_knobs():
    """Plans that never touched the pipeline knobs keep their historical
    strategy fingerprints; setting knobs changes the fingerprint."""
    g = chain_graph(n=12, grads=(3, 6, 9))
    sim = Simulator(cluster=SPEC, streams=4)
    from repro.plan import Plan

    f0 = Plan.from_graph(g, sim=sim).strategy_fingerprint()
    g.set_pp_knobs(n_stages=2)
    f1 = Plan.from_graph(g, sim=sim).strategy_fingerprint()
    assert f0 != f1


def test_warm_start_resets_pp_knobs_on_non_pipeline_target():
    """A donor plan searched with pipeline knobs warm-starts a
    non-pipeline sim with the knobs reset (inert state stripped), and a
    pipeline sim with them retained."""
    from repro.plan import Plan
    from repro.plan.cache import warm_start_state

    g = chain_graph(n=12, grads=(3, 6, 9))
    g.set_pp_knobs(n_stages=2)
    donor_sim = Simulator(cluster=SPEC, streams=4,
                          pipeline=PipelineSchedule(4, 8))
    plan = Plan.from_graph(g, sim=donor_sim)
    base = chain_graph(n=12, grads=(3, 6, 9))
    flat = warm_start_state(plan, base, Simulator(cluster=SPEC, streams=4))
    assert flat is not None and flat.pp_knobs is None
    piped = warm_start_state(plan, base, donor_sim)
    assert piped is not None and piped.pp_knobs == (2, None, None)


def test_cache_context_digest_unchanged_without_tp():
    """tp=None / level_chunks=False sims produce the exact pre-v3 context
    parts (no new keys) so historical cache keys survive."""
    from repro.plan.cache import _context_parts

    parts = _context_parts(Simulator(cluster=SPEC, streams=4))
    assert "tp" not in parts and "level_chunks" not in parts
    parts2 = _context_parts(Simulator(
        cluster=SPEC, streams=4, tp=TPTraffic(n_layers=2, fwd_bytes=1.0),
        level_chunks=True))
    assert parts2["tp"] == [2, 1.0, None, "ring", "ar"]
    assert parts2["level_chunks"] is True


# ------------------------------------------------------- per-level chunking
@settings(max_examples=30, deadline=None)
@given(algo=st.sampled_from(COLLECTIVE_ALGOS), chunks=st.integers(2, 8),
       kind=st.sampled_from(["ar", "rs_ag"]))
def test_level_chunk_conservation(algo, chunks, kind):
    """Summed over all chunk indices, the per-level decomposition's (c, d)
    equal the uniform chunking's exactly — coalescing is pure scheduling."""
    base = chunk_phases(SPEC, algo, kind, chunks)
    tot_c = sum(p.c for p in base) * chunks
    tot_d = sum(p.d for p in base) * chunks
    lc_c = sum(p.c for k in range(chunks)
               for p in level_chunk_phases(SPEC, algo, kind, chunks, k))
    lc_d = sum(p.d for k in range(chunks)
               for p in level_chunk_phases(SPEC, algo, kind, chunks, k))
    assert lc_c == pytest.approx(tot_c, rel=1e-12, abs=0.0)
    assert lc_d == pytest.approx(tot_d, rel=1e-12, abs=1e-18)
    # phase sequence shape is untouched (levels and kinds align)
    for k in range(chunks):
        lk = level_chunk_phases(SPEC, algo, kind, chunks, k)
        assert [(p.kind, p.level) for p in lk] == \
            [(p.kind, p.level) for p in base]


def test_level_chunks_engine_conserves_busy():
    """The engine's total channel busy time is identical with and without
    per-level chunk sizing (only the schedule moves)."""
    from repro.core.events import bucket_jobs

    jobs = []
    nid = 10
    for i in range(4):
        js, nid = bucket_jobs(i, 0.0, 5e6, "hier", "ar", 8, nid)
        jobs.extend(js)
    b0, _ = EventEngine(SPEC, streams=4).run(list(jobs))
    bl, _ = EventEngine(SPEC, streams=4, level_chunks=True).run(list(jobs))
    assert bl == pytest.approx(b0, rel=1e-12)


def test_level_chunks_off_is_bit_identical():
    """level_chunks=False (the default) prices chunked strategies exactly
    as before."""
    g = chain_graph(n=12, grads=(3, 6, 9))
    for i in range(len(g.buckets)):
        g.set_bucket_chunks(i, 4)
        g.set_bucket_algo(i, "hier")
    assert Simulator(cluster=SPEC, streams=4).cost(g) == \
        Simulator(cluster=SPEC, streams=4, level_chunks=False).cost(g)


def test_flat_spec_level_chunks_noop():
    """Flat compat specs have one opaque phase — nothing to coalesce."""
    flat = Simulator(n_devices=64).cluster
    for k in range(4):
        assert level_chunk_phases(flat, "ring", "ar", 4, k) == \
            chunk_phases(flat, "ring", "ar", 4)


# ----------------------------------------------------------- search plumbing
def test_search_pool_state_round_trips_pp_knobs():
    """The worker-pool state tuple carries pp_knobs through _from_parts."""
    g = chain_graph(n=12, grads=(3, 6, 9))
    g.set_pp_knobs(n_microbatches=16)
    state = (g.groups, g.provider, g._next_gid, g.buckets, g.bucket_algos,
             g.bucket_comm, g.bucket_chunks, g.bucket_fused, g.pp_knobs)
    g2 = FusionGraph._from_parts(
        g.prims, g.psuccs, g.ppreds, state[0], state[1], state[2],
        g.grad_prim, state[3], family=g.family_token(),
        bucket_algos=state[4], bucket_comm=state[5], bucket_chunks=state[6],
        bucket_fused=state[7], pp_knobs=state[8])
    assert g2.pp_knobs == (None, 16, None)
    assert g2.fast_signature() == g.fast_signature()


def test_search_on_pipeline_sim_explores_pp_knobs():
    """A short search on a pipeline-enabled sim draws pp mutations and
    never crashes; the winner prices no worse than the start."""
    from repro.core import backtracking_search

    g = chain_graph(n=16, grads=(3, 7, 11))
    sim = Simulator(cluster=SPEC, streams=4,
                    pipeline=PipelineSchedule(4, 8), incremental=False)
    # pp methods only: op fusion could legally collapse this EW chain
    # below n_stages (a ValueError by contract, see
    # test_too_many_stages_raises) — that interaction is not under test.
    res = backtracking_search(
        g, sim, unchanged_limit=10, max_steps=30, seed=0,
        methods=(METHOD_PP_SPLIT, METHOD_PP_MICROBATCH,
                 METHOD_PP_INTERLEAVE))
    assert res.best_cost <= res.initial_cost

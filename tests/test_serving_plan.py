"""Serving-plan subsystem tests (DESIGN.md Sec. 15) — import-light by
design (no jax): the trace generator, the decode-step lowering, the
``ServingPlan`` artifact, and the serving search are priced entirely on
the event engine, so these tests run on a bare interpreter the same way
the search worker pool does.

* trace generator: seeded reproducibility (bit-identical across calls and
  instances), arrival-count conservation, monotone timestamps, range
  respect, digest discrimination;
* ``ServingPlan``: JSON round-trip bit-identity, foreign-schema /
  foreign-version -> ``PlanVersionError``, malformed -> ``PlanError``,
  and the training loader rejecting serving JSON (no silent cross-load);
* decode lowering: the priced TP traffic conserves the bytes of the
  ``TPTraffic`` model it lowers — and matches what the *training*
  ``couple_tp`` lowering emits for the same traffic;
* search: the searched plan never prices worse than the default
  ``ServingState`` (the search starts there), checked on >= 2 presets;
* cache: ``ServingPlan`` round-trips through ``PlanCache`` next to
  training plans, never warm-starts a training search, and the warm
  compile is a zero-simulation hit.
"""
import json
import math
import os
import tempfile

import pytest
from _propcheck import given, settings, st

from repro.cluster import get_preset
from repro.configs import get_config
from repro.core import backtracking_search
from repro.core.events import ComputeJob
from repro.core.mutations import SERVING_METHODS
from repro.core.tp_traffic import couple_tp
from repro.plan import PlanCache, PlanError, PlanVersionError
from repro.plan.cache import _load_artifact, warm_start_state
from repro.serving.plan import (DecodeModel, ServingPlan, ServingSimulator,
                                ServingState, compile_serving,
                                kv_shard_factor)
from repro.serving.workload import TraceRequest, VirtualClock, Workload


# ------------------------------------------------------------------ trace
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 64))
def test_trace_seeded_reproducible(seed, n):
    a = Workload(n_requests=n, seed=seed)
    b = Workload(n_requests=n, seed=seed)
    assert a.requests() == b.requests()
    assert a.digest() == b.digest()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_trace_conservation_and_monotone(seed):
    wl = Workload(n_requests=32, prompt_lens=(2, 9), new_tokens=(1, 5),
                  seed=seed)
    reqs = wl.requests()
    assert len(reqs) == wl.n_requests
    assert [r.rid for r in reqs] == list(range(wl.n_requests))
    last = 0.0
    for r in reqs:
        assert r.arrival_s >= last      # Poisson arrivals never go back
        last = r.arrival_s
        assert 2 <= r.prompt_len <= 9
        assert 1 <= r.new_tokens <= 5
    assert wl.total_new_tokens == sum(r.new_tokens for r in reqs)
    fr = wl.arrival_fractions()
    assert len(fr) == wl.n_requests and all(0.0 <= f <= 1.0 for f in fr)


def test_trace_digest_discriminates():
    base = Workload(seed=0)
    assert base.digest() != Workload(seed=1).digest()
    assert base.digest() != Workload(rate=16.0).digest()
    assert base.digest() != Workload(concurrency=8).digest()
    assert Workload.from_tuple(base.to_tuple()) == base


def test_workload_validation():
    with pytest.raises(ValueError):
        Workload(n_requests=0)
    with pytest.raises(ValueError):
        Workload(rate=0.0)
    with pytest.raises(ValueError):
        Workload(prompt_lens=(5, 2))


def test_virtual_clock():
    clk = VirtualClock()
    assert clk() == 0.0
    clk.advance(1.5)
    assert clk() == 1.5
    with pytest.raises(ValueError):
        clk.advance(-0.1)


# ------------------------------------------------------------- the artifact
def _small_plan(cluster="tpu_v5e_pod_16", seed=0, cache=None):
    return compile_serving(
        "tinyllama-1.1b", cluster=cluster,
        workload=Workload(n_requests=24, seed=3),
        unchanged_limit=10, max_steps=20, seed=seed, cache=cache)


def test_serving_plan_roundtrip_bit_identity(tmp_path):
    plan = _small_plan()
    path = os.path.join(tmp_path, "sp.json")
    plan.save(path)
    loaded = ServingPlan.load(path)
    assert loaded == plan
    assert loaded.fingerprint() == plan.fingerprint()
    # a second save of the loaded artifact is byte-identical (canonical)
    path2 = os.path.join(tmp_path, "sp2.json")
    loaded.save(path2)
    with open(path) as a, open(path2) as b:
        assert a.read() == b.read()


def test_serving_plan_foreign_versions(tmp_path):
    plan = _small_plan()
    d = plan._to_json()
    bad_schema = dict(d, schema="repro.other_plan")
    with pytest.raises(PlanVersionError):
        ServingPlan.from_dict(bad_schema)
    bad_version = dict(d, version=999)
    with pytest.raises(PlanVersionError):
        ServingPlan.from_dict(bad_version)
    with pytest.raises(PlanError):
        ServingPlan.from_dict({"schema": "repro.serving_plan", "version": 1})
    # the *training* loader must reject serving JSON, not mis-parse it
    from repro.plan import Plan
    with pytest.raises(PlanError):
        Plan.from_dict(d)
    # unreadable path -> PlanError, not OSError
    with pytest.raises(PlanError):
        ServingPlan.load(os.path.join(tmp_path, "missing.json"))
    torn = os.path.join(tmp_path, "torn.json")
    with open(torn, "w") as f:
        f.write(json.dumps(d)[: len(json.dumps(d)) // 2])
    with pytest.raises(PlanError):
        ServingPlan.load(torn)


def test_cluster_mismatch_reprice():
    plan = _small_plan()
    other = get_preset("a100_nvlink_ib")
    from repro.plan import ClusterMismatchError
    with pytest.raises(ClusterMismatchError):
        plan.simulator(cluster=other)
    # price() reports instead of raising
    p = plan.price(cluster=other)
    assert p["cluster_fingerprint_match"] is False
    assert plan.price()["cluster_fingerprint_match"] is True


# -------------------------------------------------------- decode lowering
def _sim(preset="tpu_v5e_pod_16"):
    model = DecodeModel.from_config(get_config("tinyllama-1.1b"))
    return ServingSimulator(model, Workload(n_requests=24, seed=3),
                            get_preset(preset))


@pytest.mark.parametrize("layout", ("replicated", "head", "sequence"))
@pytest.mark.parametrize("algo", ("ring", "hier"))
def test_decode_lowering_byte_conservation(layout, algo):
    sim = _sim()
    state = ServingState(kv_layout=layout, algo=algo)
    tpt = sim.decode_tp(state)
    price = sim.price(state)
    assert price["feasible"]
    # every byte of the decode TP model lands in the engine's TP jobs
    assert math.isclose(price["tp_bytes_decode"], tpt.total_bytes,
                        rel_tol=1e-9)
    assert price["tp_bytes_total"] == tpt.total_bytes


def test_decode_lowering_matches_training_couple_tp():
    """The decode lowering reuses the *training* dep-coupled TP lowering
    at token granularity: feeding the decode step's TPTraffic through
    ``couple_tp`` over an equivalent compute chain must emit exactly the
    bytes the serving price reports."""
    sim = _sim()
    state = ServingState()
    tpt = sim.decode_tp(state)
    chain = [ComputeJob(ref=i, duration=1e-6, job_id=-(i + 1), key=i)
             for i in range(tpt.n_layers)]
    ends = list(range(1, tpt.n_layers + 1))
    _, fwd, bwd, _ = couple_tp(chain, ends, tpt, next_id=1)
    assert bwd == []        # decode has no backward traffic
    emitted = sum(j.nbytes for j in fwd)
    assert math.isclose(emitted, sim.price(state)["tp_bytes_decode"],
                        rel_tol=1e-9)


def test_tp1_is_commfree_but_feasible():
    model = DecodeModel.from_config(get_config("tinyllama-1.1b"))
    sim = ServingSimulator(model, Workload(n_requests=24, seed=3),
                           get_preset("tpu_v5e_pod_16"), tp_degree=1)
    p = sim.price(ServingState())
    assert p["feasible"] and p["tp_bytes_decode"] == 0.0
    assert p["seconds_per_token"] > 0.0


def test_infeasible_memory_prices_inf():
    model = DecodeModel.from_config(get_config("tinyllama-1.1b"))
    sim = ServingSimulator(model, Workload(n_requests=24, seed=3),
                           get_preset("tpu_v5e_pod_16"), hbm_bytes=1e6)
    p = sim.price(ServingState())
    assert not p["feasible"]
    assert p["seconds_per_token"] == float("inf")
    assert p["tokens_per_s"] == 0.0


def test_kv_shard_factor():
    # head layout hits the GQA wall: shards cap at n_kv_heads
    assert kv_shard_factor("head", 8, 4) == pytest.approx(0.25)
    assert kv_shard_factor("sequence", 8, 4) == pytest.approx(0.125)
    assert kv_shard_factor("replicated", 8, 4) == 1.0
    with pytest.raises(ValueError):
        kv_shard_factor("bogus", 8, 4)


# ------------------------------------------------------------------ search
@pytest.mark.parametrize("preset", ("tpu_v5e_pod_16", "a100_nvlink_ib"))
def test_searched_never_worse_than_default(preset):
    sim = _sim(preset)
    default = ServingState()
    d_cost = sim.cost(default)
    res = backtracking_search(default, sim, methods=SERVING_METHODS,
                              unchanged_limit=15, max_steps=40, seed=0)
    assert res.best_cost <= d_cost * (1 + 1e-9)
    assert res.initial_cost == d_cost
    # the best state is a ServingState the engine could enact
    assert isinstance(res.best, ServingState)
    assert sim.price(res.best)["feasible"]


def test_search_is_deterministic():
    sim = _sim()
    r1 = backtracking_search(ServingState(), sim, methods=SERVING_METHODS,
                             unchanged_limit=10, max_steps=25, seed=7)
    r2 = backtracking_search(ServingState(), sim, methods=SERVING_METHODS,
                             unchanged_limit=10, max_steps=25, seed=7)
    assert r1.best.signature() == r2.best.signature()
    assert r1.best_cost == r2.best_cost


# ------------------------------------------------------------------- cache
def test_serving_plan_through_plan_cache(tmp_path):
    cache = PlanCache(os.path.join(tmp_path, "cache"))
    plan = _small_plan()
    cache.put("servekey", plan, {"schema": "repro.serving_plan",
                                 "graph": "serving:x", "cluster": "c",
                                 "arch": "tinyllama-1.1b"})
    got = cache.get("servekey")
    assert isinstance(got, ServingPlan)
    assert got == plan and got.fingerprint() == plan.fingerprint()
    v = cache.verify()
    assert v["ok"] == 1 and not v["corrupt"]
    # a serving artifact never warm-starts a training search
    assert warm_start_state(plan, base=None, sim=None) is None
    # direct loader dispatch
    art = _load_artifact(cache._plan_path("servekey"))
    assert isinstance(art, ServingPlan)


def test_compile_serving_cache_hit_zero_search(tmp_path):
    cachedir = os.path.join(tmp_path, "cache")
    p1 = _small_plan(cache=cachedir)
    p2 = _small_plan(cache=cachedir)
    assert p1.provenance["cache"]["outcome"] == "miss"
    assert p2.provenance["cache"]["outcome"] == "hit"
    assert p1 == p2 and p1.fingerprint() == p2.fingerprint()
    # different workload -> different key (the digest joins the key)
    p3 = compile_serving("tinyllama-1.1b", cluster="tpu_v5e_pod_16",
                         workload=Workload(n_requests=24, seed=4),
                         unchanged_limit=10, max_steps=20, seed=0,
                         cache=cachedir)
    assert p3.provenance["cache"]["outcome"] == "miss"
    assert p3.provenance["cache"]["key"] != p1.provenance["cache"]["key"]

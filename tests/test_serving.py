"""Serving-engine tests: continuous-batching correctness vs offline
decode, plus the plan-enactment surface added in DESIGN.md Sec. 15 —
chunked (gathered) decode dispatch, injected virtual clock, per-request
metrics, and ``ServeEngine(plan=...)``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import stacked as ST
from repro.serving import Request, ServeEngine, VirtualClock, Workload, replay


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def offline_greedy(params, cfg, prompt, n, cache_len=64):
    lg, caches = ST.prefill(params, cfg, jnp.asarray(prompt)[None], cache_len)
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, caches = ST.decode_step(
            params, cfg, caches, jnp.asarray([toks[-1]], jnp.int32),
            jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def test_engine_matches_offline(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, max_slots=3, cache_len=64)
    rng = np.random.default_rng(1)
    reqs = []
    for i in range(7):
        p = rng.integers(0, cfg.vocab, size=int(rng.integers(3, 16)))
        r = Request(rid=i, prompt=p.astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 12)))
        reqs.append(r)
        eng.submit(r)
    done = eng.run_to_completion()
    assert len(done) == 7
    for r in sorted(done, key=lambda r: r.rid):
        ref = offline_greedy(params, cfg, r.prompt, len(r.output))
        assert r.output == ref, f"request {r.rid} diverged"


def test_engine_slot_reuse(setup):
    """More requests than slots: slots must be recycled correctly."""
    cfg, params = setup
    eng = ServeEngine(params, cfg, max_slots=2, cache_len=48)
    rng = np.random.default_rng(2)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, size=5).astype(
                               np.int32),
                           max_new_tokens=4))
    done = eng.run_to_completion()
    s = eng.stats()
    assert s["completed"] == 5
    assert s["tokens"] == 5 * 4
    # with 2 slots and 5 requests of 4 tokens, decode steps must exceed 4
    assert s["decode_steps"] >= 8


def test_engine_eos_stops(setup):
    cfg, params = setup
    # find the first greedily generated token, use it as eos -> length 1
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    first = offline_greedy(params, cfg, prompt, 2)
    eng = ServeEngine(params, cfg, max_slots=1, cache_len=48)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=16,
                       eos_id=first[1]))
    done = eng.run_to_completion()
    assert done[0].output[-1] == first[1]
    assert len(done[0].output) == 2


def test_request_timing_none_until_finished(setup):
    cfg, params = setup
    r = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=4)
    # unfinished requests report None, never a nonsense 0/negative
    assert r.ttft is None and r.latency is None
    eng = ServeEngine(params, cfg, max_slots=1, cache_len=48,
                      clock=VirtualClock())
    eng.submit(r)
    assert r.submitted_at == 0.0 and r.ttft is None
    eng.run_to_completion()
    assert r.ttft is not None and r.latency is not None
    assert r.latency >= r.ttft >= 0.0


def test_chunked_dispatch_matches_offline(setup):
    """decode_batch < max_slots takes the gathered-chunk decode path; the
    generated tokens must be bit-identical to the full-width engine."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 12)))
               for _ in range(5)]
    eng = ServeEngine(params, cfg, max_slots=4, cache_len=64,
                      decode_batch=2)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.astype(np.int32),
                           max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == 5
    for r in sorted(done, key=lambda r: r.rid):
        ref = offline_greedy(params, cfg, r.prompt, len(r.output))
        assert r.output == ref, f"request {r.rid} diverged (chunked path)"


def test_plan_enactment_and_metrics(setup):
    cfg, params = setup
    from repro.serving.plan import compile_serving

    plan = compile_serving("tinyllama-1.1b", cluster="tpu_v5e_pod_16",
                           workload=Workload(n_requests=16, seed=0),
                           unchanged_limit=8, max_steps=15, seed=0)
    clk = VirtualClock()
    eng = ServeEngine(params, cfg, plan=plan, max_slots=3, cache_len=48,
                      decode_batch=2, clock=clk)
    # explicit kwargs clamp the pod-sized plan onto this host
    assert eng.max_slots == 3 and eng.decode_batch == 2
    assert eng.plan is plan and eng.kv_layout == plan.kv_layout
    wl = Workload(n_requests=5, rate=64.0, concurrency=3,
                  prompt_lens=(3, 6), new_tokens=(2, 4), seed=2)
    m = replay(eng, wl, step_time=1e-3)
    assert m["completed"] == 5
    assert m["tokens"] == sum(r.new_tokens for r in wl.requests()) \
        or m["tokens"] >= m["completed"]  # eos can shorten outputs
    for k in ("tokens_per_s", "ttft_p50_s", "ttft_p99_s", "tpot_p50_s",
              "latency_p50_s", "latency_p99_s", "mean_ttft_s"):
        assert k in m
    assert m["tokens_per_s"] > 0.0
    assert m["latency_p99_s"] >= m["ttft_p50_s"] >= 0.0


def test_replay_is_deterministic(setup):
    cfg, params = setup
    wl = Workload(n_requests=4, rate=64.0, concurrency=2,
                  prompt_lens=(3, 6), new_tokens=(2, 4), seed=5)

    def one():
        eng = ServeEngine(params, cfg, max_slots=2, cache_len=48,
                          clock=VirtualClock())
        return replay(eng, wl, step_time=1e-3)

    assert one() == one()  # virtual time: bit-identical metrics


def test_replay_rejects_wall_clock(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, max_slots=1, cache_len=48)
    with pytest.raises(TypeError):
        replay(eng, Workload(n_requests=2, seed=0))

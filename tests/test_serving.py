"""Serving-engine tests: continuous-batching correctness vs offline decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import stacked as ST
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def offline_greedy(params, cfg, prompt, n, cache_len=64):
    lg, caches = ST.prefill(params, cfg, jnp.asarray(prompt)[None], cache_len)
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, caches = ST.decode_step(
            params, cfg, caches, jnp.asarray([toks[-1]], jnp.int32),
            jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def test_engine_matches_offline(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, max_slots=3, cache_len=64)
    rng = np.random.default_rng(1)
    reqs = []
    for i in range(7):
        p = rng.integers(0, cfg.vocab, size=int(rng.integers(3, 16)))
        r = Request(rid=i, prompt=p.astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 12)))
        reqs.append(r)
        eng.submit(r)
    done = eng.run_to_completion()
    assert len(done) == 7
    for r in sorted(done, key=lambda r: r.rid):
        ref = offline_greedy(params, cfg, r.prompt, len(r.output))
        assert r.output == ref, f"request {r.rid} diverged"


def test_engine_slot_reuse(setup):
    """More requests than slots: slots must be recycled correctly."""
    cfg, params = setup
    eng = ServeEngine(params, cfg, max_slots=2, cache_len=48)
    rng = np.random.default_rng(2)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, size=5).astype(
                               np.int32),
                           max_new_tokens=4))
    done = eng.run_to_completion()
    s = eng.stats()
    assert s["completed"] == 5
    assert s["tokens"] == 5 * 4
    # with 2 slots and 5 requests of 4 tokens, decode steps must exceed 4
    assert s["decode_steps"] >= 8


def test_engine_eos_stops(setup):
    cfg, params = setup
    # find the first greedily generated token, use it as eos -> length 1
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    first = offline_greedy(params, cfg, prompt, 2)
    eng = ServeEngine(params, cfg, max_slots=1, cache_len=48)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=16,
                       eos_id=first[1]))
    done = eng.run_to_completion()
    assert done[0].output[-1] == first[1]
    assert len(done[0].output) == 2

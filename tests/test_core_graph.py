"""Fusion-IR unit + property tests: the three mutation methods preserve the
structural invariants the simulator and search rely on."""
import random

import pytest
from _propcheck import given, settings, st

from repro.core.graph import DOT, EW, FusionGraph, LAYOUT, OPAQUE, PrimOp, REDUCE


def chain_graph(n=8, grads=(3, 6)):
    prims = []
    for i in range(n):
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6,
            grad_param=list(grads).index(i) if i in grads else -1,
            grad_bytes=256.0 if i in grads else 0.0,
            grad_sig="f32" if i in grads else ""))
    edges = [(i, i + 1) for i in range(n - 1)]
    return FusionGraph(prims, edges)


def diamond_graph():
    """0 -> (1, 2) -> 3 : classic duplicate-fusion case."""
    prims = [
        PrimOp(0, "mul", EW, 10, 8, 8, 1e-6),
        PrimOp(1, "add", EW, 10, 8, 8, 1e-6),
        PrimOp(2, "tanh", EW, 10, 8, 8, 1e-6),
        PrimOp(3, "add", EW, 10, 8, 8, 1e-6, grad_param=0, grad_bytes=64,
               grad_sig="f32"),
    ]
    return FusionGraph(prims, [(0, 1), (0, 2), (1, 3), (2, 3)])


def _invariants(g: FusionGraph):
    # every prim has a provider group containing it
    for pid in range(len(g.prims)):
        assert pid in g.groups[g.provider[pid]]
    # quotient is a DAG (topo_groups raises otherwise)
    order = g.topo_groups()
    assert len(order) == len(g.groups)
    # buckets partition the gradient set
    seen = [gp for b in g.buckets for gp in b]
    assert sorted(seen) == sorted(g.grad_prim.keys())


def test_initial_invariants():
    _invariants(chain_graph())
    _invariants(diamond_graph())


def test_nondup_fusion_reduces_groups():
    g = chain_graph()
    n0 = g.n_groups
    assert g.fuse_nondup(1, 0)
    assert g.n_groups == n0 - 1
    _invariants(g)


def test_nondup_fusion_cycle_rejected():
    # 0 -> 1 -> 2 and 0 -> 2: fusing (2, 0) non-dup would trap 1 in a cycle
    prims = [PrimOp(i, "mul", EW, 1, 8, 8, 1e-6) for i in range(3)]
    g = FusionGraph(prims, [(0, 1), (1, 2), (0, 2)])
    assert not g.fuse_nondup(2, 0)
    # duplicate fusion of the same pair IS legal (0 gets recomputed inside)
    g2 = FusionGraph(prims, [(0, 1), (1, 2), (0, 2)])
    assert g2.fuse_dup(2, 0)
    _invariants(g2)


def test_dup_fusion_keeps_provider():
    g = diamond_graph()
    assert g.fuse_dup(1, 0)   # 0 copied into 1's group; provider stays 0
    assert g.provider[0] == 0
    _invariants(g)


def test_opaque_not_fusible():
    prims = [
        PrimOp(0, "scan", OPAQUE, 1, 8, 8, 1e-6),
        PrimOp(1, "mul", EW, 1, 8, 8, 1e-6),
    ]
    g = FusionGraph(prims, [(0, 1)])
    assert not g.fuse_nondup(1, 0)
    assert not g.fuse_dup(1, 0)


def test_bucket_merge_neighbours_only():
    g = chain_graph(grads=(2, 4, 6))
    assert len(g.buckets) == 3
    assert not g.merge_buckets(0, 2)      # not adjacent
    assert g.merge_buckets(0, 1)
    assert len(g.buckets) == 2
    _invariants(g)


def test_bucket_merge_respects_sharding_sig():
    g = chain_graph(grads=(2, 4))
    # forge incompatible signatures
    p = g.prims[2]
    g.prims[2] = PrimOp(p.pid, p.op_type, p.category, p.flops, p.in_bytes,
                        p.out_bytes, p.time, p.grad_param, p.grad_bytes,
                        grad_sig="expert_sharded")
    assert not g.merge_buckets(0, 1)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n_ops=st.integers(1, 60))
def test_random_mutations_preserve_invariants(seed, n_ops):
    from repro.core.search import ALL_METHODS, random_apply

    rng = random.Random(seed)
    g = chain_graph(n=12, grads=(3, 6, 9))
    for _ in range(n_ops):
        random_apply(g, rng.choice(ALL_METHODS), 1, rng)
    _invariants(g)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_clone_isolation(seed):
    rng = random.Random(seed)
    g = chain_graph(n=10, grads=(4, 8))
    sig = g.signature()
    h = g.clone()
    from repro.core.search import ALL_METHODS, random_apply
    for _ in range(20):
        random_apply(h, rng.choice(ALL_METHODS), 1, rng)
    assert g.signature() == sig, "mutating a clone changed the original"

"""Plan artifact + compile() facade + mutation-registry property tests
(DESIGN.md Sec. 10):

* graph <-> plan <-> JSON round-trips are lossless: equal plans, equal
  ``fast_signature()``, equal simulated cost;
* legacy v0 ``strategy.json`` loads through the migration shim; corrupted
  and foreign-version files raise :class:`PlanError`;
* ``plan.simulator()`` reconstructs the exact pricing configuration and
  refuses mismatched clusters;
* the declarative mutation registry reproduces the search's historical
  per-simulator drop rules, and the ``compile()`` facade is
  trajectory-identical to a direct ``backtracking_search``.
"""
import json
import random

import pytest
from _propcheck import given, settings, st

from repro.cluster import ClusterSpec, get_preset
from repro.core import (ALL_METHODS, FusionGraph, MUTATIONS, PrimOp,
                        Simulator, active_methods, backtracking_search,
                        profile_graph, random_apply)
from repro.core.events import BackgroundTraffic
from repro.core.graph import EW
from repro.core.hw import TPU_V5E, Hardware
from repro.core.search import (METHOD_ALGO, METHOD_CHUNK, METHOD_COMM,
                               METHOD_DUP, METHOD_NONDUP, METHOD_TENSOR)
from repro.plan import (ClusterMismatchError, Plan, PlanError,
                        PlanVersionError, cluster_fingerprint, compile_plan)

SPEC = get_preset("a100_nvlink_ib")


def chain_graph(n=16, grads=(3, 6, 9, 12), grad_bytes=float(1 << 20)):
    prims = []
    for i in range(n):
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6,
            grad_param=list(grads).index(i) if i in grads else -1,
            grad_bytes=grad_bytes if i in grads else 0.0,
            grad_sig="f32" if i in grads else ""))
    return profile_graph(FusionGraph(prims, [(i, i + 1) for i in range(n - 1)]))


def mutated(base, seed, n_mut):
    rng = random.Random(seed)
    g = base.clone()
    for _ in range(n_mut):
        random_apply(g, rng.choice(ALL_METHODS), 1, rng)
    return g


# ------------------------------------------------------------- round trips
@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), n_mut=st.integers(0, 16))
def test_plan_graph_roundtrip_lossless(seed, n_mut):
    base = chain_graph()
    sim = Simulator(cluster=SPEC, streams=4)
    g = mutated(base, seed, n_mut)
    p = Plan.from_graph(g, sim=sim)
    g2 = p.to_graph(base)
    assert g2.fast_signature() == g.fast_signature()
    assert sim.cost(g2) == sim.cost(g) == p.predicted_iteration_time
    assert Plan.from_graph(g2, sim=sim) == p


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n_mut=st.integers(0, 16))
def test_plan_json_roundtrip_preserves_identity(seed, n_mut):
    import os
    import tempfile

    base = chain_graph()
    sim = Simulator(cluster=SPEC, streams=2,
                    background=(BackgroundTraffic("tp", 1 << 16, 1e-4),))
    g = mutated(base, seed, n_mut)
    p = Plan.from_graph(g, sim=sim, provenance={"seed": seed})
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.json")
        p.save(path)
        loaded = Plan.load(path)
    assert loaded == p
    assert loaded.fingerprint() == p.fingerprint()
    # the reconstructed pricing configuration reproduces the cost exactly
    sim2 = loaded.simulator()
    assert sim2.streams == 2 and sim2.background == sim.background
    assert sim2.cost(loaded.to_graph(base)) == p.predicted_iteration_time


def test_plan_to_graph_rejects_wrong_trace():
    base = chain_graph()
    p = Plan.from_graph(mutated(base, 1, 8), sim=Simulator(cluster=SPEC))
    with pytest.raises(PlanError):
        p.to_graph(chain_graph(n=20, grads=(3, 7)))


# ------------------------------------------------------- file format guards
def test_legacy_v0_strategy_migration(tmp_path):
    legacy = {"buckets": [[0, 1], [2], [3]], "barriers": True,
              "comms": ["ar", "rs_ag", "ar"]}
    path = str(tmp_path / "strategy.json")
    json.dump(legacy, open(path, "w"))
    p = Plan.load(path)
    assert p.buckets == ((0, 1), (2,), (3,))
    assert p.bucket_comm == ("ar", "rs_ag", "ar")
    assert p.barriers is True
    assert p.provenance["migrated_from"] == "v0 strategy.json"
    strat = p.grad_sync()
    assert strat.buckets == [[0, 1], [2], [3]]
    assert strat.comms == ["ar", "rs_ag", "ar"]
    assert strat.barriers is True
    # bucket-only artifact: it enacts, but cannot be re-priced
    with pytest.raises(PlanError):
        p.price(cluster=SPEC)
    # ... and re-applies its buckets onto a compatible base graph
    g = p.to_graph(chain_graph(grads=(1, 2, 5, 7)))
    assert [tuple(b) for b in g.buckets] == [(0, 1), (2,), (3,)]


def test_corrupt_and_foreign_files_raise(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(PlanError):
        Plan.load(str(bad))
    foreign = tmp_path / "foreign.json"
    json.dump({"schema": "somebody.else", "version": 1},
              open(foreign, "w"))
    with pytest.raises(PlanVersionError):
        Plan.load(str(foreign))
    p = Plan.from_graph(chain_graph(), sim=Simulator(cluster=SPEC))
    d = p._to_json()
    d["version"] = 99
    future = tmp_path / "future.json"
    json.dump(d, open(future, "w"))
    with pytest.raises(PlanVersionError):
        Plan.load(str(future))
    mangled = p._to_json()
    del mangled["provider"]
    broken = tmp_path / "broken.json"
    json.dump(mangled, open(broken, "w"))
    with pytest.raises(PlanError):
        Plan.load(str(broken))
    # truncated per-bucket vectors must fail at load, not silently drop
    # strategy at enactment
    for field in ("bucket_comm", "bucket_algos", "bucket_chunks",
                  "bucket_bytes"):
        trunc = p._to_json()
        trunc[field] = trunc[field][:-1]
        path = tmp_path / f"trunc_{field}.json"
        json.dump(trunc, open(path, "w"))
        with pytest.raises(PlanError):
            Plan.load(str(path))
    legacy_short = tmp_path / "legacy_short.json"
    json.dump({"buckets": [[0], [1], [2]], "chunks": [1]},
              open(legacy_short, "w"))
    with pytest.raises(PlanError):
        Plan.load(str(legacy_short))


def test_simulator_restores_custom_hardware():
    # the oracle's fused-op times depend on the Hardware, not just the
    # cluster — a plan searched under a non-default hw must re-price
    # identically after a save/load round trip
    import os
    import tempfile

    hw = Hardware(name="slow-chip", peak_flops=10e12, hbm_bw=100e9)
    base = chain_graph()
    for sim in (Simulator(hw=hw, n_devices=32),
                Simulator(hw=hw, cluster=SPEC, streams=4)):
        g = mutated(base, 13, 10)
        p = Plan.from_graph(g, sim=sim)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "p.json")
            p.save(path)
            loaded = Plan.load(path)
        sim2 = loaded.simulator()
        assert sim2.hw == hw
        assert sim2.cost(loaded.to_graph(base)) \
            == p.predicted_iteration_time


def test_strategy_fingerprint_ignores_pricing_context():
    g = mutated(chain_graph(), 9, 10)
    p_a = Plan.from_graph(g, sim=Simulator(cluster=SPEC, streams=4))
    p_b = Plan.from_graph(g, sim=Simulator(
        cluster=get_preset("h100_superpod"), streams=2))
    # same searched strategy -> same strategy fingerprint, even though the
    # full artifact identity (pricing context included) differs
    assert p_a.strategy_fingerprint() == p_b.strategy_fingerprint()
    assert p_a.fingerprint() != p_b.fingerprint()
    g2 = g.clone()
    g2.set_bucket_algo(0, "tree" if g.bucket_algos[0] != "tree" else "hier")
    p_c = Plan.from_graph(g2, sim=Simulator(cluster=SPEC, streams=4))
    assert p_c.strategy_fingerprint() != p_a.strategy_fingerprint()


def test_cluster_fingerprint_mismatch():
    p = Plan.from_graph(chain_graph(), sim=Simulator(cluster=SPEC))
    assert p.simulator(cluster=SPEC).cluster is SPEC
    with pytest.raises(ClusterMismatchError):
        p.simulator(cluster=get_preset("h100_superpod"))
    # flat back-compat specs fingerprint through the legacy Hardware
    flat = Simulator(hw=TPU_V5E, n_devices=64)
    pf = Plan.from_graph(chain_graph(), sim=flat)
    spec2 = pf.simulator().cluster
    assert spec2.is_flat_compat and spec2.n_devices == 64
    assert cluster_fingerprint(spec2) == pf.cluster
    other_hw = ClusterSpec.flat(Hardware(name="other", ici_bw=1e9), 64)
    with pytest.raises(ClusterMismatchError):
        pf.simulator(cluster=other_hw)


# --------------------------------------------------------- mutation registry
def test_registry_covers_all_methods():
    assert set(ALL_METHODS) <= set(MUTATIONS)
    for name in ALL_METHODS:
        m = MUTATIONS[name]
        assert m.name == name and callable(m.apply) \
            and callable(m.applicable)
    with pytest.raises(ValueError):
        random_apply(chain_graph(), "no-such-method", 1, random.Random(0))


def test_registered_mutation_is_searched_by_default():
    # the registry contract: a new dimension registers once and the
    # default (methods=None) search picks it up
    from repro.core.mutations import Mutation, register_mutation

    calls = []

    def apply(g, rng):
        calls.append(1)
        return False

    name = "test-extra-dim"
    register_mutation(Mutation(name, apply))
    try:
        sim = Simulator(cluster=SPEC, streams=4)
        assert name in active_methods(sim)
        backtracking_search(chain_graph(), sim, unchanged_limit=5,
                            max_steps=5, seed=0)
        assert calls, "registered mutation was never drawn by the search"
        with pytest.raises(ValueError):
            register_mutation(Mutation(name, apply))  # duplicate name
    finally:
        del MUTATIONS[name]


def test_applicability_reproduces_drop_rules():
    flat = Simulator(n_devices=64)                      # flat back-compat
    ser = Simulator(cluster=SPEC, streams=1)            # serialized channel
    multi = Simulator(cluster=SPEC, streams=4)          # event engine

    class NoCluster:                                    # custom cost stub
        pass

    assert active_methods(flat, ALL_METHODS) == (
        METHOD_NONDUP, METHOD_DUP, METHOD_TENSOR)
    assert active_methods(NoCluster(), ALL_METHODS) == (
        METHOD_NONDUP, METHOD_DUP, METHOD_TENSOR)
    assert active_methods(ser, ALL_METHODS) == (
        METHOD_NONDUP, METHOD_DUP, METHOD_TENSOR, METHOD_ALGO)
    assert active_methods(multi, ALL_METHODS) == ALL_METHODS
    # explicit method subsets keep their order and still get filtered
    assert active_methods(ser, (METHOD_CHUNK, METHOD_TENSOR,
                                METHOD_COMM)) == (METHOD_TENSOR,)


# ------------------------------------------------------------------- facade
@pytest.mark.parametrize("streams", [1, 4])
def test_compile_facade_is_trajectory_identical(streams):
    g0 = chain_graph()
    plan = compile_plan(graph=g0, cluster=SPEC, streams=streams,
                        unchanged_limit=30, max_steps=25, seed=3)
    res = backtracking_search(g0, Simulator(cluster=SPEC, streams=streams),
                              unchanged_limit=30, max_steps=25, seed=3)
    assert plan.predicted_iteration_time == res.best_cost
    assert plan.provenance["simulations"] == res.simulations
    assert plan == Plan.from_graph(
        res.best, sim=Simulator(cluster=SPEC, streams=streams))
    # the artifact lowers the complete searched comm configuration
    strat = plan.grad_sync()
    assert strat.buckets == [list(b) for b in plan.buckets]
    assert strat.comms == list(plan.bucket_comm)
    assert strat.chunks == [int(k) for k in plan.bucket_chunks]


def test_compile_rejects_bad_inputs():
    with pytest.raises(ValueError):
        compile_plan()          # neither cfg nor graph
    with pytest.raises(KeyError):
        compile_plan(graph=chain_graph(), cluster="no_such_preset")
    with pytest.raises(TypeError):
        compile_plan(graph=chain_graph(), cluster=123)


def test_plan_price_matches_serialized_sum():
    g = mutated(chain_graph(), 7, 10)
    sim = Simulator(cluster=SPEC, streams=1)
    p = Plan.from_graph(g, sim=sim)
    from repro.core.costs import total_comm_time

    priced = p.price()
    assert priced["serialized_comm_s"] == pytest.approx(
        total_comm_time(g, cluster=SPEC))
    assert priced["cluster_fingerprint_match"] is True
    override = p.price(cluster=get_preset("h100_superpod"))
    assert override["cluster_fingerprint_match"] is False


def test_plan_price_background_and_stream_override():
    g = mutated(chain_graph(), 5, 8)
    bg = BackgroundTraffic("tp", float(1 << 22), 5e-5)
    sim = Simulator(cluster=SPEC, streams=4, background=(bg,))
    p = Plan.from_graph(g, sim=sim)
    priced = p.price()
    # recorded TP traffic contends with the gradient set, like the sim
    assert "contention" in priced
    assert priced["contention"]["slowdown"] >= 1.0
    assert priced["engine_finish_s"] \
        >= priced["contention"]["grad_finish_alone_s"]
    # an explicit streams=1 forces serialized pricing (no background:
    # the simulator's serialized channel ignores it too)
    ser = p.price(streams=1)
    assert ser["streams"] == 1 and "contention" not in ser

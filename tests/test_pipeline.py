"""1F1B / interleaved-1F1B schedule lowering (DESIGN.md Sec. 11): analytic
makespan and bubble properties on uniform stages, and the simulator's
pipeline pricing distinguishing dep-coupled stage traffic from the blind
background-traffic model."""
import pytest

from repro.cluster import get_preset
from repro.core import (BackgroundTraffic, PipelineSchedule, Simulator,
                        SCHED_1F1B, SCHED_INTERLEAVED)
from repro.core.events import EventEngine
from repro.core.graph import EW, FusionGraph, PrimOp
from repro.core.pipeline import bubble_stats, lower_schedule

SPEC = get_preset("a100_nvlink_ib")


def uniform_makespan(sched, f=1e-3, b=1e-3, p2p_bytes=0.0, streams=4):
    S = sched.n_stages
    cjobs, p2p, last_bwd, _ = lower_schedule(
        sched, [f] * S, [b] * S, p2p_bytes, next_id=0)
    eng = EventEngine(SPEC, streams=streams)
    u = eng.run_unified(cjobs, p2p)
    return u, cjobs, p2p, last_bwd


def test_1f1b_textbook_makespan_and_bubble():
    """Uniform stages, free p2p: makespan (M + S - 1) * (f + b), bubble
    fraction (S - 1) / (M + S - 1)."""
    S, M, f, b = 4, 8, 1e-3, 1e-3
    sched = PipelineSchedule(n_stages=S, n_microbatches=M)
    u, cjobs, p2p, _ = uniform_makespan(sched, f, b)
    assert not p2p  # free transfers lower to direct deps
    assert len(cjobs) == 2 * S * M
    assert u.compute_finish == pytest.approx((M + S - 1) * (f + b))
    bub = bubble_stats(sched, [M * (f + b)] * S, u.compute_finish)
    assert bub["fraction"] == pytest.approx((S - 1) / (M + S - 1))


def test_1f1b_two_stage_hand_check():
    """S=2, M=2, f=b=1: stage 0 runs F0 F1 B0 B1 with a one-unit stall
    before each backward; makespan 6 units."""
    sched = PipelineSchedule(n_stages=2, n_microbatches=2)
    u, _, _, _ = uniform_makespan(sched, 1.0, 1.0)
    assert u.compute_finish == pytest.approx(6.0)


def test_single_stage_degenerates_to_serial():
    """S=1: no boundaries, no bubble — makespan is M * (f + b)."""
    sched = PipelineSchedule(n_stages=1, n_microbatches=5)
    u, _, p2p, _ = uniform_makespan(sched, 2e-3, 3e-3)
    assert not p2p
    assert u.compute_finish == pytest.approx(5 * 5e-3)
    bub = bubble_stats(sched, [5 * 5e-3], u.compute_finish)
    assert bub["fraction"] == pytest.approx(0.0)


def test_interleaved_completes_and_cuts_bubble():
    """Interleaving shrinks the warmup bubble: same S, M, same total work,
    strictly smaller makespan (hence bubble) than plain 1F1B."""
    S, M = 4, 8
    plain = PipelineSchedule(n_stages=S, n_microbatches=M)
    inter = PipelineSchedule(n_stages=S, n_microbatches=M,
                             schedule=SCHED_INTERLEAVED, interleave=2)
    up, cp, _, _ = uniform_makespan(plain)
    ui, ci, _, _ = uniform_makespan(inter)
    assert len(ci) == 2 * len(cp)    # twice the units (v = 2 chunks)
    assert len(ui.order) == len(ci)  # every unit scheduled
    assert ui.compute_busy == pytest.approx(up.compute_busy)
    assert ui.compute_finish < up.compute_finish


def test_interleave_one_equals_1f1b():
    S, M = 3, 6
    plain = PipelineSchedule(n_stages=S, n_microbatches=M)
    inter1 = PipelineSchedule(n_stages=S, n_microbatches=M,
                              schedule=SCHED_INTERLEAVED, interleave=1)
    up, _, _, _ = uniform_makespan(plain)
    ui, _, _, _ = uniform_makespan(inter1)
    assert ui.compute_finish == up.compute_finish
    assert ui.order == up.order


def test_p2p_transfers_delay_the_pipeline():
    sched = PipelineSchedule(n_stages=4, n_microbatches=8)
    free, _, no_jobs, _ = uniform_makespan(sched, p2p_bytes=0.0)
    paid, _, jobs, _ = uniform_makespan(sched, p2p_bytes=float(1 << 24))
    assert not no_jobs and jobs
    assert paid.finish > free.finish


def test_last_bwd_is_the_gradient_release_point():
    sched = PipelineSchedule(n_stages=3, n_microbatches=4)
    u, cjobs, _, last_bwd = uniform_makespan(sched)
    by_id = {j.job_id: j for j in cjobs}
    assert len(set(last_bwd)) == len(last_bwd)
    for s, jid in enumerate(last_bwd):
        j = by_id[jid]
        assert j.kind == "bwd" and j.stream == s


def test_schedule_validation():
    with pytest.raises(ValueError):
        PipelineSchedule(n_stages=0, n_microbatches=4)
    with pytest.raises(ValueError):
        PipelineSchedule(n_stages=2, n_microbatches=5,
                         schedule=SCHED_INTERLEAVED, interleave=2)
    with pytest.raises(ValueError):
        PipelineSchedule(n_stages=2, n_microbatches=4, schedule="gpipe")
    sched = PipelineSchedule(n_stages=2, n_microbatches=4,
                             schedule=SCHED_INTERLEAVED, interleave=2,
                             p2p_bytes=1024.0)
    assert PipelineSchedule.from_tuple(sched.to_tuple()) == sched


# ------------------------------------------------ simulator pipeline path
def chain_graph(n=14, grads=(3, 7, 11)):
    prims = []
    for i in range(n):
        gi = list(grads).index(i) if i in grads else -1
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=4096.0, time=1e-6, grad_param=gi,
            grad_bytes=float(1 << 20) if gi >= 0 else 0.0,
            grad_sig="f32" if gi >= 0 else ""))
    return FusionGraph(prims, [(i, i + 1) for i in range(n - 1)])


def test_simulator_pipeline_pricing():
    g = chain_graph()
    sched = PipelineSchedule(n_stages=2, n_microbatches=4)
    sim = Simulator(cluster=SPEC, streams=4, pipeline=sched,
                    keep_timeline=True)
    r = sim.run(g)
    assert r.pipeline is not None
    assert r.pipeline["n_stages"] == 2
    assert 0.0 <= r.pipeline["bubble"]["fraction"] < 1.0
    assert r.pipeline["p2p_busy_s"] > 0.0
    assert r.iteration_time > 0.0
    kinds = {e[0] for e in r.timeline}
    assert "fwd" in kinds and "bwd" in kinds
    # pipeline pricing is always a full replay
    assert sim.stats["full"] == 1 and sim.stats["delta"] == 0


def test_pipeline_contention_differs_from_background_model():
    """Dep-coupled stage-boundary transfers are not periodic noise: the
    same p2p volume priced as 1F1B structure vs blind background jobs must
    give different iteration times (this asymmetry is what fig_pp_sweep
    measures)."""
    g = chain_graph()
    sched = PipelineSchedule(n_stages=2, n_microbatches=4)
    sim_pp = Simulator(cluster=SPEC, streams=4, pipeline=sched)
    r_pp = sim_pp.run(g)
    pbytes = sim_pp.pipeline_inputs(g)["p2p_bytes"]
    n = 2 * (sched.n_stages - 1) * sched.n_microbatches
    bg = BackgroundTraffic("pp", pbytes, period=1e-5, kind="p2p", count=n)
    r_bg = Simulator(cluster=SPEC, streams=4, background=(bg,)).run(g)
    assert r_pp.iteration_time > 0 and r_bg.iteration_time > 0
    assert r_pp.iteration_time != r_bg.iteration_time


def test_too_many_stages_raises():
    g = chain_graph(n=3, grads=(1,))
    sched = PipelineSchedule(n_stages=8, n_microbatches=8)
    sim = Simulator(cluster=SPEC, streams=4, pipeline=sched)
    with pytest.raises(ValueError):
        sim.run(g)

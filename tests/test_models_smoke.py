"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family runs one forward/train step on CPU with shape + finiteness
asserts; decode agrees with the parallel forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.pipeline import materialize_batch
from repro.models import model as M
from repro.models import stacked as ST


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    return materialize_batch(cfg, B, S, seed=0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch).reduced()
    params = ST.init_params(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = ST.forward(params, cfg, batch["tokens"],
                             prefix_emb=batch.get("prefix_emb"),
                             enc_frames=batch.get("enc_frames"))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, key):
    from repro.optim import adamw, apply_updates

    cfg = get_config(arch).reduced()
    params = ST.init_params(key, cfg)
    batch = _batch(cfg)
    init, update = adamw(1e-3)
    opt = init(params)
    loss, grads = jax.value_and_grad(
        lambda p: ST.loss_fn(p, cfg, batch, remat=True))(params)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    updates, opt = update(grads, opt, params)
    params2 = apply_updates(params, updates)
    loss2 = ST.loss_fn(params2, cfg, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-v2-lite-16b",
                                  "recurrentgemma-9b", "rwkv6-3b",
                                  "seamless-m4t-medium"])
def test_decode_matches_forward(arch, key):
    cfg = get_config(arch).reduced()
    params = ST.init_params(key, cfg)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    memory = (ST.encode(params, cfg, batch["enc_frames"])
              if cfg.encdec else None)
    logits_full, _ = ST.forward(params, cfg, toks,
                                enc_frames=batch.get("enc_frames"))
    caches = ST.init_cache(cfg, B, 32)
    for t in range(S):
        lg, caches = ST.decode_step(params, cfg, caches, toks[:, t],
                                    jnp.int32(t), memory=memory)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-0.5b"])
def test_prefill_then_decode(arch, key):
    cfg = get_config(arch).reduced()
    params = ST.init_params(key, cfg)
    B, S = 2, 12
    toks = _batch(cfg, B, S)["tokens"]
    logits_full, _ = ST.forward(params, cfg, toks)
    lg_pf, caches = ST.prefill(params, cfg, toks[:, :-1], 32)
    np.testing.assert_allclose(np.asarray(lg_pf),
                               np.asarray(logits_full[:, S - 2]),
                               rtol=2e-3, atol=2e-3)
    lg, _ = ST.decode_step(params, cfg, caches, toks[:, -1],
                           jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


def test_stacked_matches_unstacked(key):
    """The scanned-layer path is numerically identical to the per-layer
    loop (same per-layer RNG keys)."""
    cfg = get_config("tinyllama-1.1b").reduced()
    sp = ST.init_params(key, cfg)
    # rebuild the unstacked layout from the stacked leaves
    up = {k: v for k, v in sp.items() if k not in ("groups",)}
    layers = []
    g = sp["groups"][0]
    n = jax.tree.leaves(g)[0].shape[0]
    for i in range(n):
        layers.append(jax.tree.map(lambda a: a[i], g))
    up["layers"] = layers
    toks = _batch(cfg)["tokens"]
    l1, _ = ST.forward(sp, cfg, toks)
    l2, _ = M.forward(up, cfg, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_variant_matches_ref():
    """long_500k dense variant: windowed attention == full attention
    restricted to the window."""
    from repro.kernels.ref import flash_attention_ref

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              window=8)
    key = jax.random.PRNGKey(1)
    params = ST.init_params(key, cfg)
    toks = _batch(cfg, 2, 24)["tokens"]
    logits, _ = ST.forward(params, cfg, toks)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # direct attention check
    q = jax.random.normal(key, (1, 16, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 2, 8))
    from repro.models.layers import sdpa
    out = sdpa(q, k, v, None, window=4)
    ref = flash_attention_ref(q, k, v, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_param_count_accounting():
    """active_param_count < param_count for MoE; both positive."""
    for arch in ARCHS:
        cfg = get_config(arch)
        n = cfg.param_count()
        na = cfg.active_param_count()
        assert n > 0 and na > 0
        if cfg.moe is not None:
            assert na < n
        else:
            assert na == n


def test_moe_dispatch_balanced_load_exact():
    """With generous capacity the sort-based dispatch is exact: MoE output
    equals the dense per-token expert mixture."""
    from repro.models import layers as L

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    out, aux = L.moe_fwd(p, cfg, x)
    # dense reference: run every expert on every token
    e = cfg.moe
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    topv, topi = jax.lax.top_k(probs, e.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for ei in range(e.n_routed):
        up = xt @ p["w_up"][ei]
        gate = jax.nn.silu(xt @ p["w_gate"][ei])
        h = (gate * up) @ p["w_down"][ei]
        w = jnp.sum(jnp.where(topi == ei, topv, 0.0), axis=-1)
        ref = ref + h * w[:, None]
    ref = ref + L.mlp_fwd(p["shared"], cfg, xt)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)

"""Property-testing shim: re-exports ``hypothesis`` when it is installed,
otherwise provides a minimal deterministic fallback (seeded ``random``
sampling) with the same ``given`` / ``settings`` / ``strategies`` surface the
test-suite uses.  CI images without network access (no pip) stay green; dev
machines with hypothesis get real shrinking/edge-case generation.

Usage in tests::

    from _propcheck import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 100  # hypothesis' own default

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value=0, max_value=(1 << 31) - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pc_max_examples", _DEFAULT_MAX_EXAMPLES)
                # deterministic per-test seed (PYTHONHASHSEED-independent)
                name = f"{fn.__module__}:{fn.__qualname__}"
                rng = random.Random(zlib.crc32(name.encode()))
                for _ in range(n):
                    drawn_args = [s._draw(rng) for s in arg_strategies]
                    drawn_kw = {k: s._draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)

            # pytest must not resolve the original params as fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco


st = strategies

"""Plan-cache + warm-started search property tests (DESIGN.md Sec. 12):

* an exact-key cache hit replays a Plan with equal ``fast_signature()``,
  bit-equal simulated cost and identical ``strategy_fingerprint()`` to the
  cold-compiled one — and burns zero simulator evaluations;
* warm-started search never returns a plan worse than its own start state,
  and the re-application contract resets the per-bucket dimensions the new
  simulator cannot price;
* every failure is a *miss*, never a crash: truncated artifacts (torn
  writes), corrupt indexes, foreign files — and concurrent writers on the
  same key leave a readable index;
* ``Plan.save`` is atomic (temp + ``os.replace``), and the
  ``--plan``/``--cluster`` mismatch diff names the differing fields.
"""
import json
import os
import random
import subprocess
import sys
import tempfile

import pytest
from _propcheck import given, settings, st

from repro.cluster import ClusterSpec, get_preset
from repro.core import (ALL_METHODS, FusionGraph, PrimOp, Simulator,
                        backtracking_search, profile_graph, random_apply)
from repro.core.graph import EW
from repro.core.hw import TPU_V5E
from repro.plan import (ClusterMismatchError, Plan, PlanCache,
                        cluster_fingerprint, cluster_fingerprint_diff,
                        compile_key, compile_plan, graph_digest, knob_digest,
                        similarity, warm_start_state)
from repro.plan.cache import cache_features, open_cache

SPEC = get_preset("a100_nvlink_ib")
OTHER = get_preset("h100_superpod")


def chain_graph(n=16, grads=(3, 6, 9, 12), grad_bytes=float(1 << 20)):
    prims = []
    for i in range(n):
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6,
            grad_param=list(grads).index(i) if i in grads else -1,
            grad_bytes=grad_bytes if i in grads else 0.0,
            grad_sig="f32" if i in grads else ""))
    return profile_graph(FusionGraph(prims, [(i, i + 1) for i in range(n - 1)]))


def mutated(base, seed, n_mut):
    rng = random.Random(seed)
    g = base.clone()
    for _ in range(n_mut):
        random_apply(g, rng.choice(ALL_METHODS), 1, rng)
    return g


KNOBS = dict(unchanged_limit=25, max_steps=20)


# ----------------------------------------------------------- exact-key hits
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_exact_hit_is_bit_identical_to_cold(seed):
    # tempfile (not a pytest fixture): the _propcheck shim's @given wrapper
    # hides the signature from pytest's fixture resolution
    d = tempfile.mkdtemp(prefix="plan-cache-")
    g0 = chain_graph()
    sim = Simulator(cluster=SPEC, streams=4)
    cache = PlanCache(d)
    cold = compile_plan(graph=g0, cluster=SPEC, streams=4, seed=seed,
                        cache=cache, **KNOBS)
    assert cold.provenance["cache"]["outcome"] == "cold"
    hit = compile_plan(graph=g0, cluster=SPEC, streams=4, seed=seed,
                       cache=cache, **KNOBS)
    assert hit.provenance["cache"]["outcome"] == "hit"
    # the replay is the cold artifact: equal plan, fingerprints, price,
    # and the re-applied strategy state is signature-identical
    assert hit == cold
    assert hit.fingerprint() == cold.fingerprint()
    assert hit.strategy_fingerprint() == cold.strategy_fingerprint()
    assert hit.predicted_iteration_time == cold.predicted_iteration_time
    g_hit, g_cold = hit.to_graph(g0), cold.to_graph(g0)
    assert g_hit.fast_signature() == g_cold.fast_signature()
    assert sim.cost(g_hit) == sim.cost(g_cold) \
        == cold.predicted_iteration_time
    assert cache.stats["hits"] == 1


def test_key_separates_graph_cluster_and_knobs():
    g0, g1 = chain_graph(), chain_graph(n=20, grads=(3, 7))
    sim_a = Simulator(cluster=SPEC, streams=4)
    sim_b = Simulator(cluster=OTHER, streams=4)
    k1 = knob_digest(alpha=1.05, beta=10, unchanged_limit=25, max_steps=20,
                     methods=None, seed=0)
    k2 = knob_digest(alpha=1.05, beta=10, unchanged_limit=25, max_steps=20,
                     methods=None, seed=1)
    assert compile_key(g0, sim_a, k1) == compile_key(g0, sim_a, k1)
    assert compile_key(g0, sim_a, k1) != compile_key(g1, sim_a, k1)
    assert compile_key(g0, sim_a, k1) != compile_key(g0, sim_b, k1)
    assert compile_key(g0, sim_a, k1) != compile_key(g0, sim_a, k2)
    # strategy state is part of the content address
    assert graph_digest(g0) != graph_digest(mutated(g0, 3, 6))


# --------------------------------------------------------------- warm start
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_warm_start_never_worse_than_start_state(seed):
    d = tempfile.mkdtemp(prefix="plan-cache-")
    g0 = chain_graph()
    cache = PlanCache(d)
    compile_plan(graph=g0, cluster=SPEC, streams=4, seed=seed, cache=cache,
                 **KNOBS)
    warm = compile_plan(graph=g0, cluster=OTHER, streams=4, seed=seed,
                        cache=cache, **KNOBS)
    prov = warm.provenance["cache"]
    if prov["outcome"] == "warm":
        # the incumbent starts at the warm state: the final plan can only
        # be at least as good
        assert warm.predicted_iteration_time <= prov["warm_start_cost"]
        # ... and the warm state beat the trivial baseline by construction
        assert prov["warm_start_cost"] < Simulator(
            cluster=OTHER, streams=4).cost(g0)
    else:
        assert prov["outcome"] == "cold"


def test_search_initial_injection_never_worse():
    g0 = chain_graph()
    sim = Simulator(cluster=SPEC, streams=4)
    start = mutated(g0, 11, 12)
    res = backtracking_search(g0, sim, unchanged_limit=5, max_steps=4,
                              seed=0, initial=start)
    assert res.best_cost <= sim.cost(start)
    assert res.best_cost <= sim.cost(g0)
    assert res.initial_cost == sim.cost(g0)
    # quality history: sims nondecreasing, cost nonincreasing
    sims = [s for s, _ in res.quality_history]
    costs = [c for _, c in res.quality_history]
    assert sims == sorted(sims)
    assert costs == sorted(costs, reverse=True)
    assert costs[-1] == res.best_cost


def test_warm_start_resets_inapplicable_dimensions():
    g0 = chain_graph()
    rich = mutated(g0, 5, 14)
    plan = Plan.from_graph(rich, sim=Simulator(cluster=SPEC, streams=4))
    # serialized channel: comm-kind and chunk flips are unpriceable —
    # the re-applied state must reset them to the defaults
    ser = warm_start_state(plan, g0, Simulator(cluster=SPEC, streams=1))
    assert all(k == "ar" for k in ser.bucket_comm)
    assert all(c == 1 for c in ser.bucket_chunks)
    assert ser.bucket_algos == list(plan.bucket_algos)[:len(ser.buckets)]
    # flat spec: algorithm-blind too
    flat = warm_start_state(plan, g0, Simulator(hw=TPU_V5E, n_devices=64))
    assert all(a == "ring" for a in flat.bucket_algos)
    # multi-stream engine keeps the full strategy: signature round-trips
    full = warm_start_state(plan, g0, Simulator(cluster=SPEC, streams=4))
    assert full.fast_signature() == rich.fast_signature()
    # wrong trace family -> None (ladder falls through, no crash)
    assert warm_start_state(plan, chain_graph(n=20, grads=(3, 7)),
                            Simulator(cluster=SPEC, streams=4)) is None


def test_similarity_ranking_prefers_same_arch_then_cluster():
    g0 = chain_graph()
    req = cache_features(g0, Simulator(cluster=SPEC, streams=4), arch="a")
    same_arch_other_cluster = cache_features(
        g0, Simulator(cluster=OTHER, streams=4), arch="a")
    other_graph_same_cluster = cache_features(
        chain_graph(n=20, grads=(3, 7)),
        Simulator(cluster=SPEC, streams=4), arch="b")
    assert similarity(req, req) > similarity(req, same_arch_other_cluster)
    assert similarity(req, same_arch_other_cluster) \
        > similarity(req, other_graph_same_cluster)


# ------------------------------------------------- corruption / atomicity
def test_truncated_entry_is_a_miss_not_a_crash(tmp_path):
    cache = PlanCache(str(tmp_path))
    g = mutated(chain_graph(), 3, 8)
    plan = Plan.from_graph(g, sim=Simulator(cluster=SPEC, streams=4))
    cache.put("k1", plan)
    path = cache._plan_path("k1")
    blob = open(path).read()
    open(path, "w").write(blob[:len(blob) // 2])  # torn write
    assert cache.get("k1") is None
    assert cache.stats["stale"] == 1 and cache.stats["misses"] == 1
    # verify names it; prune drops it
    rep = cache.verify()
    assert [c["key"] for c in rep["corrupt"]] == ["k1"]
    assert cache.prune()["dropped"] == ["k1"]
    assert len(cache) == 0 and not os.path.exists(path)


def test_plan_save_is_atomic(tmp_path):
    g = mutated(chain_graph(), 1, 6)
    plan = Plan.from_graph(g, sim=Simulator(cluster=SPEC))
    path = str(tmp_path / "p.json")
    plan.save(path)
    assert Plan.load(path) == plan
    # no temp droppings, and a re-save replaces in place
    plan.save(path)
    assert sorted(os.listdir(tmp_path)) == ["p.json"]


def test_corrupt_index_is_rebuilt_from_plan_files(tmp_path):
    cache = PlanCache(str(tmp_path))
    g0 = chain_graph()
    sim = Simulator(cluster=SPEC, streams=4)
    feats = cache_features(g0, sim, arch="chain")
    plan = Plan.from_graph(mutated(g0, 2, 8), sim=sim)
    cache.put("kx", plan, feats)
    open(cache._index_path(), "w").write("{torn")
    fresh = PlanCache(str(tmp_path))
    ents = fresh.entries()
    assert [e["key"] for e in ents] == ["kx"]
    # similarity coordinates ride inside the artifact and survive rebuild
    assert ents[0]["arch"] == "chain"
    assert fresh.get("kx") == plan


def test_capacity_evicts_oldest(tmp_path):
    cache = PlanCache(str(tmp_path), capacity=2)
    g0 = chain_graph()
    sim = Simulator(cluster=SPEC, streams=4)
    for i in range(4):
        cache.put(f"k{i}", Plan.from_graph(mutated(g0, i, 6), sim=sim))
    assert len(cache) == 2
    assert cache.stats["evictions"] == 2
    assert cache.get("k0") is None and cache.get("k3") is not None


_WRITER = """
import sys
sys.path.insert(0, "src")
sys.path.insert(0, "tests")
from test_plan_cache import chain_graph, mutated, SPEC
from repro.core import Simulator
from repro.plan import Plan, PlanCache

d, key, seed = sys.argv[1], sys.argv[2], int(sys.argv[3])
cache = PlanCache(d)
sim = Simulator(cluster=SPEC, streams=4)
for _ in range(20):
    cache.put(key, Plan.from_graph(mutated(chain_graph(), seed, 8), sim=sim))
print("done")
"""


def test_concurrent_writers_leave_readable_index(tmp_path):
    d = str(tmp_path)
    env = dict(os.environ)
    procs = [
        subprocess.Popen([sys.executable, "-c", _WRITER, d, "shared", "7"],
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE)
        for _ in range(2)
    ]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
    # both raced on the same key: the index is readable JSON and the
    # surviving entry loads (last writer wins)
    cache = PlanCache(d)
    idx = json.load(open(cache._index_path()))
    assert set(idx["entries"]) == {"shared"}
    assert cache.get("shared") is not None
    assert not [n for n in os.listdir(d) if ".tmp." in n]


# -------------------------------------------------------- mismatch diff UX
def test_cluster_fingerprint_diff_names_fields():
    assert cluster_fingerprint_diff(cluster_fingerprint(SPEC),
                                    cluster_fingerprint(SPEC)) == []
    diff = cluster_fingerprint_diff(cluster_fingerprint(SPEC),
                                    cluster_fingerprint(OTHER))
    assert any(d.startswith("name:") for d in diff)
    # flat vs hierarchical: family-level difference
    flat = ClusterSpec.flat(TPU_V5E, 64)
    fam = cluster_fingerprint_diff(cluster_fingerprint(flat),
                                   cluster_fingerprint(SPEC))
    assert fam and "topology family" in fam[0]
    # flat vs flat: the differing Hardware field is named
    flat2 = ClusterSpec.flat(TPU_V5E, 128)
    nd = cluster_fingerprint_diff(cluster_fingerprint(flat),
                                  cluster_fingerprint(flat2))
    assert nd == ["n_devices: 64 != 128"]
    # JSON round-tripped (list-shaped) fingerprints diff identically
    rt = json.loads(json.dumps(cluster_fingerprint(SPEC)))
    assert cluster_fingerprint_diff(rt, cluster_fingerprint(OTHER)) == diff


def test_mismatch_error_carries_diff():
    p = Plan.from_graph(chain_graph(), sim=Simulator(cluster=SPEC))
    with pytest.raises(ClusterMismatchError) as ei:
        p.simulator(cluster=OTHER)
    assert "name:" in str(ei.value)


# ---------------------------------------------------------------- CLI / misc
def test_cache_cli_ls_stats_prune_verify(tmp_path, capsys):
    from repro.plan.cache import main

    d = str(tmp_path)
    cache = PlanCache(d)
    g0 = chain_graph()
    sim = Simulator(cluster=SPEC, streams=4)
    cache.put("a", Plan.from_graph(mutated(g0, 0, 6), sim=sim),
              cache_features(g0, sim, arch="chain"))
    cache.put("b", Plan.from_graph(mutated(g0, 1, 6), sim=sim))
    assert main(["ls", "--dir", d]) == 0
    assert "2 entries" in capsys.readouterr().out
    assert main(["stats", "--dir", d]) == 0
    assert json.loads(capsys.readouterr().out)["entries"] == 2
    assert main(["verify", "--dir", d]) == 0
    capsys.readouterr()
    open(cache._plan_path("b"), "w").write("{torn")
    assert main(["verify", "--dir", d]) == 1
    capsys.readouterr()
    assert main(["prune", "--dir", d]) == 0
    assert "dropped 1" in capsys.readouterr().out
    assert main(["prune", "--dir", d, "--max-entries", "0"]) == 0
    assert len(PlanCache(d)) == 0


def test_open_cache_accepts_path_and_rejects_junk(tmp_path):
    c = open_cache(str(tmp_path / "c"))
    assert isinstance(c, PlanCache)
    assert open_cache(c) is c
    assert open_cache(None) is None
    with pytest.raises(TypeError):
        open_cache(42)

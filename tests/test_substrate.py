"""Optimizer / data-pipeline / checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticLMDataset
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         cosine_schedule, linear_warmup_cosine, sgd)


def test_adamw_matches_reference_math():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3])}
    lr, b1, b2, eps = 0.01, 0.9, 0.999, 1e-8
    init, update = adamw(lr, b1, b2, eps)
    state = init(params)
    updates, state = update(grads, state, params)
    # step 1 closed form: m_hat = g, v_hat = g^2
    g = np.array([0.1, 0.2, -0.3])
    expect = -lr * g / (np.sqrt(g * g) + eps)
    np.testing.assert_allclose(np.asarray(updates["w"]), expect, rtol=1e-5)


def test_adamw_decreases_quadratic():
    init, update = adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        updates, state = update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_sgd_momentum():
    init, update = sgd(0.1, momentum=0.9)
    params = {"w": jnp.array([1.0])}
    state = init(params)
    grads = {"w": jnp.array([1.0])}
    u1, state = update(grads, state, params)
    u2, state = update(grads, state, params)
    assert float(u2["w"][0]) == pytest.approx(float(u1["w"][0]) * 1.9)


def test_clip_by_global_norm():
    grads = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(10.0)
    total = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(clipped))
    assert total == pytest.approx(1.0, rel=1e-4)


def test_schedules():
    fn = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(fn(0)) == pytest.approx(0.1)
    assert float(fn(9)) == pytest.approx(1.0)
    assert float(fn(10)) == pytest.approx(1.0, rel=1e-2)
    assert float(fn(1000)) == pytest.approx(0.05, rel=1e-2)
    cs = cosine_schedule(2.0, 100)
    assert float(cs(0)) == pytest.approx(2.0)


def test_dataset_deterministic_and_sharded():
    ds = SyntheticLMDataset(vocab=1000, seq_len=32, global_batch=16, seed=3)
    b1 = ds.global_step_batch(5)
    b2 = ds.global_step_batch(5)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (16, 32)
    assert b1.min() >= 0 and b1.max() < 1000
    # shards are deterministic and correctly sized
    s0 = ds.shard_step_batch(5, 0, 4)
    s0b = ds.shard_step_batch(5, 0, 4)
    np.testing.assert_array_equal(s0, s0b)
    assert s0.shape == (4, 32)
    s1 = ds.shard_step_batch(5, 1, 4)
    assert not np.array_equal(s0, s1)


def test_dataset_is_learnable_structure():
    """bigram structure: successor entropy far below uniform."""
    ds = SyntheticLMDataset(vocab=256, seq_len=128, global_batch=8, seed=0)
    toks = ds.global_step_batch(0)
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), set()).add(int(b))
    avg_succ = np.mean([len(v) for v in pairs.values()])
    assert avg_succ < 24  # branching 8 + jumps << 256


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": jnp.ones((4,), jnp.bfloat16),
        "nested": [{"x": jnp.zeros((2,), jnp.int32)}],
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, jax.tree.map(lambda a: a + 1, tree))
    assert latest_step(d) == 12
    restored, step = restore_checkpoint(d, tree)
    assert step == 12
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves(jax.tree.map(lambda a: a + 1, tree))):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    restored7, _ = restore_checkpoint(d, tree, step=7)
    np.testing.assert_array_equal(np.asarray(restored7["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "none"), {"w": jnp.zeros(1)})

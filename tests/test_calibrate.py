"""repro.cluster.calibrate: least-squares (alpha, beta) fitting round-trips
on synthetic timings (ROADMAP "calibrate from measured traces")."""
import random

import pytest
from _propcheck import given, settings, st

from repro.cluster import (COLLECTIVE_ALGOS, ClusterSpec, LinkLevel,
                           comm_time, get_preset)
from repro.cluster.calibrate import (TimingSample, fit_levels,
                                     samples_from_dryrun, spec_from_describe)

SIZES = (1e4, 1e5, 1e6, 1e7, 1e8, 1e9)


def synth_samples(spec, sizes=SIZES, kinds=("ar",)):
    return [TimingSample(x, comm_time(x, spec, a, k), a, k)
            for x in sizes for a in COLLECTIVE_ALGOS for k in kinds]


def test_round_trip_two_level():
    """Timings generated from a ground-truth spec recover its per-level
    (alpha, beta) from wrong datasheet starting constants."""
    true = ClusterSpec("true", (
        LinkLevel("nvlink", 8, 280e9, 2.4e-6),
        LinkLevel("ib", 4, 21e9, 18e-6, contention=2.0)))
    start = ClusterSpec("guess", (
        LinkLevel("nvlink", 8, 300e9, 3e-6),
        LinkLevel("ib", 4, 25e9, 15e-6, contention=2.0)))
    res = fit_levels(synth_samples(true), start)
    assert res.rel_rmse < 1e-8
    assert all(res.identifiable)
    for lt, lf in zip(true.levels, res.spec.levels):
        assert lf.bandwidth == pytest.approx(lt.bandwidth, rel=1e-3)
        assert lf.alpha == pytest.approx(lt.alpha, rel=1e-3)
    # structure is preserved, only (alpha, beta) moved
    assert [l.degree for l in res.spec.levels] == [8, 4]
    assert res.spec.levels[1].contention == 2.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_round_trip_random_perturbation(seed):
    """Random true/start perturbations of a zoo preset still round-trip
    (including RS/AG samples — the ZeRO-3 pricing path is calibratable)."""
    rng = random.Random(seed)
    base = get_preset("a100_nvlink_ib")
    import dataclasses
    true = ClusterSpec("true", tuple(
        dataclasses.replace(l, bandwidth=l.bandwidth * rng.uniform(0.4, 2.5),
                            alpha=l.alpha * rng.uniform(0.4, 2.5))
        for l in base.levels))
    samples = synth_samples(true, kinds=("ar", "rs", "ag"))
    res = fit_levels(samples, base)
    assert res.rel_rmse < 1e-6
    for lt, lf in zip(true.levels, res.spec.levels):
        assert lf.bandwidth == pytest.approx(lt.bandwidth, rel=1e-2)
        assert lf.alpha == pytest.approx(lt.alpha, rel=1e-2)


def test_unidentifiable_level_keeps_datasheet_value():
    """A degree-1 level is invisible to every collective: the fit must keep
    its datasheet constants and flag it unidentifiable."""
    true = ClusterSpec("true", (
        LinkLevel("solo", 1, 123e9, 7e-6),
        LinkLevel("ib", 16, 20e9, 12e-6)))
    start = ClusterSpec("guess", (
        LinkLevel("solo", 1, 123e9, 7e-6),
        LinkLevel("ib", 16, 30e9, 9e-6)))
    res = fit_levels(synth_samples(true), start)
    assert res.identifiable == [False, True]
    assert res.spec.levels[0].bandwidth == 123e9
    assert res.spec.levels[0].alpha == 7e-6
    assert res.spec.levels[1].bandwidth == pytest.approx(20e9, rel=1e-3)


def test_non_physical_fit_keeps_datasheet_value_and_flags_it():
    """Contradictory timings that drive a level's beta negative must not
    silently yield ~infinite bandwidth: the datasheet value is kept and the
    level is flagged ``clamped``."""
    start = ClusterSpec("guess", (
        LinkLevel("nvlink", 8, 300e9, 3e-6),
        LinkLevel("ib", 4, 25e9, 15e-6)))
    # timings far *below* what any positive ib beta could produce at large
    # sizes, while the nvlink term is pinned by the small-size samples
    samples = [TimingSample(x, comm_time(x, start, a) * (1e-4 if x > 1e6
                                                         else 1.0), a)
               for x in SIZES for a in COLLECTIVE_ALGOS]
    res = fit_levels(samples, start, iters=1)
    for l, l0, cl in zip(res.spec.levels, start.levels, res.clamped):
        if cl:
            assert l.bandwidth == l0.bandwidth and l.alpha >= 0.0
        assert l.bandwidth <= 1e15  # never priced as free
    assert any(res.clamped)


def test_rejects_flat_compat_and_empty():
    from repro.core.hw import TPU_V5E

    with pytest.raises(ValueError):
        fit_levels([], get_preset("a100_nvlink_ib"))
    with pytest.raises(ValueError):
        fit_levels([TimingSample(1e6, 1e-3)], ClusterSpec.flat(TPU_V5E, 8))


def test_dryrun_adapter_round_trip():
    """A dryrun-shaped cluster block (as written by collective_cost_model)
    feeds the fit: spec rebuild + per-algo samples + RS/AG block."""
    spec = get_preset("h100_superpod")
    assert spec_from_describe(spec.describe()).describe() == spec.describe()
    count, mean = 10, 2e7
    doc = {"cluster": {
        "spec": spec.describe(),
        "allreduce_bytes": count * mean,
        "allreduce_count": count,
        "allreduce_time_s": {
            a: count * comm_time(mean, spec, a) for a in COLLECTIVE_ALGOS},
        "rs_ag": {"reduce-scatter": {
            "bytes": count * mean, "count": count,
            "time_s": {a: count * comm_time(mean, spec, a, "rs")
                       for a in COLLECTIVE_ALGOS}}},
    }}
    samples, got = samples_from_dryrun(doc)
    assert got.describe() == spec.describe()
    assert len(samples) == 2 * len(COLLECTIVE_ALGOS)
    for s in samples:
        assert s.time_s == pytest.approx(
            comm_time(s.nbytes, spec, s.algo, s.kind), rel=1e-12)

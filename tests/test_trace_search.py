"""Tracer (jaxpr -> FusionGraph) and backtracking-search tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (Simulator, backtracking_search, evaluate_baselines,
                        profile_graph, trace_grad_graph)
from repro.core.baselines import (jax_default, pytorch_ddp,
                                  threshold_tensor_fusion,
                                  xla_post_order_op_fusion)
from repro.core.graph import DOT


def mlp_graph(layers=4, d=64, batch=8):
    params = {f"w{i}": jnp.ones((d, d)) for i in range(layers)}

    def loss(p, bt):
        x, y = bt
        h = x
        for i in range(layers):
            h = jnp.tanh(h @ p[f"w{i}"])
        return jnp.mean((h - y) ** 2)

    batch_data = (jnp.ones((batch, d)), jnp.ones((batch, d)))
    return profile_graph(trace_grad_graph(loss, params, batch_data)), layers


def test_trace_marks_all_gradients():
    g, layers = mlp_graph()
    assert len(g.grad_prim) == layers
    assert len(g.buckets) == layers
    # gradient bytes match parameter sizes
    for gi, pid in g.grad_prim.items():
        assert g.prims[pid].grad_bytes == 64 * 64 * 4


def test_trace_finds_matmuls():
    g, layers = mlp_graph()
    dots = [p for p in g.prims if p.category == DOT]
    # forward + 2 backward matmuls per layer
    assert len(dots) >= 2 * layers
    for p in dots:
        assert p.flops > 0


def test_trace_inlines_pjit():
    @jax.jit
    def inner(x, w):
        return jnp.tanh(x @ w)

    params = {"w": jnp.ones((16, 16))}

    def loss(p, bt):
        return jnp.sum(inner(bt, p["w"]))

    g = trace_grad_graph(loss, params, jnp.ones((4, 16)))
    assert not any(p.op_type == "pjit" for p in g.prims)


def test_trace_scan_is_opaque_with_scaled_cost():
    params = {"w": jnp.ones((16, 16))}

    def loss(p, x):
        def body(c, _):
            return jnp.tanh(c @ p["w"]), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(out)

    g = trace_grad_graph(loss, params, jnp.ones((4, 16)))
    scans = [p for p in g.prims if p.op_type == "scan"]
    assert scans and all(p.category == "opaque" for p in scans)
    # body cost multiplied by trip count: >= 8 matmuls worth
    assert max(p.flops for p in scans) >= 8 * 2 * 4 * 16 * 16


def test_search_improves_over_initial_and_baselines():
    g, _ = mlp_graph(layers=6, d=128, batch=32)
    sim = Simulator(n_devices=64)
    base = evaluate_baselines(g, sim)
    res = backtracking_search(g, sim, alpha=1.05, beta=10,
                              unchanged_limit=60, seed=0)
    assert res.best_cost <= res.initial_cost
    best_baseline = min(v for k, v in base.items() if k != "FO")
    assert res.best_cost <= best_baseline * 1.001
    # history is monotonically decreasing
    costs = [c for _, c in res.history]
    assert all(a >= b for a, b in zip(costs, costs[1:]))


def test_search_respects_method_subset():
    g, _ = mlp_graph()
    sim = Simulator(n_devices=64)
    res = backtracking_search(g, sim, methods=("tensor",),
                              unchanged_limit=30, seed=1)
    # tensor-only search must not alter op fusion state
    assert res.best.n_groups == g.n_groups


def test_baselines_are_valid_strategies():
    g, layers = mlp_graph()
    sim = Simulator(n_devices=64)
    for name, fn in (("op", xla_post_order_op_fusion),
                     ("ar", threshold_tensor_fusion),
                     ("default", jax_default),
                     ("ddp", pytorch_ddp)):
        h = fn(g)
        r = sim.run(h)
        assert r.iteration_time > 0, name
    # op fusion reduces group count
    assert xla_post_order_op_fusion(g).n_groups < g.n_groups
    # ddp merges buckets into <=25MB groups (all tiny here -> 1 bucket)
    assert len(pytorch_ddp(g).buckets) == 1


def test_search_deterministic_given_seed():
    g, _ = mlp_graph()
    sim = Simulator(n_devices=64)
    r1 = backtracking_search(g, sim, unchanged_limit=25, seed=42)
    r2 = backtracking_search(g, sim, unchanged_limit=25, seed=42)
    assert r1.best_cost == r2.best_cost
    assert r1.best.signature() == r2.best.signature()

"""GNN Fused-Op Estimator (paper Sec. 4.3): trains on oracle-labelled fused
subgraphs and predicts held-out fused-op times within tolerance."""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Simulator, profile_graph, trace_grad_graph
from repro.core.gnn import GNNConfig, GNNEstimator, predict_times, train
from repro.core.profile_cpu import sample_fused_groups

from test_trace_search import mlp_graph


@pytest.fixture(scope="module")
def corpus():
    g, _ = mlp_graph(layers=5, d=96, batch=16)
    rng = random.Random(0)
    samples = sample_fused_groups(g, 400, rng, max_members=12)
    assert len(samples) > 150
    return samples


def test_gnn_trains_and_generalizes(corpus):
    n = len(corpus)
    tr, te = corpus[: int(n * 0.8)], corpus[int(n * 0.8):]
    cfg = GNNConfig(n_layers=2, n_heads=2, head_dim=8, mlp_dim=32)
    params, losses = train(tr, cfg, epochs=40, batch_size=32, lr=3e-3, seed=0)
    assert losses[-1] < losses[0] * 0.5, "training loss did not drop"
    pred = predict_times(params, te)
    true = np.array([s[3] for s in te])
    rel_err = np.abs(pred - true) / true
    # paper: >90% of predictions within 14% error on a GPU; our budgeted
    # CPU-trained estimator must get the bulk within 50%
    assert np.median(rel_err) < 0.5, f"median rel err {np.median(rel_err)}"


def test_gnn_estimator_drives_simulator(corpus):
    g, _ = mlp_graph(layers=5, d=96, batch=16)
    cfg = GNNConfig(n_layers=2, n_heads=2, head_dim=8, mlp_dim=32)
    params, _ = train(corpus, cfg, epochs=25, batch_size=32, seed=0)
    est = GNNEstimator(params, cfg)
    sim = Simulator(estimator=est, n_devices=64)
    r = sim.run(g)
    assert r.iteration_time > 0
    # singleton groups use profiled times exactly
    gid = next(iter(g.groups))
    assert est.group_time(g, gid) == g.prims[min(g.groups[gid])].time

"""GNN Fused-Op Estimator (paper Sec. 4.3): trains on oracle-labelled fused
subgraphs and predicts held-out fused-op times within tolerance."""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Simulator, profile_graph, trace_grad_graph
from repro.core.gnn import GNNConfig, GNNEstimator, predict_times, train
from repro.core.profile_cpu import sample_fused_groups

from test_trace_search import mlp_graph


@pytest.fixture(scope="module")
def corpus():
    g, _ = mlp_graph(layers=5, d=96, batch=16)
    rng = random.Random(0)
    samples = sample_fused_groups(g, 400, rng, max_members=12)
    assert len(samples) > 150
    return samples


def test_gnn_trains_and_generalizes(corpus):
    n = len(corpus)
    tr, te = corpus[: int(n * 0.8)], corpus[int(n * 0.8):]
    cfg = GNNConfig(n_layers=2, n_heads=2, head_dim=8, mlp_dim=32)
    params, losses = train(tr, cfg, epochs=40, batch_size=32, lr=3e-3, seed=0)
    assert losses[-1] < losses[0] * 0.5, "training loss did not drop"
    pred = predict_times(params, te)
    true = np.array([s[3] for s in te])
    rel_err = np.abs(pred - true) / true
    # paper: >90% of predictions within 14% error on a GPU; our budgeted
    # CPU-trained estimator must get the bulk within 50%
    assert np.median(rel_err) < 0.5, f"median rel err {np.median(rel_err)}"


def test_group_features_see_comm_dimensions():
    """The feature vector carries (bucket algo, comm kind, chunk count) on
    gradient-producing nodes and changes when the search mutates them —
    and the estimator cache does not replay stale predictions across comm
    mutations."""
    import numpy as np

    from repro.core.gnn import GNNConfig, N_COMM_FEATURES, N_FEATURES, \
        group_features, init_params, GNNEstimator
    import jax

    g, _ = mlp_graph(layers=3, d=32, batch=4)
    # fuse a gradient-producing prim into a multi-op group
    grad_pid = g.grad_prim[g.buckets[0][0]]
    gid = g.provider[grad_pid]
    preds = list(g.group_preds(gid))
    assert preds and g.fuse_nondup(gid, preds[0])
    gid = g.provider[grad_pid]
    assert len(g.groups[gid]) > 1

    feat0, _, _ = group_features(g, gid, 16)
    assert feat0.shape[1] == N_FEATURES
    base = N_FEATURES - N_COMM_FEATURES
    assert feat0[:, base:].any(), "comm features all zero on a grad group"
    bi = next(i for i, b in enumerate(g.buckets) if g.buckets[0][0] in b)
    g.set_bucket_algo(bi, "hier")
    feat1, _, _ = group_features(g, gid, 16)
    assert (feat0[:, base] != feat1[:, base]).any()
    g.set_bucket_chunks(bi, 4)
    feat2, _, _ = group_features(g, gid, 16)
    assert (feat1[:, base + 2] != feat2[:, base + 2]).any()

    cfg = GNNConfig(n_layers=1, n_heads=2, head_dim=4, mlp_dim=8)
    est = GNNEstimator(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    t_hier = est.group_time(g, gid)
    g.set_bucket_algo(bi, "tree")
    t_tree = est.group_time(g, gid)
    # an (untrained) net still must be *queried* with the new features,
    # not served the cached hier-keyed value
    assert t_hier != t_tree or len(est._cache) == 2


def test_gnn_incremental_equals_full_across_comm_mutations():
    """A comm-sensitive estimator invalidates the delta path across bucket-
    dimension mutations: incremental and full replay must agree bit-for-bit
    even though cached group times depend on bucket algo/comm/chunks."""
    import jax

    from repro.cluster import get_preset
    from repro.core import Simulator
    from repro.core.gnn import GNNConfig, GNNEstimator, init_params
    from repro.core.search import ALL_METHODS, random_apply

    cfg = GNNConfig(n_layers=1, n_heads=2, head_dim=4, mlp_dim=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    spec = get_preset("a100_nvlink_ib")
    est = GNNEstimator(params, cfg)
    sim_inc = Simulator(estimator=est, cluster=spec, streams=4,
                        incremental=True)
    sim_full = Simulator(estimator=est, cluster=spec, streams=4,
                         incremental=False)
    rng = random.Random(3)
    parent, _ = mlp_graph(layers=3, d=32, batch=4)
    for step in range(30):
        child = parent.clone()
        for _ in range(rng.randint(1, 3)):
            random_apply(child, rng.choice(ALL_METHODS), 1, rng)
        ri = sim_inc.run(child)
        rf = sim_full.run(child)
        assert ri.iteration_time == rf.iteration_time, step
        if rng.random() < 0.6:
            parent = child


def test_gnn_estimator_drives_simulator(corpus):
    g, _ = mlp_graph(layers=5, d=96, batch=16)
    cfg = GNNConfig(n_layers=2, n_heads=2, head_dim=8, mlp_dim=32)
    params, _ = train(corpus, cfg, epochs=25, batch_size=32, seed=0)
    est = GNNEstimator(params, cfg)
    sim = Simulator(estimator=est, n_devices=64)
    r = sim.run(g)
    assert r.iteration_time > 0
    # singleton groups use profiled times exactly
    gid = next(iter(g.groups))
    assert est.group_time(g, gid) == g.prims[min(g.groups[gid])].time

"""Distributed-runtime integration tests.

These need >1 XLA host device, so they run in subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax (the main
pytest process keeps the default single device for the smoke tests).
"""
import json
import os
import subprocess
import sys

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.slow
def test_ddp_tp_step_matches_single_device():
    """Bucketed-psum DisCo enactment on a 2x2 mesh computes the same loss
    trajectory as plain single-device training."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_compat
from repro.configs import get_config
from repro.models import stacked as ST
from repro.distributed.train_step import (GradSyncStrategy, build_train_step,
                                          jit_train_step)
from repro.distributed import sharding as SH
from repro.optim import adamw, apply_updates, clip_by_global_norm
from repro.data.pipeline import materialize_batch

cfg = get_config("qwen2-0.5b").reduced()
key = jax.random.PRNGKey(0)
params = ST.init_params(key, cfg)
init, update = adamw(1e-3, weight_decay=0.01)
opt = init(jax.tree.map(lambda p: p.astype(jnp.float32), params))
batch = materialize_batch(cfg, 8, 32, seed=0)

# single-device reference (same clip + optimizer math)
def ref_step(params, opt, batch):
    loss, grads = jax.value_and_grad(
        lambda p: ST.loss_fn(p, cfg, batch, remat=True))(params)
    grads, _ = clip_by_global_norm(grads, 1.0)
    updates, opt = update(grads, opt, params)
    return apply_updates(params, updates), opt, loss

p_ref, o_ref = params, opt
ref_losses = []
for i in range(3):
    p_ref, o_ref, l = ref_step(p_ref, o_ref, batch)
    ref_losses.append(float(l))

mesh = make_mesh_compat((2, 2), ("data", "model"))
strat = GradSyncStrategy.size_capped(params, 1 << 16)
step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strat,
                        grad_accum=1, remat=True, lr=1e-3)
jf = jit_train_step(step, cfg, mesh, params, opt,
                    {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                     for k, v in batch.items()})
p, o = params, opt
dist_losses = []
for i in range(3):
    p, o, m = jf(p, o, batch)
    dist_losses.append(float(m["loss"]))
print("REF", ref_losses)
print("DIST", dist_losses)
np.testing.assert_allclose(ref_losses, dist_losses, rtol=2e-4, atol=2e-4)
print("MATCH_OK")
""")
    assert "MATCH_OK" in out


@pytest.mark.slow
def test_bucketing_strategies_equivalent():
    """per-tensor / capped / single-bucket gradient sync produce identical
    gradients (tensor fusion must not change the math — paper Sec. 2.5)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_compat
from repro.configs import get_config
from repro.models import stacked as ST
from repro.distributed.train_step import GradSyncStrategy, build_train_step, jit_train_step
from repro.optim import adamw
from repro.data.pipeline import materialize_batch

cfg = get_config("tinyllama-1.1b").reduced()
key = jax.random.PRNGKey(0)
params = ST.init_params(key, cfg)
init, _ = adamw(1e-3)
opt = init(jax.tree.map(lambda p: p.astype(jnp.float32), params))
batch = materialize_batch(cfg, 8, 32, seed=0)
mesh = make_mesh_compat((4, 2), ("data", "model"))
specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
results = []
for strat in (GradSyncStrategy.per_tensor(params),
              GradSyncStrategy.size_capped(params, 1 << 14),
              GradSyncStrategy.single_bucket(params)):
    step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strat, lr=1e-3)
    jf = jit_train_step(step, cfg, mesh, params, opt, specs)
    # donate_argnums consumes inputs: pass fresh copies each round
    p_in = jax.tree.map(jnp.array, params)
    o_in = jax.tree.map(jnp.array, opt)
    p2, _, m = jf(p_in, o_in, batch)
    results.append((float(m["loss"]), float(m["grad_norm"])))
print(results)
for a, b in zip(results, results[1:]):
    np.testing.assert_allclose(a, b, rtol=1e-4)
print("EQUIV_OK")
""")
    assert "EQUIV_OK" in out


@pytest.mark.slow
def test_rs_ag_bucket_lowering_matches_allreduce():
    """A ZeRO-3 ``rs_ag`` bucket enacts as reduce-scatter + all-gather in
    the compiled HLO (fully-manual ``layout="dp"`` region — the lowering
    0.4.x XLA can partition) and computes losses identical to the fused
    AllReduce path; in the partial-manual TP layout the 0.4.x fallback
    keeps numerics identical too."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_compat
from repro.configs import get_config
from repro.models import stacked as ST
from repro.distributed.train_step import (GradSyncStrategy, build_train_step,
                                          jit_train_step)
from repro.launch.dryrun import parse_collectives
from repro.optim import adamw
from repro.data.pipeline import materialize_batch

cfg = get_config("tinyllama-1.1b").reduced()
key = jax.random.PRNGKey(0)
params = ST.init_params(key, cfg)
init, _ = adamw(1e-3)
opt = init(jax.tree.map(lambda p: p.astype(jnp.float32), params))
batch = materialize_batch(cfg, 8, 32, seed=0)
mesh = make_mesh_compat((4, 2), ("data", "model"))
specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
base = GradSyncStrategy.size_capped(params, 1 << 14)
results = {}
for kind in ("ar", "rs_ag"):
    strat = GradSyncStrategy(base.buckets, comms=[kind] * len(base.buckets))
    step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strat,
                            lr=1e-3, layout="dp")
    jf = jit_train_step(step, cfg, mesh, params, opt, specs, layout="dp")
    lowered = jf.lower(params, opt, specs)
    coll = parse_collectives(lowered.compile().as_text())
    p_in = jax.tree.map(jnp.array, params)
    o_in = jax.tree.map(jnp.array, opt)
    _, _, m = jf(p_in, o_in, batch)
    results[kind] = (float(m["loss"]), float(m["grad_norm"]), coll["per_op"])
print({k: v[:2] for k, v in results.items()})
np.testing.assert_allclose(results["ar"][:2], results["rs_ag"][:2], rtol=1e-4)
# the rs_ag lowering really emits RS+AG pairs where the ar path psums
assert results["rs_ag"][2].get("reduce-scatter", {}).get("count", 0) > 0
assert results["rs_ag"][2].get("all-gather", {}).get("count", 0) > 0
assert results["ar"][2].get("reduce-scatter", {}).get("count", 0) == 0

# partial-manual TP layout: 0.4.x falls back to psum for rs_ag buckets,
# modern JAX lowers the real pair -- either way the loss must match
strat = GradSyncStrategy(base.buckets, comms=["rs_ag"] * len(base.buckets))
step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strat, lr=1e-3)
jf = jit_train_step(step, cfg, mesh, params, opt, specs)
p_in = jax.tree.map(jnp.array, params)
o_in = jax.tree.map(jnp.array, opt)
_, _, m = jf(p_in, o_in, batch)
np.testing.assert_allclose(float(m["loss"]), results["ar"][0], rtol=2e-4)
print("RS_AG_OK")
""")
    assert "RS_AG_OK" in out


@pytest.mark.slow
def test_chunked_bucket_enactment():
    """A bucket with ``chunks=k`` enacts as k per-chunk collectives in the
    compiled HLO — the collective count scales exactly with the chunk
    count — while the loss and grad norm stay bit-identical (each
    element's reduction is unchanged, only the op it rides in shrinks).
    Covers both the fused-AllReduce and ZeRO-3 RS+AG lowering paths."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_compat
from repro.configs import get_config
from repro.models import stacked as ST
from repro.distributed.train_step import (GradSyncStrategy, build_train_step,
                                          jit_train_step)
from repro.launch.dryrun import parse_collectives
from repro.optim import adamw
from repro.data.pipeline import materialize_batch

cfg = get_config("tinyllama-1.1b").reduced()
key = jax.random.PRNGKey(0)
params = ST.init_params(key, cfg)
init, _ = adamw(1e-3)
opt = init(jax.tree.map(lambda p: p.astype(jnp.float32), params))
batch = materialize_batch(cfg, 8, 32, seed=0)
mesh = make_mesh_compat((4, 2), ("data", "model"))
specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
base = GradSyncStrategy.size_capped(params, 1 << 14)
B = len(base.buckets)
res = {}
for kind, k in (("ar", 1), ("ar", 4), ("rs_ag", 2)):
    strat = GradSyncStrategy(base.buckets, comms=[kind] * B,
                             chunks=[k] * B)
    step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strat,
                            lr=1e-3, layout="dp")
    jf = jit_train_step(step, cfg, mesh, params, opt, specs, layout="dp")
    coll = parse_collectives(jf.lower(params, opt, specs).compile().as_text())
    p_in = jax.tree.map(jnp.array, params)
    o_in = jax.tree.map(jnp.array, opt)
    _, _, m = jf(p_in, o_in, batch)
    res[(kind, k)] = (float(m["loss"]), float(m["grad_norm"]),
                      {op: d["count"] for op, d in coll["per_op"].items()})
print(res)
# the collective count scales exactly with the chunk count ...
ar1, ar4 = res[("ar", 1)][2], res[("ar", 4)][2]
assert ar4["all-reduce"] - ar1["all-reduce"] == 3 * B, (ar1, ar4, B)
rs2 = res[("rs_ag", 2)][2]
assert rs2["reduce-scatter"] == 2 * B and rs2["all-gather"] == 2 * B, rs2
# ... the psum path is bit-identical chunked vs whole, and the chunked
# RS+AG split matches to collective-reassociation tolerance
assert res[("ar", 4)][:2] == res[("ar", 1)][:2], res
np.testing.assert_allclose(res[("rs_ag", 2)][:2], res[("ar", 1)][:2],
                           rtol=1e-4)
print("CHUNKED_OK")
""")
    assert "CHUNKED_OK" in out


@pytest.mark.slow
def test_fused_bucket_enactment():
    """A ``fused``-flagged bucket enacts through the Pallas fused-sync
    kernel path (pack epilogue -> per-chunk reduce-scatter + all-gather ->
    unpack prologue) in the fully-manual ``layout="dp"`` region: the
    compiled HLO carries one RS/AG pair per chunk per bucket and the loss /
    grad norm match the plain AllReduce path to collective-reassociation
    tolerance.  In the partial-manual TP layout the compat ladder falls all
    the way back to psum with identical numerics."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_compat
from repro.configs import get_config
from repro.models import stacked as ST
from repro.distributed.train_step import (GradSyncStrategy, build_train_step,
                                          jit_train_step)
from repro.launch.dryrun import parse_collectives
from repro.optim import adamw
from repro.data.pipeline import materialize_batch

cfg = get_config("tinyllama-1.1b").reduced()
key = jax.random.PRNGKey(0)
params = ST.init_params(key, cfg)
init, _ = adamw(1e-3)
opt = init(jax.tree.map(lambda p: p.astype(jnp.float32), params))
batch = materialize_batch(cfg, 8, 32, seed=0)
mesh = make_mesh_compat((4, 2), ("data", "model"))
specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
base = GradSyncStrategy.size_capped(params, 1 << 14)
B = len(base.buckets)
res = {}
for tag, kind, fused, k in (("ar", "ar", 0, 1),
                            ("rs_ag", "rs_ag", 0, 1),
                            ("fused", "ar", 1, 1),
                            ("fused_c2", "ar", 1, 2)):
    strat = GradSyncStrategy(base.buckets, comms=[kind] * B,
                             fused=[fused] * B, chunks=[k] * B)
    step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strat,
                            lr=1e-3, layout="dp")
    jf = jit_train_step(step, cfg, mesh, params, opt, specs, layout="dp")
    coll = parse_collectives(jf.lower(params, opt, specs).compile().as_text())
    p_in = jax.tree.map(jnp.array, params)
    o_in = jax.tree.map(jnp.array, opt)
    _, _, m = jf(p_in, o_in, batch)
    res[tag] = (float(m["loss"]), float(m["grad_norm"]),
                {op: d["count"] for op, d in coll["per_op"].items()})
print({t: v[:2] for t, v in res.items()})
# the fused kernel path really lowers to RS+AG pairs, one per chunk ...
for tag, k in (("fused", 1), ("fused_c2", 2)):
    per_op = res[tag][2]
    assert per_op.get("reduce-scatter", 0) == k * B, (tag, per_op, B)
    assert per_op.get("all-gather", 0) >= k * B, (tag, per_op, B)
assert res["ar"][2].get("reduce-scatter", 0) == 0, res["ar"][2]
# ... and the enacted numerics match the psum and ZeRO-3 paths
np.testing.assert_allclose(res["fused"][:2], res["ar"][:2], rtol=1e-4)
np.testing.assert_allclose(res["fused"][:2], res["rs_ag"][:2], rtol=1e-4)
np.testing.assert_allclose(res["fused_c2"][:2], res["ar"][:2], rtol=1e-4)

# partial-manual TP layout: the compat ladder drops the kernel path and
# keeps numerics identical to AllReduce
strat = GradSyncStrategy(base.buckets, comms=["ar"] * B, fused=[1] * B)
step = build_train_step(cfg, mesh, mode="ddp_tp", strategy=strat, lr=1e-3)
jf = jit_train_step(step, cfg, mesh, params, opt, specs)
p_in = jax.tree.map(jnp.array, params)
o_in = jax.tree.map(jnp.array, opt)
_, _, m = jf(p_in, o_in, batch)
np.testing.assert_allclose(float(m["loss"]), res["ar"][0], rtol=2e-4)
print("FUSED_OK")
""")
    assert "FUSED_OK" in out


@pytest.mark.slow
def test_vocab_parallel_matches_dense():
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_compat
from repro.models import vocab_parallel as VP
mesh = make_mesh_compat((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
V, D, B, S = 64, 16, 2, 8
embed = jax.random.normal(key, (V, D))
toks = jax.random.randint(key, (B, S), 0, V)
x = jax.jit(lambda e, t: VP.embed_lookup(e, t, mesh))(embed, toks)
np.testing.assert_allclose(np.asarray(x), np.asarray(embed[toks]), rtol=1e-5)
# CE
head = jax.random.normal(key, (D, V))
h = jax.random.normal(key, (B, S, D))
w = jnp.ones((B, S))
ce, cnt = jax.jit(lambda *a: VP.ce_chunk(*a, mesh, transpose_head=False))(
    h, head, toks, w)
logits = (h @ head).astype(jnp.float32)
logz = jax.nn.logsumexp(logits, -1)
gold = jnp.take_along_axis(logits, toks[..., None], -1)[..., 0]
ref = float(jnp.sum(logz - gold))
np.testing.assert_allclose(float(ce), ref, rtol=1e-5)
assert float(cnt) == B * S
# grads flow (jit: the shard_map transpose needs the jit context to
# resolve auto-axis specs)
g = jax.jit(jax.grad(lambda hh: VP.ce_chunk(hh, head, toks, w, mesh,
                                            transpose_head=False)[0]))(h)
gref = jax.grad(lambda hh: jnp.sum(
    jax.nn.logsumexp((hh @ head).astype(jnp.float32), -1)
    - jnp.take_along_axis((hh @ head).astype(jnp.float32),
                          toks[..., None], -1)[..., 0]))(h)
np.testing.assert_allclose(np.asarray(g), np.asarray(gref), rtol=1e-4,
                           atol=1e-5)
print("VP_OK")
""")
    assert "VP_OK" in out


@pytest.mark.slow
def test_dryrun_reduced_mesh():
    """End-to-end dryrun machinery on a small mesh + reduced config."""
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh_compat
from repro.configs import get_config
from repro.models import stacked as ST
from repro.distributed.train_step import build_train_step, jit_train_step
from repro.optim import adamw
from repro.launch.dryrun import parse_collectives
from repro.data.pipeline import make_batch_specs

cfg = get_config("deepseek-v2-lite-16b").reduced()
mesh = make_mesh_compat((4, 2), ("data", "model"))
params = jax.eval_shape(lambda: ST.init_params(jax.random.PRNGKey(0), cfg))
init, _ = adamw(1e-3)
opt = jax.eval_shape(lambda: init(jax.tree.map(
    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)))
specs = make_batch_specs(cfg, 8, 64)
step = build_train_step(cfg, mesh, mode="ddp_tp")
jf = jit_train_step(step, cfg, mesh, params, opt, specs)
lowered = jf.lower(params, opt, specs)
compiled = lowered.compile()
from repro.compat import cost_analysis_compat
assert cost_analysis_compat(compiled).get("flops", 0) > 0
coll = parse_collectives(compiled.as_text())
assert coll["per_op"].get("all-reduce", {}).get("count", 0) > 0
print("DRYRUN_OK", coll["per_op"]["all-reduce"]["count"])
""")
    assert "DRYRUN_OK" in out


def test_strategy_save_load(tmp_path):
    from repro.distributed.train_step import GradSyncStrategy

    s = GradSyncStrategy([[0, 1], [2], [3, 4, 5]], barriers=True,
                         comms=["ar", "rs_ag", "ar"], chunks=[1, 2, 4])
    p = str(tmp_path / "s.json")
    s.save(p)
    s2 = GradSyncStrategy.load(p)
    assert s2.buckets == s.buckets and s2.barriers is True
    assert s2.comms == s.comms and s2.comm_kind(1) == "rs_ag"
    assert s2.chunks == s.chunks and s2.chunk_count(2) == 4
    # legacy strategy files (no comms/chunks) default to one fused AllReduce
    s3 = GradSyncStrategy([[0]])
    p3 = str(tmp_path / "legacy.json")
    s3.save(p3)
    loaded = GradSyncStrategy.load(p3)
    assert loaded.comm_kind(0) == "ar" and loaded.chunk_count(0) == 1


def test_strategy_from_fusion_graph():
    import jax.numpy as jnp
    from repro.core import profile_graph, trace_grad_graph
    from repro.distributed.train_step import GradSyncStrategy

    params = {"a": jnp.ones((8, 8)), "b": jnp.ones((8,)),
              "c": jnp.ones((8, 8))}

    def loss(p, x):
        return jnp.sum(jnp.tanh(x @ p["a"] + p["b"]) @ p["c"])

    g = profile_graph(trace_grad_graph(loss, params, jnp.ones((4, 8))))
    while g.merge_buckets(0, 1):
        pass
    g.set_bucket_comm(0, "rs_ag")
    g.set_bucket_chunks(0, 4)
    strat = GradSyncStrategy.from_fusion_graph(g, params)
    flat = sorted(i for b in strat.buckets for i in b)
    assert flat == [0, 1, 2]
    assert len(strat.buckets) == 1
    # the searched comm kind and chunk count ride along into enactment
    assert strat.comms == ["rs_ag"]
    assert strat.chunks == [4]


@pytest.mark.slow
def test_dp_layout_and_zero1():
    """layout='dp' (all-axes data parallel) and ZeRO-1 moment sharding both
    compile and train one step equal to the tp layout's loss."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_compat
from repro.configs import get_config
from repro.models import stacked as ST
from repro.distributed.train_step import build_train_step, jit_train_step
from repro.optim import adamw
from repro.data.pipeline import materialize_batch

cfg = get_config("tinyllama-1.1b").reduced()
key = jax.random.PRNGKey(0)
params = ST.init_params(key, cfg)
init, _ = adamw(1e-3)
opt = init(jax.tree.map(lambda p: p.astype(jnp.float32), params))
batch = materialize_batch(cfg, 8, 32, seed=0)
mesh = make_mesh_compat((4, 2), ("data", "model"))
specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
losses = {}
for name, kw in (("tp", {}), ("dp", {"layout": "dp"}),
                 ("tp_zero1", {"zero1": True})):
    step = build_train_step(cfg, mesh, mode="ddp_tp", lr=1e-3,
                            layout=kw.get("layout", "tp"))
    jf = jit_train_step(step, cfg, mesh, params, opt, specs,
                        layout=kw.get("layout", "tp"),
                        zero1=kw.get("zero1", False))
    p_in = jax.tree.map(jnp.array, params)
    o_in = jax.tree.map(jnp.array, opt)
    _, _, m = jf(p_in, o_in, batch)
    losses[name] = float(m["loss"])
print(losses)
vals = list(losses.values())
np.testing.assert_allclose(vals, [vals[0]] * len(vals), rtol=1e-4)
print("LAYOUTS_OK")
""")
    assert "LAYOUTS_OK" in out


def test_int8_kv_cache_decode_accuracy():
    import dataclasses

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import stacked as ST

    cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                              kv_cache_dtype="int8", dtype="float32")
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits_full, _ = ST.forward(params, cfg, toks)
    caches = ST.init_cache(cfg, 2, 16)
    for leaf in jax.tree.leaves(caches):
        assert leaf.dtype in (jnp.int8, jnp.bfloat16, jnp.float32)
    errs = []
    for t in range(12):
        lg, caches = ST.decode_step(params, cfg, caches, toks[:, t],
                                    jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, t]))))
    assert max(errs) < 0.05, f"int8 cache decode error too large: {max(errs)}"

"""Sharding-rule and analytic-cost invariants (property-style, no devices).

These run against the *full* production configs — every PartitionSpec the
dry-run would use must be divisibility-valid for the 16-way model axis and
the data axes, for every architecture.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core.analytic import shape_cost
from repro.distributed import sharding as SH
from repro.launch.shapes import FSDP_ARCHS, SHAPES, applicability
from repro.models import stacked as ST

MESH_SHAPE = {"data": 16, "model": 16}
MESH_SHAPE_MP = {"pod": 2, "data": 16, "model": 16}


class _FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _spec_sizes(shape):
    return shape


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh_shape", [MESH_SHAPE, MESH_SHAPE_MP],
                         ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh_shape):
    """Every sharded dim must be divisible by the product of its mesh axes
    (our rules never rely on GSPMD padding)."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: ST.init_params(jax.random.PRNGKey(0),
                                                   cfg))
    mesh = _FakeMesh(mesh_shape)
    align = SH.head_alignment(cfg, mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh_shape)
    SH._dp_size_cache[dp_axes] = int(np.prod([mesh_shape[a]
                                              for a in dp_axes]))
    fsdp = arch in FSDP_ARCHS

    def check(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return
        spec = SH.param_spec(path, leaf, model_size=mesh_shape["model"],
                             dp_axes=dp_axes, fsdp=fsdp, **align)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([mesh_shape[a] for a in axes]))
            assert dim % total == 0, (
                f"{arch}: {jax.tree_util.keystr(path)} dim {dim} not "
                f"divisible by {axes}={total}")

    jax.tree_util.tree_map_with_path(check, params)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    caches = jax.eval_shape(lambda: ST.init_cache(cfg, 128, 1024))
    mesh = _FakeMesh(MESH_SHAPE)

    # emulate cache_shardings' decisions without a real device mesh
    def check(path, leaf):
        names = SH._path_names(path)
        name = names[-1] if names else ""
        # same logic the rules use
        if name in ("k", "v", "k_scale", "v_scale"):
            kv_ax = 3
            if leaf.ndim > kv_ax and leaf.shape[kv_ax] % 16 == 0:
                assert leaf.shape[kv_ax] % 16 == 0
            elif name in ("k", "v") and leaf.shape[-1] % 16 == 0:
                assert leaf.shape[-1] % 16 == 0

    jax.tree_util.tree_map_with_path(check, caches)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_analytic_costs_positive_and_scaled(arch, shape):
    """Analytic model sanity: all terms positive; multi-pod halves the
    per-device compute for batch-sharded kinds."""
    cfg0 = get_config(arch)
    ok, _, cfg = applicability(cfg0, shape)
    if not ok:
        pytest.skip("shape not applicable")
    cb1 = shape_cost(cfg, shape, MESH_SHAPE, fsdp=arch in FSDP_ARCHS)
    cb2 = shape_cost(cfg, shape, MESH_SHAPE_MP, fsdp=arch in FSDP_ARCHS)
    assert cb1.flops > 0 and cb1.hbm_bytes > 0
    assert cb1.model_flops > 0
    if SHAPES[shape]["kind"] != "decode" and SHAPES[shape]["batch"] >= 32:
        assert cb2.flops == pytest.approx(cb1.flops / 2, rel=1e-6)


def test_model_flops_vs_param_count():
    """6·N·D model flops must track the per-token forward flops within 3x
    for dense archs (sanity tie between the two accounting paths)."""
    from repro.core.analytic import _per_token_forward_flops

    for arch in ("tinyllama-1.1b", "qwen2-0.5b", "deepseek-coder-33b"):
        cfg = get_config(arch)
        fwd = _per_token_forward_flops(cfg, 4096, decode=False)
        ideal = 2.0 * cfg.active_param_count()
        assert 0.5 < fwd / ideal < 3.0, (arch, fwd / ideal)


def test_head_alignment_rules():
    mesh = _FakeMesh(MESH_SHAPE)
    a = SH.head_alignment(get_config("stablelm-1.6b"), mesh)   # 32 H, 32 kv
    assert a == {"q_aligned": True, "kv_aligned": True}
    b = SH.head_alignment(get_config("qwen2-0.5b"), mesh)      # 14 H, 2 kv
    assert b == {"q_aligned": False, "kv_aligned": False}
    c = SH.head_alignment(get_config("deepseek-coder-33b"), mesh)  # 56/8
    assert c == {"q_aligned": False, "kv_aligned": False}


def test_batch_pspec_divisibility():
    mesh = _FakeMesh(MESH_SHAPE)
    assert tuple(SH.batch_pspec(256, mesh, 2))[0] == "data"
    assert tuple(SH.batch_pspec(1, mesh, 2))[0] is None  # indivisible -> rep
    mesh2 = _FakeMesh(MESH_SHAPE_MP)
    assert tuple(SH.batch_pspec(256, mesh2, 2))[0] == ("pod", "data")

"""Per-kernel correctness: shape/dtype sweeps asserted against the pure-jnp
oracles in ref.py (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K

RNG = np.random.default_rng(0)


def arr(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA
    (1, 256, 8, 1, 32),     # MQA
    (1, 128, 2, 2, 128),    # large head dim
])
def test_flash_attention_causal(B, S, H, KV, hd, dtype):
    q, k, v = arr((B, S, H, hd), dtype), arr((B, S, KV, hd), dtype), \
        arr((B, S, KV, hd), dtype)
    out = K.flash_attention(q, k, v, causal=True)
    ref = K.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [32, 128, 500])
def test_flash_attention_window(window):
    q, k, v = arr((1, 256, 4, 64)), arr((1, 256, 2, 64)), arr((1, 256, 2, 64))
    out = K.flash_attention(q, k, v, causal=True, window=window)
    ref = K.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    q, k, v = arr((1, 128, 4, 64)), arr((1, 128, 4, 64)), arr((1, 128, 4, 64))
    out = K.flash_attention(q, k, v, causal=False)
    ref = K.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,L", [(1, 128, 256), (2, 256, 512),
                                   (1, 512, 128)])
def test_rglru(B, S, L, dtype):
    x = arr((B, S, L), dtype)
    r = jax.nn.sigmoid(arr((B, S, L), dtype))
    i = jax.nn.sigmoid(arr((B, S, L), dtype))
    lam = jnp.linspace(2.0, 6.0, L)
    out = K.rglru_scan(x, r, i, lam)
    ref = K.rglru_ref(x, r, i, lam)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,hd", [(1, 128, 2, 64), (2, 256, 4, 32),
                                      (1, 256, 1, 128)])
def test_rwkv6(B, S, H, hd, dtype):
    r, k, v = (arr((B, S, H, hd), dtype) for _ in range(3))
    w = (jax.nn.sigmoid(arr((B, S, H, hd))) * 0.5 + 0.45).astype(dtype)
    u = (arr((H, hd)) * 0.1).astype(jnp.float32)
    out = K.rwkv6_wkv(r, k, v, w, u)
    ref = K.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 5e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 5e-4)


@pytest.mark.parametrize("sizes", [[17], [31, 64], [5, 1000, 3]])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bucket_pack(sizes, dtype):
    leaves = [arr((s,), dtype) for s in sizes]
    total = sum(sizes) + 13
    out = K.bucket_pack(leaves, total)
    ref = K.bucket_pack_ref(leaves, sizes, total)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("sizes", [[17], [31, 64], [5, 1000, 3]])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("dp,chunks", [(1, 1), (4, 1), (4, 3), (8, 8)])
def test_fused_pack(sizes, dtype, dp, chunks):
    """Chunked fused-sync pack == jnp oracle exactly: same chunk cuts, same
    per-chunk shard padding, f32 upcast, zero tails."""
    leaves = [arr((s,), dtype) for s in sizes]
    total = sum(sizes)
    parts = K.fused_pack(leaves, total, dp, chunks)
    refs = K.fused_pack_ref(leaves, total, dp, chunks)
    assert len(parts) == len(refs)
    for part, ref in zip(parts, refs):
        assert part.dtype == jnp.float32
        assert part.shape == ref.shape
        assert part.shape[0] % dp == 0
        np.testing.assert_array_equal(np.asarray(part), np.asarray(ref))


@pytest.mark.parametrize("sizes", [[17], [31, 64], [5, 1000, 3]])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_unpack_roundtrip(sizes, dtype):
    """unpack(concat(pack(...)) trimmed to total) returns every leaf
    bit-identically (f32) / value-identically after the bf16 round-trip."""
    leaves = [arr((s,), dtype) for s in sizes]
    total = sum(sizes)
    parts = K.fused_pack(leaves, total, 1, 2)
    flat = jnp.concatenate(parts)[:total]
    out = K.fused_unpack(flat, [l.shape for l in leaves],
                         [l.dtype for l in leaves])
    ref = K.fused_unpack_ref(flat, [l.shape for l in leaves],
                             [l.dtype for l in leaves])
    for o, r, l in zip(out, ref, leaves):
        assert o.shape == l.shape and o.dtype == l.dtype
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
        np.testing.assert_array_equal(
            np.asarray(o, np.float32),
            np.asarray(l.astype(jnp.float32).astype(dtype), np.float32))


def test_flash_kernel_inside_model():
    """use_kernels=True path produces the same logits as the XLA path."""
    from repro.configs import get_config
    from repro.models import stacked as ST

    cfg = get_config("tinyllama-1.1b").reduced()
    key = jax.random.PRNGKey(0)
    params = ST.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 128), 0, cfg.vocab)
    l_ref, _ = ST.forward(params, cfg, toks, use_kernels=False)
    l_ker, _ = ST.forward(params, cfg, toks, use_kernels=True)
    np.testing.assert_allclose(np.asarray(l_ker), np.asarray(l_ref),
                               rtol=5e-4, atol=5e-4)

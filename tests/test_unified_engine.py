"""PR 6 regression surface for the unified event engine (DESIGN.md
Sec. 11): seed-arithmetic golden equivalence, the unified timeline record
schema, zero-byte bucket parity across both comm paths, and keep_timeline
runs staying on the incremental (delta-resume) lineage."""
import heapq
import random

import pytest

from repro.cluster import KIND_AR, comm_coeffs, get_preset
from repro.configs import get_config
from repro.core import (BackgroundTraffic, PipelineSchedule, Simulator,
                        profile_graph, trace_grad_graph)
from repro.core.graph import EW, FusionGraph, PrimOp
from repro.core.search import ALL_METHODS, random_apply
from repro.plan import PLAN_VERSION, Plan


def traced_graph(arch: str):
    import jax

    from repro.data.pipeline import materialize_batch
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = materialize_batch(cfg, 2, 16, seed=0)
    return profile_graph(trace_grad_graph(
        lambda p, bt: M.loss_fn(p, cfg, bt), params, data))


@pytest.fixture(scope="module")
def transformer_graph():
    return traced_graph("transformer-paper")


@pytest.fixture(scope="module")
def qwen_graph():
    return traced_graph("qwen2-0.5b")


def chain_graph(n=14, grads=(3, 7, 11), grad_bytes=(1 << 18,) * 3):
    prims = []
    for i in range(n):
        gi = list(grads).index(i) if i in grads else -1
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6, grad_param=gi,
            grad_bytes=float(grad_bytes[gi]) if gi >= 0 else 0.0,
            grad_sig="f32" if gi >= 0 else ""))
    return FusionGraph(prims, [(i, i + 1) for i in range(n - 1)])


# ------------------------------------------- seed-arithmetic golden oracle
def seed_reference(g, sim):
    """The pre-refactor serialized pricing, transcribed from the seed
    ``_run_full``/``_comm_pass``: a (key, gid) ready heap with
    ``bucket_waiting`` provider-count side-channels, then the serialized
    channel as a bare ``max(chan_free, ready) + C*x + D`` loop.  The
    unified engine replaced this with one dependency-aware job graph; this
    oracle pins its results to the seed's exact accumulation order."""
    succs, preds = g.quotient()
    indeg = {gid: len(ps) for gid, ps in preds.items()}
    key = g._group_key
    done_at = {}
    ready = [(key[gid], gid) for gid, k in indeg.items() if k == 0]
    heapq.heapify(ready)
    device_free = 0.0
    compute_busy = 0.0
    bucket_waiting = {
        i: set(g.bucket_ready_groups(b)) for i, b in enumerate(g.buckets)
    }
    bucket_ready_at = {i: 0.0 for i, w in bucket_waiting.items() if not w}
    group_to_buckets = {}
    for i, w in bucket_waiting.items():
        for gid in w:
            group_to_buckets.setdefault(gid, []).append(i)
    while ready:
        _, gid = heapq.heappop(ready)
        t = sim.estimator.group_time(g, gid)
        end = device_free + t
        done_at[gid] = end
        device_free = end
        compute_busy += t
        for i in group_to_buckets.get(gid, ()):
            bucket_waiting[i].discard(gid)
            if not bucket_waiting[i]:
                bucket_ready_at[i] = end
        for d in succs[gid]:
            indeg[d] -= 1
            if indeg[d] == 0:
                heapq.heappush(ready, (key[d], d))
    assert len(done_at) == len(g.groups)

    chan_free = 0.0
    comm_busy = 0.0
    comm_finish = 0.0
    algos = g.bucket_algos
    kinds = g.bucket_comm
    buckets = g.buckets
    order = sorted(bucket_ready_at.items(), key=lambda kv: (kv[1], kv[0]))
    for i, ready_t in order:
        nbytes = g.bucket_bytes(buckets[i])
        if nbytes <= 0.0:
            continue
        c, d = comm_coeffs(sim.cluster, algos[i], kinds[i])
        t = c * nbytes + d
        start = max(chan_free, ready_t)
        chan_free = start + t
        comm_busy += t
        comm_finish = chan_free
    return {
        "iteration_time": max(device_free, comm_finish),
        "compute_time": compute_busy,
        "comm_time": comm_busy,
        "compute_finish": device_free,
        "comm_finish": comm_finish,
    }


def _golden_walk(g0, seed, steps=25):
    rng = random.Random(seed)
    # exactly one incremental sim: _remember stamps the graph's base token,
    # so a second one would clobber the first's lineage into full fallbacks
    sims = {
        "full": Simulator(n_devices=64, incremental=False),
        "hier_full": Simulator(cluster=get_preset("a100_nvlink_ib"),
                               incremental=False),
        "delta": Simulator(n_devices=64, incremental=True),
    }
    parent = g0
    for step in range(steps):
        child = parent.clone()
        for _ in range(rng.randint(1, 2)):
            random_apply(child, rng.choice(ALL_METHODS), 1, rng)
        for name, sim in sims.items():
            want = seed_reference(child, sim)
            got = sim.run(child)
            for f, v in want.items():
                assert getattr(got, f) == v, (step, name, f)
        if rng.random() < 0.6:
            parent = child
    assert sims["delta"].stats["delta"] > 0


def test_unified_matches_seed_arithmetic_transformer(transformer_graph):
    _golden_walk(transformer_graph, seed=2)


def test_unified_matches_seed_arithmetic_qwen(qwen_graph):
    _golden_walk(qwen_graph, seed=4, steps=15)


# ------------------------------------------------- timeline record schema
def _check_records(timeline):
    assert timeline, "empty timeline"
    for e in timeline:
        assert isinstance(e, tuple) and len(e) == 8, e
        kind, ref = e[0], e[1]
        assert isinstance(kind, str) and kind, e
        assert isinstance(ref, int), e
        start, end = e[6], e[7]
        assert isinstance(start, float) and isinstance(end, float), e
        assert 0.0 <= start <= end, e
        if kind in ("compute", "fwd", "bwd"):
            # compute spans are readable at both the legacy (2, 3) and the
            # unified (6, 7) positions
            assert (e[2], e[3]) == (start, end), e
            assert e[4] == "compute" and e[5].startswith("stream"), e


def test_timeline_schema_all_paths(transformer_graph):
    g = transformer_graph
    hier = get_preset("a100_nvlink_ib")
    bg = (BackgroundTraffic("tp", 1 << 20, period=1e-5, count=8),)
    sched = PipelineSchedule(n_stages=2, n_microbatches=4)
    paths = {
        "serialized": Simulator(n_devices=64, keep_timeline=True,
                                incremental=False),
        "serialized_delta": Simulator(n_devices=64, keep_timeline=True),
        "phased": Simulator(cluster=hier, streams=4, keep_timeline=True,
                            incremental=False),
        "phased_bg": Simulator(cluster=hier, streams=4, background=bg,
                               keep_timeline=True, incremental=False),
        "pipeline": Simulator(cluster=hier, streams=4, pipeline=sched,
                              keep_timeline=True),
    }
    for name, sim in paths.items():
        r = sim.run(g)
        assert r.timeline is not None, name
        _check_records(r.timeline)
        if name == "serialized_delta":
            # the delta path must emit the same schema
            child = g.clone()
            assert child.merge_buckets(0, 1) or True
            r2 = sim.run(child)
            _check_records(r2.timeline)
        if name == "pipeline":
            kinds = {e[0] for e in r.timeline}
            assert "fwd" in kinds and "bwd" in kinds, kinds


# ------------------------------------------------- zero-byte bucket parity
@pytest.mark.parametrize("streams", [1, 4])
def test_zero_byte_bucket_is_noop_both_paths(streams):
    """A zero-byte gradient bucket must vanish from pricing identically on
    the serialized channel and the phased engine (satellite: before PR 6
    the streams>1 path materialized zero-byte jobs)."""
    spec = get_preset("a100_nvlink_ib")
    gz = chain_graph(grads=(3, 7, 11), grad_bytes=(1 << 18, 0, 1 << 18))
    # control: the zero-byte tensor is not a gradient at all — identical
    # compute stream, identical readiness of the nonzero buckets
    gc = chain_graph(grads=(3, 11), grad_bytes=(1 << 18, 1 << 18))
    sim = Simulator(cluster=spec, streams=streams, keep_timeline=True,
                    incremental=False)
    rz = sim.run(gz)
    # the zero-byte bucket contributes nothing: no zero-span comm record
    zero_recs = [e for e in rz.timeline
                 if e[0] != "compute" and e[6] == e[7]]
    assert not zero_recs, zero_recs
    rc = sim.run(gc)
    assert rz.comm_time == rc.comm_time
    assert rz.comm_finish == rc.comm_finish
    assert rz.iteration_time == rc.iteration_time


def test_zero_byte_streams_parity_finish():
    """With every bucket zero-byte, both engines price pure compute."""
    g = chain_graph(grads=(3, 7, 11), grad_bytes=(0, 0, 0))
    spec = get_preset("a100_nvlink_ib")
    r1 = Simulator(cluster=spec, streams=1, incremental=False).run(g)
    r4 = Simulator(cluster=spec, streams=4, incremental=False).run(g)
    assert r1.comm_time == r4.comm_time == 0.0
    assert r1.comm_finish == r4.comm_finish == 0.0
    assert r1.iteration_time == r4.iteration_time == r1.compute_finish


# ------------------------------------------------ keep_timeline lineage
def test_keep_timeline_runs_stay_incremental(transformer_graph):
    """keep_timeline sims must record/remember state: mutated children hit
    the delta path and their timelines stay bit-identical to a
    non-incremental replay (satellite: the seed bypassed ``_remember`` for
    timeline runs, severing the lineage)."""
    g = transformer_graph
    sim = Simulator(n_devices=64, keep_timeline=True, incremental=True)
    ref = Simulator(n_devices=64, keep_timeline=True, incremental=False)
    r0 = sim.run(g)
    assert r0.timeline is not None
    rng = random.Random(9)
    parent = g
    for _ in range(6):
        child = parent.clone()
        random_apply(child, rng.choice(ALL_METHODS), 1, rng)
        ri = sim.run(child)
        rf = ref.run(child)
        assert ri.iteration_time == rf.iteration_time
        assert ri.timeline == rf.timeline
        parent = child
    assert sim.stats["delta"] > 0, \
        "keep_timeline severed the incremental lineage"


# ---------------------------------------------------- Plan v2 round-trip
def test_plan_v2_records_pipeline_and_v1_loads(transformer_graph):
    g = transformer_graph
    spec = get_preset("a100_nvlink_ib")
    sched = PipelineSchedule(n_stages=2, n_microbatches=4)
    sim = Simulator(cluster=spec, streams=4, pipeline=sched)
    plan = Plan.from_graph(g, sim=sim, predicted=sim.cost(g))
    assert plan.version == PLAN_VERSION
    assert plan.pipeline == sched.to_tuple()
    d = plan._to_json()
    back = Plan.from_dict(d)
    assert back == plan
    sim2 = back.simulator()
    assert sim2.pipeline == sched
    # a v1 dict (no pipeline field) still loads, normalized to current
    d1 = plan._to_json()
    d1["version"] = 1
    d1.pop("pipeline")
    old = Plan.from_dict(d1)
    assert old.version == PLAN_VERSION and old.pipeline is None
    assert old.simulator().pipeline is None

"""repro.cluster: topology specs, collective cost models, and their joint
threading through the cost substrate / simulator / search (property tests
run through tests/_propcheck.py when hypothesis is absent)."""
import random

import pytest
from _propcheck import given, settings, st

from repro.cluster import (ALGO_HIER, ALGO_RING, ALGO_TREE, COLLECTIVE_ALGOS,
                           ClusterSpec, LinkLevel, PRESETS, best_algo,
                           bucket_time, get_preset, hier_allreduce,
                           list_presets, ring_allreduce, tree_allreduce)
from repro.core import (FusionGraph, PrimOp, Simulator, backtracking_search,
                        profile_graph, total_comm_time)
from repro.core.graph import EW
from repro.core.hw import TPU_V5E, allreduce_time
from repro.core.search import ALL_METHODS, METHOD_ALGO, random_apply


def chain_graph(n=12, grads=(3, 6, 9), grad_bytes=256.0):
    prims = []
    for i in range(n):
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6,
            grad_param=list(grads).index(i) if i in grads else -1,
            grad_bytes=grad_bytes if i in grads else 0.0,
            grad_sig="f32" if i in grads else ""))
    return profile_graph(FusionGraph(prims, [(i, i + 1) for i in range(n - 1)]))


# ------------------------------------------------------------ flat-spec shim
@settings(max_examples=200, deadline=None)
@given(nbytes=st.floats(min_value=1.0, max_value=1e10),
       n=st.integers(1, 4096))
def test_flat_shim_bit_identical_to_hw_allreduce(nbytes, n):
    spec = ClusterSpec.flat(TPU_V5E, n)
    assert ring_allreduce(nbytes, spec) == allreduce_time(nbytes, TPU_V5E, n)
    # the default bucket algorithm routes through the same path
    assert bucket_time(nbytes, spec) == allreduce_time(nbytes, TPU_V5E, n)


def test_flat_shim_shape():
    spec = ClusterSpec.flat(TPU_V5E, 64)
    assert spec.is_flat_compat
    assert spec.n_devices == 64
    assert len(spec.levels) == 1


# ----------------------------------------------------------------- presets
def test_preset_zoo():
    assert set(list_presets()) == set(PRESETS)
    assert len(PRESETS) >= 6
    for name in PRESETS:
        spec = get_preset(name)
        assert spec.n_devices >= 2
        assert not spec.is_flat_compat
        assert spec.describe()["levels"]
    # the zoo covers hierarchy and heterogeneity
    assert any(len(s.levels) >= 2 for s in PRESETS.values())
    assert any(l.straggler > 1.0 for s in PRESETS.values() for l in s.levels)
    with pytest.raises(KeyError):
        get_preset("no_such_cluster")


def _random_spec(rng: random.Random, max_levels=3) -> ClusterSpec:
    n_levels = rng.randint(1, max_levels)
    levels = []
    for i in range(n_levels):
        levels.append(LinkLevel(
            name=f"l{i}", degree=rng.randint(2, 16),
            bandwidth=10.0 ** rng.uniform(9, 11.7),
            alpha=10.0 ** rng.uniform(-6.3, -3.5),
            straggler=rng.choice([1.0, 1.0, 2.0, 8.0]),
            contention=rng.choice([1.0, 1.0, 1.5, 4.0])))
    return ClusterSpec("rand", tuple(levels))


@settings(max_examples=120, deadline=None)
@given(seed=st.integers(0, 10_000),
       x1=st.floats(min_value=0.0, max_value=1e9),
       x2=st.floats(min_value=0.0, max_value=1e9))
def test_collectives_monotonic_in_bytes(seed, x1, x2):
    """Every model (and the auto choice) is monotonically non-decreasing in
    message size, on random specs and the whole preset zoo."""
    lo, hi = sorted((x1, x2))
    rng = random.Random(seed)
    specs = [_random_spec(rng), rng.choice(list(PRESETS.values())),
             ClusterSpec.flat(TPU_V5E, rng.randint(1, 512))]
    for spec in specs:
        for algo in COLLECTIVE_ALGOS:
            assert bucket_time(lo, spec, algo) <= bucket_time(hi, spec, algo) + 1e-15
        assert best_algo(lo, spec)[1] <= best_algo(hi, spec)[1] + 1e-15


@settings(max_examples=150, deadline=None)
@given(seed=st.integers(0, 10_000),
       nbytes=st.floats(min_value=0.0, max_value=1e10))
def test_hier_never_loses_to_ring_when_inter_host_bottlenecked(seed, nbytes):
    """With inner levels uniformly faster (alpha and effective beta) than the
    outer level — the inter-host-bottleneck regime — hierarchical AllReduce
    is never worse than the flat ring."""
    rng = random.Random(seed)
    bw_out = 10.0 ** rng.uniform(9, 10.5)
    alpha_out = 10.0 ** rng.uniform(-5.5, -3.5)
    contention_out = rng.choice([1.0, 2.0, 4.0])
    inner = LinkLevel(
        "intra", rng.randint(2, 16),
        # inner effective beta <= outer effective beta (even after the
        # outer level's contention is discounted)
        bandwidth=bw_out * rng.uniform(1.0, 50.0),
        alpha=alpha_out * rng.uniform(0.01, 1.0))
    outer = LinkLevel("inter", rng.randint(2, 16), bw_out, alpha_out,
                      contention=contention_out)
    spec = ClusterSpec("two_level", (inner, outer))
    t_hier = hier_allreduce(nbytes, spec)
    t_ring = ring_allreduce(nbytes, spec)
    assert t_hier <= t_ring * (1 + 1e-12) + 1e-15


def test_compat_spec_is_algorithm_blind():
    """The seed's fixed-D linear model cannot distinguish algorithms: on
    the flat back-compat spec every model degenerates to the legacy formula
    (no fictitious tree/hier latencies from treating D as per-step)."""
    spec = ClusterSpec.flat(TPU_V5E, 256)
    for x in (1.0, 1e4, 1e8):
        t = allreduce_time(x, TPU_V5E, 256)
        for algo in COLLECTIVE_ALGOS:
            assert bucket_time(x, spec, algo) == t


def test_hier_without_inner_hierarchy_is_the_flat_ring():
    """'Hierarchical' on a spec with no inner fan-out IS the flat ring —
    it must pay the same contention, not be priced cheaper under a
    different label."""
    lone = ClusterSpec("ib_only",
                       (LinkLevel("ib", 16, 25e9, 15e-6, contention=2.0),))
    degenerate = ClusterSpec(
        "unit_inner",
        (LinkLevel("nvlink", 1, 300e9, 3e-6),
         LinkLevel("ib", 16, 25e9, 15e-6, contention=2.0)))
    for spec in (lone, degenerate):
        for x in (1e3, 1e6, 1e9):
            assert hier_allreduce(x, spec) == ring_allreduce(x, spec)


def test_trivial_sizes_are_free():
    for spec in [ClusterSpec.flat(TPU_V5E, 8), *PRESETS.values()]:
        for algo in COLLECTIVE_ALGOS:
            assert bucket_time(0.0, spec, algo) == 0.0
            assert bucket_time(-1.0, spec, algo) == 0.0
    one = ClusterSpec("solo", (LinkLevel("ici", 1, 50e9, 1e-6),))
    for fn in (ring_allreduce, tree_allreduce, hier_allreduce):
        assert fn(1e6, one) == 0.0


def test_ring_tree_crossover_on_torus_axis():
    """On a single torus axis the ring is neighbour-aligned (no contention)
    while halving-doubling pays link dilation: tree wins small messages on
    latency, ring wins large messages on bandwidth — a real trade-off, not
    a dominated choice."""
    spec = get_preset("tpu_v5e_pod_16")
    assert tree_allreduce(1e3, spec) < ring_allreduce(1e3, spec)
    assert ring_allreduce(1e8, spec) < tree_allreduce(1e8, spec)


def test_coeffs_match_model_and_cache():
    """bucket_time is one multiply-add over memoised (C, D) coefficients;
    the coefficients must reproduce the model exactly."""
    from repro.cluster import allreduce_coeffs

    for spec in PRESETS.values():
        for algo in COLLECTIVE_ALGOS:
            c, d = allreduce_coeffs(spec, algo)
            assert allreduce_coeffs(spec, algo) == (c, d)  # memo stable
            for x in (1.0, 1e6):
                assert bucket_time(x, spec, algo) == c * x + d


def test_best_algo_is_argmin():
    for spec in PRESETS.values():
        for x in (1e2, 1e5, 1e8):
            name, t = best_algo(x, spec)
            times = {a: bucket_time(x, spec, a) for a in COLLECTIVE_ALGOS}
            assert t == min(times.values())
            assert times[name] == t


def test_hier_beats_ring_on_interhost_presets():
    """The zoo contains inter-host-bottlenecked presets where the
    hierarchical algorithm strictly beats the flat ring at DNN gradient
    sizes (the fig_cluster_sweep acceptance bar)."""
    winners = [
        name for name, spec in PRESETS.items()
        if hier_allreduce(1e8, spec) < ring_allreduce(1e8, spec)
    ]
    assert "a100_nvlink_ib" in winners
    assert "cross_dc_2pod" in winners
    assert len(winners) >= 2


# --------------------------------------------------- zero-byte bucket fix
def test_zero_byte_bucket_costs_nothing():
    g = chain_graph(grads=(3, 6, 9), grad_bytes=0.0)
    assert len(g.buckets) == 3
    assert total_comm_time(g, TPU_V5E, 64) == 0.0
    r = Simulator(n_devices=64).run(g)
    assert r.comm_time == 0.0
    assert r.comm_finish == 0.0
    # and on a hierarchical spec through every algorithm
    sim = Simulator(cluster=get_preset("a100_nvlink_ib"))
    h = g.clone()
    for i, a in enumerate(COLLECTIVE_ALGOS):
        h.set_bucket_algo(i, a)
    assert sim.run(h).comm_time == 0.0


# ------------------------------------------------- threading & the search
def test_simulator_flat_default_unchanged():
    """Default-constructed Simulator == explicit flat spec == seed values."""
    g = chain_graph()
    r1 = Simulator(n_devices=64).run(g)
    r2 = Simulator(cluster=ClusterSpec.flat(TPU_V5E, 64)).run(g)
    assert r1.comm_time == r2.comm_time
    assert r1.iteration_time == r2.iteration_time
    exp = sum(allreduce_time(256.0, TPU_V5E, 64) for _ in range(3))
    assert r1.comm_time == exp


def test_cluster_overrides_n_devices():
    spec = get_preset("a100_nvlink_ib")
    sim = Simulator(n_devices=7, cluster=spec)
    assert sim.n_devices == spec.n_devices == 32


def test_algo_choice_changes_cost_and_signatures():
    spec = get_preset("cross_dc_2pod")
    sim = Simulator(cluster=spec)
    g = chain_graph(grad_bytes=float(1 << 22))
    c_ring = sim.cost(g)
    h = g.clone()
    with pytest.raises(ValueError):
        h.set_bucket_algo(0, "heir")  # typo fails fast at the call site
    assert h.set_bucket_algo(0, ALGO_HIER)
    assert not h.set_bucket_algo(0, ALGO_HIER)  # no-op choice rejected
    assert h.fast_signature() != g.fast_signature()
    assert h.signature() != g.signature()
    c_hier = sim.cost(h)
    assert c_hier != c_ring
    # merged buckets keep the leading bucket's algorithm
    assert h.merge_buckets(0, 1)
    assert h.bucket_algos[0] == ALGO_HIER and len(h.bucket_algos) == 2


def test_incremental_equals_full_with_algo_mutations():
    """Golden equivalence extends to the cluster dimension: delta replay
    after algo/bucket/fusion mutations matches full replay bit-for-bit on a
    hierarchical spec."""
    spec = get_preset("h100_superpod")
    sim_inc = Simulator(cluster=spec, incremental=True)
    sim_full = Simulator(cluster=spec, incremental=False)
    rng = random.Random(3)
    parent = chain_graph(n=16, grads=(3, 6, 9, 12), grad_bytes=float(1 << 20))
    saw_algo = False
    for step in range(50):
        child = parent.clone()
        for _ in range(rng.randint(1, 3)):
            m = rng.choice(ALL_METHODS)
            changed = random_apply(child, m, 1, rng)
            saw_algo |= changed and m == METHOD_ALGO
        ri = sim_inc.run(child)
        rf = sim_full.run(child)
        assert ri.iteration_time == rf.iteration_time, step
        assert ri.comm_time == rf.comm_time, step
        assert ri.comm_finish == rf.comm_finish, step
        if rng.random() < 0.6:
            parent = child
    assert saw_algo, "algo mutation never drawn"
    assert sim_inc.stats["delta"] > 0


def test_search_is_joint_over_algorithms():
    """On an inter-host-bottlenecked preset the search flips buckets away
    from the default ring (the joint dimension actually gets used)."""
    spec = get_preset("a100_straggler_ib")
    g = chain_graph(n=20, grads=(3, 7, 11, 15), grad_bytes=float(1 << 24))
    res = backtracking_search(g, Simulator(cluster=spec),
                              unchanged_limit=60, max_steps=120, seed=0)
    algos = set(res.best.bucket_algos)
    assert algos - {ALGO_RING}, algos
    assert res.best_cost <= res.initial_cost


def test_flat_search_skips_algo_method():
    """On the algorithm-blind flat spec the search drops METHOD_ALGO: no
    candidate evaluations are spent on flips that cannot improve, and the
    winning strategy stays all-ring."""
    g = chain_graph(n=16, grads=(3, 6, 9, 12), grad_bytes=float(1 << 20))
    res = backtracking_search(g, Simulator(n_devices=64),
                              unchanged_limit=30, max_steps=50, seed=0)
    assert set(res.best.bucket_algos) == {ALGO_RING}


def test_worker_pool_ships_cluster_and_algos():
    spec = get_preset("a100_nvlink_ib")
    g = chain_graph(n=10, grads=(4, 8), grad_bytes=float(1 << 20))
    kw = dict(unchanged_limit=20, max_steps=25, seed=5)
    r_ser = backtracking_search(g, Simulator(cluster=spec), **kw)
    r_par = backtracking_search(g, Simulator(cluster=spec), workers=2, **kw)
    assert r_par.best_cost == r_ser.best_cost
    assert r_par.best.signature() == r_ser.best.signature()


def test_cluster_from_mesh_bridge():
    """The launch bridge maps mesh axes to link levels (pure shape logic —
    no jax device state needed)."""
    import types

    from repro.launch.mesh import cluster_from_mesh

    single = cluster_from_mesh(types.SimpleNamespace(
        shape={"data": 16, "model": 16}))
    assert single.n_devices == 256
    assert [l.name for l in single.levels] == ["ici_x", "ici_y"]
    assert single.levels[0].bandwidth == TPU_V5E.ici_bw

    multi = cluster_from_mesh(types.SimpleNamespace(
        shape={"pod": 2, "data": 16, "model": 16}))
    assert multi.n_devices == 512
    assert [l.name for l in multi.levels] == ["ici_x", "ici_y", "dcn"]
    assert multi.levels[-1].degree == 2
    # DCN is the bottleneck of the multi-pod mesh, and the bridge shares
    # its level constants with the preset zoo (single source)
    assert multi.bottleneck().name == "dcn"
    assert multi.levels[-1] == get_preset("cross_dc_2pod").levels[-1]
    assert multi.levels[:2] == get_preset("tpu_v5e_pod_256").levels

    small = cluster_from_mesh(types.SimpleNamespace(
        shape={"data": 4, "model": 2}))
    assert small.n_devices == 8

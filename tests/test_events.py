"""Phase-level comm event engine (DESIGN.md Sec. 8) property tests:

(a) ``streams=1`` is bit-identical to the seed's serialized ``_comm_pass``
    on flat *and* hierarchical specs (golden equivalence of the refactor);
(b) no link level is ever oversubscribed beyond its capacity in any
    produced schedule (fair-share invariant);
(c) incremental delta simulation == full replay under stream / algo /
    comm-kind mutations (the engine composes with the PR-1 delta path).
"""
import random

import pytest
from _propcheck import given, settings, st

from repro.cluster import (BUCKET_COMM_KINDS, COLLECTIVE_ALGOS, ClusterSpec,
                           PRESETS, chunk_phases, comm_coeffs, fused_phases,
                           get_preset, phases)
from repro.core import (BackgroundTraffic, CommEngine, CommJob, FusionGraph,
                        PrimOp, Simulator, backtracking_search, profile_graph)
from repro.core.graph import EW
from repro.core.hw import TPU_V5E
from repro.core.search import (ALL_METHODS, CHUNK_CHOICES, METHOD_CHUNK,
                               METHOD_COMM, METHOD_FUSED, random_apply)


def serialized_reference(jobs, spec):
    """The seed's `_comm_pass` arithmetic, verbatim: readiness-ordered FIFO
    on one channel, one c*x+d opaque interval per non-empty bucket."""
    chan_free = 0.0
    busy = 0.0
    finish = 0.0
    for job in sorted(jobs, key=lambda j: (j.ready, j.bucket)):
        if job.nbytes <= 0.0:
            continue
        c, d = comm_coeffs(spec, job.algo, job.kind)
        t = c * job.nbytes + d
        start = max(chan_free, job.ready)
        chan_free = start + t
        busy += t
        finish = chan_free
    return busy, finish


def random_jobs(rng: random.Random, n: int, kinds=("ar",)) -> list[CommJob]:
    return [
        CommJob(bucket=i, ready=rng.uniform(0.0, 2e-3),
                nbytes=rng.choice([0.0, float(rng.randint(1, 1 << 26))]),
                algo=rng.choice(COLLECTIVE_ALGOS),
                kind=rng.choice(kinds))
        for i in range(n)
    ]


SPECS = [ClusterSpec.flat(TPU_V5E, 64), ClusterSpec.flat(TPU_V5E, 1),
         *PRESETS.values()]


# ----------------------------------------------------- (a) golden identity
@settings(max_examples=120, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 12))
def test_streams1_bit_identical_to_serialized_comm_pass(seed, n):
    rng = random.Random(seed)
    spec = rng.choice(SPECS)
    kinds = ("ar",) if spec.is_flat_compat else BUCKET_COMM_KINDS
    jobs = random_jobs(rng, n, kinds)
    eng = CommEngine(spec, streams=1)
    busy, finish = eng.run(list(jobs))
    rbusy, rfinish = serialized_reference(jobs, spec)
    assert busy == rbusy
    assert finish == rfinish


def test_simulator_default_streams_is_seed_channel():
    """Simulator() still prices comm exactly as the seed formula."""
    from repro.core.hw import allreduce_time

    g = chain_graph(grad_bytes=float(1 << 22))
    r = Simulator(n_devices=64).run(g)
    assert r.comm_time == sum(
        allreduce_time(float(1 << 22), TPU_V5E, 64) for _ in range(3))


# ------------------------------------------------- (b) capacity invariant
@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 16),
       streams=st.integers(2, 5))
def test_no_level_oversubscribed(seed, n, streams):
    rng = random.Random(seed)
    spec = rng.choice([s for s in SPECS if not s.is_flat_compat])
    eng = CommEngine(spec, streams=streams, record_load=True)
    jobs = random_jobs(rng, n, BUCKET_COMM_KINDS)
    busy, finish = eng.run(list(jobs), timeline := [])
    # fair-share: the *observed* progress rate on a level (work the level
    # actually advanced / segment span) never exceeds its capacity of one
    # full-bandwidth stream-equivalent
    for level, t0, t1, work in eng.level_load:
        assert 0 <= level < len(spec.levels)
        assert t1 > t0
        assert work / (t1 - t0) <= 1.0 + 1e-9
    # and total level-busy integral is bounded by the makespan
    for level in range(len(spec.levels)):
        occupied = sum(work for l, t0, t1, work in eng.level_load
                       if l == level)
        assert occupied <= finish + 1e-9
    # timeline phases stay inside the schedule span
    for kind, bucket, chunk, tclass, algo, level, start, end in timeline:
        assert start >= 0.0 and end <= finish + 1e-12
        assert kind in ("allreduce", "reduce_scatter", "all_gather")
        assert tclass == "dp" and chunk == 0


@settings(max_examples=80, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 10),
       streams=st.integers(2, 5))
def test_no_level_oversubscribed_mixed_traffic(seed, n, streams):
    """Chunk chains + TP/PP background jobs + deps: the fair-share/FIFO
    capacity invariant must hold for any mix of traffic classes."""
    rng = random.Random(seed)
    spec = rng.choice([s for s in SPECS if not s.is_flat_compat])
    disc = rng.choice(["fair", "fifo"])
    eng = CommEngine(spec, streams=streams, record_load=True,
                     discipline=disc)
    jobs = []
    jid = 1000
    for i in range(n):
        nb = float(rng.randint(1, 1 << 26))
        algo = rng.choice(COLLECTIVE_ALGOS)
        k = rng.choice((1, 2, 4))
        deps = ()
        if jobs and rng.random() < 0.3:
            deps = (rng.choice(jobs).jid,)
        if k == 1:
            jobs.append(CommJob(bucket=i, ready=rng.uniform(0, 2e-3),
                                nbytes=nb, algo=algo, deps=deps))
            continue
        prev = None
        ready = rng.uniform(0, 2e-3)
        for c in range(k):
            jobs.append(CommJob(bucket=i, ready=ready, nbytes=nb / k,
                                algo=algo, job_id=jid, after=prev,
                                chunk=c, chunks=k, deps=deps))
            prev = jid
            jid += 1
    for traffic in (BackgroundTraffic("tp", float(1 << 22), period=3e-4),
                    BackgroundTraffic("pp", float(1 << 20), period=5e-4,
                                      kind="p2p")):
        made = traffic.materialize(2e-3, jid)
        jid += len(made)
        jobs.extend(made)
    busy, finish = eng.run(list(jobs), timeline := [])
    for level, t0, t1, work in eng.level_load:
        assert 0 <= level < len(spec.levels)
        assert t1 > t0
        assert work / (t1 - t0) <= 1.0 + 1e-9
    # every job finished, and finish covers them all
    assert len(eng.job_finish) == len(jobs)
    assert all(f <= finish + 1e-12 for f in eng.job_finish.values())
    for e in timeline:
        assert len(e) == 8 and e[3] in ("dp", "tp", "pp")
        assert e[0] in ("allreduce", "reduce_scatter", "all_gather",
                        "permute")
        assert e[7] >= e[6] >= 0.0
    # deps really are finish-first: a dependent job never starts a phase
    # before every dependency finished
    starts = {}
    for e in timeline:
        jb = (e[1], e[2])
        starts[jb] = min(starts.get(jb, float("inf")), e[6])
    for j in jobs:
        for d in j.deps:
            if d in eng.job_finish and (j.bucket, j.chunk) in starts:
                assert starts[(j.bucket, j.chunk)] >=                     eng.job_finish[d] - 1e-9


# -------------------------------------------- (c) incremental == full
def chain_graph(n=16, grads=(3, 6, 9), grad_bytes=256.0):
    prims = []
    for i in range(n):
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6,
            grad_param=list(grads).index(i) if i in grads else -1,
            grad_bytes=grad_bytes if i in grads else 0.0,
            grad_sig="f32" if i in grads else ""))
    return profile_graph(FusionGraph(prims, [(i, i + 1) for i in range(n - 1)]))


@pytest.mark.parametrize("streams", [1, 4])
def test_incremental_equals_full_with_stream_and_comm_mutations(streams):
    spec = get_preset("a100_nvlink_ib")
    sim_inc = Simulator(cluster=spec, streams=streams, incremental=True)
    sim_full = Simulator(cluster=spec, streams=streams, incremental=False)
    rng = random.Random(11)
    parent = chain_graph(n=18, grads=(3, 7, 11, 15),
                         grad_bytes=float(1 << 22))
    saw_comm = False
    saw_chunk = False
    for step in range(60):
        child = parent.clone()
        for _ in range(rng.randint(1, 3)):
            m = rng.choice(ALL_METHODS)
            changed = random_apply(child, m, 1, rng)
            saw_comm |= changed and m == METHOD_COMM
            saw_chunk |= changed and m == METHOD_CHUNK
        ri = sim_inc.run(child)
        rf = sim_full.run(child)
        assert ri.iteration_time == rf.iteration_time, step
        assert ri.comm_time == rf.comm_time, step
        assert ri.comm_finish == rf.comm_finish, step
        if rng.random() < 0.6:
            parent = child
    assert saw_comm, "comm-kind mutation never drawn"
    assert saw_chunk, "chunk mutation never drawn"
    assert sim_inc.stats["delta"] > 0


# ------------------------------------------------------- engine semantics
def test_rs_ag_prices_like_allreduce_on_serialized_channel():
    """RS + AG legs equal the AllReduce term by term, so the ZeRO-3 split
    never gets a fictitious discount on the serialized channel."""
    for spec in (get_preset("a100_nvlink_ib"), get_preset("cross_dc_2pod"),
                 ClusterSpec.flat(TPU_V5E, 32)):
        for algo in COLLECTIVE_ALGOS:
            c_ar, d_ar = comm_coeffs(spec, algo, "ar")
            c, d = comm_coeffs(spec, algo, "rs_ag")
            assert c == pytest.approx(c_ar, rel=1e-12, abs=1e-30)
            assert d == pytest.approx(d_ar, rel=1e-12, abs=1e-30)


def test_phase_decomposition_sums_to_opaque_coeffs():
    for spec in PRESETS.values():
        for algo in COLLECTIVE_ALGOS:
            for kind in ("ar", "rs", "ag", "rs_ag", "p2p"):
                ph = phases(spec, algo, kind)
                c, d = comm_coeffs(spec, algo, kind)
                assert sum(p.c for p in ph) == pytest.approx(c, rel=1e-12)
                assert sum(p.d for p in ph) == pytest.approx(d, rel=1e-12)
                for p in ph:
                    assert 0 <= p.level < len(spec.levels)


def test_chunk_phases_conserve_coefficients():
    """Per-chunk phase coefficients sum (over the chunks) exactly to the
    unchunked ones — chunking gets no fictitious discount, and chunks=1 is
    the identical phases() tuple (bit-identical schedules)."""
    for spec in PRESETS.values():
        for algo in COLLECTIVE_ALGOS:
            for kind in ("ar", "rs_ag"):
                assert chunk_phases(spec, algo, kind, 1) is \
                    phases(spec, algo, kind)
                c0, d0 = comm_coeffs(spec, algo, kind)
                for k in (2, 4, 8):
                    ph = chunk_phases(spec, algo, kind, k)
                    assert sum(p.c for p in ph) == pytest.approx(
                        c0, rel=1e-12)
                    assert k * sum(p.d for p in ph) == pytest.approx(
                        d0, rel=1e-12, abs=1e-30)


def test_fused_phases_conserve_coefficients():
    """In-kernel fusion conserves link work exactly: the per-chunk fused
    phase ``(c, d)`` coefficients equal the :func:`chunk_phases` ones for
    every discount (only readiness moves), kinds gain the ``fused_`` tag,
    and ``discount=0`` is the identical ``chunk_phases`` tuple
    (bit-identical schedules, same cache line)."""
    for spec in PRESETS.values():
        for algo in COLLECTIVE_ALGOS:
            for kind in ("ar", "rs_ag"):
                for k in (1, 2, 8):
                    base = chunk_phases(spec, algo, kind, k)
                    assert fused_phases(spec, algo, kind, k, 0.0) is base
                    fz = fused_phases(spec, algo, kind, k, 0.525)
                    assert len(fz) == len(base)
                    for p, q in zip(base, fz):
                        assert q.c == p.c and q.d == p.d
                        assert q.level == p.level
                        assert q.kind == f"fused_{p.kind}"
                        assert q.overlap == 0.525
    with pytest.raises(ValueError):
        fused_phases(get_preset("a100_nvlink_ib"), "ring", "ar", 1, 1.0)


@pytest.mark.parametrize("streams", [1, 4])
def test_incremental_equals_full_with_fused_mutations(streams):
    """Delta simulation == full replay when METHOD_FUSED flips per-bucket
    in-kernel fusion flags alongside every legacy mutation, on a calibrated
    (discounted) sim."""
    spec = get_preset("a100_nvlink_ib")
    kw = dict(cluster=spec, streams=streams, overlap_discount=0.525)
    sim_inc = Simulator(incremental=True, **kw)
    sim_full = Simulator(incremental=False, **kw)
    rng = random.Random(23)
    parent = chain_graph(n=18, grads=(3, 7, 11, 15),
                         grad_bytes=float(1 << 22))
    methods = ALL_METHODS + (METHOD_FUSED,)
    saw_fused = False
    for step in range(60):
        child = parent.clone()
        for _ in range(rng.randint(1, 3)):
            m = rng.choice(methods)
            changed = random_apply(child, m, 1, rng)
            saw_fused |= changed and m == METHOD_FUSED
        ri = sim_inc.run(child)
        rf = sim_full.run(child)
        assert ri.iteration_time == rf.iteration_time, step
        assert ri.comm_time == rf.comm_time, step
        assert ri.comm_finish == rf.comm_finish, step
        if rng.random() < 0.6:
            parent = child
    assert saw_fused, "fused mutation never drawn"
    assert sim_inc.stats["delta"] > 0


def test_search_fuses_only_on_discounted_multistream_sim():
    """METHOD_FUSED is dropped on serialized or undiscounted sims (legacy
    trajectories bit-identical) and live on a calibrated multi-stream sim,
    where a fused bucket never prices worse than its unfused twin."""
    spec = get_preset("cross_dc_2pod")
    g = chain_graph(n=20, grads=(3, 7, 11, 15), grad_bytes=float(1 << 24))
    kw = dict(unchanged_limit=40, max_steps=60, seed=2)
    for sim in (Simulator(cluster=spec, streams=1, overlap_discount=0.525),
                Simulator(cluster=spec, streams=4, overlap_discount=0.0)):
        res = backtracking_search(g, sim, **kw)
        assert not any(res.best.bucket_fused)
    sim4 = Simulator(cluster=spec, streams=4, overlap_discount=0.525)
    res4 = backtracking_search(g, sim4, **kw)
    assert res4.best_cost <= res4.initial_cost
    base = sim4.run(g).iteration_time
    fz = g.clone()
    for i in range(len(fz.buckets)):
        fz.set_bucket_fused(i, True)
    assert sim4.run(fz).iteration_time <= base + 1e-15


def _chunk_chain(bucket, ready, nbytes, algo, k, base_id, kind="ar"):
    jobs = []
    prev = None
    for c in range(k):
        jobs.append(CommJob(bucket=bucket, ready=ready, nbytes=nbytes / k,
                            algo=algo, kind=kind, job_id=base_id + c,
                            after=prev, chunk=c, chunks=k))
        prev = base_id + c
    return jobs


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_chunks1_bit_identical_on_flat_and_hier(seed):
    """A chunks=1 'chain' is the plain job: engine results are bit-equal
    on flat and hierarchical specs, any stream count."""
    rng = random.Random(seed)
    spec = rng.choice(SPECS)
    streams = rng.choice((1, 2, 4))
    kinds = ("ar",) if spec.is_flat_compat else BUCKET_COMM_KINDS
    plain = random_jobs(rng, rng.randint(1, 8), kinds)
    chained = [CommJob(bucket=j.bucket, ready=j.ready, nbytes=j.nbytes,
                       algo=j.algo, kind=j.kind, job_id=j.bucket,
                       chunk=0, chunks=1) for j in plain]
    b0, f0 = CommEngine(spec, streams=streams).run(list(plain))
    b1, f1 = CommEngine(spec, streams=streams).run(chained)
    assert b0 == b1 and f0 == f1


def test_chunked_bucket_strictly_beats_whole_on_multiphase_schedule():
    """One large hierarchical bucket: chunks pipeline its RS/AR/AG legs
    across levels — strictly earlier finish, identical total work — while
    a single-phase ring schedule gains nothing (store-and-forward through
    one level is just the same transfer in k pieces)."""
    spec = get_preset("a100_nvlink_ib")
    nb = float(1 << 26)
    _, whole = CommEngine(spec, streams=2).run([CommJob(0, 0.0, nb, "hier")])
    busy_whole = CommEngine(spec, streams=2).run(
        [CommJob(0, 0.0, nb, "hier")])[0]
    last = whole
    for k in (2, 4, 8):
        busy, fin = CommEngine(spec, streams=2).run(
            _chunk_chain(0, 0.0, nb, "hier", k, 100))
        assert fin < whole
        assert fin <= last + 1e-15  # finer chunks never hurt
        assert busy == pytest.approx(busy_whole, rel=1e-9)  # work conserved
        last = fin
    # ring: single bottleneck phase, no pipeline to exploit
    _, ring_whole = CommEngine(spec, streams=2).run(
        [CommJob(0, 0.0, nb, "ring")])
    _, ring_chunk = CommEngine(spec, streams=2).run(
        _chunk_chain(0, 0.0, nb, "ring", 4, 200))
    assert ring_chunk == pytest.approx(ring_whole, rel=1e-9)


def test_fifo_discipline_serves_in_arrival_order():
    """Under per-level FIFO the first arrival finishes first at full
    rate; fair-share runs the same pair in lockstep."""
    spec = get_preset("a100_nvlink_ib")
    nb = float(1 << 24)
    jobs = [CommJob(0, 0.0, nb, "ring"), CommJob(1, 1e-6, nb, "ring")]
    fifo = CommEngine(spec, streams=2, discipline="fifo")
    _, f_fifo = fifo.run(list(jobs))
    t_one = comm_coeffs(spec, "ring", "ar")[0] * nb \
        + comm_coeffs(spec, "ring", "ar")[1]
    assert fifo.job_finish[0] == pytest.approx(t_one, rel=1e-12)
    assert fifo.job_finish[1] > fifo.job_finish[0]
    fair = CommEngine(spec, streams=2)
    fair.run(list(jobs))
    # fair-share: both in flight, both finish near the end
    assert fair.job_finish[0] > t_one


def test_store_and_forward_chunks_never_overtake():
    """Chunk c's phase-p record never ends before chunk c-1's phase-p
    record (the after-dependency orders the chain at every level)."""
    spec = get_preset("cross_dc_2pod")
    tl = []
    CommEngine(spec, streams=2).run(
        _chunk_chain(0, 0.0, float(1 << 26), "hier", 4, 10), tl)
    ends: dict = {}
    for kind, bucket, chunk, tclass, algo, level, start, end in tl:
        ends.setdefault(chunk, []).append(end)
    n_phases = {c: len(v) for c, v in ends.items()}
    assert len(set(n_phases.values())) == 1  # same phase count per chunk
    for c in range(1, 4):
        for p, (e_prev, e_cur) in enumerate(zip(ends[c - 1], ends[c])):
            assert e_cur >= e_prev - 1e-15, (c, p)


def test_background_traffic_contends_and_is_classed():
    """TP background jobs slow the gradient class down and show up under
    their own class in the tallies and the timeline."""
    spec = get_preset("a100_nvlink_ib")
    nb = float(1 << 25)
    grads = [CommJob(0, 0.0, nb, "hier"), CommJob(1, 3e-4, nb, "hier")]
    alone = CommEngine(spec, streams=4)
    alone.run(list(grads))
    bg = BackgroundTraffic("tp", float(1 << 23), period=1e-4,
                           algo="ring").materialize(alone.class_finish["dp"],
                                                    100)
    cont = CommEngine(spec, streams=4)
    tl = []
    cont.run(list(grads) + bg, tl)
    assert cont.class_finish["dp"] > alone.class_finish["dp"]
    assert cont.class_busy["tp"] > 0.0
    assert {e[3] for e in tl} == {"dp", "tp"}
    # gradient busy work is unchanged by contention (fluid model conserves
    # work; only the schedule stretches)
    assert cont.class_busy["dp"] == pytest.approx(
        alone.class_busy["dp"], rel=1e-9)


def test_simulator_background_prices_contention():
    spec = get_preset("a100_nvlink_ib")
    g = chain_graph(n=18, grads=(3, 7, 11, 15), grad_bytes=float(1 << 24))
    bg = (BackgroundTraffic("tp", float(1 << 22), period=2e-5,
                            algo="ring"),)
    r0 = Simulator(cluster=spec, streams=4).run(g)
    r1 = Simulator(cluster=spec, streams=4, background=bg).run(g)
    assert r1.comm_finish > r0.comm_finish
    # serialized channel ignores background (seed model stays bit-identical)
    s0 = Simulator(cluster=spec, streams=1).run(g)
    s1 = Simulator(cluster=spec, streams=1, background=bg).run(g)
    assert s0.iteration_time == s1.iteration_time


def test_search_chunks_only_on_multistream_sim():
    """METHOD_CHUNK is dropped on serialized/flat sims (PR-2/PR-3
    trajectories unchanged) and live on multi-stream topology sims."""
    spec = get_preset("cross_dc_2pod")
    g = chain_graph(n=20, grads=(3, 7, 11, 15), grad_bytes=float(1 << 24))
    res1 = backtracking_search(g, Simulator(cluster=spec, streams=1),
                               unchanged_limit=40, max_steps=60, seed=2)
    assert set(res1.best.bucket_chunks) == {1}
    flat = backtracking_search(g, Simulator(n_devices=64),
                               unchanged_limit=40, max_steps=60, seed=2)
    assert set(flat.best.bucket_chunks) == {1}
    res4 = backtracking_search(g, Simulator(cluster=spec, streams=4),
                               unchanged_limit=40, max_steps=60, seed=2)
    assert res4.best_cost <= res4.initial_cost
    assert CHUNK_CHOICES[0] == 1


def test_hier_phase_sequence_is_rs_ar_ag():
    """Hierarchical AllReduce decomposes into intra reduce-scatter ->
    inter allreduce -> intra all-gather, inner levels outward-in."""
    spec = get_preset("a100_nvlink_ib")  # nvlink x ib
    ph = phases(spec, "hier", "ar")
    kinds = [p.kind for p in ph]
    assert kinds == ["reduce_scatter", "allreduce", "all_gather"]
    assert [p.level for p in ph] == [0, 1, 0]


def test_pipelined_streams_strictly_beat_serialized_channel():
    """Two hierarchical buckets with staggered readiness (gradients finish
    at different compute times): bucket B's intra-host phase overlaps
    bucket A's inter-host phase on a 2-stream engine — strictly earlier
    finish than the serialized channel.  (Simultaneous identical jobs
    progress in lockstep under fair share and gain nothing — the win comes
    from phase offset, which real schedules always have.)"""
    spec = get_preset("a100_nvlink_ib")
    nb = float(1 << 26)
    stagger = phases(spec, "hier", "ar")[0].seconds(nb)  # A's intra-RS span
    jobs = [CommJob(0, 0.0, nb, "hier"),
            CommJob(1, stagger, nb, "hier")]
    _, ser = CommEngine(spec, streams=1).run(list(jobs))
    _, pip = CommEngine(spec, streams=2).run(list(jobs))
    assert pip < ser
    # but never faster than one bucket alone (the fabric is conserved)
    _, solo = CommEngine(spec, streams=1).run([jobs[0]])
    assert pip >= solo - 1e-15


def test_phased_timeline_distinguishes_phases():
    spec = get_preset("a100_nvlink_ib")
    jobs = [CommJob(0, 0.0, float(1 << 24), "hier"),
            CommJob(1, 0.0, float(1 << 24), "hier", kind="rs_ag")]
    tl = []
    CommEngine(spec, streams=2).run(jobs, tl)
    kinds = {e[0] for e in tl}
    assert "reduce_scatter" in kinds and "all_gather" in kinds
    levels = {e[5] for e in tl}
    assert levels == {"nvlink", "ib_hdr"}
    # records are (kind, bucket, chunk, traffic_class, algo, level, start,
    # end) with non-negative, ordered spans
    for e in tl:
        assert len(e) == 8 and e[7] >= e[6] >= 0.0


def test_engine_reuse_resets_utilisation_segments():
    """A second run() on the same engine is an independent schedule:
    level_load must not accumulate segments across runs."""
    spec = get_preset("a100_nvlink_ib")
    eng = CommEngine(spec, streams=2, record_load=True)
    jobs = [CommJob(0, 0.0, float(1 << 24), "hier"),
            CommJob(1, 1e-4, float(1 << 24), "hier")]
    eng.run(list(jobs))
    first = list(eng.level_load)
    _, finish = eng.run(list(jobs))
    assert eng.level_load == first
    for level in range(len(spec.levels)):
        occupied = sum(w for l, _, _, w in eng.level_load if l == level)
        assert occupied <= finish + 1e-9


def test_zero_byte_jobs_are_free_in_both_modes():
    spec = get_preset("h100_superpod")
    jobs = [CommJob(0, 0.0, 0.0, "hier"), CommJob(1, 0.0, 0.0, "ring")]
    for streams in (1, 3):
        busy, finish = CommEngine(spec, streams=streams).run(list(jobs))
        assert busy == 0.0 and finish == 0.0


def test_search_flips_comm_kind_on_multistream_sim():
    """METHOD_COMM is live on a multi-stream sim over a real topology (and
    the joint search still improves), while a streams=1 search keeps the
    PR-2 method set — every bucket stays on the AllReduce path."""
    spec = get_preset("cross_dc_2pod")
    g = chain_graph(n=20, grads=(3, 7, 11, 15), grad_bytes=float(1 << 24))
    res1 = backtracking_search(g, Simulator(cluster=spec, streams=1),
                               unchanged_limit=40, max_steps=60, seed=2)
    assert set(res1.best.bucket_comm) == {"ar"}
    res4 = backtracking_search(g, Simulator(cluster=spec, streams=4),
                               unchanged_limit=40, max_steps=60, seed=2)
    assert res4.best_cost <= res4.initial_cost


def test_worker_pool_ships_streams_and_comm_kinds():
    spec = get_preset("a100_nvlink_ib")
    g = chain_graph(n=12, grads=(4, 8), grad_bytes=float(1 << 22))
    kw = dict(unchanged_limit=15, max_steps=20, seed=5)
    r_ser = backtracking_search(g, Simulator(cluster=spec, streams=4), **kw)
    r_par = backtracking_search(g, Simulator(cluster=spec, streams=4),
                                workers=2, **kw)
    assert r_par.best_cost == r_ser.best_cost
    assert r_par.best.signature() == r_ser.best.signature()

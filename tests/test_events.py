"""Phase-level comm event engine (DESIGN.md Sec. 8) property tests:

(a) ``streams=1`` is bit-identical to the seed's serialized ``_comm_pass``
    on flat *and* hierarchical specs (golden equivalence of the refactor);
(b) no link level is ever oversubscribed beyond its capacity in any
    produced schedule (fair-share invariant);
(c) incremental delta simulation == full replay under stream / algo /
    comm-kind mutations (the engine composes with the PR-1 delta path).
"""
import random

import pytest
from _propcheck import given, settings, st

from repro.cluster import (BUCKET_COMM_KINDS, COLLECTIVE_ALGOS, ClusterSpec,
                           PRESETS, comm_coeffs, get_preset, phases)
from repro.core import (CommEngine, CommJob, FusionGraph, PrimOp, Simulator,
                        backtracking_search, profile_graph)
from repro.core.graph import EW
from repro.core.hw import TPU_V5E
from repro.core.search import ALL_METHODS, METHOD_COMM, random_apply


def serialized_reference(jobs, spec):
    """The seed's `_comm_pass` arithmetic, verbatim: readiness-ordered FIFO
    on one channel, one c*x+d opaque interval per non-empty bucket."""
    chan_free = 0.0
    busy = 0.0
    finish = 0.0
    for job in sorted(jobs, key=lambda j: (j.ready, j.bucket)):
        if job.nbytes <= 0.0:
            continue
        c, d = comm_coeffs(spec, job.algo, job.kind)
        t = c * job.nbytes + d
        start = max(chan_free, job.ready)
        chan_free = start + t
        busy += t
        finish = chan_free
    return busy, finish


def random_jobs(rng: random.Random, n: int, kinds=("ar",)) -> list[CommJob]:
    return [
        CommJob(bucket=i, ready=rng.uniform(0.0, 2e-3),
                nbytes=rng.choice([0.0, float(rng.randint(1, 1 << 26))]),
                algo=rng.choice(COLLECTIVE_ALGOS),
                kind=rng.choice(kinds))
        for i in range(n)
    ]


SPECS = [ClusterSpec.flat(TPU_V5E, 64), ClusterSpec.flat(TPU_V5E, 1),
         *PRESETS.values()]


# ----------------------------------------------------- (a) golden identity
@settings(max_examples=120, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 12))
def test_streams1_bit_identical_to_serialized_comm_pass(seed, n):
    rng = random.Random(seed)
    spec = rng.choice(SPECS)
    kinds = ("ar",) if spec.is_flat_compat else BUCKET_COMM_KINDS
    jobs = random_jobs(rng, n, kinds)
    eng = CommEngine(spec, streams=1)
    busy, finish = eng.run(list(jobs))
    rbusy, rfinish = serialized_reference(jobs, spec)
    assert busy == rbusy
    assert finish == rfinish


def test_simulator_default_streams_is_seed_channel():
    """Simulator() still prices comm exactly as the seed formula."""
    from repro.core.hw import allreduce_time

    g = chain_graph(grad_bytes=float(1 << 22))
    r = Simulator(n_devices=64).run(g)
    assert r.comm_time == sum(
        allreduce_time(float(1 << 22), TPU_V5E, 64) for _ in range(3))


# ------------------------------------------------- (b) capacity invariant
@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 16),
       streams=st.integers(2, 5))
def test_no_level_oversubscribed(seed, n, streams):
    rng = random.Random(seed)
    spec = rng.choice([s for s in SPECS if not s.is_flat_compat])
    eng = CommEngine(spec, streams=streams, record_load=True)
    jobs = random_jobs(rng, n, BUCKET_COMM_KINDS)
    busy, finish = eng.run(list(jobs), timeline := [])
    # fair-share: the *observed* progress rate on a level (work the level
    # actually advanced / segment span) never exceeds its capacity of one
    # full-bandwidth stream-equivalent
    for level, t0, t1, work in eng.level_load:
        assert 0 <= level < len(spec.levels)
        assert t1 > t0
        assert work / (t1 - t0) <= 1.0 + 1e-9
    # and total level-busy integral is bounded by the makespan
    for level in range(len(spec.levels)):
        occupied = sum(work for l, t0, t1, work in eng.level_load
                       if l == level)
        assert occupied <= finish + 1e-9
    # timeline phases stay inside the schedule span
    for kind, bucket, algo, level, start, end in timeline:
        assert start >= 0.0 and end <= finish + 1e-12
        assert kind in ("allreduce", "reduce_scatter", "all_gather")


# -------------------------------------------- (c) incremental == full
def chain_graph(n=16, grads=(3, 6, 9), grad_bytes=256.0):
    prims = []
    for i in range(n):
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6,
            grad_param=list(grads).index(i) if i in grads else -1,
            grad_bytes=grad_bytes if i in grads else 0.0,
            grad_sig="f32" if i in grads else ""))
    return profile_graph(FusionGraph(prims, [(i, i + 1) for i in range(n - 1)]))


@pytest.mark.parametrize("streams", [1, 4])
def test_incremental_equals_full_with_stream_and_comm_mutations(streams):
    spec = get_preset("a100_nvlink_ib")
    sim_inc = Simulator(cluster=spec, streams=streams, incremental=True)
    sim_full = Simulator(cluster=spec, streams=streams, incremental=False)
    rng = random.Random(11)
    parent = chain_graph(n=18, grads=(3, 7, 11, 15),
                         grad_bytes=float(1 << 22))
    saw_comm = False
    for step in range(60):
        child = parent.clone()
        for _ in range(rng.randint(1, 3)):
            m = rng.choice(ALL_METHODS)
            changed = random_apply(child, m, 1, rng)
            saw_comm |= changed and m == METHOD_COMM
        ri = sim_inc.run(child)
        rf = sim_full.run(child)
        assert ri.iteration_time == rf.iteration_time, step
        assert ri.comm_time == rf.comm_time, step
        assert ri.comm_finish == rf.comm_finish, step
        if rng.random() < 0.6:
            parent = child
    assert saw_comm, "comm-kind mutation never drawn"
    assert sim_inc.stats["delta"] > 0


# ------------------------------------------------------- engine semantics
def test_rs_ag_prices_like_allreduce_on_serialized_channel():
    """RS + AG legs equal the AllReduce term by term, so the ZeRO-3 split
    never gets a fictitious discount on the serialized channel."""
    for spec in (get_preset("a100_nvlink_ib"), get_preset("cross_dc_2pod"),
                 ClusterSpec.flat(TPU_V5E, 32)):
        for algo in COLLECTIVE_ALGOS:
            c_ar, d_ar = comm_coeffs(spec, algo, "ar")
            c, d = comm_coeffs(spec, algo, "rs_ag")
            assert c == pytest.approx(c_ar, rel=1e-12, abs=1e-30)
            assert d == pytest.approx(d_ar, rel=1e-12, abs=1e-30)


def test_phase_decomposition_sums_to_opaque_coeffs():
    for spec in PRESETS.values():
        for algo in COLLECTIVE_ALGOS:
            for kind in ("ar", "rs", "ag", "rs_ag"):
                ph = phases(spec, algo, kind)
                c, d = comm_coeffs(spec, algo, kind)
                assert sum(p.c for p in ph) == pytest.approx(c, rel=1e-12)
                assert sum(p.d for p in ph) == pytest.approx(d, rel=1e-12)
                for p in ph:
                    assert 0 <= p.level < len(spec.levels)


def test_hier_phase_sequence_is_rs_ar_ag():
    """Hierarchical AllReduce decomposes into intra reduce-scatter ->
    inter allreduce -> intra all-gather, inner levels outward-in."""
    spec = get_preset("a100_nvlink_ib")  # nvlink x ib
    ph = phases(spec, "hier", "ar")
    kinds = [p.kind for p in ph]
    assert kinds == ["reduce_scatter", "allreduce", "all_gather"]
    assert [p.level for p in ph] == [0, 1, 0]


def test_pipelined_streams_strictly_beat_serialized_channel():
    """Two hierarchical buckets with staggered readiness (gradients finish
    at different compute times): bucket B's intra-host phase overlaps
    bucket A's inter-host phase on a 2-stream engine — strictly earlier
    finish than the serialized channel.  (Simultaneous identical jobs
    progress in lockstep under fair share and gain nothing — the win comes
    from phase offset, which real schedules always have.)"""
    spec = get_preset("a100_nvlink_ib")
    nb = float(1 << 26)
    stagger = phases(spec, "hier", "ar")[0].seconds(nb)  # A's intra-RS span
    jobs = [CommJob(0, 0.0, nb, "hier"),
            CommJob(1, stagger, nb, "hier")]
    _, ser = CommEngine(spec, streams=1).run(list(jobs))
    _, pip = CommEngine(spec, streams=2).run(list(jobs))
    assert pip < ser
    # but never faster than one bucket alone (the fabric is conserved)
    _, solo = CommEngine(spec, streams=1).run([jobs[0]])
    assert pip >= solo - 1e-15


def test_phased_timeline_distinguishes_phases():
    spec = get_preset("a100_nvlink_ib")
    jobs = [CommJob(0, 0.0, float(1 << 24), "hier"),
            CommJob(1, 0.0, float(1 << 24), "hier", kind="rs_ag")]
    tl = []
    CommEngine(spec, streams=2).run(jobs, tl)
    kinds = {e[0] for e in tl}
    assert "reduce_scatter" in kinds and "all_gather" in kinds
    levels = {e[3] for e in tl}
    assert levels == {"nvlink", "ib_hdr"}
    # records are (kind, bucket, algo, level, start, end), time-ordered ends
    for e in tl:
        assert len(e) == 6 and e[5] >= e[4] >= 0.0


def test_engine_reuse_resets_utilisation_segments():
    """A second run() on the same engine is an independent schedule:
    level_load must not accumulate segments across runs."""
    spec = get_preset("a100_nvlink_ib")
    eng = CommEngine(spec, streams=2, record_load=True)
    jobs = [CommJob(0, 0.0, float(1 << 24), "hier"),
            CommJob(1, 1e-4, float(1 << 24), "hier")]
    eng.run(list(jobs))
    first = list(eng.level_load)
    _, finish = eng.run(list(jobs))
    assert eng.level_load == first
    for level in range(len(spec.levels)):
        occupied = sum(w for l, _, _, w in eng.level_load if l == level)
        assert occupied <= finish + 1e-9


def test_zero_byte_jobs_are_free_in_both_modes():
    spec = get_preset("h100_superpod")
    jobs = [CommJob(0, 0.0, 0.0, "hier"), CommJob(1, 0.0, 0.0, "ring")]
    for streams in (1, 3):
        busy, finish = CommEngine(spec, streams=streams).run(list(jobs))
        assert busy == 0.0 and finish == 0.0


def test_search_flips_comm_kind_on_multistream_sim():
    """METHOD_COMM is live on a multi-stream sim over a real topology (and
    the joint search still improves), while a streams=1 search keeps the
    PR-2 method set — every bucket stays on the AllReduce path."""
    spec = get_preset("cross_dc_2pod")
    g = chain_graph(n=20, grads=(3, 7, 11, 15), grad_bytes=float(1 << 24))
    res1 = backtracking_search(g, Simulator(cluster=spec, streams=1),
                               unchanged_limit=40, max_steps=60, seed=2)
    assert set(res1.best.bucket_comm) == {"ar"}
    res4 = backtracking_search(g, Simulator(cluster=spec, streams=4),
                               unchanged_limit=40, max_steps=60, seed=2)
    assert res4.best_cost <= res4.initial_cost


def test_worker_pool_ships_streams_and_comm_kinds():
    spec = get_preset("a100_nvlink_ib")
    g = chain_graph(n=12, grads=(4, 8), grad_bytes=float(1 << 22))
    kw = dict(unchanged_limit=15, max_steps=20, seed=5)
    r_ser = backtracking_search(g, Simulator(cluster=spec, streams=4), **kw)
    r_par = backtracking_search(g, Simulator(cluster=spec, streams=4),
                                workers=2, **kw)
    assert r_par.best_cost == r_ser.best_cost
    assert r_par.best.signature() == r_ser.best.signature()

"""Golden equivalence: the incremental fusion-graph engine (maintained
quotient + delta simulation + rolling signature + worker pool) must be
bit-identical in cost to the seed full-replay path on fixed seeds."""
import random

import pytest

from repro.configs import get_config
from repro.core import (OracleEstimator, Simulator, backtracking_search,
                        profile_graph, trace_grad_graph)
from repro.core.graph import EW, FusionGraph, PrimOp
from repro.core.search import ALL_METHODS, random_apply


def traced_graph(arch: str):
    import jax

    from repro.data.pipeline import materialize_batch
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = materialize_batch(cfg, 2, 16, seed=0)
    return profile_graph(trace_grad_graph(
        lambda p, bt: M.loss_fn(p, cfg, bt), params, data))


@pytest.fixture(scope="module")
def transformer_graph():
    return traced_graph("transformer-paper")


@pytest.fixture(scope="module")
def qwen_graph():
    return traced_graph("qwen2-0.5b")


def _mutation_walk_equivalence(g0, seed, steps=60):
    """After every accepted mutation: maintained quotient == from-scratch
    quotient, and delta-path SimResult == full-replay SimResult (bit-equal)."""
    rng = random.Random(seed)
    sim_inc = Simulator(n_devices=64, incremental=True)
    sim_full = Simulator(n_devices=64, incremental=False)
    parent = g0
    for step in range(steps):
        child = parent.clone()
        for _ in range(rng.randint(1, 3)):
            random_apply(child, rng.choice(ALL_METHODS), 1, rng)
        succs, preds = child.quotient()
        succs2, preds2 = child._quotient_from_scratch()
        assert succs == succs2 and preds == preds2, step
        ri = sim_inc.run(child)
        rf = sim_full.run(child)
        assert ri.iteration_time == rf.iteration_time, step
        assert ri.compute_time == rf.compute_time, step
        assert ri.comm_time == rf.comm_time, step
        assert ri.compute_finish == rf.compute_finish, step
        assert ri.comm_finish == rf.comm_finish, step
        if rng.random() < 0.6:
            parent = child
    assert sim_inc.stats["delta"] > 0, "delta path never exercised"


def test_mutation_walk_equivalence_transformer(transformer_graph):
    _mutation_walk_equivalence(transformer_graph, seed=0)


def test_mutation_walk_equivalence_qwen(qwen_graph):
    _mutation_walk_equivalence(qwen_graph, seed=1, steps=40)


@pytest.mark.parametrize("seed", [0, 7])
def test_search_golden_equivalence_transformer(transformer_graph, seed):
    kw = dict(unchanged_limit=30, max_steps=40, seed=seed)
    r_inc = backtracking_search(
        transformer_graph, Simulator(n_devices=64, incremental=True), **kw)
    r_full = backtracking_search(
        transformer_graph, Simulator(n_devices=64, incremental=False), **kw)
    assert r_inc.best_cost == r_full.best_cost
    assert r_inc.initial_cost == r_full.initial_cost
    assert r_inc.steps == r_full.steps
    assert r_inc.simulations == r_full.simulations
    assert r_inc.best.signature() == r_full.best.signature()


def test_search_golden_equivalence_qwen(qwen_graph):
    kw = dict(unchanged_limit=25, max_steps=30, seed=3)
    r_inc = backtracking_search(
        qwen_graph, Simulator(n_devices=64, incremental=True), **kw)
    r_full = backtracking_search(
        qwen_graph, Simulator(n_devices=64, incremental=False), **kw)
    assert r_inc.best_cost == r_full.best_cost
    assert r_inc.simulations == r_full.simulations
    assert r_inc.best.signature() == r_full.best.signature()


# --------------------------------------------------------------- unit tests
def chain_graph(n=12, grads=(3, 6, 9)):
    prims = []
    for i in range(n):
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW, flops=100.0, in_bytes=64.0,
            out_bytes=64.0, time=1e-6,
            grad_param=list(grads).index(i) if i in grads else -1,
            grad_bytes=256.0 if i in grads else 0.0,
            grad_sig="f32" if i in grads else ""))
    return FusionGraph(prims, [(i, i + 1) for i in range(n - 1)])


def test_fast_signature_tracks_full_signature():
    """Graphs with equal strategies have equal rolling hashes regardless of
    the mutation path that produced them."""
    a = chain_graph()
    b = chain_graph()
    # same end state via different operand orders
    assert a.fuse_nondup(2, 1) and a.fuse_nondup(a.provider[1], 0)
    assert b.fuse_nondup(1, 0) and b.fuse_nondup(2, b.provider[0])
    assert a.signature() == b.signature()
    assert a.fast_signature() == b.fast_signature()
    # diverge: hashes must split too
    assert a.merge_buckets(0, 1)
    assert a.fast_signature() != b.fast_signature()


class _ConstSim:
    """Stub simulator: constant cost — no candidate ever improves."""

    def cost(self, g):
        return 1.0


def test_unchanged_counted_once_per_step():
    """Paper Alg. 1: patience is per dequeued step, independent of how many
    method draws a step makes (the seed counted up to 3x per step)."""
    res = backtracking_search(chain_graph(), _ConstSim(), unchanged_limit=9,
                              alpha=2.0, seed=0)
    assert res.steps == 9


def test_estimator_cache_not_stale_across_graphs():
    """One estimator shared across graphs whose prims differ (same pids,
    different flops/bytes) must not return cached times from the other."""
    prims_a = [PrimOp(i, "mul", EW, 1e4, 64.0, 64.0, 0.0) for i in range(3)]
    prims_b = [PrimOp(i, "mul", EW, 1e9, 1e6, 1e6, 0.0) for i in range(3)]
    edges = [(0, 1), (1, 2)]
    ga = profile_graph(FusionGraph(prims_a, edges))
    gb = profile_graph(FusionGraph(prims_b, edges))
    est = OracleEstimator()
    gid_a = next(iter(ga.groups))
    gid_b = next(iter(gb.groups))
    ta = est.group_time(ga, gid_a)
    tb = est.group_time(gb, gid_b)
    assert ta != tb
    # and repeated queries still hit the (now correctly keyed) cache
    assert est.group_time(ga, gid_a) == ta
    assert est.group_time(gb, gid_b) == tb


def test_worker_pool_matches_serial():
    g = chain_graph(n=10, grads=(4, 8))
    kw = dict(unchanged_limit=20, max_steps=25, seed=5)
    r_ser = backtracking_search(g, Simulator(n_devices=64), **kw)
    r_par = backtracking_search(g, Simulator(n_devices=64), workers=2, **kw)
    assert r_par.best_cost == r_ser.best_cost
    assert r_par.simulations == r_ser.simulations
    assert r_par.best.signature() == r_ser.best.signature()

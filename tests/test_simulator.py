"""Simulator (paper Sec. 4.4) unit + property tests."""
import random

import pytest
from _propcheck import given, settings, st

from repro.core import (FusionGraph, OracleEstimator, PrimOp, Simulator,
                        profile_graph)
from repro.core.graph import EW
from repro.core.hw import TPU_V5E, allreduce_time
from repro.core.search import ALL_METHODS, random_apply

from test_core_graph import chain_graph, diamond_graph


def random_dag(seed: int, n: int = 20, n_grads: int = 4) -> FusionGraph:
    rng = random.Random(seed)
    prims, edges = [], []
    grad_pids = set(rng.sample(range(n // 2, n), n_grads))
    gi = 0
    for i in range(n):
        gp = -1
        gb = 0.0
        if i in grad_pids:
            gp, gb = gi, float(rng.randint(64, 1 << 20))
            gi += 1
        prims.append(PrimOp(
            pid=i, op_type="mul", category=EW,
            flops=float(rng.randint(10, 10**7)),
            in_bytes=float(rng.randint(8, 1 << 18)),
            out_bytes=float(rng.randint(8, 1 << 18)),
            time=0.0, grad_param=gp, grad_bytes=gb,
            grad_sig="f32" if gp >= 0 else ""))
        for j in rng.sample(range(i), min(i, rng.randint(0, 3))):
            edges.append((j, i))
    return profile_graph(FusionGraph(prims, edges))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_iteration_time_at_least_fo_bound(seed):
    """iteration >= max(total compute, total comm) for the SAME graph —
    the FO bound is a true lower bound per strategy."""
    g = random_dag(seed)
    sim = Simulator(n_devices=64)
    r = sim.run(g)
    assert r.iteration_time >= sim.full_overlap_bound(g) - 1e-12
    assert r.iteration_time >= r.compute_time - 1e-12
    assert r.iteration_time >= r.comm_time - 1e-12


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), muts=st.integers(0, 30))
def test_sim_valid_after_mutations(seed, muts):
    g = random_dag(seed)
    rng = random.Random(seed)
    for _ in range(muts):
        random_apply(g, rng.choice(ALL_METHODS), 1, rng)
    sim = Simulator(n_devices=64)
    r = sim.run(g)
    assert r.iteration_time > 0
    assert r.comm_finish >= 0
    assert 1.0 <= r.overlap_ratio + 1e-9 <= 2.0 + 1e-9


def test_no_grads_means_no_comm():
    prims = [PrimOp(i, "mul", EW, 100, 8, 8, 1e-6) for i in range(5)]
    g = profile_graph(FusionGraph(prims, [(i, i + 1) for i in range(4)]))
    r = Simulator(n_devices=64).run(g)
    assert r.comm_time == 0.0
    assert r.iteration_time == pytest.approx(r.compute_time)


def test_comm_overlaps_compute():
    """A gradient produced early overlaps its AllReduce with later compute."""
    prims = [
        PrimOp(0, "mul", EW, 1e9, 8, 8, 0.0, grad_param=0,
               grad_bytes=1 << 20, grad_sig="f32"),
        PrimOp(1, "mul", EW, 1e9, 8, 8, 0.0),
        PrimOp(2, "mul", EW, 1e9, 8, 8, 0.0),
    ]
    g = profile_graph(FusionGraph(prims, [(0, 1), (1, 2)]))
    sim = Simulator(n_devices=64)
    r = sim.run(g)
    t_ar = allreduce_time(float(1 << 20), TPU_V5E, 64)
    # AllReduce starts right after op 0, overlapping ops 1-2
    assert r.iteration_time < r.compute_time + t_ar - 1e-12


def test_fused_allreduce_starts_later_but_fewer_latencies():
    g = chain_graph(n=10, grads=(2, 4, 6, 8))
    sim = Simulator(n_devices=64)
    r1 = sim.run(g)
    g2 = g.clone()
    while g2.merge_buckets(0, 1):
        pass
    r2 = sim.run(g2)
    assert len(g2.buckets) == 1
    # 4 latencies -> 1 latency; bandwidth term identical
    assert r2.comm_time < r1.comm_time


def test_timeline_consistency():
    g = random_dag(7)
    sim = Simulator(n_devices=64, keep_timeline=True)
    r = sim.run(g)
    compute_events = [e for e in r.timeline if e[0] == "compute"]
    # comm records are (kind, bucket, chunk, traffic_class, algo, level,
    # start, end)
    comm_events = [e for e in r.timeline if e[0] != "compute"]
    assert len(compute_events) == g.n_groups
    assert len(comm_events) == len(g.buckets)
    assert all(e[0] == "allreduce" and e[3] == "dp" and e[4] == "ring"
               for e in comm_events)
    # serialized streams: no overlap within a stream
    compute_spans = sorted((e[2], e[3]) for e in compute_events)
    comm_spans = sorted((e[6], e[7]) for e in comm_events)
    for spans in (compute_spans, comm_spans):
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert s2 >= e1 - 1e-12

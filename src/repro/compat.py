"""JAX version-compatibility shims (stock 0.4.x <-> 0.5+ APIs).

The repo targets the modern surface (``jax.shard_map``, ``AxisType``,
``check_vma``); stock JAX 0.4.x ships the same machinery under the older
names (``jax.experimental.shard_map.shard_map``, implicit auto axes,
``check_rep``).  Mesh construction compat lives in
:func:`repro.launch.mesh.make_mesh_compat`.
"""
from __future__ import annotations

import jax


def in_named_axis_context() -> bool:
    """Whether tracing is currently inside a shard_map/pmap region with
    bound axis names."""
    try:
        from jax._src import core as _core

        return bool(getattr(_core.get_axis_env(), "axis_sizes", None))
    except Exception:
        return False


def needs_partial_manual_workarounds() -> bool:
    """JAX 0.4.x's bundled XLA aborts (``Check failed: ...IsManualSubgroup()``)
    when partitioning certain ops inside a partial-manual shard_map region —
    ``lax.scan`` over auto-sharded operands and ``lax.top_k`` among them.
    Modern JAX partitions both fine."""
    if hasattr(jax, "shard_map"):
        return False
    return in_named_axis_context()


def top_k_compat(x, k: int):
    """``lax.top_k``, lowered through (stable) sort when the legacy backend
    cannot partition the top-k custom op in the current context.  Tie order
    matches ``top_k`` (ascending original index)."""
    if not needs_partial_manual_workarounds():
        return jax.lax.top_k(x, k)
    import jax.numpy as jnp

    idx = jnp.argsort(-x, axis=-1, stable=True)[..., :k]
    return jnp.take_along_axis(x, idx, axis=-1), idx


def scan_compat(body, carry, xs):
    """``lax.scan(body, carry, xs)``, unrolled to a python loop when the
    legacy backend cannot partition scan in the current context (see
    :func:`needs_partial_manual_workarounds`).  Semantics (including the
    stacked ``ys`` output) match ``lax.scan``."""
    if not needs_partial_manual_workarounds():
        return jax.lax.scan(body, carry, xs)
    import jax.numpy as jnp

    leaves = jax.tree_util.tree_leaves(xs)
    n = leaves[0].shape[0] if leaves else 0
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree_util.tree_map(lambda a: a[i], xs))
        ys.append(y)
    if not ys:
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *vs: jnp.stack(vs), *ys)
    return carry, stacked


def cost_analysis_compat(compiled) -> dict:
    """``compiled.cost_analysis()`` returns ``[dict]`` on JAX 0.4.x and a
    flat dict on >=0.5; always return the dict (empty when unavailable)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca or {}


def supports_nested_partial_manual() -> bool:
    """Whether a partial-manual shard_map may nest inside another manual
    region over disjoint axes (vocab-parallel CE / nested bucket fusion
    inside the ddp_tp region).  The 0.4.x ``auto=`` machinery rejects the
    nested specs ("Axis ... is also found in manual_axes"), so callers fall
    back to the flat GSPMD formulations there."""
    return hasattr(jax, "shard_map")


def axis_size_compat(axis_name):
    """``jax.lax.axis_size`` (modern) / ``psum(1, axis)`` (0.4.x idiom)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map_compat(fn, *, mesh=None, in_specs, out_specs, axis_names=None,
                     check: bool = False, use_ambient_mesh: bool = False):
    """``jax.shard_map`` with partial-manual axes across JAX versions.

    ``axis_names`` is the modern *manual*-axes set; on 0.4.x it is
    translated to the complementary ``auto=`` frozenset.  ``check`` maps to
    ``check_vma`` (modern) / ``check_rep`` (0.4.x).  With
    ``use_ambient_mesh`` the modern path picks up the ambient
    (partial-manual) mesh context; 0.4.x has no ambient mesh, so the
    explicit ``mesh`` is used there regardless.
    """
    if hasattr(jax, "shard_map"):
        kw = dict(in_specs=in_specs, out_specs=out_specs, check_vma=check)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if use_ambient_mesh or mesh is None:
            return jax.shard_map(fn, **kw)
        return jax.shard_map(fn, mesh=mesh, **kw)
    from jax.experimental.shard_map import shard_map

    if mesh is None:
        raise ValueError(
            "JAX 0.4.x shard_map has no ambient-mesh mode; pass mesh=")
    kw = dict(in_specs=in_specs, out_specs=out_specs, check_rep=check)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kw["auto"] = auto
    return shard_map(fn, mesh, **kw)

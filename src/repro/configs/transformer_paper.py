"""Transformer-base — the paper's own communication-bound benchmark model
(Vaswani et al., used in DisCo Fig. 6/7 as the model with the largest
speed-up).  Included alongside the assigned pool per the repo structure
spec ("one <arch>.py per assigned architecture (+ paper's own)").
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="transformer-paper",
    arch_type="dense",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=32768,
    norm="layer",
    act="relu",
    glu=False,
    rope_frac=0.0,          # sinusoidal positions, as in the original
    source="arXiv:1706.03762 (Transformer-base; DisCo benchmark model)",
)

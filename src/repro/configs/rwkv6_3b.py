"""RWKV-6 "Finch" 3B [arXiv:2404.05892].

32L, d_model 2560, attention-free (data-dependent decay WKV), head_dim 64
(40 heads), channel-mix d_ff 8960, vocab 65536.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # wkv heads (head_dim 64)
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    block="rwkv",
    norm="layer",
    glu=False,
    act="relu",
    rope_frac=0.0,
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)

"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

27L, d_model 2048, 16 heads, MLA (kv_lora 512, no q-lora), MoE with
64 routed experts top-6 + 2 shared, d_expert 1408; layer 0 dense.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,          # dense layer-0 FFN width
    vocab=102400,
    block="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                  first_dense_layers=1),
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
)

"""DeepSeek-V2 236B (21B active) [arXiv:2405.04434].

60L, d_model 5120, 128 heads, MLA (kv_lora 512, q_lora 1536), MoE with
160 routed experts top-6 + 2 shared, d_expert 1536; layer 0 dense.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,          # dense layer-0 FFN width
    vocab=102400,
    block="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=160, n_shared=2, top_k=6, d_expert=1536,
                  first_dense_layers=1),
    source="arXiv:2405.04434 (DeepSeek-V2)",
)

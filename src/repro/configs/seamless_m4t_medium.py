"""SeamlessM4T-Medium text/unit backbone [arXiv:2308.11596].

Encoder-decoder transformer: 12 encoder + 12 decoder layers, d_model 1024,
16 heads, d_ff 4096, vocab 256206 (padded to 256208 for 16-way tensor parallelism,
standard practice), ReLU FFN (no GLU), LayerNorm.
The speech frontend (mel + conformer feature extractor) is a STUB per spec:
input_specs() supplies precomputed frame embeddings (enc_seq x 1024).
"""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,               # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256208,  # 256206 padded to /16
    norm="layer",
    act="relu",
    glu=False,
    rope_frac=0.0,             # learned/sinusoidal positions; no rope
    encdec=EncDecConfig(n_enc_layers=12, enc_seq=1024, frontend_dim=1024),
    source="arXiv:2308.11596 (SeamlessM4T-Medium)",
)

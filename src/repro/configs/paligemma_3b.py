"""PaliGemma-3B language backbone [arXiv:2407.07726].

Gemma decoder: 18L, d_model 2048, 8 heads, MQA (kv=1), d_ff 16384,
vocab 257216, GeGLU, tied embeddings.  The SigLIP vision tower + projector is
a STUB per spec: input_specs() supplies 256 precomputed patch embeddings
(d_model) prepended to the text tokens.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    norm="rms",
    act="gelu",
    glu=True,
    tie_embeddings=True,
    vlm_prefix_len=256,
    source="arXiv:2407.07726 (SigLIP + Gemma-2B)",
)

"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b].

24L, d_model 2048, 32 heads (full MHA: kv=32), d_ff 5632, vocab 100352,
LayerNorm, partial rotary (25% of head dim), SiLU-gated FFN, untied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    norm="layer",
    act="silu",
    glu=True,
    rope_frac=0.25,
    source="hf:stabilityai/stablelm-2-1_6b",
)

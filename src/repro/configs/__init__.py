"""Assigned-architecture registry: ``get_config("<arch-id>")``.

Every entry cites its source in ``ModelConfig.source``; reduced smoke-test
variants come from ``cfg.reduced()``.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "stablelm-1.6b",
    "paligemma-3b",
    "qwen2-0.5b",
    "deepseek-v2-lite-16b",
    "deepseek-v2-236b",
    "deepseek-coder-33b",
    "seamless-m4t-medium",
    "recurrentgemma-9b",
    "rwkv6-3b",
    "tinyllama-1.1b",
)

# the paper's own benchmark model ships alongside the assigned pool
EXTRA_ARCHS = ("transformer-paper",)

_MODULES = {a: a.replace("-", "_").replace(".", "_")
            for a in ARCHS + EXTRA_ARCHS}


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}

"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L, d_model 4096, pattern (rec, rec, local-attn) 2:1, RG-LRU width 4096,
local attention window 2048 with 16 heads MQA (kv=1), d_ff 12288 (GeGLU),
vocab 256000.
"""
from repro.models.config import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    act="gelu",
    glu=True,
    window=2048,
    tie_embeddings=True,
    recurrent=RecurrentConfig(lru_width=4096, conv_width=4,
                              pattern=("rec", "rec", "attn")),
    source="arXiv:2402.19427 (Griffin / RecurrentGemma-9B)",
)

"""The Plan artifact: a frozen, versioned record of one searched strategy.

DisCo's workflow is "search once, then train with the optimized
configuration" (paper Sec. 3.1), but until this layer existed the searched
strategy was not a value — it was mutable :class:`~repro.core.graph.
FusionGraph` state plus an ad-hoc ``strategy.json``.  :class:`Plan` is the
compilation artifact separable from the run (the same discipline as Alpa's
serializable parallelism plans / TASO's exported substitutions): everything
the search decided, everything needed to re-price it, and nothing tied to a
live process.

Contents (DESIGN.md Sec. 10):

* **Op fusion** — the group partition and per-prim provider, in a canonical
  content-sorted order (gid-free, so two graphs with the same strategy
  serialize identically).
* **Tensor fusion** — buckets plus the per-bucket ``(algo, comm kind,
  chunks)`` triple and each bucket's gradient byte volume (so a saved plan
  can be *priced* without re-tracing the model).
* **Pricing context** — stream count, background-traffic classes, a full
  cluster fingerprint (exact level constants, or the legacy flat
  ``Hardware``), and estimator provenance.
* **Prediction** — the simulator's iteration time for the plan, plus a
  free-form ``provenance`` dict (search stats; excluded from equality).

Round-tripping: ``Plan.from_graph(plan.to_graph(base)) == plan`` and the
reconstructed graph keeps the original ``fast_signature()`` and simulated
cost.  ``save``/``load`` are schema-versioned JSON; corrupted files,
foreign versions and cluster-fingerprint mismatches raise
:class:`PlanError` (``PlanVersionError`` / ``ClusterMismatchError``).  A
legacy v0 ``strategy.json`` (the old hand-rolled enactment format) loads
through a migration shim — bucket-only, enactable via :meth:`Plan.
grad_sync`, not re-priceable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Sequence

from ..cluster import ClusterSpec, LinkLevel, comm_time
from ..core.costs import OracleEstimator
from ..core.events import (BackgroundTraffic, CommEngine, CommJob, TC_DP,
                           bucket_jobs)
from ..core.graph import FusionGraph
from ..core.hw import Hardware
from ..core.pipeline import PipelineSchedule
from ..core.simulator import Simulator

SCHEMA = "repro.plan"
# v2 added the optional pipeline-schedule knobs; v1 artifacts load with
# pipeline=None.  v3 added the searched pipeline-knob overrides
# (``pp_knobs``), the first-class TP traffic description (``tp``) and the
# per-level chunk flag (``level_chunks``); v1/v2 artifacts load with all
# three at their None/False defaults (every other field is unchanged).
PLAN_VERSION = 3
SUPPORTED_VERSIONS = (1, 2, 3)


class PlanError(Exception):
    """A Plan artifact could not be read, validated, or applied."""


class PlanVersionError(PlanError):
    """The file is not a Plan of a schema version this code understands."""


class ClusterMismatchError(PlanError):
    """The plan was searched against a different cluster than requested."""


# ------------------------------------------------------------- fingerprints
def cluster_fingerprint(spec: ClusterSpec) -> tuple:
    """Canonical, reconstructible identity of a cluster spec.  Flat
    back-compat specs record the full legacy ``Hardware`` (their pricing
    delegates to it); real specs record the exact per-level constants."""
    if spec.is_flat_compat:
        hw = dataclasses.asdict(spec.compat_hw)
        return ("flat", int(spec.n_devices), tuple(sorted(hw.items())))
    return ("spec", spec.name,
            tuple((l.name, int(l.degree), float(l.bandwidth),
                   float(l.alpha), float(l.straggler), float(l.contention))
                  for l in spec.levels))


_LEVEL_FIELDS = ("name", "degree", "bandwidth", "alpha", "straggler",
                 "contention")


def cluster_fingerprint_diff(a: tuple, b: tuple) -> list[str]:
    """Human-readable field-level differences between two cluster
    fingerprints — which levels and which per-level constants disagree —
    so a ``--plan`` / ``--cluster`` mismatch reports *what* differs
    instead of only that something does.  Empty list iff equal.  Accepts
    either tuple- or (JSON round-tripped) list-shaped fingerprints."""
    a, b = _tuplize(_listize(a)), _tuplize(_listize(b))
    if a == b:
        return []
    if a[0] != b[0]:
        return [f"topology family: {a[0]} != {b[0]}"]
    diffs: list[str] = []
    if a[0] == "flat":
        if a[1] != b[1]:
            diffs.append(f"n_devices: {a[1]} != {b[1]}")
        for (ka, va), (kb, vb) in zip(a[2], b[2]):
            if va != vb:
                diffs.append(f"hw.{ka}: {va} != {vb}")
        return diffs or [f"flat fingerprint differs: {a} != {b}"]
    if a[1] != b[1]:
        diffs.append(f"name: {a[1]!r} != {b[1]!r}")
    la, lb = a[2], b[2]
    if len(la) != len(lb):
        diffs.append(f"levels: {len(la)} != {len(lb)} "
                     f"({[l[0] for l in la]} vs {[l[0] for l in lb]})")
    for i, (lvl_a, lvl_b) in enumerate(zip(la, lb)):
        for f, va, vb in zip(_LEVEL_FIELDS, lvl_a, lvl_b):
            if va != vb:
                diffs.append(f"level[{i}].{f}: {va} != {vb}")
    return diffs or [f"fingerprint differs: {a} != {b}"]


def _listize(x):
    """Mirror of ``_tuplize`` so diffing works on raw JSON shapes too."""
    if isinstance(x, tuple):
        return [_listize(e) for e in x]
    return x


def _spec_from_fingerprint(fp: tuple) -> ClusterSpec:
    if fp[0] == "flat":
        return ClusterSpec.flat(Hardware(**dict(fp[2])), fp[1])
    if fp[0] == "spec":
        return ClusterSpec(fp[1], tuple(LinkLevel(*lvl) for lvl in fp[2]))
    raise PlanError(f"unknown cluster fingerprint tag {fp[0]!r}")


def _bg_tuple(b: BackgroundTraffic) -> tuple:
    return (b.traffic_class, float(b.nbytes), float(b.period), b.algo,
            b.kind, float(b.offset), b.count)


def estimator_name(est) -> str:
    if est is None or isinstance(est, OracleEstimator):
        return "oracle"
    return type(est).__name__


def _tuplize(x):
    """JSON gives lists; equality needs the exact nested-tuple shape."""
    if isinstance(x, list):
        return tuple(_tuplize(e) for e in x)
    return x


# ------------------------------------------------------------------- artifact
@dataclasses.dataclass(frozen=True)
class Plan:
    """A complete searched strategy, frozen and serializable.

    ``provenance`` (search statistics, lineage notes) is carried along but
    excluded from equality — two plans prescribing the same strategy under
    the same pricing context are equal regardless of how they were found.
    """
    version: int
    # op fusion: canonical content-sorted groups; provider[pid] indexes them
    groups: tuple[tuple[int, ...], ...]
    provider: tuple[int, ...]
    # tensor fusion: buckets of grad-param indices + per-bucket choices
    buckets: tuple[tuple[int, ...], ...]
    bucket_algos: tuple[str, ...]
    bucket_comm: tuple[str, ...]
    bucket_chunks: tuple[int, ...]
    bucket_bytes: tuple[float, ...]      # () when unknown (v0 migration)
    # per-bucket in-kernel compute+comm fusion flags (0/1, DESIGN.md
    # Sec. 13).  Optional v2 field: () in pre-fused artifacts means "no
    # bucket fused", so old plans load (and fingerprint) unchanged.
    bucket_fused: tuple[int, ...] = ()
    # pricing context
    streams: int = 1
    background: tuple[tuple, ...] = ()
    # PipelineSchedule.to_tuple(), or None when the plan was priced on the
    # single-device replay (v1 artifacts)
    pipeline: tuple | None = None
    # searched pipeline-knob overrides (n_stages, n_microbatches,
    # interleave; each may be None) resolved against ``pipeline`` at
    # pricing time — part of the *strategy*, unlike ``pipeline`` which is
    # pricing context.  None in v1/v2 artifacts.
    pp_knobs: tuple | None = None
    # TPTraffic.to_tuple(), or None when the plan was priced without
    # first-class tp traffic (v1/v2 artifacts, background-only sims)
    tp: tuple | None = None
    # per-level chunk pipelining flag (DESIGN.md Sec. 14); False in
    # v1/v2 artifacts
    level_chunks: bool = False
    cluster: tuple | None = None         # cluster_fingerprint(), or unknown
    hw: tuple | None = None              # sorted Hardware items, or unknown
    estimator: str = "oracle"
    predicted_iteration_time: float | None = None
    barriers: bool = False               # enactment fence (v0 carry-over)
    provenance: dict = dataclasses.field(default_factory=dict, compare=False)

    def __post_init__(self):
        # per-bucket vectors must agree in length (truncated artifacts must
        # fail loudly at load, not silently drop strategy at enactment)
        nb = len(self.buckets)
        for f in ("bucket_algos", "bucket_comm", "bucket_chunks"):
            n = len(getattr(self, f))
            if n != nb:
                raise PlanError(f"corrupt plan: {f} has {n} entries for "
                                f"{nb} buckets")
        if self.bucket_bytes and len(self.bucket_bytes) != nb:
            raise PlanError(f"corrupt plan: bucket_bytes has "
                            f"{len(self.bucket_bytes)} entries for "
                            f"{nb} buckets")
        if self.bucket_fused and len(self.bucket_fused) != nb:
            raise PlanError(f"corrupt plan: bucket_fused has "
                            f"{len(self.bucket_fused)} entries for "
                            f"{nb} buckets")

    # ------------------------------------------------------------ graph I/O
    @classmethod
    def from_graph(cls, g: FusionGraph, *, sim: Simulator | None = None,
                   predicted: float | None = None,
                   provenance: dict | None = None) -> "Plan":
        """Capture ``g``'s complete strategy.  With ``sim`` the pricing
        context (cluster, streams, background, estimator) is recorded and
        ``predicted`` defaults to ``sim.cost(g)``."""
        # canonical group order: by (members, provided) content, never by
        # gid — gids are allocation order, not strategy
        order = sorted(
            g.groups,
            key=lambda gid: (tuple(sorted(g.groups[gid])),
                             tuple(sorted(g.provided_set(gid)))))
        index = {gid: i for i, gid in enumerate(order)}
        kw: dict = {}
        if sim is not None:
            hw = getattr(sim, "hw", None)
            pp = getattr(sim, "pipeline", None)
            tp = getattr(sim, "tp", None)
            kw = dict(
                streams=int(getattr(sim, "streams", 1)),
                background=tuple(_bg_tuple(b)
                                 for b in getattr(sim, "background", ())),
                pipeline=None if pp is None else pp.to_tuple(),
                tp=None if tp is None else tp.to_tuple(),
                level_chunks=bool(getattr(sim, "level_chunks", False)),
                cluster=cluster_fingerprint(sim.cluster),
                hw=(tuple(sorted(dataclasses.asdict(hw).items()))
                    if hw is not None else None),
                estimator=estimator_name(getattr(sim, "estimator", None)),
            )
            if predicted is None:
                predicted = sim.cost(g)
        return cls(
            version=PLAN_VERSION,
            groups=tuple(tuple(sorted(g.groups[gid])) for gid in order),
            provider=tuple(index[g.provider[pid]]
                           for pid in range(len(g.prims))),
            buckets=tuple(tuple(b) for b in g.buckets),
            bucket_algos=tuple(g.bucket_algos),
            bucket_comm=tuple(g.bucket_comm),
            bucket_chunks=tuple(int(k) for k in g.bucket_chunks),
            bucket_bytes=tuple(float(g.bucket_bytes(b)) for b in g.buckets),
            bucket_fused=tuple(int(bool(f)) for f in g.bucket_fused),
            pp_knobs=(None if getattr(g, "pp_knobs", None) is None
                      else tuple(g.pp_knobs)),
            predicted_iteration_time=predicted,
            provenance=dict(provenance or {}),
            **kw,
        )

    def to_graph(self, base: FusionGraph) -> FusionGraph:
        """Re-apply this strategy onto ``base`` (the traced/profiled prim
        graph the plan was searched over, or an equivalent re-trace).  The
        result's ``fast_signature()`` and simulated cost equal the searched
        graph's.  Raises :class:`PlanError` when the plan does not fit."""
        n = len(base.prims)
        if self.groups:
            if len(self.provider) != n:
                raise PlanError(
                    f"plan was built over {len(self.provider)} prims but "
                    f"this graph has {n} — wrong trace for this artifact")
            groups = {i: frozenset(m) for i, m in enumerate(self.groups)}
            provider = {}
            for pid, gi in enumerate(self.provider):
                if not 0 <= gi < len(self.groups) or pid not in groups[gi]:
                    raise PlanError(
                        f"corrupt plan: prim {pid} names provider group "
                        f"{gi} which does not contain it")
                provider[pid] = gi
            for gi, members in groups.items():
                if any(not 0 <= p < n for p in members):
                    raise PlanError(
                        f"corrupt plan: group {gi} names unknown prims")
            g = FusionGraph._from_parts(
                base.prims, base.psuccs, base.ppreds, groups, provider,
                len(self.groups), base.grad_prim,
                [tuple(b) for b in self.buckets],
                family=base.family_token(),
                bucket_algos=list(self.bucket_algos),
                bucket_comm=list(self.bucket_comm),
                bucket_chunks=list(self.bucket_chunks),
                bucket_fused=([bool(f) for f in self.bucket_fused]
                              if self.bucket_fused else None),
                pp_knobs=self.pp_knobs)
        else:
            # v0-migrated bucket-only plan: keep base's op-fusion state
            g = FusionGraph._from_parts(
                base.prims, base.psuccs, base.ppreds, base.groups,
                base.provider, base._next_gid, base.grad_prim,
                [tuple(b) for b in self.buckets],
                family=base.family_token(),
                bucket_algos=list(self.bucket_algos),
                bucket_comm=list(self.bucket_comm),
                bucket_chunks=list(self.bucket_chunks),
                bucket_fused=([bool(f) for f in self.bucket_fused]
                              if self.bucket_fused else None),
                pp_knobs=self.pp_knobs)
        seen: set[int] = set()
        for b in g.buckets:
            for p in b:
                if p not in g.grad_prim:
                    raise PlanError(
                        f"plan bucket names gradient {p} which this graph "
                        f"does not produce")
                if p in seen:
                    raise PlanError(f"gradient {p} appears in two buckets")
                seen.add(p)
        try:
            g.topo_groups()
        except RuntimeError as e:
            raise PlanError(f"plan op-fusion state is cyclic: {e}") from e
        return g

    # -------------------------------------------------------------- lowering
    def grad_sync(self, params=None):
        """Lower the tensor-fusion half of the plan to an enactable
        :class:`repro.distributed.train_step.GradSyncStrategy` — buckets,
        per-bucket comm kinds *and* chunk counts (chunked collectives are
        enacted for real; see ``sync_grads``).  With ``params`` the buckets
        are clipped to the real leaf count and uncovered leaves get
        singleton AllReduce buckets (the ``from_fusion_graph`` contract)."""
        from ..distributed.train_step import GradSyncStrategy

        return GradSyncStrategy.from_buckets(
            self.buckets, self.bucket_comm, self.bucket_chunks,
            fused=self.bucket_fused or None,
            params=params, barriers=self.barriers)

    def cluster_spec(self) -> ClusterSpec | None:
        """Reconstruct the exact ClusterSpec the plan was searched against
        (None when the artifact records no pricing context)."""
        return (None if self.cluster is None
                else _spec_from_fingerprint(self.cluster))

    def simulator(self, *, cluster: ClusterSpec | None = None,
                  estimator=None, **kw) -> Simulator:
        """Reconstruct the pricing configuration the plan was searched
        under: cluster, stream count and background traffic.  Passing
        ``cluster`` asserts it matches the recorded fingerprint
        (:class:`ClusterMismatchError` otherwise) — re-pricing a plan on a
        different topology must be an explicit re-compile, not an
        accident."""
        spec = self.cluster_spec()
        if cluster is not None:
            if (self.cluster is not None
                    and cluster_fingerprint(cluster) != self.cluster):
                diff = cluster_fingerprint_diff(
                    self.cluster, cluster_fingerprint(cluster))
                raise ClusterMismatchError(
                    f"plan was searched against "
                    f"{spec.name if spec else '<unknown>'} but "
                    f"{cluster.name} was requested "
                    f"({'; '.join(diff)}); re-run compile() to "
                    f"target a different cluster")
            spec = cluster
        if self.estimator != "oracle" and estimator is None:
            raise PlanError(
                f"plan was priced by a {self.estimator!r} estimator, which "
                f"an artifact cannot reconstruct — pass estimator=")
        # restore the recorded compute hardware too: the oracle estimator's
        # fused-op times depend on it, not just on the cluster
        sim_kw = dict(kw)
        if self.hw is not None:
            sim_kw.setdefault("hw", Hardware(**dict(self.hw)))
        if self.pipeline is not None:
            sim_kw.setdefault(
                "pipeline", PipelineSchedule.from_tuple(self.pipeline))
        if self.tp is not None:
            from ..core.tp_traffic import TPTraffic
            sim_kw.setdefault("tp", TPTraffic.from_tuple(self.tp))
        if self.level_chunks:
            sim_kw.setdefault("level_chunks", True)
        return Simulator(
            estimator=estimator, cluster=spec,
            streams=self.streams,
            background=tuple(BackgroundTraffic(*b)
                             for b in self.background),
            **sim_kw)

    # --------------------------------------------------------------- pricing
    def comm_jobs(self, ready: Sequence[float] | None = None) -> list[CommJob]:
        """The plan's gradient traffic as event-engine jobs (the same
        chunked decomposition the simulator prices), ready at ``ready[i]``
        (default: all at 0).  Needs recorded bucket volumes."""
        if not self.bucket_bytes:
            raise PlanError("artifact records no bucket volumes "
                            "(v0-migrated plans are enact-only)")
        jobs: list[CommJob] = []
        next_id = len(self.buckets)
        for i, nb in enumerate(self.bucket_bytes):
            r = float(ready[i]) if ready is not None else 0.0
            js, next_id = bucket_jobs(i, r, nb, self.bucket_algos[i],
                                      self.bucket_comm[i],
                                      self.bucket_chunks[i], next_id)
            jobs.extend(js)
        return jobs

    def price(self, *, cluster: ClusterSpec | None = None,
              streams: int | None = None) -> dict:
        """Price the saved gradient traffic without re-tracing or
        re-searching: the serialized-channel sum and the event-engine
        finish of the plan's bucket set (all buckets ready at 0 — the
        comm-bound floor), on the recorded cluster or an explicit
        override.  When the plan records background TP/PP traffic and the
        engine is multi-stream, the recorded classes are materialized over
        the uncontended finish horizon and the contended gradient finish is
        reported alongside (mirroring the simulator's injection rule)."""
        spec = cluster or self.cluster_spec()
        if spec is None:
            raise PlanError("artifact records no cluster; pass cluster=")
        s = max(int(streams or self.streams), 1)
        serialized = sum(
            comm_time(nb, spec, a, k)
            for nb, a, k in zip(self.bucket_bytes, self.bucket_algos,
                                self.bucket_comm)
            if nb > 0.0)
        jobs = self.comm_jobs()
        busy, finish = CommEngine(spec, streams=s).run(list(jobs))
        out = {
            "cluster": spec.describe(),
            "cluster_fingerprint_match": (
                self.cluster is None
                or cluster_fingerprint(spec) == self.cluster),
            "streams": s,
            "buckets": len(self.buckets),
            "total_grad_bytes": float(sum(self.bucket_bytes)),
            "serialized_comm_s": serialized,
            "engine_busy_s": busy,
            "engine_finish_s": finish,
            "predicted_iteration_time_s": self.predicted_iteration_time,
        }
        if self.background and s > 1:
            next_id = max((j.jid for j in jobs),
                          default=len(self.buckets)) + 1
            bg: list[CommJob] = []
            for t in self.background:
                made = BackgroundTraffic(*t).materialize(finish, next_id)
                next_id += len(made)
                bg.extend(made)
            if bg:
                eng = CommEngine(spec, streams=s)
                eng.run(list(jobs) + bg)
                contended = eng.class_finish.get(TC_DP, 0.0)
                out["contention"] = {
                    "background_jobs": len(bg),
                    "grad_finish_alone_s": finish,
                    "grad_finish_contended_s": contended,
                    "slowdown": contended / finish if finish > 0 else 1.0,
                }
                out["engine_busy_s"] = eng.class_busy.get(TC_DP, 0.0)
                out["engine_finish_s"] = contended
        return out

    # ------------------------------------------------------------------ misc
    def describe(self) -> dict:
        """Strategy statistics, mirroring ``FusionGraph.describe`` for the
        fields a plan carries (sweep/report consumers)."""
        return {
            "groups": len(self.groups),
            "fused_groups": sum(1 for m in self.groups if len(m) > 1),
            "allreduce_buckets": len(self.buckets),
            "grad_tensors": sum(len(b) for b in self.buckets),
            "bucket_algos": {a: self.bucket_algos.count(a)
                             for a in set(self.bucket_algos)},
            "bucket_comm": {k: self.bucket_comm.count(k)
                            for k in set(self.bucket_comm)},
            "bucket_chunks": {k: self.bucket_chunks.count(k)
                              for k in set(self.bucket_chunks)},
            "fused_comm_buckets": sum(1 for f in self.bucket_fused if f),
            "streams": self.streams,
            "estimator": self.estimator,
            "pipeline": self.pipeline,
            "pp_knobs": self.pp_knobs,
            "tp": self.tp,
            "level_chunks": self.level_chunks,
            "predicted_iteration_time_s": self.predicted_iteration_time,
        }

    def fingerprint(self) -> str:
        """Process-stable identity of the strategy + pricing context
        (PYTHONHASHSEED-independent; provenance excluded)."""
        d = self._to_json()
        d.pop("provenance", None)
        blob = json.dumps(d, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    def strategy_fingerprint(self) -> str:
        """Identity of the *strategy alone* — op-fusion partition, buckets
        and per-bucket choices — excluding the pricing context, so two
        searches that converge on the same strategy under different
        clusters/streams fingerprint identically (the cross-topology
        distinctness metric of ``fig_cluster_sweep``)."""
        parts = [self.groups, self.provider, self.buckets, self.bucket_algos,
                 self.bucket_comm, self.bucket_chunks]
        if any(self.bucket_fused):
            # appended only when some bucket is fused: all-unfused (and
            # pre-fused) plans keep their historical fingerprints
            parts.append(self.bucket_fused)
        if self.pp_knobs is not None:
            # same rule for the searched pipeline knobs: plans that never
            # touched them keep their historical fingerprints
            parts.append(list(self.pp_knobs))
        blob = json.dumps(parts, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    # -------------------------------------------------------------- file I/O
    def _to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = SCHEMA
        return d

    def save(self, path: str) -> None:
        """Atomic write: temp file in the target directory + ``os.replace``,
        so an interrupted save can never leave a torn JSON artifact (a
        half-written plan in a cache directory must stay a *miss*, not
        become a crash or a silently-wrong strategy)."""
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(self._to_json(), f, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                try:
                    os.remove(tmp)
                except OSError:
                    pass

    @staticmethod
    def load(path: str) -> "Plan":
        try:
            with open(path) as f:
                d = json.load(f)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise PlanError(f"{path}: not a Plan artifact "
                            f"(corrupt JSON: {e})") from e
        return Plan.from_dict(d, source=path)

    @staticmethod
    def from_dict(d: dict, source: str = "<dict>") -> "Plan":
        if not isinstance(d, dict):
            raise PlanError(f"{source}: not a Plan artifact")
        if d.get("schema") != SCHEMA:
            if "schema" not in d and "buckets" in d:
                return Plan._migrate_v0(d, source)
            raise PlanVersionError(
                f"{source}: schema {d.get('schema')!r} is not {SCHEMA!r}")
        version = d.get("version")
        if version not in SUPPORTED_VERSIONS:
            raise PlanVersionError(
                f"{source}: plan version {version!r} is not supported by "
                f"this build (wants one of {SUPPORTED_VERSIONS}); "
                f"re-run compile()")
        try:
            cluster = d.get("cluster")
            pipeline = d.get("pipeline")   # absent in v1 artifacts
            pp_knobs = d.get("pp_knobs")   # absent in v1/v2 artifacts
            tp = d.get("tp")               # absent in v1/v2 artifacts
            return Plan(
                version=PLAN_VERSION,
                groups=_tuplize(d["groups"]),
                provider=_tuplize(d["provider"]),
                buckets=_tuplize(d["buckets"]),
                bucket_algos=_tuplize(d["bucket_algos"]),
                bucket_comm=_tuplize(d["bucket_comm"]),
                bucket_chunks=_tuplize(d["bucket_chunks"]),
                bucket_bytes=_tuplize(d["bucket_bytes"]),
                bucket_fused=_tuplize(d.get("bucket_fused", [])),
                streams=int(d.get("streams", 1)),
                background=_tuplize(d.get("background", [])),
                pipeline=None if pipeline is None else _tuplize(pipeline),
                pp_knobs=None if pp_knobs is None else _tuplize(pp_knobs),
                tp=None if tp is None else _tuplize(tp),
                level_chunks=bool(d.get("level_chunks", False)),
                cluster=None if cluster is None else _tuplize(cluster),
                hw=(None if d.get("hw") is None
                    else _tuplize(d["hw"])),
                estimator=d.get("estimator", "oracle"),
                predicted_iteration_time=d.get("predicted_iteration_time"),
                barriers=bool(d.get("barriers", False)),
                provenance=dict(d.get("provenance", {})),
            )
        except (KeyError, TypeError, ValueError) as e:
            raise PlanError(f"{source}: corrupt plan artifact: {e}") from e

    @staticmethod
    def _migrate_v0(d: dict, source: str) -> "Plan":
        """Legacy hand-rolled ``strategy.json`` (buckets / barriers /
        comms) -> bucket-only Plan.  Enactable via ``grad_sync``; carries
        no op-fusion state, volumes or pricing context."""
        try:
            buckets = tuple(tuple(int(i) for i in b) for b in d["buckets"])
        except (KeyError, TypeError, ValueError) as e:
            raise PlanError(f"{source}: corrupt legacy strategy "
                            f"file: {e}") from e
        comms = d.get("comms") or ["ar"] * len(buckets)
        if len(comms) != len(buckets):
            raise PlanError(f"{source}: legacy strategy comms/buckets "
                            f"length mismatch")
        return Plan(
            version=PLAN_VERSION,
            groups=(), provider=(),
            buckets=buckets,
            bucket_algos=("ring",) * len(buckets),
            bucket_comm=tuple(comms),
            bucket_chunks=tuple(int(k) for k in
                                d.get("chunks") or (1,) * len(buckets)),
            bucket_bytes=(),
            barriers=bool(d.get("barriers", False)),
            provenance={"migrated_from": "v0 strategy.json",
                        "source": source},
        )

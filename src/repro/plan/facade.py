"""``repro.plan.compile`` — the one entry point for search -> Plan.

Owns the trace -> profile -> search pipeline that ``examples/``,
``benchmarks/`` and ``launch/`` used to re-plumb by hand: build (or accept)
a profiled :class:`FusionGraph`, construct the pricing
:class:`~repro.core.simulator.Simulator` from ``(cluster, streams,
background, workers)``, run the backtracking search, and freeze the winner
into a :class:`~repro.plan.artifact.Plan` (DESIGN.md Sec. 10).

Two modes:

* ``compile("qwen2-0.5b", cluster="a100_nvlink_ib", streams=4)`` — trace a
  config's training step (lazy jax import) and search it.
* ``compile(graph=g0, cluster=spec, ...)`` — search a pre-traced graph
  (benchmark sweeps reuse one cached trace across many presets).  The
  facade adds no search work of its own: its overhead over a direct
  ``backtracking_search`` call is plan construction, gated < 5% by
  ``benchmarks/perf_search.py --smoke``.

The search provenance (steps, simulations, wall times, initial cost) rides
along in ``plan.provenance``.
"""
from __future__ import annotations

import time as _time

from ..cluster import ClusterSpec, get_preset
from ..core.hw import TPU_V5E, Hardware
from ..core.search import backtracking_search
from ..core.simulator import Simulator
from .artifact import Plan


def trace_model_graph(cfg, *, batch: int = 8, seq: int = 64,
                      model: str = "stacked", reduced: bool = True,
                      n_layers: int | None = None, hw: Hardware = TPU_V5E,
                      seed: int = 0):
    """Trace + profile one training step of a model config (the Search
    Phase's input).  ``model="stacked"`` is the production scanned-layer
    implementation; ``model="layers"`` the unstacked per-layer loop whose
    traced DAG exposes the full backward structure (benchmark suite —
    see DESIGN.md Sec. 5).  Imports jax lazily: plan/artifact consumers
    stay jax-free."""
    import dataclasses as _dc

    import jax

    from ..configs import get_config
    from ..core import profile_graph, trace_grad_graph
    from ..data.pipeline import materialize_batch

    if isinstance(cfg, str):
        cfg = get_config(cfg)
    if reduced:
        cfg = cfg.reduced()
    if model == "stacked":
        from ..models import stacked as MM
    elif model == "layers":
        from ..models import model as MM

        if n_layers is not None and cfg.recurrent is None:
            cfg = _dc.replace(cfg, n_layers=n_layers)
    else:
        raise ValueError(f"unknown model variant {model!r} "
                         f"(expected 'stacked' or 'layers')")
    params = MM.init_params(jax.random.PRNGKey(seed), cfg)
    data = materialize_batch(cfg, batch, seq, seed=seed)
    g = trace_grad_graph(lambda p, bt: MM.loss_fn(p, cfg, bt), params, data)
    return profile_graph(g, hw)


def compile_plan(cfg=None, *, cluster=None, streams: int = 1,
                 background=(), pipeline=None, tp=None,
                 level_chunks: bool = False, workers: int | None = None,
                 overlap_discount: float | None = None,
                 graph=None, estimator=None, hw: Hardware = TPU_V5E,
                 n_devices: int = 256,
                 batch: int = 8, seq: int = 64, model: str = "stacked",
                 reduced: bool = True, n_layers: int | None = None,
                 alpha: float = 1.05, beta: int = 10,
                 unchanged_limit: int = 200, max_steps: int | None = None,
                 methods=None, seed: int = 0,
                 cache=None, warm_start: bool = True) -> Plan:
    """Search once, return the strategy as a first-class artifact.

    ``cfg`` is a config name / ModelConfig (traced via
    :func:`trace_model_graph`) — or pass ``graph=`` to search a pre-traced
    profiled FusionGraph directly.  ``cluster`` is a preset name or
    :class:`ClusterSpec` (default: the legacy flat ``(hw, n_devices)``
    model).  ``streams`` / ``background`` / ``pipeline`` pick the
    event-engine pricing (``pipeline`` is a
    :class:`~repro.core.pipeline.PipelineSchedule` that prices the run
    under a 1F1B stage schedule instead of pure data parallelism; ``tp``
    a :class:`~repro.core.tp_traffic.TPTraffic` that dep-couples
    per-layer tensor-parallel activation collectives into the schedule;
    ``level_chunks`` coalesces store-and-forward chunks on the fat link
    levels — DESIGN.md Sec. 14), ``workers`` the candidate-evaluation
    pool; ``overlap_discount``
    overrides the preset's calibrated in-kernel fusion discount (pass
    ``0.0`` to exclude the fused dimension from the search); the
    remaining knobs are the search hyper-parameters of
    ``backtracking_search``.

    ``cache`` (a :class:`repro.plan.cache.PlanCache` or a directory path)
    short-circuits the search (DESIGN.md Sec. 12): an exact key hit —
    same graph content-signature, cluster/pricing fingerprint and search
    knobs — *replays* the stored Plan bit-identically (no simulator
    evaluations); a near miss re-applies the most similar cached plan's
    strategy onto this graph as the backtracking search's warm start
    state (``warm_start=False`` disables that half), and the result is
    stored back.  ``plan.provenance['cache']`` records the outcome
    (``hit`` / ``warm`` / ``cold``) and the warm-start lineage.
    """
    t_start = _time.perf_counter()
    if isinstance(cluster, str):
        cluster = get_preset(cluster)
    if cluster is not None and not isinstance(cluster, ClusterSpec):
        raise TypeError(f"cluster must be a preset name or ClusterSpec, "
                        f"got {type(cluster).__name__}")
    arch = cfg if isinstance(cfg, str) else getattr(cfg, "name", None)
    if graph is None:
        if cfg is None:
            raise ValueError("compile() needs a config (cfg=) or a "
                             "pre-traced graph (graph=)")
        graph = trace_model_graph(cfg, batch=batch, seq=seq, model=model,
                                  reduced=reduced, n_layers=n_layers,
                                  hw=hw, seed=seed)
    sim = Simulator(estimator=estimator, hw=hw, n_devices=n_devices,
                    cluster=cluster, streams=streams,
                    background=tuple(background), pipeline=pipeline,
                    tp=tp, level_chunks=level_chunks,
                    overlap_discount=overlap_discount)

    # ---------------------------------------------------------- plan cache
    store = key = features = None
    initial = None
    cache_prov: dict = {}
    if cache is not None:
        from .cache import (cache_features, compile_key, graph_digest,
                            knob_digest, open_cache, warm_start_state)

        store = open_cache(cache)
        knobs = knob_digest(alpha=alpha, beta=beta,
                            unchanged_limit=unchanged_limit,
                            max_steps=max_steps, methods=methods, seed=seed)
        gd = graph_digest(graph)
        key = compile_key(graph, sim, knobs, digest=gd)
        features = cache_features(graph, sim, arch=arch, knobs=knobs,
                                  digest=gd)
        hit = store.get(key)
        if hit is not None:
            # exact-key replay: the stored artifact IS the answer — same
            # strategy, same fingerprints, same predicted price, zero
            # simulator evaluations
            hit.provenance["cache"] = {"outcome": "hit", "key": key}
            hit.provenance["facade_wall_time"] = \
                _time.perf_counter() - t_start
            return hit
        cache_prov = {"outcome": "cold", "key": key}
        if warm_start:
            for score, ent, near in store.nearest(features, exclude=key):
                g_warm = warm_start_state(near, graph, sim)
                if g_warm is None:
                    continue  # wrong trace family — next candidate
                warm_cost = sim.cost(g_warm)
                if warm_cost >= sim.cost(graph):
                    # prices worse than the trivial start: a misleading
                    # seed state buys nothing — fall through to cold
                    continue
                initial = g_warm
                store.stats["warm_starts"] += 1
                cache_prov = {
                    "outcome": "warm", "key": key,
                    "warm_from": ent.get("key"),
                    "warm_similarity": score,
                    "warm_from_cluster": ent.get("cluster_name"),
                    "warm_start_cost": warm_cost,
                }
                break

    kw = {} if methods is None else {"methods": tuple(methods)}
    res = backtracking_search(
        graph, sim, alpha=alpha, beta=beta,
        unchanged_limit=unchanged_limit, max_steps=max_steps, seed=seed,
        workers=workers, initial=initial, **kw)
    plan = Plan.from_graph(
        res.best, sim=sim, predicted=res.best_cost,
        provenance={
            "arch": arch,
            "grad_tensors": len(graph.grad_prim),
            "initial_cost": res.initial_cost,
            "best_cost": res.best_cost,
            "steps": res.steps,
            "simulations": res.simulations,
            "search_wall_time": res.wall_time,
            "quality_history": [list(t) for t in res.quality_history],
            "seed": seed,
        })
    if store is not None:
        plan.provenance["cache"] = cache_prov
        store.put(key, plan, features)
    plan.provenance["facade_wall_time"] = _time.perf_counter() - t_start
    return plan


# ``repro.plan.compile(...)`` is the public spelling; the module-level name
# only shadows the builtin at the attribute level, never in this file.
compile = compile_plan

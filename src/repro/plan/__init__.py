"""``repro.plan`` — the public API layer: search once, carry the result.

:class:`Plan` is a frozen, versioned, serializable record of one searched
strategy (op-fusion groups, tensor-fusion buckets, per-bucket
``(algo, comm kind, chunks)``, stream count, cluster fingerprint, estimator
provenance, predicted iteration time); :func:`compile` is the facade that
produces one (trace -> profile -> search).  From a plan:

* ``plan.grad_sync(params)`` lowers to an enactable ``GradSyncStrategy``
  (buckets, comm kinds *and* chunk counts);
* ``plan.simulator()`` reconstructs the exact pricing configuration;
* ``plan.to_graph(base)`` re-applies the strategy onto a traced graph
  (equal ``fast_signature()`` and simulated cost);
* ``plan.price()`` prices the saved gradient traffic without re-tracing
  (``python -m repro.launch.dryrun --plan <file>``);
* ``plan.save(path)`` / ``Plan.load(path)`` round-trip JSON (atomic
  writes — no torn artifacts), with a migration shim for legacy v0
  ``strategy.json`` files and :class:`PlanError` on corruption / foreign
  versions / cluster mismatches;
* :class:`PlanCache` (``repro.plan.cache``) stores compiled plans
  content-addressed on disk — ``compile(cache=...)`` replays exact-key
  hits bit-identically and warm-starts the search from the nearest
  cached strategy on a near miss (``python -m repro.plan.cache
  ls|stats|prune|verify`` to inspect a cache directory).

See DESIGN.md Sec. 10 and 12.  jax-free except ``compile()``'s tracing
mode.
"""
from .artifact import (ClusterMismatchError, PLAN_VERSION, Plan, PlanError,
                       PlanVersionError, SCHEMA, cluster_fingerprint,
                       cluster_fingerprint_diff, estimator_name)
from .cache import (PlanCache, cache_features, compile_key, graph_digest,
                    knob_digest, open_cache, similarity, warm_start_state)
from .facade import compile, compile_plan, trace_model_graph

__all__ = [
    "ClusterMismatchError", "PLAN_VERSION", "Plan", "PlanCache",
    "PlanError", "PlanVersionError", "SCHEMA", "cache_features",
    "cluster_fingerprint", "cluster_fingerprint_diff", "compile",
    "compile_key", "compile_plan", "estimator_name", "graph_digest",
    "knob_digest", "open_cache", "similarity", "trace_model_graph",
    "warm_start_state",
]

"""``repro.plan`` — the public API layer: search once, carry the result.

:class:`Plan` is a frozen, versioned, serializable record of one searched
strategy (op-fusion groups, tensor-fusion buckets, per-bucket
``(algo, comm kind, chunks)``, stream count, cluster fingerprint, estimator
provenance, predicted iteration time); :func:`compile` is the facade that
produces one (trace -> profile -> search).  From a plan:

* ``plan.grad_sync(params)`` lowers to an enactable ``GradSyncStrategy``
  (buckets, comm kinds *and* chunk counts);
* ``plan.simulator()`` reconstructs the exact pricing configuration;
* ``plan.to_graph(base)`` re-applies the strategy onto a traced graph
  (equal ``fast_signature()`` and simulated cost);
* ``plan.price()`` prices the saved gradient traffic without re-tracing
  (``python -m repro.launch.dryrun --plan <file>``);
* ``plan.save(path)`` / ``Plan.load(path)`` round-trip JSON, with a
  migration shim for legacy v0 ``strategy.json`` files and
  :class:`PlanError` on corruption / foreign versions / cluster
  mismatches.

See DESIGN.md Sec. 10.  jax-free except ``compile()``'s tracing mode.
"""
from .artifact import (ClusterMismatchError, PLAN_VERSION, Plan, PlanError,
                       PlanVersionError, SCHEMA, cluster_fingerprint,
                       estimator_name)
from .facade import compile, compile_plan, trace_model_graph

__all__ = [
    "ClusterMismatchError", "PLAN_VERSION", "Plan", "PlanError",
    "PlanVersionError", "SCHEMA", "cluster_fingerprint", "estimator_name",
    "compile", "compile_plan", "trace_model_graph",
]

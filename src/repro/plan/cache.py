"""``repro.plan.cache`` — content-addressed Plan cache + warm-start ranking.

DisCo's search output is a reusable artifact (PR 5 froze it into
:class:`~repro.plan.artifact.Plan`), but ``compile()`` still re-ran the
backtracking search from scratch for every (model, cluster, knobs) point.
This module is the storage/index layer above the artifact (DESIGN.md
Sec. 12): a :class:`PlanCache` directory keyed on

    ``sha256(graph content-signature x cluster fingerprint x search-knob
    digest)``

whose values are the Plan JSON files themselves.  Exact-key hits *replay*
the artifact — bit-identical strategy, fingerprints and predicted price, no
simulator evaluations (the ``compile once, replay everywhere`` discipline;
DeepCompile/DistIR in PAPERS.md argue simulator-driven search only scales
across fleets of (model, topology) points this way).

Near misses go through :func:`rank_entries`: cached entries are scored by a
similarity over (same traced graph > same arch, same cluster fingerprint >
same level structure, close gradient volume / device count / stream count),
and ``compile(cache=...)`` re-applies the nearest Plan's strategy onto the
fresh :class:`~repro.core.graph.FusionGraph` (through the mutation
registry's applicability contract — dimensions the new simulator cannot
price are reset to their defaults) as the backtracking search's **warm
start state**.  The failure/fallback ladder is total: a corrupt entry is a
miss, a plan that does not fit the new trace is skipped, and a warm state
that prices worse than the trivial (unfused) baseline is discarded — the
search then runs cold, exactly as without a cache.

Key derivation notes: the in-memory ``FusionGraph.fast_signature()`` is a
per-process salted hash (Python string hashing), so the on-disk key derives
from the *stable* content signature — prim payloads, the prim DAG's edges
and the full sorted strategy ``signature()`` — plus the canonical cluster
fingerprint of :func:`repro.plan.artifact.cluster_fingerprint` and a digest
of the trajectory-determining search knobs (``workers`` is excluded: the
worker pool evaluates candidates concurrently but the RNG stream, and thus
the result, is identical).

CLI (``python -m repro.plan.cache``): ``ls`` / ``stats`` / ``prune`` /
``verify`` over a cache directory.  jax-free.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Iterable, Sequence

from ..cluster import ClusterSpec
from ..core.graph import FusionGraph
from ..core.mutations import (METHOD_ALGO, METHOD_CHUNK, METHOD_COMM,
                              METHOD_FUSED, METHOD_PP_SPLIT, active_methods)
from .artifact import Plan, PlanError, cluster_fingerprint, estimator_name

INDEX_NAME = "index.json"
INDEX_VERSION = 1
PLAN_SUFFIX = ".plan.json"


# ----------------------------------------------------------------- digests
def _sha(obj) -> str:
    """Stable short digest of a JSON-able structure (tuples and lists
    collapse to the same JSON arrays on purpose — fingerprints round-trip
    through JSON as lists)."""
    return hashlib.sha256(
        json.dumps(obj, sort_keys=True, default=repr).encode()
    ).hexdigest()[:20]


def _trace_digest(g: FusionGraph) -> str:
    """Digest of the *immutable* traced half of a graph: prim payloads and
    DAG edges.  Mutations only move groups/buckets, never prims, so the
    value is memoized on the instance — repeated cache lookups over one
    traced graph (the sweep-benchmark pattern) pay it once."""
    d = getattr(g, "_cache_trace_digest", None)
    if d is None:
        h = hashlib.sha256()
        for p in g.prims:
            h.update(repr((p.pid, p.op_type, p.category, p.flops,
                           p.in_bytes, p.out_bytes, p.time, p.grad_param,
                           p.grad_bytes, p.grad_sig)).encode())
        for s, dsts in enumerate(g.psuccs):
            if dsts:
                h.update(repr((s, tuple(sorted(dsts)))).encode())
        d = h.hexdigest()
        g._cache_trace_digest = d
    return d


def graph_digest(g: FusionGraph) -> str:
    """Content address of a traced+profiled graph *and* its current
    strategy state: prim payloads (op types, flops/bytes/times, gradient
    metadata), the prim DAG's edges, and the sorted strategy signature.
    Process-stable, unlike ``fast_signature()`` (whose string components
    are salted per interpreter)."""
    h = hashlib.sha256()
    h.update(_trace_digest(g).encode())
    h.update(repr(g.signature()).encode())
    return h.hexdigest()[:20]


def knob_digest(*, alpha: float, beta: int, unchanged_limit: int,
                max_steps: int | None, methods: Sequence[str] | None,
                seed: int) -> str:
    """Digest of the trajectory-determining search hyper-parameters.
    ``workers`` is deliberately absent — candidate evaluation order does
    not change the RNG stream or the winner."""
    return _sha({
        "alpha": float(alpha), "beta": int(beta),
        "unchanged_limit": int(unchanged_limit),
        "max_steps": None if max_steps is None else int(max_steps),
        "methods": None if methods is None else list(methods),
        "seed": int(seed),
    })


def _context_parts(sim) -> dict:
    """The pricing context a Simulator bakes into candidate costs: cluster
    fingerprint, stream count, background classes, pipeline schedule,
    compute Hardware and estimator provenance."""
    hw = getattr(sim, "hw", None)
    pp = getattr(sim, "pipeline", None)
    parts = {
        "cluster": cluster_fingerprint(sim.cluster),
        "streams": int(getattr(sim, "streams", 1)),
        "background": [
            (b.traffic_class, float(b.nbytes), float(b.period), b.algo,
             b.kind, float(b.offset), b.count)
            for b in getattr(sim, "background", ())
        ],
        "pipeline": None if pp is None else list(pp.to_tuple()),
        "hw": None if hw is None else sorted(dataclasses.asdict(hw).items()),
        "estimator": estimator_name(getattr(sim, "estimator", None)),
        # the in-kernel overlap discount changes every fused bucket's price,
        # so two sims differing only in calibration must not share entries
        "overlap_discount": float(getattr(sim, "overlap_discount", 0.0)),
    }
    # added only when present so every pre-v3 compile point keeps its
    # historical cache key (tp=None / level_chunks=False sims digest
    # exactly as before)
    tp = getattr(sim, "tp", None)
    if tp is not None:
        parts["tp"] = list(tp.to_tuple())
    if getattr(sim, "level_chunks", False):
        parts["level_chunks"] = True
    return parts


def compile_key(graph: FusionGraph, sim, knobs: str, *,
                digest: str | None = None) -> str:
    """The cache key of one ``compile()`` point: graph content-signature x
    cluster/pricing fingerprint x search-knob digest.  ``digest`` lets a
    caller that already computed :func:`graph_digest` pass it in."""
    return _sha({
        "graph": digest or graph_digest(graph),
        "context": _context_parts(sim),
        "knobs": knobs,
    })


# ------------------------------------------------------- similarity ranking
def cache_features(graph: FusionGraph, sim, *, arch: str | None = None,
                   knobs: str | None = None,
                   digest: str | None = None) -> dict:
    """The similarity coordinates of one compile point (recorded per entry
    at ``put`` time, recomputed for the request on a miss)."""
    spec: ClusterSpec = sim.cluster
    if spec.is_flat_compat:
        levels, bws = ["flat"], [float(spec.compat_hw.ici_bw)]
    else:
        levels = [l.name for l in spec.levels]
        bws = [float(l.bandwidth) for l in spec.levels]
    return {
        "graph": digest or graph_digest(graph),
        "arch": arch,
        "grad_bytes": float(sum(graph.bucket_bytes(b) for b in graph.buckets)),
        "grad_tensors": len(graph.grad_prim),
        "cluster": _sha(cluster_fingerprint(spec)),
        "cluster_name": spec.name,
        "n_devices": int(spec.n_devices),
        "levels": levels,
        "level_bw": bws,
        "streams": int(getattr(sim, "streams", 1)),
        "pipeline": (None if getattr(sim, "pipeline", None) is None
                     else list(sim.pipeline.to_tuple())),
        "tp": (None if getattr(sim, "tp", None) is None
               else list(sim.tp.to_tuple())),
        "knobs": knobs,
    }


def _ratio_closeness(a: float, b: float) -> float:
    """1.0 when equal, decaying toward 0 as the ratio diverges."""
    if a <= 0 or b <= 0:
        return 1.0 if a == b else 0.0
    r = a / b if a < b else b / a
    return r


def similarity(req: dict, ent: dict) -> float:
    """Score a cached entry against a request.  Dominant terms first: the
    exact traced graph (same arch *and* shapes), then the arch family, then
    cluster identity/structure, then the pricing knobs.  A plan from a
    different trace family can still rank (its strategy may not re-apply —
    the warm-start ladder just falls through to the next candidate)."""
    s = 0.0
    if req["graph"] == ent.get("graph"):
        s += 8.0
    if req.get("arch") and req["arch"] == ent.get("arch"):
        s += 4.0
    elif req.get("grad_tensors") == ent.get("grad_tensors"):
        s += 1.0
    s += 2.0 * _ratio_closeness(req.get("grad_bytes", 0.0),
                                ent.get("grad_bytes", 0.0))
    if req["cluster"] == ent.get("cluster"):
        s += 4.0
    else:
        if req.get("levels") == ent.get("levels"):
            s += 1.0
        elif len(req.get("levels", ())) == len(ent.get("levels", ())):
            s += 0.5
        s += _ratio_closeness(req.get("n_devices", 0),
                              ent.get("n_devices", 0))
        bw_a, bw_b = req.get("level_bw") or [], ent.get("level_bw") or []
        if bw_a and bw_b:
            s += _ratio_closeness(min(bw_a), min(bw_b))
    if req.get("streams") == ent.get("streams"):
        s += 1.0
    if req.get("pipeline") == ent.get("pipeline"):
        s += 0.5
    if req.get("tp") == ent.get("tp"):
        s += 0.5
    if req.get("knobs") and req["knobs"] == ent.get("knobs"):
        s += 0.5
    return s


def rank_entries(req: dict, entries: Iterable[dict]) -> list[tuple[float, dict]]:
    """Cached entries most-similar-first.  Ties break on recency so a
    re-searched point shadows its stale ancestor."""
    scored = [(similarity(req, e), e) for e in entries]
    scored.sort(key=lambda t: (-t[0], -t[1].get("created", 0.0),
                               t[1].get("key", "")))
    return scored


# -------------------------------------------------- warm-start re-application
def warm_start_state(plan: Plan, base: FusionGraph, sim) -> FusionGraph | None:
    """Re-apply a cached plan's strategy onto a fresh traced graph as a
    search start state.  ``Plan.to_graph`` rebuilds the op/tensor-fusion
    state; the mutation registry's applicability contract then resets the
    per-bucket dimensions this ``sim`` cannot price (a serialized channel
    ignores comm-kind/chunk flips, a flat spec is algorithm-blind) through
    the same ``set_bucket_*`` mutations the search would use, so the state
    is journal/rolling-hash consistent.  Returns None when the plan does
    not fit the trace — the caller falls back down the ladder."""
    if not hasattr(plan, "to_graph"):
        # not a training plan (e.g. a ServingPlan sharing the cache): there
        # is no fusion state to re-apply, so no warm start
        return None
    try:
        g = plan.to_graph(base)
    except PlanError:
        return None
    active = set(active_methods(sim))
    for i in range(len(g.buckets)):
        if METHOD_ALGO not in active:
            g.set_bucket_algo(i, "ring")
        if METHOD_COMM not in active:
            g.set_bucket_comm(i, "ar")
        if METHOD_CHUNK not in active:
            g.set_bucket_chunks(i, 1)
        if METHOD_FUSED not in active:
            g.set_bucket_fused(i, False)
    if METHOD_PP_SPLIT not in active:
        # the target sim cannot price pipeline knobs (no pipeline
        # schedule): carrying a donor plan's overrides would be inert
        # state that pollutes signatures and re-saved plans
        g.reset_pp_knobs()
    return g


def _load_artifact(path: str):
    """Load a cached artifact by schema: training ``Plan`` (the default)
    or a serving plan (``repro.serving_plan``).  The schema peek keeps the
    two families in one store without either loader having to tolerate the
    other's JSON; any read/parse failure surfaces as ``PlanError`` so the
    cache's corruption-tolerance contract is unchanged."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise PlanError(f"unreadable plan artifact at {path}: {e}") from e
    if isinstance(doc, dict) and doc.get("schema") == "repro.serving_plan":
        from ..serving.plan import ServingPlan  # import-light, no jax
        return ServingPlan.from_dict(doc)
    return Plan.from_dict(doc, source=path)


# ---------------------------------------------------------------- the cache
def _atomic_write_json(path: str, obj) -> None:
    """Torn-write-proof JSON write: temp file in the same directory +
    ``os.replace`` (the same discipline as ``Plan.save``)."""
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=1)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass


class PlanCache:
    """Content-addressed on-disk store of Plan artifacts.

    Layout: ``<root>/index.json`` (entry metadata: key, similarity
    features, predicted time, creation time) plus one
    ``<root>/<key>.plan.json`` per entry — the Plan JSON itself, loadable
    by ``Plan.load`` without the cache.

    Every load is corruption-tolerant: a truncated/foreign/mismatched
    entry counts as ``stale`` and behaves as a miss, never a crash.  An
    unreadable index is rebuilt from a directory scan.  Writers are
    crash/concurrency-safe by atomic replace — two processes putting the
    same key leave a readable index and a complete plan file (last writer
    wins).  ``capacity`` bounds the entry count: puts beyond it evict the
    oldest entries first.
    """

    def __init__(self, root: str, capacity: int | None = None):
        self.root = str(root)
        self.capacity = capacity
        os.makedirs(self.root, exist_ok=True)
        self.stats = {"hits": 0, "misses": 0, "stale": 0, "puts": 0,
                      "evictions": 0, "warm_starts": 0}

    # ------------------------------------------------------------- index IO
    def _index_path(self) -> str:
        return os.path.join(self.root, INDEX_NAME)

    def _read_index(self) -> dict:
        try:
            with open(self._index_path()) as f:
                d = json.load(f)
            if (isinstance(d, dict) and d.get("version") == INDEX_VERSION
                    and isinstance(d.get("entries"), dict)):
                return d
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            pass
        # missing or corrupt index: rebuild from the plan files on disk so
        # a torn index write never strands valid entries
        entries = {}
        try:
            names = os.listdir(self.root)
        except OSError:
            names = []
        for name in names:
            if not name.endswith(PLAN_SUFFIX):
                continue
            key = name[:-len(PLAN_SUFFIX)]
            try:
                plan = _load_artifact(os.path.join(self.root, name))
            except PlanError:
                continue
            entries[key] = {
                "key": key,
                "created": 0.0,
                "predicted_s": plan.predicted_iteration_time,
                "rebuilt": True,
                **{k: v for k, v in plan.provenance.get(
                    "cache_features", {}).items()},
            }
        return {"version": INDEX_VERSION, "entries": entries}

    def _write_index(self, index: dict) -> None:
        _atomic_write_json(self._index_path(), index)

    def _plan_path(self, key: str) -> str:
        return os.path.join(self.root, key + PLAN_SUFFIX)

    # ------------------------------------------------------------ get / put
    def get(self, key: str) -> Plan | None:
        """Exact-key lookup.  A present-but-unreadable entry (torn write,
        foreign schema, truncated vectors) is counted ``stale`` and
        reported as a miss."""
        path = self._plan_path(key)
        if not os.path.exists(path):
            self.stats["misses"] += 1
            return None
        try:
            plan = _load_artifact(path)
        except PlanError:
            self.stats["stale"] += 1
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        return plan

    def put(self, key: str, plan: Plan, features: dict | None = None) -> None:
        """Store a plan under ``key``.  The plan file is written first
        (atomically), then the index — a crash between the two leaves a
        valid plan the index rebuild will recover."""
        feats = dict(features or {})
        # ride the features inside the artifact too, so index rebuilds
        # recover the similarity coordinates
        plan.provenance.setdefault("cache_features", feats)
        plan.save(self._plan_path(key))
        index = self._read_index()
        index["entries"][key] = {
            "key": key,
            "created": time.time(),
            "predicted_s": plan.predicted_iteration_time,
            **feats,
        }
        self.stats["puts"] += 1
        if self.capacity is not None and len(index["entries"]) > self.capacity:
            excess = sorted(index["entries"].values(),
                            key=lambda e: (e.get("created", 0.0),
                                           e.get("key", "")))
            for e in excess[:len(index["entries"]) - self.capacity]:
                self._drop(index, e["key"])
                self.stats["evictions"] += 1
        self._write_index(index)

    def _drop(self, index: dict, key: str) -> None:
        index["entries"].pop(key, None)
        try:
            os.remove(self._plan_path(key))
        except OSError:
            pass

    # --------------------------------------------------------------- queries
    def entries(self) -> list[dict]:
        """Index metadata, oldest first."""
        ents = list(self._read_index()["entries"].values())
        ents.sort(key=lambda e: (e.get("created", 0.0), e.get("key", "")))
        return ents

    def __len__(self) -> int:
        return len(self._read_index()["entries"])

    def nearest(self, features: dict, *, exclude: str | None = None,
                limit: int = 3) -> list[tuple[float, dict, Plan]]:
        """The ``limit`` most similar *loadable* entries to ``features``,
        most-similar-first, each with its loaded Plan.  Unloadable entries
        are skipped (counted ``stale``); ``exclude`` drops the request's
        own key so a near-miss never warm-starts from itself."""
        out: list[tuple[float, dict, Plan]] = []
        for score, ent in rank_entries(features, self.entries()):
            key = ent.get("key")
            if not key or key == exclude:
                continue
            try:
                plan = _load_artifact(self._plan_path(key))
            except PlanError:
                self.stats["stale"] += 1
                continue
            out.append((score, ent, plan))
            if len(out) >= limit:
                break
        return out

    # ----------------------------------------------------------- maintenance
    def verify(self) -> dict:
        """Re-load every indexed entry; report (and optionally let
        ``prune`` drop) the corrupt ones, plus plan files the index does
        not know about."""
        index = self._read_index()
        ok, corrupt = [], []
        for key in sorted(index["entries"]):
            try:
                _load_artifact(self._plan_path(key))
                ok.append(key)
            except PlanError as e:
                corrupt.append({"key": key, "error": str(e)})
        known = {k + PLAN_SUFFIX for k in index["entries"]}
        orphans = sorted(
            n for n in os.listdir(self.root)
            if n.endswith(PLAN_SUFFIX) and n not in known)
        return {"entries": len(index["entries"]), "ok": len(ok),
                "corrupt": corrupt, "orphans": orphans}

    def prune(self, *, max_entries: int | None = None,
              max_age_s: float | None = None,
              drop_corrupt: bool = True) -> dict:
        """Evict: corrupt entries (always a miss anyway), entries older
        than ``max_age_s``, then the oldest beyond ``max_entries``."""
        index = self._read_index()
        dropped: list[str] = []
        if drop_corrupt:
            for item in self.verify()["corrupt"]:
                self._drop(index, item["key"])
                dropped.append(item["key"])
        if max_age_s is not None:
            cutoff = time.time() - max_age_s
            for e in list(index["entries"].values()):
                if e.get("created", 0.0) < cutoff:
                    self._drop(index, e["key"])
                    dropped.append(e["key"])
        if max_entries is not None and len(index["entries"]) > max_entries:
            excess = sorted(index["entries"].values(),
                            key=lambda e: (e.get("created", 0.0),
                                           e.get("key", "")))
            for e in excess[:len(index["entries"]) - max_entries]:
                self._drop(index, e["key"])
                dropped.append(e["key"])
        self.stats["evictions"] += len(dropped)
        self._write_index(index)
        return {"dropped": dropped, "remaining": len(index["entries"])}

    def describe(self) -> dict:
        ents = self.entries()
        return {
            "root": self.root,
            "entries": len(ents),
            "archs": sorted({e.get("arch") for e in ents
                             if e.get("arch")}),
            "clusters": sorted({e.get("cluster_name") for e in ents
                                if e.get("cluster_name")}),
            "stats": dict(self.stats),
        }


def open_cache(cache) -> PlanCache | None:
    """Normalize ``compile(cache=...)``'s argument: a PlanCache, a
    directory path, or None."""
    if cache is None or isinstance(cache, PlanCache):
        return cache
    if isinstance(cache, (str, os.PathLike)):
        return PlanCache(os.fspath(cache))
    raise TypeError(f"cache must be a PlanCache or a directory path, "
                    f"got {type(cache).__name__}")


# --------------------------------------------------------------------- CLI
def _cmd_ls(cache: PlanCache) -> int:
    ents = cache.entries()
    if not ents:
        print(f"{cache.root}: empty cache")
        return 0
    for e in ents:
        created = (time.strftime("%Y-%m-%d %H:%M:%S",
                                 time.localtime(e["created"]))
                   if e.get("created") else "<rebuilt>")
        pred = e.get("predicted_s")
        pred_s = f"{pred*1e3:9.3f} ms" if pred is not None else "        ?"
        print(f"  {e['key']}  {created}  {pred_s}  "
              f"{e.get('arch') or '?':24s} {e.get('cluster_name') or '?'}")
    print(f"{len(ents)} entries in {cache.root}")
    return 0


def _cmd_stats(cache: PlanCache) -> int:
    print(json.dumps(cache.describe(), indent=1))
    return 0


def _cmd_verify(cache: PlanCache) -> int:
    rep = cache.verify()
    print(json.dumps(rep, indent=1))
    return 1 if rep["corrupt"] else 0


def _cmd_prune(cache: PlanCache, max_entries, max_age_s) -> int:
    rep = cache.prune(max_entries=max_entries, max_age_s=max_age_s)
    print(f"dropped {len(rep['dropped'])} entries, "
          f"{rep['remaining']} remaining")
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.plan.cache",
        description="inspect / maintain a repro.plan cache directory")
    ap.add_argument("cmd", choices=("ls", "stats", "prune", "verify"))
    ap.add_argument("--dir", default=".plan-cache",
                    help="cache directory (default .plan-cache)")
    ap.add_argument("--max-entries", type=int, default=None,
                    help="prune: keep at most this many entries")
    ap.add_argument("--max-age-s", type=float, default=None,
                    help="prune: drop entries older than this many seconds")
    args = ap.parse_args(argv)
    cache = PlanCache(args.dir)
    if args.cmd == "ls":
        return _cmd_ls(cache)
    if args.cmd == "stats":
        return _cmd_stats(cache)
    if args.cmd == "verify":
        return _cmd_verify(cache)
    return _cmd_prune(cache, args.max_entries, args.max_age_s)


if __name__ == "__main__":
    raise SystemExit(main())

"""Unified model: decoder LMs (dense / MLA / MoE / RWKV-6 / RG-LRU hybrid),
encoder-decoder (Seamless backbone) and VLM prefix decoders (PaliGemma
backbone) — one functional implementation driven by ``ModelConfig``.

Entry points:
    init_params(key, cfg)
    forward(params, cfg, tokens, ...)          full-sequence logits (train)
    loss_fn(params, cfg, batch)                mean next-token CE (+ MoE aux)
    init_cache(cfg, batch_size, cache_len)     decode-state pytree
    prefill(params, cfg, tokens, cache_len)    logits + warm cache
    decode_step(params, cfg, cache, token, pos) one-token serving step
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import layers as L
from . import recurrent as R


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------- params
def init_layer(key, cfg: ModelConfig, li: int) -> dict:
    kind = cfg.block_kind(li)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict = {"ln1": L.init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = L.init_mla(k1, cfg) if cfg.block == "mla" else \
            L.init_attention(k1, cfg)
    elif kind == "rec":
        p["rec"] = R.init_recurrent_block(k1, cfg)
    elif kind == "rwkv":
        p["tmix"] = R.init_rwkv_block(k1, cfg)
        p["ln2"] = L.init_norm(cfg, cfg.d_model)
        return p
    p["ln2"] = L.init_norm(cfg, cfg.d_model)
    if cfg.is_moe_layer(li):
        p["moe"] = L.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    if cfg.encdec is not None:
        p["ln_x"] = L.init_norm(cfg, cfg.d_model)
        p["xattn"] = L.init_cross_attention(k3, cfg)
    return p


def init_enc_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 4)
    dt = _dtype(cfg)
    params: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "layers": [jax.tree.map(lambda a: a.astype(dt) if a.dtype == jnp.float32
                                else a, init_layer(keys[1 + i], cfg, i))
                   for i in range(cfg.n_layers)],
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[-1],
                                               (cfg.d_model, cfg.vocab))
                             * 0.02).astype(dt)
    if cfg.encdec is not None:
        ek = jax.random.split(keys[-2], cfg.encdec.n_enc_layers + 1)
        params["encoder"] = {
            "in_proj": (jax.random.normal(ek[0], (cfg.encdec.frontend_dim,
                                                  cfg.d_model))
                        / np.sqrt(cfg.encdec.frontend_dim)).astype(dt),
            "layers": [jax.tree.map(lambda a: a.astype(dt),
                                    init_enc_layer(ek[1 + i], cfg))
                       for i in range(cfg.encdec.n_enc_layers)],
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
    if cfg.vlm_prefix_len:
        params["vision_proj"] = jnp.eye(cfg.d_model, dtype=dt)  # stub projector
    return params


# ----------------------------------------------------------------- helpers
def _sinusoid(S: int, D: int, dtype) -> jnp.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(0, D, 2)[None, :]
    ang = pos / np.power(10000.0, dim / D)
    out = np.zeros((S, D), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out, dtype)


def _embed(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]
    if cfg.tie_embeddings:   # gemma-family scaling
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def _unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def _layer_fwd(p, cfg: ModelConfig, li: int, x, positions, *, memory=None,
               cache=None, pos=None, return_cache=False, cache_len=0,
               use_kernels=False):
    """One block.  Returns (x, aux_loss, new_cache)."""
    kind = cfg.block_kind(li)
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    h = L.norm_fwd(p["ln1"], cfg, x)
    if kind == "attn":
        window = cfg.window if cfg.recurrent is not None or cfg.window else None
        if cfg.block == "mla":
            r = L.mla_fwd(p["attn"], cfg, h, positions, cache=cache, pos=pos,
                          return_cache=return_cache, cache_len=cache_len)
        else:
            r = L.attention_fwd(p["attn"], cfg, h, positions, cache=cache,
                                pos=pos, window=window, use_flash=use_kernels,
                                return_cache=return_cache, cache_len=cache_len)
        if return_cache or cache is not None:
            attn_out, new_cache = r
        else:
            attn_out = r
        x = x + attn_out
    elif kind == "rec":
        r = R.recurrent_block_fwd(p["rec"], cfg, h, state=cache,
                                  return_state=return_cache,
                                  use_kernel=use_kernels)
        if return_cache or cache is not None:
            rec_out, new_cache = r
        else:
            rec_out = r
        x = x + rec_out
    elif kind == "rwkv":
        tstate = cache["tmix"] if cache is not None else None
        tm_out, tnew = R.rwkv_time_mix(p["tmix"], cfg, h, state=tstate,
                                       use_kernel=use_kernels)
        x = x + tm_out
        h2 = L.norm_fwd(p["ln2"], cfg, x)
        cstate = cache["cmix"] if cache is not None else None
        cm_out, cnew = R.rwkv_channel_mix(p["tmix"], cfg, h2, state=cstate)
        x = x + cm_out
        if return_cache or cache is not None:
            new_cache = {"tmix": tnew, "cmix": cnew}
        return x, aux, new_cache
    if memory is not None:
        hx = L.norm_fwd(p["ln_x"], cfg, x)
        x = x + L.cross_attention_fwd(p["xattn"], cfg, hx, memory)
    h2 = L.norm_fwd(p["ln2"], cfg, x)
    if cfg.is_moe_layer(li):
        ff, aux = L.moe_fwd(p["moe"], cfg, h2)
    else:
        ff = L.mlp_fwd(p["mlp"], cfg, h2)
    x = x + ff
    return x, aux, new_cache


def encode(params, cfg: ModelConfig, frames):
    """Encoder over precomputed frontend frame embeddings (B, T, F)."""
    enc = params["encoder"]
    x = frames.astype(_dtype(cfg)) @ enc["in_proj"]
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
    for lp in enc["layers"]:
        h = L.norm_fwd(lp["ln1"], cfg, x)
        B, T, D = h.shape
        hd = cfg.hd
        q = (h @ lp["attn"]["wq"].astype(h.dtype)).reshape(B, T, cfg.n_heads, hd)
        k = (h @ lp["attn"]["wk"].astype(h.dtype)).reshape(B, T, cfg.n_kv_heads, hd)
        v = (h @ lp["attn"]["wv"].astype(h.dtype)).reshape(B, T, cfg.n_kv_heads, hd)
        a = L.sdpa(q, k, v, None, causal=False).reshape(B, T, -1)
        x = x + a @ lp["attn"]["wo"].astype(h.dtype)
        h2 = L.norm_fwd(lp["ln2"], cfg, x)
        x = x + L.mlp_fwd(lp["mlp"], cfg, h2)
    return L.norm_fwd(enc["final_norm"], cfg, x)


# ------------------------------------------------------------------ forward
def forward(params, cfg: ModelConfig, tokens, *, prefix_emb=None,
            enc_frames=None, use_kernels: bool = False, remat: bool = False):
    """Full-sequence logits.  ``prefix_emb``: (B, P, D) VLM patch embeddings
    (stub frontend); ``enc_frames``: (B, T, F) audio frame embeddings."""
    x = _embed(params, cfg, tokens)
    offset = 0
    if cfg.vlm_prefix_len and prefix_emb is not None:
        pre = prefix_emb.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([pre, x], axis=1)
        offset = prefix_emb.shape[1]
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.rope_frac == 0.0 and cfg.block != "rwkv" and cfg.recurrent is None:
        x = x + _sinusoid(S, cfg.d_model, x.dtype)[None]
    memory = encode(params, cfg, enc_frames) if enc_frames is not None else None

    total_aux = jnp.zeros((), jnp.float32)

    def block(x, p, li):
        return _layer_fwd(p, cfg, li, x, positions, memory=memory,
                          use_kernels=use_kernels)

    for li, p in enumerate(params["layers"]):
        fn = (lambda xx, pp, li=li: block(xx, pp, li)[:2])
        if remat:
            fn = jax.checkpoint(fn)
        x, aux = fn(x, p)
        total_aux = total_aux + aux
    x = L.norm_fwd(params["final_norm"], cfg, x)
    logits = _unembed(params, cfg, x)
    if offset:
        logits = logits[:, offset:]
    return logits, total_aux


def loss_fn(params, cfg: ModelConfig, batch, *, use_kernels: bool = False,
            remat: bool = False):
    """Mean next-token cross-entropy (+ MoE aux)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, tokens,
                          prefix_emb=batch.get("prefix_emb"),
                          enc_frames=batch.get("enc_frames"),
                          use_kernels=use_kernels, remat=remat)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux


# ------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> list:
    """Per-layer decode state with static shapes."""
    dt = dtype or _dtype(cfg)
    caches = []
    for li in range(cfg.n_layers):
        kind = cfg.block_kind(li)
        if kind == "attn":
            if cfg.block == "mla":
                m = cfg.mla
                caches.append({
                    "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dt),
                    "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim),
                                        dt),
                })
            else:
                size = min(cache_len, cfg.window) if cfg.window else cache_len
                if cfg.kv_cache_dtype == "int8":
                    caches.append({
                        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd),
                                       jnp.int8),
                        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd),
                                       jnp.int8),
                        "k_scale": jnp.zeros((batch, size, cfg.n_kv_heads),
                                             jnp.bfloat16),
                        "v_scale": jnp.zeros((batch, size, cfg.n_kv_heads),
                                             jnp.bfloat16),
                    })
                else:
                    caches.append({
                        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd),
                                       dt),
                        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd),
                                       dt),
                    })
        elif kind == "rec":
            Lw = cfg.recurrent.lru_width
            caches.append({
                "h": jnp.zeros((batch, Lw), jnp.float32),
                "conv": jnp.zeros((batch, cfg.recurrent.conv_width - 1, Lw), dt),
            })
        elif kind == "rwkv":
            caches.append({
                "tmix": {"wkv": jnp.zeros((batch, cfg.n_heads, cfg.hd, cfg.hd),
                                          jnp.float32),
                         "prev": jnp.zeros((batch, cfg.d_model), dt)},
                "cmix": {"prev": jnp.zeros((batch, cfg.d_model), dt)},
            })
    return caches


def decode_step(params, cfg: ModelConfig, caches, token, pos, *, memory=None):
    """One serving step: token (B,) int32, pos scalar int32 (current write
    position).  Returns (logits (B, vocab), new_caches)."""
    x = _embed(params, cfg, token[:, None])
    if cfg.rope_frac == 0.0 and cfg.block != "rwkv" and cfg.recurrent is None:
        # sinusoidal position for this step
        D = cfg.d_model
        dim = jnp.arange(0, D, 2) / D
        ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim)
        pe = jnp.zeros((D,), x.dtype)
        pe = pe.at[0::2].set(jnp.sin(ang).astype(x.dtype))
        pe = pe.at[1::2].set(jnp.cos(ang).astype(x.dtype))
        x = x + pe[None, None]
    positions = pos[None] if hasattr(pos, "shape") else jnp.array([pos])
    new_caches = []
    for li, p in enumerate(params["layers"]):
        x, _, nc = _layer_fwd(p, cfg, li, x, positions, memory=memory,
                              cache=caches[li], pos=pos)
        new_caches.append(nc)
    x = L.norm_fwd(params["final_norm"], cfg, x)
    return _unembed(params, cfg, x)[:, 0], new_caches


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
            prefix_emb=None, enc_frames=None, use_kernels: bool = False):
    """Process a prompt, returning (last-token logits, warm cache)."""
    x = _embed(params, cfg, tokens)
    if cfg.vlm_prefix_len and prefix_emb is not None:
        pre = prefix_emb.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.rope_frac == 0.0 and cfg.block != "rwkv" and cfg.recurrent is None:
        x = x + _sinusoid(S, cfg.d_model, x.dtype)[None]
    memory = encode(params, cfg, enc_frames) if enc_frames is not None else None
    caches = []
    for li, p in enumerate(params["layers"]):
        x, _, nc = _layer_fwd(p, cfg, li, x, positions, memory=memory,
                              return_cache=True, cache_len=cache_len,
                              use_kernels=use_kernels)
        caches.append(nc)
    x = L.norm_fwd(params["final_norm"], cfg, x)
    return _unembed(params, cfg, x[:, -1:])[:, 0], caches

"""Transformer building blocks (pure functional JAX, dict params).

Conventions:
* params are nested dicts of jnp arrays; init fns take (key, cfg, ...).
* activations (B, S, D); attention heads laid out (B, S, H, hd) so the head
  axis is shardable over the ``model`` mesh axis.
* every block supports three execution modes: train/prefill over a full
  sequence (optionally returning a KV cache), and single-token decode against
  a cache (static shapes; position passed as a traced scalar).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Axis = jax.sharding.PartitionSpec  # alias used by sharding rules


# ------------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layer":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope_freqs(cfg: ModelConfig, rot_dim: int) -> jnp.ndarray:
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, rot_dim, 2) / rot_dim))
    return jnp.asarray(inv, jnp.float32)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig,
               rot_dim: Optional[int] = None) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    rot = rot_dim if rot_dim is not None else int(hd * cfg.rope_frac)
    if rot == 0:
        return x
    inv = rope_freqs(cfg, rot)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * inv[None, :]  # (S, rot/2)
        ang = ang[None, :, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * inv
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    xr = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention
def _dense(key, d_in, d_out, scale=None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * s


def init_attention(key, cfg: ModelConfig) -> dict:
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense(k1, cfg.d_model, cfg.n_heads * hd),
        "wk": _dense(k2, cfg.d_model, cfg.n_kv_heads * hd),
        "wv": _dense(k3, cfg.d_model, cfg.n_kv_heads * hd),
        "wo": _dense(k4, cfg.n_heads * hd, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def _sdpa_dense(q, k, v, bias):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); bias broadcastable to (B,KV,G,S,T)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    group = H // KV
    qg = q.reshape(B, S, KV, group, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / np.sqrt(hd)
    scores = scores.astype(jnp.float32) + bias
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def _causal_bias(S, T, causal, window, q_offset=0):
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    ok = kpos <= qpos if causal else jnp.ones((S, T), bool)
    if window is not None:
        ok = ok & (kpos > qpos - window)
    return jnp.where(ok, 0.0, jnp.finfo(jnp.float32).min)


# Above this query length the XLA path chunks queries to bound the softmax
# working set (the Pallas flash kernel is the TPU runtime fast path).
_CHUNK_THRESHOLD = 2048
_Q_BLOCK = 512


def sdpa(q, k, v, mask, use_flash: bool = False, window: Optional[int] = None,
         causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); mask: (B,1,1|S,T) additive or None.

    GQA: query heads grouped over KV heads.  ``use_flash`` routes to the
    Pallas kernel when the mask is the standard causal(+window) one;
    otherwise long sequences take a query-chunked XLA path so the score
    matrix working set stays bounded.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    if use_flash and mask is None:
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if mask is None:
        if S > _CHUNK_THRESHOLD and S == T:
            for blk in (_Q_BLOCK, 256, 128, 64):
                if S % blk == 0:
                    return _flash_xla(q, k, v, causal, window, qb=blk, kb=blk)
        bias = _causal_bias(S, T, causal, window)[None, None, None]
        return _sdpa_dense(q, k, v, bias)
    bias = mask[:, :, None] if mask.ndim == 4 else mask
    return _sdpa_dense(q, k, v, bias)


def _flash_xla(q, k, v, causal, window, qb: int = _Q_BLOCK, kb: int = _Q_BLOCK):
    """Online-softmax attention in plain XLA (double lax.scan over query and
    KV blocks).  Working set per step is (B,H,qb,kb) — the flash-attention
    recurrence, so 32k/500k contexts lower with bounded temps.  Causality is
    enforced by masking (blocks are not skipped — the Pallas kernel is the
    block-skipping fast path on real TPUs)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = S // qb, T // kb
    qs = q.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)  # nq,B,KV,G,qb,hd
    ks = k.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)        # nk,B,KV,kb,hd
    vs = v.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / np.sqrt(hd)
    neg = jnp.finfo(jnp.float32).min

    @jax.checkpoint
    def q_step(_, qin):
        qi, qblk = qin

        @jax.checkpoint
        def kv_step(carry, kin):
            m, l, acc = carry
            ki, kblk, vblk = kin
            s = jnp.einsum("bkgqh,bkth->bkgqt", qblk, kblk).astype(jnp.float32)
            s = s * scale
            qpos = qi * qb + jnp.arange(qb)[:, None]
            kpos = ki * kb + jnp.arange(kb)[None, :]
            ok = kpos <= qpos if causal else jnp.ones((qb, kb), bool)
            if window is not None:
                ok = ok & (kpos > qpos - window)
            s = jnp.where(ok[None, None, None], s, neg)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,bkth->bkgqh", p.astype(vblk.dtype), vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qb), neg, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # outs: (nq, B, KV, G, qb, hd) -> (B, S, H, hd)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, hd)


def attention_fwd(p: dict, cfg: ModelConfig, x, positions, *,
                  cache: Optional[dict] = None, pos: Optional[jnp.ndarray] = None,
                  window: Optional[int] = None, use_flash: bool = False,
                  return_cache: bool = False, cache_len: int = 0):
    """Self-attention.  Train/prefill when ``cache is None`` (optionally
    returning a fresh cache of length ``cache_len``); decode when ``cache``
    and ``pos`` are given (x is (B,1,D))."""
    B, S, D = x.shape
    hd = cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)

    new_cache = None
    if cache is not None:
        # decode: write k,v at slot (pos % cache_size for ring buffers)
        ck, cv = cache["k"], cache["v"]
        csize = ck.shape[1]
        slot = pos % csize if window is not None else pos
        quant = "k_scale" in cache
        if quant:
            # int8 KV cache: symmetric per-(batch, slot, head) scales
            ks = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0
            vs = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-1) / 127.0
            kq = jnp.round(k.astype(jnp.float32)
                           / jnp.maximum(ks[..., None], 1e-8)).astype(jnp.int8)
            vq = jnp.round(v.astype(jnp.float32)
                           / jnp.maximum(vs[..., None], 1e-8)).astype(jnp.int8)
            ck = jax.lax.dynamic_update_slice(ck, kq, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, vq, (0, slot, 0, 0))
            cks = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks.astype(cache["k_scale"].dtype),
                (0, slot, 0))
            cvs = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs.astype(cache["v_scale"].dtype),
                (0, slot, 0))
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, slot, 0, 0))
            new_cache = {"k": ck, "v": cv}
        kpos = jnp.arange(csize)
        if window is not None:
            # ring buffer: entry i holds absolute position matching i when
            # within the last `csize` positions
            age = (slot - kpos) % csize
            ok = age <= jnp.minimum(pos, csize - 1)
        else:
            ok = kpos <= pos
        bias = jnp.where(ok, 0.0, jnp.finfo(jnp.float32).min)
        mask = jnp.broadcast_to(bias[None, None, None, :], (B, 1, 1, csize))
        if quant:
            kd = (ck.astype(q.dtype) * cks[..., None].astype(q.dtype))
            vd = (cv.astype(q.dtype) * cvs[..., None].astype(q.dtype))
            out = sdpa(q, kd, vd, mask)
        else:
            out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
    else:
        out = sdpa(q, k, v, None, use_flash=use_flash, window=window)
        if return_cache:
            size = cache_len or S
            ck = jnp.zeros((B, size, cfg.n_kv_heads, hd), x.dtype)
            cv = jnp.zeros((B, size, cfg.n_kv_heads, hd), x.dtype)
            take = min(S, size)
            ck = jax.lax.dynamic_update_slice(ck, k[:, -take:].astype(ck.dtype),
                                              (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v[:, -take:].astype(cv.dtype),
                                              (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}
    out = out.reshape(B, S, cfg.n_heads * hd)
    out = out @ p["wo"].astype(x.dtype)
    return (out, new_cache) if (return_cache or cache is not None) else out


def init_cross_attention(key, cfg: ModelConfig) -> dict:
    return init_attention(key, cfg)


def cross_attention_fwd(p: dict, cfg: ModelConfig, x, memory):
    """Decoder cross-attention over encoder memory (B, T, D)."""
    B, S, D = x.shape
    hd = cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    k = (memory @ p["wk"].astype(x.dtype)).reshape(
        B, memory.shape[1], cfg.n_kv_heads, hd)
    v = (memory @ p["wv"].astype(x.dtype)).reshape(
        B, memory.shape[1], cfg.n_kv_heads, hd)
    out = sdpa(q, k, v, None, causal=False)
    return out.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------- MLA
def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    keys = jax.random.split(key, 7)
    qdim = cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    p: dict = {}
    if m.q_lora_rank:
        p["w_dq"] = _dense(keys[0], cfg.d_model, m.q_lora_rank)
        p["w_uq"] = _dense(keys[1], m.q_lora_rank, qdim)
        p["q_norm"] = {"scale": jnp.ones((m.q_lora_rank,), jnp.float32)}
    else:
        p["wq"] = _dense(keys[0], cfg.d_model, qdim)
    p["w_dkv"] = _dense(keys[2], cfg.d_model, m.kv_lora_rank)
    p["w_kr"] = _dense(keys[3], cfg.d_model, m.qk_rope_head_dim)
    p["kv_norm"] = {"scale": jnp.ones((m.kv_lora_rank,), jnp.float32)}
    p["w_uk"] = _dense(keys[4], m.kv_lora_rank, cfg.n_heads * m.qk_nope_head_dim)
    p["w_uv"] = _dense(keys[5], m.kv_lora_rank, cfg.n_heads * m.v_head_dim)
    p["wo"] = _dense(keys[6], cfg.n_heads * m.v_head_dim, cfg.d_model)
    return p


def _rms(x, scale):
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * scale).astype(x.dtype)


def mla_fwd(p: dict, cfg: ModelConfig, x, positions, *,
            cache: Optional[dict] = None, pos=None,
            return_cache: bool = False, cache_len: int = 0):
    """Multi-head Latent Attention (DeepSeek-V2).  The decode cache stores
    only the compressed latent (c_kv, k_rope) — MLA's memory saving."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    if m.q_lora_rank:
        q = _rms(x @ p["w_dq"].astype(x.dtype), p["q_norm"]["scale"])
        q = q @ p["w_uq"].astype(x.dtype)
    else:
        q = x @ p["wq"].astype(x.dtype)
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg, rot_dim=m.qk_rope_head_dim)

    c_kv = _rms(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"]["scale"])  # (B,S,r)
    k_rope = apply_rope((x @ p["w_kr"].astype(x.dtype))[:, :, None, :],
                        positions, cfg, rot_dim=m.qk_rope_head_dim)  # (B,S,1,rr)

    new_cache = None
    if cache is not None:
        cc, cr = cache["c_kv"], cache["k_rope"]
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cr, k_rope[:, :, 0].astype(cr.dtype), (0, pos, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        c_kv_all = cc.astype(x.dtype)
        k_rope_all = cr.astype(x.dtype)[:, :, None]
        T = cc.shape[1]
        ok = jnp.arange(T) <= pos
        bias = jnp.where(ok, 0.0, jnp.finfo(jnp.float32).min)[None, None, None]
    else:
        c_kv_all, k_rope_all = c_kv, k_rope
        T = S
        qpos = jnp.arange(S)[:, None]
        ok = jnp.arange(T)[None, :] <= qpos
        bias = jnp.where(ok, 0.0, jnp.finfo(jnp.float32).min)[None, None]
        if return_cache:
            size = cache_len or S
            cc = jnp.zeros((B, size, m.kv_lora_rank), x.dtype)
            cr = jnp.zeros((B, size, m.qk_rope_head_dim), x.dtype)
            cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, 0, 0))
            cr = jax.lax.dynamic_update_slice(cr, k_rope[:, :, 0].astype(cr.dtype),
                                              (0, 0, 0))
            new_cache = {"c_kv": cc, "k_rope": cr}

    # absorb: k_nope = c_kv @ w_uk  (B,T,H,nope); v = c_kv @ w_uv
    k_nope = (c_kv_all @ p["w_uk"].astype(x.dtype)).reshape(
        B, T, H, m.qk_nope_head_dim)
    vv = (c_kv_all @ p["w_uv"].astype(x.dtype)).reshape(B, T, H, m.v_head_dim)
    if cache is None:
        # train/prefill: fold (nope ++ rope) into one effective head dim and
        # reuse the (flash-chunked) sdpa path — scores = qn.kn + qr.kr, and
        # long sequences must not materialise the (S, T) matrix densely.
        # (sdpa's 1/sqrt(hd_eff) scale == MLA's 1/sqrt(nope+rope).)
        q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_eff = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_rope_all, (B, T, H, m.qk_rope_head_dim))], axis=-1)
        v_pad = jnp.pad(vv, ((0, 0), (0, 0), (0, 0),
                             (0, q_eff.shape[-1] - m.v_head_dim)))
        out = sdpa(q_eff, k_eff, v_pad, None, causal=True)[..., :m.v_head_dim]
        out = out.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
        return (out, new_cache) if return_cache else out
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_nope = jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
    s_rope = jnp.einsum("bshd,btxd->bhst", q_rope,
                        jnp.broadcast_to(k_rope_all, (B, T, 1, m.qk_rope_head_dim)))
    scores = (s_nope + s_rope).astype(jnp.float32) * scale + bias
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, vv).reshape(B, S, -1)
    out = out @ p["wo"].astype(x.dtype)
    return (out, new_cache) if (return_cache or cache is not None) else out


# --------------------------------------------------------------------- FFN
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_down": _dense(k2, d_ff, cfg.d_model)}
    p["w_up"] = _dense(k1, cfg.d_model, d_ff)
    if cfg.glu:
        p["w_gate"] = _dense(k3, cfg.d_model, d_ff)
    return p


def _act(cfg: ModelConfig, x):
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.relu(x)


def mlp_fwd(p: dict, cfg: ModelConfig, x):
    up = x @ p["w_up"].astype(x.dtype)
    h = _act(cfg, x @ p["w_gate"].astype(x.dtype)) * up if cfg.glu else _act(cfg, up)
    return h @ p["w_down"].astype(x.dtype)


# --------------------------------------------------------------------- MoE
def init_moe(key, cfg: ModelConfig) -> dict:
    e = cfg.moe
    keys = jax.random.split(key, 5)
    de, d = e.d_expert, cfg.d_model
    p = {
        "router": _dense(keys[0], d, e.n_routed, scale=0.02),
        "w_up": jax.random.normal(keys[1], (e.n_routed, d, de)) / np.sqrt(d),
        "w_down": jax.random.normal(keys[2], (e.n_routed, de, d)) / np.sqrt(de),
    }
    if cfg.glu:
        p["w_gate"] = jax.random.normal(keys[3], (e.n_routed, d, de)) / np.sqrt(d)
    if e.n_shared:
        p["shared"] = init_mlp(keys[4], cfg, d_ff=e.n_shared * e.d_expert)
    return p


def moe_fwd(p: dict, cfg: ModelConfig, x):
    """Top-k routed experts with sort-based dispatch (MaxText-style).

    Tokens are sorted by assigned expert and packed into a per-expert
    capacity buffer (E, C, D); expert FFNs run as one batched einsum over the
    expert dimension (shardable over the ``model`` axis — expert parallelism);
    results scatter-add back to token order.  No one-hot matmuls, so compiled
    FLOPs reflect only the active experts.  Returns (out, aux_loss)."""
    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    k = e.top_k
    E = e.n_routed
    C = max(int(np.ceil(e.capacity_factor * k * T / E)), min(8, T * k))
    xt = x.reshape(T, D)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    from ..compat import top_k_compat

    topv, topi = top_k_compat(probs, k)                  # (T,k)
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)
    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], E), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    aux = e.aux_loss_coef * E * jnp.sum(density * router_prob)

    flat_e = topi.reshape(-1)                            # (T*k,)
    flat_w = topv.reshape(-1).astype(x.dtype)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(sorted_e, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - offsets[sorted_e]
    keep = (pos_in_e < C).astype(x.dtype)
    slot = sorted_e * C + jnp.minimum(pos_in_e, C - 1)
    tok_sorted = flat_tok[order]
    buf = jnp.zeros((E * C, D), x.dtype).at[slot].add(
        xt[tok_sorted] * keep[:, None])
    xe = buf.reshape(E, C, D)
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
    if cfg.glu:
        gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype))
        h = _act(cfg, gate) * up
    else:
        h = _act(cfg, up)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    contrib = out_e.reshape(E * C, D)[slot] * (flat_w[order] * keep)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[tok_sorted].add(contrib)
    if e.n_shared:
        out = out + mlp_fwd(p["shared"], cfg, xt)
    return out.reshape(B, S, D), aux

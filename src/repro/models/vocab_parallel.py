"""Megatron-style vocab-parallel embedding and cross-entropy.

The embedding table / LM head keep their vocab dim sharded over ``model``.
A plain GSPMD gather over a vocab-sharded table triggers involuntary full
rematerialisation (the partitioner replicates the table), so both the lookup
and the CE loss are written as explicit partial-manual ``shard_map`` over
the ``model`` axis:

* lookup: each rank gathers only its vocab slice (masked), then one small
  psum((B,S,D)) combines;
* CE: each rank computes logits against its vocab slice; max/sum/gold are
  combined with pmax/psum over ``model`` — the (B,S,V) logits tensor only
  ever exists vocab-sharded.

Both are differentiable (shard_map transposes psum/pmax correctly).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _inside_manual() -> bool:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.shape:
            return False
        return any("Manual" in str(t) for t in m.axis_types)
    except Exception:
        return False


def _smap(fn, mesh, in_specs, out_specs):
    from repro.compat import shard_map_compat

    return shard_map_compat(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, axis_names={"model"},
                            check=False, use_ambient_mesh=_inside_manual())


def applicable(mesh, vocab: int) -> bool:
    return (mesh is not None and "model" in mesh.shape
            and vocab % mesh.shape["model"] == 0)


def _vstarts(vocab: int, model_size: int):
    """(model_size,) array of per-rank vocab offsets; passed P("model") so
    each rank's local slice is its own offset (avoids axis_index, which
    Shardy cannot lower in nested manual contexts)."""
    vshard = vocab // model_size
    return jnp.arange(model_size, dtype=jnp.int32) * vshard


def embed_lookup(embed, tokens, mesh):
    """embed: (V, D) sharded P("model", None); tokens: (B, S) int32."""
    model_size = mesh.shape["model"] if mesh is not None else 1

    def local(emb_loc, toks, vstart):
        vshard = emb_loc.shape[0]
        loc = toks - vstart[0]
        ok = (loc >= 0) & (loc < vshard)
        x = emb_loc[jnp.clip(loc, 0, vshard - 1)]
        x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
        # psum in f32 (XLA:CPU bf16 all-reduce miscompile workaround)
        return jax.lax.psum(x.astype(jnp.float32), "model").astype(x.dtype)

    starts = _vstarts(embed.shape[0], model_size)
    return _smap(local, mesh, (P("model", None), P(), P("model")), P())(
        embed, tokens, starts)


def ce_chunk(x, head, targets, weights, mesh, *, transpose_head: bool):
    """Vocab-parallel CE over one sequence chunk.

    x: (B, c, D); head: (D, V) P(None,"model") or — tied — (V, D)
    P("model",None) with ``transpose_head=True``; targets/weights: (B, c).
    Returns (ce_sum, weight_sum) scalars (replicated).
    """
    head_spec = P("model", None) if transpose_head else P(None, "model")

    def local(xc, head_loc, tc, wc, vstart):
        w = head_loc.T if transpose_head else head_loc          # (D, V/m)
        logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)  # (B,c,V/m)
        vshard = logits.shape[-1]
        start = vstart[0]
        # the max is a constant shift for stability: stop_gradient *inside*
        # the pmax so its tangent is symbolically zero (pmax has no JVP rule)
        m = jax.lax.pmax(jax.lax.stop_gradient(jnp.max(logits, axis=-1)),
                         "model")
        z = jax.lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                         "model")
        logz = m + jnp.log(z)
        loc = tc - start
        ok = (loc >= 0) & (loc < vshard)
        picked = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, vshard - 1)[..., None], axis=-1)[..., 0]
        gold = jax.lax.psum(jnp.where(ok, picked, 0.0), "model")
        ce = jnp.sum((logz - gold) * wc)
        return ce, jnp.sum(wc)

    model_size = mesh.shape["model"] if mesh is not None else 1
    vocab = head.shape[0] if transpose_head else head.shape[-1]
    starts = _vstarts(vocab, model_size)
    return _smap(local, mesh, (P(), head_spec, P(), P(), P("model")),
                 (P(), P()))(x, head, targets, weights, starts)

"""Model configuration covering all assigned architecture families.

One ``ModelConfig`` drives the unified model in :mod:`repro.models.model`:
dense/GQA decoders, MLA + MoE (DeepSeek-V2), RG-LRU hybrid (RecurrentGemma),
RWKV-6, encoder-decoder (Seamless-M4T backbone), and VLM prefix decoders
(PaliGemma backbone).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None   # None: full-rank queries (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 64
    n_shared: int = 2
    top_k: int = 6
    d_expert: int = 1408          # per-expert FFN hidden size
    first_dense_layers: int = 1   # DeepSeek-V2: layer 0 is dense
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 12
    enc_seq: int = 1024           # frame-embedding sequence length (stub)
    frontend_dim: int = 1024      # dim of precomputed frame embeddings


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    lru_width: int = 4096
    conv_width: int = 4
    # Griffin/RecurrentGemma block pattern: (recurrent, recurrent, local_attn)
    pattern: tuple = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    norm: str = "rms"             # rms | layer
    act: str = "silu"             # silu | gelu | relu
    glu: bool = True              # gated FFN (SwiGLU/GeGLU)
    qkv_bias: bool = False
    rope_frac: float = 1.0        # fraction of head_dim rotated (StableLM: 0.25)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    window: Optional[int] = None  # sliding-window size for "attn" blocks
    block: str = "attn"           # attn | mla | rwkv (or hybrid via recurrent)
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    encdec: Optional[EncDecConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    vlm_prefix_len: int = 0       # image-token prefix length (stub embeddings)
    dtype: str = "bfloat16"
    kv_cache_dtype: str = ""      # "" = activations dtype; "int8" = quantized
    source: str = ""              # citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def block_kind(self, layer: int) -> str:
        """Block category for a given layer index: attn | rec | rwkv.
        (MLA is an ``attn`` block variant, selected via ``cfg.block``.)"""
        if self.recurrent is not None:
            return {"rec": "rec", "attn": "attn"}[
                self.recurrent.pattern[layer % len(self.recurrent.pattern)]
            ]
        return "attn" if self.block == "mla" else self.block

    def is_moe_layer(self, layer: int) -> bool:
        return self.moe is not None and layer >= self.moe.first_dense_layers

    # ------------------------------------------------------------ accounting
    def param_count(self) -> float:
        """Approximate parameter count (for roofline 6·N·D)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind == "attn":
                if self.block == "mla" and self.mla:
                    m = self.mla
                    qdim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    total += d * (m.q_lora_rank or 0) or 0
                    total += (m.q_lora_rank or d) * qdim
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.n_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.hd * (self.n_heads + 2 * self.n_kv_heads)
                    total += self.n_heads * self.hd * d
            elif kind == "rec":
                L = self.recurrent.lru_width
                total += 2 * d * L + L * d + self.recurrent.conv_width * L + 3 * L
            elif kind == "rwkv":
                total += 6 * d * d + d * 64 * 2  # r,k,v,g,o + decay lora
            if self.is_moe_layer(i):
                e = self.moe
                nff = 3 if self.glu else 2
                total += e.n_routed * nff * d * e.d_expert
                total += e.n_shared * nff * d * e.d_expert
                total += d * e.n_routed
            elif kind != "rwkv":
                total += (3 if self.glu else 2) * d * self.d_ff
            else:
                total += 2 * d * self.d_ff + d * d  # rwkv channel-mix
        if self.encdec is not None:
            for _ in range(self.encdec.n_enc_layers):
                total += 4 * d * self.hd * self.n_heads
                total += (3 if self.glu else 2) * d * self.d_ff
            # decoder cross-attention
            total += self.n_layers * 4 * d * self.hd * self.n_heads
        return float(total)

    def active_param_count(self) -> float:
        """Active params per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        nff = 3 if self.glu else 2
        n_moe_layers = self.n_layers - e.first_dense_layers
        inactive = (e.n_routed - e.top_k) * nff * self.d_model * e.d_expert
        return self.param_count() - n_moe_layers * inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        kw: dict = dict(
            n_layers=2 if self.recurrent is None else 3,
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=512,
            vocab=512,
            head_dim=64,
            window=min(self.window, 64) if self.window else None,
            dtype="float32",
        )
        if self.mla is not None:
            kw["mla"] = MLAConfig(kv_lora_rank=64,
                                  q_lora_rank=64 if self.mla.q_lora_rank else None,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_routed=4, n_shared=1, top_k=2, d_expert=128,
                capacity_factor=8.0)  # generous: no token drops at toy scale
        if self.encdec is not None:
            kw["encdec"] = EncDecConfig(n_enc_layers=2, enc_seq=32,
                                        frontend_dim=256)
        if self.recurrent is not None:
            kw["recurrent"] = dataclasses.replace(self.recurrent, lru_width=256)
        if self.vlm_prefix_len:
            kw["vlm_prefix_len"] = 8
        return dataclasses.replace(self, name=self.name + "-reduced", **kw)

"""Scanned-layer (stacked-parameter) model path.

Production frameworks stack homogeneous layer parameters along a leading
``layer`` dim and apply them with ``lax.scan`` — compile time and HLO size
stay O(1) in depth (essential for the 60-62-layer assigned configs).

Layers are partitioned into homogeneous *groups* (same pytree structure):

* dense/GQA/MLA archs .... one group of n_layers
* DeepSeek MoE ........... [dense layer 0] + [MoE layers 1..n-1]
* RWKV-6 ................. one group
* RecurrentGemma ......... cycles of (rec, rec, attn) + a trailing remainder
* Seamless enc-dec ....... encoder group + decoder group

``params["groups"]`` is a list of stacked layer pytrees; caches are stacked
the same way so decode also scans.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from ..compat import scan_compat
from . import layers as L
from . import model as M


# ------------------------------------------------------------------- groups
def layer_groups(cfg: ModelConfig) -> list[dict]:
    """Segments of homogeneous layers: [{"kind", "count", "start", "cycle"}]."""
    if cfg.recurrent is not None:
        cyc = len(cfg.recurrent.pattern)
        n_cycles = cfg.n_layers // cyc
        groups = []
        if n_cycles:
            groups.append({"kind": "cycle", "count": n_cycles, "start": 0,
                           "cycle": cyc})
        rem = cfg.n_layers - n_cycles * cyc
        if rem:
            groups.append({"kind": "tail", "count": 1, "start": n_cycles * cyc,
                           "cycle": rem})
        return groups
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        return [
            {"kind": "plain", "count": fd, "start": 0, "cycle": 1},
            {"kind": "plain", "count": cfg.n_layers - fd, "start": fd,
             "cycle": 1},
        ]
    return [{"kind": "plain", "count": cfg.n_layers, "start": 0, "cycle": 1}]


def _stack_init(init_one, count: int, keys):
    """Initialise ``count`` layers and stack leaves along axis 0."""
    if count == 1:
        return jax.tree.map(lambda a: a[None], init_one(keys[0]))
    trees = [init_one(k) for k in keys]
    return jax.tree.map(lambda *a: jnp.stack(a), *trees)


def init_params(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 4)
    cast = lambda t: jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, t)
    params: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": L.init_norm(cfg, cfg.d_model),
        "groups": [],
    }
    lk = jax.random.split(keys[1], cfg.n_layers)
    for g in layer_groups(cfg):
        cyc = g["cycle"]
        if g["kind"] in ("cycle", "tail"):
            def init_cycle(k, start=g["start"], cyc=cyc):
                ks = jax.random.split(k, cyc)
                return {f"b{j}": cast(M.init_layer(ks[j], cfg, start + j))
                        for j in range(cyc)}
            gkeys = lk[g["start"]:g["start"] + g["count"]]
            params["groups"].append(_stack_init(init_cycle, g["count"], gkeys))
        else:
            def init_plain(k, li=g["start"]):
                return cast(M.init_layer(k, cfg, li))
            gkeys = lk[g["start"]:g["start"] + g["count"]]
            params["groups"].append(_stack_init(init_plain, g["count"], gkeys))
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab)) * 0.02).astype(dt)
    if cfg.encdec is not None:
        ek = jax.random.split(keys[-2], cfg.encdec.n_enc_layers + 1)
        params["encoder"] = {
            "in_proj": (jax.random.normal(ek[0], (cfg.encdec.frontend_dim,
                                                  cfg.d_model))
                        / np.sqrt(cfg.encdec.frontend_dim)).astype(dt),
            "layers": _stack_init(lambda k: cast(M.init_enc_layer(k, cfg)),
                                  cfg.encdec.n_enc_layers,
                                  jax.random.split(ek[0],
                                                   cfg.encdec.n_enc_layers)),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
    if cfg.vlm_prefix_len:
        params["vision_proj"] = jnp.eye(cfg.d_model, dtype=dt)
    return params


# ------------------------------------------------------------------ forward
def _group_scan(x, gparams, cfg, g, positions, memory, use_kernels, remat,
                caches=None, pos=None, return_cache=False, cache_len=0):
    """Scan one group.  Returns (x, aux, new_caches or None)."""
    kind = g["kind"]
    want_cache = return_cache or caches is not None

    def body(carry, inp):
        x, aux = carry
        p, cache = inp
        if kind in ("cycle", "tail"):
            ncs = {}
            for j in range(g["cycle"]):
                li = g["start"] + j
                c_j = cache[f"b{j}"] if cache is not None else None
                x, a, nc = M._layer_fwd(
                    p[f"b{j}"], cfg, li, x, positions, memory=memory,
                    cache=c_j, pos=pos, return_cache=return_cache,
                    cache_len=cache_len, use_kernels=use_kernels)
                aux = aux + a
                ncs[f"b{j}"] = nc
            return (x, aux), (ncs if want_cache else 0)
        li = g["start"]
        x, a, nc = M._layer_fwd(p, cfg, li, x, positions, memory=memory,
                                cache=cache, pos=pos,
                                return_cache=return_cache,
                                cache_len=cache_len, use_kernels=use_kernels)
        return (x, aux + a), (nc if want_cache else 0)

    if remat:
        body = jax.checkpoint(body)
    if caches is None:
        # scan needs a pytree of xs with leading dim = count
        (x, aux), ys = scan_compat(
            lambda c, p: body(c, (p, None)),
            (x, jnp.zeros((), jnp.float32)), gparams)
    else:
        (x, aux), ys = scan_compat(body, (x, jnp.zeros((), jnp.float32)),
                                   (gparams, caches))
    return x, aux, (ys if want_cache else None)


def forward(params, cfg: ModelConfig, tokens, *, prefix_emb=None,
            enc_frames=None, use_kernels: bool = False, remat: bool = False):
    x = M._embed(params, cfg, tokens)
    offset = 0
    if cfg.vlm_prefix_len and prefix_emb is not None:
        pre = prefix_emb.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([pre, x], axis=1)
        offset = prefix_emb.shape[1]
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.rope_frac == 0.0 and cfg.block != "rwkv" and cfg.recurrent is None:
        x = x + M._sinusoid(S, cfg.d_model, x.dtype)[None]
    memory = encode(params, cfg, enc_frames) if enc_frames is not None else None
    total_aux = jnp.zeros((), jnp.float32)
    for g, gp in zip(layer_groups(cfg), params["groups"]):
        x, aux, _ = _group_scan(x, gp, cfg, g, positions, memory,
                                use_kernels, remat)
        total_aux = total_aux + aux
    x = L.norm_fwd(params["final_norm"], cfg, x)
    logits = M._unembed(params, cfg, x)
    if offset:
        logits = logits[:, offset:]
    return logits, total_aux


def encode(params, cfg: ModelConfig, frames):
    enc = params["encoder"]
    x = frames.astype(jnp.dtype(cfg.dtype)) @ enc["in_proj"]
    x = x + M._sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]

    def body(x, lp):
        h = L.norm_fwd(lp["ln1"], cfg, x)
        B, T, D = h.shape
        hd = cfg.hd
        q = (h @ lp["attn"]["wq"].astype(h.dtype)).reshape(B, T, cfg.n_heads, hd)
        k = (h @ lp["attn"]["wk"].astype(h.dtype)).reshape(
            B, T, cfg.n_kv_heads, hd)
        v = (h @ lp["attn"]["wv"].astype(h.dtype)).reshape(
            B, T, cfg.n_kv_heads, hd)
        a = L.sdpa(q, k, v, None, causal=False).reshape(B, T, -1)
        x = x + a @ lp["attn"]["wo"].astype(h.dtype)
        h2 = L.norm_fwd(lp["ln2"], cfg, x)
        return x + L.mlp_fwd(lp["mlp"], cfg, h2), 0

    x, _ = scan_compat(body, x, enc["layers"])
    return L.norm_fwd(enc["final_norm"], cfg, x)


_CE_CHUNK = 512


def _embed_maybe_vp(params, cfg: ModelConfig, tokens, vp_mesh):
    from . import vocab_parallel as VP

    if vp_mesh is not None and VP.applicable(vp_mesh, cfg.vocab):
        x = VP.embed_lookup(params["embed"], tokens, vp_mesh)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        return x
    return M._embed(params, cfg, tokens)


def hidden_forward(params, cfg: ModelConfig, tokens, *, prefix_emb=None,
                   enc_frames=None, use_kernels=False, remat=False,
                   vp_mesh=None):
    """forward() up to (but excluding) the unembed; returns (hidden, aux,
    prefix_offset)."""
    x = _embed_maybe_vp(params, cfg, tokens, vp_mesh)
    offset = 0
    if cfg.vlm_prefix_len and prefix_emb is not None:
        pre = prefix_emb.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([pre, x], axis=1)
        offset = prefix_emb.shape[1]
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.rope_frac == 0.0 and cfg.block != "rwkv" and cfg.recurrent is None:
        x = x + M._sinusoid(S, cfg.d_model, x.dtype)[None]
    memory = encode(params, cfg, enc_frames) if enc_frames is not None else None
    total_aux = jnp.zeros((), jnp.float32)
    for g, gp in zip(layer_groups(cfg), params["groups"]):
        x, aux, _ = _group_scan(x, gp, cfg, g, positions, memory,
                                use_kernels, remat)
        total_aux = total_aux + aux
    x = L.norm_fwd(params["final_norm"], cfg, x)
    if offset:
        x = x[:, offset:]
    return x, total_aux, offset


def loss_fn(params, cfg: ModelConfig, batch, *, use_kernels: bool = False,
            remat: bool = False, vp_mesh=None, vp_ce: bool = True):
    """Next-token CE computed in sequence chunks — the full (B, S, V) logits
    tensor is never materialised.  With ``vp_mesh`` set the chunks run
    vocab-parallel (Megatron-style) over the ``model`` axis."""
    from . import vocab_parallel as VP

    tokens = batch["tokens"]
    x, aux, _ = hidden_forward(params, cfg, tokens,
                               prefix_emb=batch.get("prefix_emb"),
                               enc_frames=batch.get("enc_frames"),
                               use_kernels=use_kernels, remat=remat,
                               vp_mesh=vp_mesh)
    B, S, D = x.shape
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    weights = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)],
        axis=1)
    chunk = min(_CE_CHUNK, S)
    while S % chunk:
        chunk -= 1
    use_vp = vp_ce and vp_mesh is not None and VP.applicable(vp_mesh, cfg.vocab)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]

    def ce_chunk(carry, inp):
        xc, tc, wc = inp        # (B, c, D), (B, c), (B, c)
        if use_vp:
            # f32 in: the shard_map transpose inserts a psum over `model`
            # for the replicated xc cotangent — it must not be bf16
            # (XLA:CPU AllReducePromotion miscompiles 16-bit all-reduce).
            ce, cnt = VP.ce_chunk(xc.astype(jnp.float32), head, tc, wc,
                                  vp_mesh,
                                  transpose_head=cfg.tie_embeddings)
        else:
            logits = M._unembed(params, cfg, xc).astype(jnp.float32)
            m = jnp.max(logits, axis=-1)
            logz = m + jnp.log(
                jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
            gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
            ce = jnp.sum((logz - gold) * wc)
            cnt = jnp.sum(wc)
        return (carry[0] + ce, carry[1] + cnt), None

    nc = S // chunk
    xs = (x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3),
          targets.reshape(B, nc, chunk).transpose(1, 0, 2),
          weights.reshape(B, nc, chunk).transpose(1, 0, 2))
    body = jax.checkpoint(ce_chunk) if remat else ce_chunk
    (ce_sum, cnt), _ = scan_compat(body, (jnp.zeros(()), jnp.zeros(())), xs)
    return ce_sum / jnp.maximum(cnt, 1.0) + aux


# ------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    flat = M.init_cache(cfg, batch, cache_len, dtype=dt)
    out = []
    for g in layer_groups(cfg):
        if g["kind"] in ("cycle", "tail"):
            per_cycle = []
            for c in range(g["count"]):
                start = g["start"] + c * g["cycle"]
                per_cycle.append({f"b{j}": flat[start + j]
                                  for j in range(g["cycle"])})
            out.append(jax.tree.map(lambda *a: jnp.stack(a), *per_cycle)
                       if g["count"] > 1 else
                       jax.tree.map(lambda a: a[None], per_cycle[0]))
        else:
            seg = flat[g["start"]:g["start"] + g["count"]]
            out.append(jax.tree.map(lambda *a: jnp.stack(a), *seg)
                       if len(seg) > 1 else
                       jax.tree.map(lambda a: a[None], seg[0]))
    return out


def decode_step(params, cfg: ModelConfig, caches, token, pos, *, memory=None,
                vp_mesh=None):
    x = _embed_maybe_vp(params, cfg, token[:, None], vp_mesh)
    if cfg.rope_frac == 0.0 and cfg.block != "rwkv" and cfg.recurrent is None:
        D = cfg.d_model
        dim = jnp.arange(0, D, 2) / D
        ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim)
        pe = jnp.zeros((D,), x.dtype)
        pe = pe.at[0::2].set(jnp.sin(ang).astype(x.dtype))
        pe = pe.at[1::2].set(jnp.cos(ang).astype(x.dtype))
        x = x + pe[None, None]
    positions = pos[None]
    new_caches = []
    for g, gp, gc in zip(layer_groups(cfg), params["groups"], caches):
        x, _, nc = _group_scan(x, gp, cfg, g, positions, memory, False, False,
                               caches=gc, pos=pos)
        new_caches.append(nc)
    x = L.norm_fwd(params["final_norm"], cfg, x)
    return M._unembed(params, cfg, x)[:, 0], new_caches


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
            prefix_emb=None, enc_frames=None, use_kernels: bool = False,
            vp_mesh=None):
    x = _embed_maybe_vp(params, cfg, tokens, vp_mesh)
    if cfg.vlm_prefix_len and prefix_emb is not None:
        pre = prefix_emb.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.rope_frac == 0.0 and cfg.block != "rwkv" and cfg.recurrent is None:
        x = x + M._sinusoid(S, cfg.d_model, x.dtype)[None]
    memory = encode(params, cfg, enc_frames) if enc_frames is not None else None
    caches = []
    for g, gp in zip(layer_groups(cfg), params["groups"]):
        x, _, nc = _group_scan(x, gp, cfg, g, positions, memory, use_kernels,
                               False, return_cache=True, cache_len=cache_len)
        caches.append(nc)
    x = L.norm_fwd(params["final_norm"], cfg, x)
    return M._unembed(params, cfg, x[:, -1:])[:, 0], caches

"""Recurrent blocks: RG-LRU (Griffin / RecurrentGemma) and RWKV-6 (Finch).

Both expose train-mode (full sequence, associative-scan / chunked recurrence)
and decode-mode (O(1) state update) forwards.  The Pallas kernels in
:mod:`repro.kernels` are the TPU fast paths; these jnp implementations are
the reference/XLA paths used for smoke tests and dry-run lowering.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _act, _dense, init_mlp, mlp_fwd


# =============================================================== RG-LRU block
def init_recurrent_block(key, cfg: ModelConfig) -> dict:
    """Griffin recurrent block: in-proj (+gate branch), temporal conv,
    RG-LRU, out-proj."""
    r = cfg.recurrent
    L = r.lru_width
    keys = jax.random.split(key, 6)
    return {
        "w_x": _dense(keys[0], cfg.d_model, L),
        "w_gate": _dense(keys[1], cfg.d_model, L),
        "conv_w": jax.random.normal(keys[2], (r.conv_width, L)) * 0.02,
        "conv_b": jnp.zeros((L,)),
        # RG-LRU gates: input gate i_t and recurrence gate r_t
        "w_ri": _dense(keys[3], L, L),
        "w_ii": _dense(keys[4], L, L),
        # log-lambda parametrisation: a = sigmoid(lam)^(c * r_t), c = 8
        "lam": jnp.asarray(
            np.log(np.expm1(np.linspace(0.9, 0.999, L) ** -0.125 - 0 + 1e-9)) * 0
            + np.linspace(2.0, 6.0, L), jnp.float32),
        "w_out": _dense(keys[5], L, cfg.d_model),
    }


_LRU_C = 8.0


def _rg_lru_scan(x, r_gate, i_gate, lam):
    """x, gates: (B, S, L); returns h: (B, S, L) via associative scan.

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(lam) * r_t)   (RG-LRU, arXiv:2402.19427)
    """
    log_a = -_LRU_C * jax.nn.softplus(lam)[None, None, :] * r_gate
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i_gate * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def recurrent_block_fwd(p: dict, cfg: ModelConfig, x, *,
                        state: Optional[dict] = None,
                        return_state: bool = False,
                        use_kernel: bool = False):
    """x: (B, S, D).  ``state`` (decode): {"h": (B,L), "conv": (B,W-1,L)}."""
    r = cfg.recurrent
    B, S, D = x.shape
    W = r.conv_width
    gate = _act(cfg, x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_x"].astype(x.dtype)                       # (B,S,L)

    if state is not None:
        hist = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
        conv = jnp.einsum("bwl,wl->bl", hist[:, -W:], p["conv_w"].astype(u.dtype))
        conv = (conv + p["conv_b"].astype(u.dtype))[:, None]
        new_conv = hist[:, -(W - 1):]
    else:
        pad = jnp.zeros((B, W - 1, u.shape[-1]), u.dtype)
        hist = jnp.concatenate([pad, u], axis=1)
        frames = jnp.stack([hist[:, i:i + S] for i in range(W)], axis=2)  # B,S,W,L
        conv = jnp.einsum("bswl,wl->bsl", frames, p["conv_w"].astype(u.dtype))
        conv = conv + p["conv_b"].astype(u.dtype)
        new_conv = hist[:, -(W - 1):]

    r_gate = jax.nn.sigmoid(conv @ p["w_ri"].astype(u.dtype))
    i_gate = jax.nn.sigmoid(conv @ p["w_ii"].astype(u.dtype))
    if state is not None:
        log_a = -_LRU_C * jax.nn.softplus(p["lam"])[None, None] * r_gate
        a = jnp.exp(log_a)
        gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) * (
            i_gate * conv)
        h = a * state["h"].astype(u.dtype)[:, None] + gated   # (B,1,L)
        new_state = {"h": h[:, 0], "conv": new_conv}
    elif use_kernel:
        from ..kernels import ops as kops
        h = kops.rglru_scan(conv, r_gate, i_gate, p["lam"])
        new_state = {"h": h[:, -1], "conv": new_conv}
    else:
        h = _rg_lru_scan(conv, r_gate, i_gate, p["lam"])
        new_state = {"h": h[:, -1], "conv": new_conv}
    out = (h * gate) @ p["w_out"].astype(x.dtype)
    if return_state or state is not None:
        return out, new_state
    return out


# ================================================================ RWKV-6 block
def init_rwkv_block(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    keys = jax.random.split(key, 12)
    lora = 64
    return {
        # time-mix
        "mu_r": jnp.full((D,), 0.5), "mu_k": jnp.full((D,), 0.5),
        "mu_v": jnp.full((D,), 0.5), "mu_w": jnp.full((D,), 0.5),
        "mu_g": jnp.full((D,), 0.5),
        "w_r": _dense(keys[0], D, H * hd),
        "w_k": _dense(keys[1], D, H * hd),
        "w_v": _dense(keys[2], D, H * hd),
        "w_g": _dense(keys[3], D, H * hd),
        "w_o": _dense(keys[4], H * hd, D),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((H * hd,), -2.0),
        "wA": _dense(keys[5], D, lora, scale=0.01),
        "wB": _dense(keys[6], lora, H * hd, scale=0.01),
        "bonus": jax.random.normal(keys[7], (H, hd)) * 0.1,   # per-head u
        "ln_x": {"scale": jnp.ones((H * hd,)), "bias": jnp.zeros((H * hd,))},
        # channel-mix
        "cmu_k": jnp.full((D,), 0.5), "cmu_r": jnp.full((D,), 0.5),
        "c_k": _dense(keys[8], D, cfg.d_ff),
        "c_v": _dense(keys[9], cfg.d_ff, D),
        "c_r": _dense(keys[10], D, D),
    }


def _token_shift(x, mu, prev=None):
    """lerp between current token and previous token (RWKV token shift)."""
    if prev is None:
        shifted = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    else:
        shifted = jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]],
                                  axis=1)
    return x + (shifted - x) * mu.astype(x.dtype)


def _wkv6_scan(r, k, v, w, u):
    """Sequential WKV-6 recurrence (reference path).

    r,k,v,w: (B,S,H,hd); u: (H,hd).  State S_h: (B,H,hd,hd).
      out_t = (S + u^T . (k_t v_t^T)) r_t ;  S <- diag(w_t) S + k_t v_t^T
    """
    B, S, H, hd = r.shape

    def step(state, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None] [..., None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    final, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), final  # (B,S,H,hd)


def rwkv_time_mix(p: dict, cfg: ModelConfig, x, *, state: Optional[dict] = None,
                  use_kernel: bool = False):
    """RWKV-6 time mix.  state: {"wkv": (B,H,hd,hd), "prev": (B,D)}."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    prev = state["prev"] if state is not None else None
    xr = _token_shift(x, p["mu_r"], prev)
    xk = _token_shift(x, p["mu_k"], prev)
    xv = _token_shift(x, p["mu_v"], prev)
    xw = _token_shift(x, p["mu_w"], prev)
    xg = _token_shift(x, p["mu_g"], prev)
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["w_g"].astype(x.dtype))
    dd = jnp.tanh(xw @ p["wA"].astype(x.dtype)) @ p["wB"].astype(x.dtype)
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + dd.astype(jnp.float32))
                         )).reshape(B, S, H, hd)  # decay in (0,1)
    u = p["bonus"].astype(jnp.float32)

    if state is not None:
        rt, kt, vt, wt = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        s_prev = state["wkv"].astype(jnp.float32)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s_prev + u[None][..., None] * kv)
        new_wkv = wt[..., None] * s_prev + kv
        out = out[:, None].astype(x.dtype)
        new_state = {"wkv": new_wkv, "prev": x[:, -1]}
    else:
        if use_kernel:
            from ..kernels import ops as kops
            out = kops.rwkv6_wkv(r, k, v, w, u)
            final = None  # kernel path is for training; prefill uses scan path
        else:
            out, final = _wkv6_scan(r, k, v, w, u)
        new_state = {"wkv": final, "prev": x[:, -1]}
    out = out.reshape(B, -1, H * hd)
    # group norm over heads (ln_x)
    of = out.astype(jnp.float32).reshape(B, -1, H, hd)
    mu = jnp.mean(of, axis=-1, keepdims=True)
    var = jnp.var(of, axis=-1, keepdims=True)
    of = ((of - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, -1, H * hd)
    out = (of * p["ln_x"]["scale"] + p["ln_x"]["bias"]).astype(x.dtype)
    out = (out * g) @ p["w_o"].astype(x.dtype)
    return out, new_state


def rwkv_channel_mix(p: dict, cfg: ModelConfig, x, *,
                     state: Optional[dict] = None):
    """RWKV channel mix.  state: {"prev": (B,D)}."""
    prev = state["prev"] if state is not None else None
    xk = _token_shift(x, p["cmu_k"], prev)
    xr = _token_shift(x, p["cmu_r"], prev)
    k = jnp.square(jax.nn.relu(xk @ p["c_k"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ p["c_r"].astype(x.dtype))
    out = r * (k @ p["c_v"].astype(x.dtype))
    return out, {"prev": x[:, -1]}

from .graph import DOT, EW, FusionGraph, LAYOUT, OPAQUE, PrimOp, REDUCE
from .hw import Hardware, TPU_V5E, allreduce_time, ring_allreduce_coeffs
from .costs import (OracleEstimator, group_time_oracle, prim_time,
                    profile_graph, total_comm_time, total_compute_time)
from .simulator import SimResult, Simulator
from .search import (ALL_METHODS, METHOD_DUP, METHOD_NONDUP, METHOD_TENSOR,
                     SearchResult, backtracking_search, random_apply)
from .baselines import BASELINES, evaluate_baselines
from .trace import graph_from_jaxpr, trace_grad_graph

__all__ = [
    "DOT", "EW", "FusionGraph", "LAYOUT", "OPAQUE", "PrimOp", "REDUCE",
    "Hardware", "TPU_V5E", "allreduce_time", "ring_allreduce_coeffs",
    "OracleEstimator", "group_time_oracle", "prim_time", "profile_graph",
    "total_comm_time", "total_compute_time",
    "SimResult", "Simulator",
    "ALL_METHODS", "METHOD_DUP", "METHOD_NONDUP", "METHOD_TENSOR",
    "SearchResult", "backtracking_search", "random_apply",
    "BASELINES", "evaluate_baselines",
    "graph_from_jaxpr", "trace_grad_graph",
]

from .graph import DOT, EW, FusionGraph, LAYOUT, OPAQUE, PrimOp, REDUCE
from .hw import Hardware, TPU_V5E, allreduce_time, ring_allreduce_coeffs
from .costs import (OracleEstimator, group_time_oracle, prim_time,
                    profile_graph, total_comm_time, total_compute_time)
from .simulator import SimResult, Simulator
from .events import (BackgroundTraffic, CommEngine, CommJob, ComputeJob,
                     DISC_FAIR, DISC_FIFO, EventEngine, TC_COMPUTE, TC_DP,
                     TC_PP, TC_TP, TRAFFIC_CLASSES, UnifiedResult)
from .pipeline import (PipelineSchedule, SCHED_1F1B, SCHED_INTERLEAVED,
                       SCHEDULES, resolve_schedule)
from .tp_traffic import (TPTraffic, balanced_spans, couple_tp,
                         couple_tp_pipeline)
from .mutations import (ALL_METHODS, CHUNK_CHOICES, METHOD_ALGO,
                        METHOD_CHUNK, METHOD_COMM, METHOD_DUP,
                        METHOD_NONDUP, METHOD_PP_INTERLEAVE,
                        METHOD_PP_MICROBATCH, METHOD_PP_SPLIT,
                        METHOD_TENSOR, MUTATIONS, Mutation,
                        active_methods, random_apply, register_mutation)
from .search import SearchResult, backtracking_search
from .baselines import (BASELINES, assign_bucket_algos,
                        assign_bucket_chunks, assign_bucket_comm,
                        evaluate_baselines)

__all__ = [
    "DOT", "EW", "FusionGraph", "LAYOUT", "OPAQUE", "PrimOp", "REDUCE",
    "Hardware", "TPU_V5E", "allreduce_time", "ring_allreduce_coeffs",
    "OracleEstimator", "group_time_oracle", "prim_time", "profile_graph",
    "total_comm_time", "total_compute_time",
    "SimResult", "Simulator", "BackgroundTraffic", "CommEngine", "CommJob",
    "ComputeJob", "EventEngine", "UnifiedResult",
    "DISC_FAIR", "DISC_FIFO", "TC_COMPUTE", "TC_DP", "TC_PP", "TC_TP",
    "TRAFFIC_CLASSES",
    "PipelineSchedule", "SCHED_1F1B", "SCHED_INTERLEAVED", "SCHEDULES",
    "resolve_schedule",
    "TPTraffic", "balanced_spans", "couple_tp", "couple_tp_pipeline",
    "ALL_METHODS", "CHUNK_CHOICES", "METHOD_ALGO", "METHOD_CHUNK",
    "METHOD_COMM", "METHOD_DUP", "METHOD_NONDUP", "METHOD_PP_INTERLEAVE",
    "METHOD_PP_MICROBATCH", "METHOD_PP_SPLIT", "METHOD_TENSOR",
    "MUTATIONS", "Mutation", "active_methods", "register_mutation",
    "SearchResult", "backtracking_search", "random_apply",
    "BASELINES", "assign_bucket_algos", "assign_bucket_chunks",
    "assign_bucket_comm", "evaluate_baselines",
    "graph_from_jaxpr", "trace_grad_graph",
]

_TRACE_EXPORTS = ("graph_from_jaxpr", "trace_grad_graph")


def __getattr__(name):
    # .trace is the one submodule that imports jax; loading it lazily keeps
    # `import repro.core.<x>` jax-free for the search worker-pool processes
    # (spawned with a bare interpreter) and for pure-IR consumers.
    if name in _TRACE_EXPORTS:
        from . import trace

        return getattr(trace, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

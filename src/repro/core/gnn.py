"""GNN-based Fused-Op Estimator (paper Sec. 4.3), in pure JAX.

Encoder: multi-head graph attention layers (eq. (1)) over the fused op's
internal subgraph — node features are (op-category one-hot, log FLOPs,
log in/out bytes, log standalone time) plus, on gradient-producing nodes,
the comm dimensions of the bucket the gradient lands in (collective
algorithm, comm kind, chunk count — the searched communication state).  A sum-pool layer produces
the fused-op embedding (eq. (2)), followed by an FC regression head.  Loss
is squared error in log-time (eq. (3)); training uses our AdamW
(:mod:`repro.optim`).

Samples are padded to ``max_nodes`` so training batches are jit-static.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..cluster import BUCKET_COMM_KINDS, COLLECTIVE_ALGOS
from ..optim import adamw, apply_updates
from .graph import DOT, EW, FusionGraph, LAYOUT, OPAQUE, REDUCE

CATEGORIES = (EW, REDUCE, DOT, LAYOUT, OPAQUE)
# per-node features: op-category one-hot, log flops/in_b/out_b/time, and —
# for gradient-producing prims — the comm dimensions of the bucket the
# gradient lands in (collective algorithm, comm kind, chunk count), so the
# estimator is not blind to the searched communication state
N_COMM_FEATURES = 3
N_FEATURES = len(CATEGORIES) + 4 + N_COMM_FEATURES


def _param_bucket_index(g: FusionGraph) -> dict[int, int]:
    """grad-param leaf index -> bucket index (buckets partition the params)."""
    out: dict[int, int] = {}
    for bi, bucket in enumerate(g.buckets):
        for param in bucket:
            out[param] = bi
    return out


# ------------------------------------------------------------------ features
def group_features(g: FusionGraph, gid: int, max_nodes: int,
                   param_bucket: dict[int, int] | None = None):
    """(feat [N,F], adj [N,N], mask [N]) for the members of one fused
    group.  ``param_bucket`` (grad-param -> bucket index) may be passed by
    callers that already hold the map (the estimator caches it); otherwise
    it is built lazily on the first gradient-producing member."""
    members = sorted(g.groups[gid])
    n = min(len(members), max_nodes)
    members = members[:n]
    index = {pid: i for i, pid in enumerate(members)}
    feat = np.zeros((max_nodes, N_FEATURES), np.float32)
    adj = np.zeros((max_nodes, max_nodes), np.float32)
    mask = np.zeros((max_nodes,), np.float32)
    base = len(CATEGORIES)
    for i, pid in enumerate(members):
        p = g.prims[pid]
        feat[i, CATEGORIES.index(p.category)] = 1.0
        feat[i, base + 0] = np.log1p(p.flops) / 30.0
        feat[i, base + 1] = np.log1p(p.in_bytes) / 30.0
        feat[i, base + 2] = np.log1p(p.out_bytes) / 30.0
        feat[i, base + 3] = np.log1p(p.time * 1e9) / 30.0
        if p.grad_param >= 0:
            if param_bucket is None:
                param_bucket = _param_bucket_index(g)
            bi = param_bucket.get(p.grad_param)
            if bi is not None:
                feat[i, base + 4] = (
                    (COLLECTIVE_ALGOS.index(g.bucket_algos[bi]) + 1.0)
                    / len(COLLECTIVE_ALGOS))
                feat[i, base + 5] = (
                    (BUCKET_COMM_KINDS.index(g.bucket_comm[bi]) + 1.0)
                    / len(BUCKET_COMM_KINDS))
                feat[i, base + 6] = np.log2(g.bucket_chunks[bi]) / 4.0
        mask[i] = 1.0
        adj[i, i] = 1.0
        for q in g.ppreds[pid]:
            j = index.get(q)
            if j is not None:
                adj[j, i] = 1.0
                adj[i, j] = 1.0  # undirected message passing + self loops
    return feat, adj, mask


# -------------------------------------------------------------------- model
@dataclasses.dataclass(frozen=True)
class GNNConfig:
    n_layers: int = 3          # paper uses 6 graph-conv layers
    n_heads: int = 4
    head_dim: int = 16
    mlp_dim: int = 64
    n_mlp: int = 3             # paper: 3 dense layers
    max_nodes: int = 48


def init_params(cfg: GNNConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers * 3 + cfg.n_mlp + 2)
    ki = iter(keys)
    params: dict = {"layers": [], "mlp": []}
    f_in = N_FEATURES
    for _ in range(cfg.n_layers):
        w = jax.random.normal(next(ki), (cfg.n_heads, f_in, cfg.head_dim)) * (
            1.0 / np.sqrt(f_in)
        )
        a_src = jax.random.normal(next(ki), (cfg.n_heads, cfg.head_dim)) * 0.1
        a_dst = jax.random.normal(next(ki), (cfg.n_heads, cfg.head_dim)) * 0.1
        params["layers"].append({"w": w, "a_src": a_src, "a_dst": a_dst})
        f_in = cfg.n_heads * cfg.head_dim
    params["pool_w"] = jax.random.normal(next(ki), (f_in, cfg.mlp_dim)) * (
        1.0 / np.sqrt(f_in)
    )
    d = cfg.mlp_dim
    for i in range(cfg.n_mlp):
        d_out = 1 if i == cfg.n_mlp - 1 else cfg.mlp_dim
        params["mlp"].append({
            "w": jax.random.normal(next(ki), (d, d_out)) * (1.0 / np.sqrt(d)),
            "b": jnp.zeros((d_out,)),
        })
        d = d_out
    return params


def forward(params: dict, feat, adj, mask):
    """Predicted log-time for one padded graph."""
    e = feat
    neg = jnp.finfo(jnp.float32).min
    edge_mask = adj * mask[None, :] * mask[:, None]
    for layer in params["layers"]:
        h = jnp.einsum("nf,kfd->knd", e, layer["w"])            # [K,N,D]
        s_src = jnp.einsum("knd,kd->kn", h, layer["a_src"])     # [K,N]
        s_dst = jnp.einsum("knd,kd->kn", h, layer["a_dst"])
        logits = s_src[:, :, None] + s_dst[:, None, :]          # [K,N,N]
        logits = jax.nn.leaky_relu(logits, 0.2)
        logits = jnp.where(edge_mask[None] > 0, logits, neg)
        gamma = jax.nn.softmax(logits, axis=2)                  # eq. (1) coeffs
        gamma = jnp.where(edge_mask[None] > 0, gamma, 0.0)
        out = jnp.einsum("knm,kmd->knd", gamma, h)              # aggregate
        e = jax.nn.elu(out).transpose(1, 0, 2).reshape(feat.shape[0], -1)
        e = e * mask[:, None]
    pooled = jax.nn.elu(jnp.einsum("nf,fd->d", e * mask[:, None],
                                   params["pool_w"]))            # eq. (2)
    x = pooled
    for i, layer in enumerate(params["mlp"]):
        x = x @ layer["w"] + layer["b"]
        if i < len(params["mlp"]) - 1:
            x = jax.nn.relu(x)
    return x[0]


forward_batch = jax.vmap(forward, in_axes=(None, 0, 0, 0))


def loss_fn(params, feat, adj, mask, log_t):
    pred = forward_batch(params, feat, adj, mask)
    return jnp.mean(jnp.square(pred - log_t))  # eq. (3), log-space MSE


@partial(jax.jit, static_argnames=("update",))
def _train_step(params, opt_state, feat, adj, mask, log_t, update):
    loss, grads = jax.value_and_grad(loss_fn)(params, feat, adj, mask, log_t)
    updates, opt_state = update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


def train(
    samples: Sequence[tuple],  # (feat, adj, mask, time_seconds)
    cfg: GNNConfig = GNNConfig(),
    *,
    epochs: int = 60,
    batch_size: int = 32,
    lr: float = 3e-3,
    seed: int = 0,
    verbose: bool = False,
) -> tuple[dict, list[float]]:
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    init, update = adamw(lr)
    opt_state = init(params)
    feat = jnp.asarray(np.stack([s[0] for s in samples]))
    adj = jnp.asarray(np.stack([s[1] for s in samples]))
    mask = jnp.asarray(np.stack([s[2] for s in samples]))
    log_t = jnp.asarray(np.array([np.log(max(s[3], 1e-9)) for s in samples],
                                 np.float32))
    n = len(samples)
    rng = np.random.default_rng(seed)
    losses = []
    for ep in range(epochs):
        order = rng.permutation(n)
        ep_loss = 0.0
        nb = 0
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i : i + batch_size]
            params, opt_state, l = _train_step(
                params, opt_state, feat[idx], adj[idx], mask[idx], log_t[idx],
                update)
            ep_loss += float(l)
            nb += 1
        losses.append(ep_loss / max(nb, 1))
        if verbose and ep % 10 == 0:
            print(f"  gnn epoch {ep}: loss {losses[-1]:.4f}")
    return params, losses


def predict_times(params, samples) -> np.ndarray:
    feat = jnp.asarray(np.stack([s[0] for s in samples]))
    adj = jnp.asarray(np.stack([s[1] for s in samples]))
    mask = jnp.asarray(np.stack([s[2] for s in samples]))
    return np.exp(np.asarray(forward_batch(params, feat, adj, mask)))


# ----------------------------------------------------------------- estimator
class GNNEstimator:
    """Drop-in for :class:`repro.core.costs.OracleEstimator`, backed by the
    trained GNN for multi-op groups; singleton groups use profiled times.

    ``comm_sensitive`` tells the simulator's delta path that predictions
    depend on the searched comm dimensions (bucket algo / comm kind /
    chunks): cached per-group times from an ancestor schedule are stale
    across bucket-dimension mutations, so those journals must fall back to
    a full replay (the comm-blind oracle keeps the fast delta path)."""

    comm_sensitive = True

    def __init__(self, params: dict, cfg: GNNConfig):
        self.params = params
        self.cfg = cfg
        self._cache: dict = {}
        self._bucket_maps: dict = {}
        self._fwd = jax.jit(forward)

    def _param_bucket(self, g: FusionGraph) -> dict[int, int]:
        # content-keyed so clones sharing a bucket partition share the map
        key = tuple(g.buckets)
        m = self._bucket_maps.get(key)
        if m is None:
            # bucket mutations mint a new partition per candidate: bound
            # the cache so a long search cannot accumulate O(n_params)
            # dicts without end
            if len(self._bucket_maps) >= 256:
                self._bucket_maps.clear()
            m = _param_bucket_index(g)
            self._bucket_maps[key] = m
        return m

    def group_time(self, g: FusionGraph, gid: int) -> float:
        members = g.groups[gid]
        if len(members) == 1:
            (pid,) = members
            return g.prims[pid].time
        # the feature vector carries the comm dimensions of any member
        # gradient's bucket, so the cache must key on them too or a comm
        # mutation would replay a stale prediction.  Most groups produce no
        # gradients: their key is (members, ()) with no bucket scan at all.
        grad_params = [g.prims[pid].grad_param for pid in members
                       if g.prims[pid].grad_param >= 0]
        if grad_params:
            pb = self._param_bucket(g)
            comm_key = tuple(
                (g.bucket_algos[bi], g.bucket_comm[bi], g.bucket_chunks[bi])
                for bi in sorted({pb[p] for p in grad_params if p in pb})
            )
        else:
            comm_key = ()
        key = (members, comm_key)
        t = self._cache.get(key)
        if t is None:
            feat, adj, mask = group_features(
                g, gid, self.cfg.max_nodes,
                param_bucket=self._param_bucket(g) if grad_params else None)
            t = float(np.exp(self._fwd(self.params, feat, adj, mask)))
            self._cache[key] = t
        return t

"""Hardware model constants for the cost substrate.

The reproduction targets TPU v5e (the container is CPU-only; these constants
drive the analytic roofline used by the Profiler / simulator / dry-run
roofline analysis).  All values are per chip.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s HBM
    ici_bw: float = 50e9              # bytes/s per ICI link
    vmem_bytes: float = 128 * 2**20   # VMEM capacity
    # Per-dispatched-op overhead.  On GPU this is the kernel-launch cost the
    # paper's op fusion amortises (~5 us); XLA:TPU dispatch is cheaper but
    # non-zero.  Kept configurable — see DESIGN.md "Hardware adaptation".
    launch_overhead: float = 1.5e-6
    # Fixed AllReduce negotiation/synchronisation overhead (the ``D`` of the
    # paper's linear model T = C x + D, Sec. 4.2).
    allreduce_latency: float = 10e-6
    # MXU tile edge — matmul dims are padded up to multiples of this.
    mxu_dim: int = 128
    # Fraction of peak achievable by well-tiled kernels (compiler inefficiency).
    efficiency: float = 0.85


TPU_V5E = Hardware()


def ring_allreduce_coeffs(hw: Hardware, n_devices: int) -> tuple[float, float]:
    """Linear AllReduce model T = C*x + D (paper Sec. 4.2, Parallax formula).

    C = 2(N-1)/(N*B) for a full-duplex ring over the slowest link B.

    This single-link model is the *flat* special case: hierarchical,
    heterogeneous interconnects and alternative collective algorithms live
    in :mod:`repro.cluster` (DESIGN.md Sec. 7), whose flat back-compat spec
    reproduces this formula bit-for-bit.
    """
    if n_devices <= 1:
        return 0.0, 0.0
    c = 2.0 * (n_devices - 1) / (n_devices * hw.ici_bw)
    return c, hw.allreduce_latency


def allreduce_time(nbytes: float, hw: Hardware, n_devices: int) -> float:
    c, d = ring_allreduce_coeffs(hw, n_devices)
    return c * nbytes + d

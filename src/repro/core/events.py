"""Phase-level, multi-resource communication event engine (DESIGN.md Sec. 8).

The seed simulator priced communication as one serialized channel: each
bucket's collective was a single opaque interval, FIFO in readiness order.
That model cannot see the effects that dominate on hierarchical clusters —
two buckets whose phases occupy *different* link levels (one still inside
its intra-host reduce-scatter while another crosses the inter-host fabric)
genuinely overlap, and buckets contending on the *same* level share its
bandwidth rather than queueing politely.

This engine schedules :class:`CommJob` s (one per gradient bucket) as
sequences of :class:`repro.cluster.collectives.CommPhase` steps over one
resource per :class:`~repro.cluster.topology.LinkLevel`:

* ``streams`` bounds how many jobs are in flight concurrently (NCCL-channel
  style).  ``streams=1`` is the **serialized channel**: jobs run one at a
  time as opaque intervals, and the arithmetic is bit-identical to the
  seed's ``_comm_pass`` (same ordering, same ``c*x + d`` multiply-add, same
  ``max(chan_free, ready)`` — the PR-1/PR-2 golden equivalence tests pass
  unmodified).
* With ``streams > 1`` each job executes its phase sequence in order; when
  ``k`` active phases occupy one level, each progresses at rate ``1/k``
  (fair-share / processor-sharing fluid model), so no level is ever driven
  past its capacity.  Phases on different levels proceed at full rate
  concurrently — the pipelining win of hierarchical collectives.

The engine is jax-free and allocation-light: phase decompositions and
opaque-interval coefficients are memoised per (algo, kind), so the hot
serialized path is a dict hit + multiply-add exactly like the seed.

Timeline records are 6-tuples ``(kind, bucket, algo, level, start, end)``
where ``kind`` is ``allreduce`` / ``reduce_scatter`` / ``all_gather`` (or
the opaque ``rs_ag`` in serialized mode), distinguishing ring vs tree vs
hierarchical phases and the ZeRO-3 RS/AG path in ``--timeline`` output.
``record_load=True`` additionally keeps per-level utilisation segments
``(level, t0, t1, work_seconds)`` — the seconds of work the level actually
advanced during the segment — so tests can assert no oversubscription from
observed progress (``work_seconds <= t1 - t0``), not from the prescribed
shares.
"""
from __future__ import annotations

import dataclasses

from ..cluster import ClusterSpec
from ..cluster.collectives import (KIND_AR, KIND_RS_AG, comm_coeffs, phases)


@dataclasses.dataclass(frozen=True)
class CommJob:
    """One bucket's collective: ready time, volume, and how to run it."""
    bucket: int
    ready: float
    nbytes: float
    algo: str = "ring"
    kind: str = KIND_AR


class _Active:
    """A job in flight: its phase worklist and current-phase progress."""
    __slots__ = ("bucket", "algo", "steps", "idx", "level", "kind",
                 "remaining", "work", "phase_start")

    def __init__(self, job: CommJob, steps: list[tuple[str, int, float]]):
        self.bucket = job.bucket
        self.algo = job.algo
        self.steps = steps     # [(phase_kind, level, work_seconds), ...]
        self.idx = -1

    def advance(self, now: float) -> bool:
        """Move to the next non-empty phase; False when the job is done."""
        while True:
            self.idx += 1
            if self.idx >= len(self.steps):
                return False
            kind, level, work = self.steps[self.idx]
            if work > 0.0:
                self.kind = kind
                self.level = level
                self.work = work
                self.remaining = work
                self.phase_start = now
                return True


class CommEngine:
    """Schedules one iteration's communication jobs on the link levels of a
    :class:`ClusterSpec`; returns ``(busy_seconds, finish_time)``."""

    def __init__(self, spec: ClusterSpec, streams: int = 1,
                 record_load: bool = False):
        self.spec = spec
        self.streams = max(int(streams), 1)
        self.record_load = record_load
        self.level_load: list[tuple[int, float, float, float]] = []
        self._coeffs: dict[tuple[str, str], tuple[float, float]] = {}
        self._steps: dict[tuple[str, str], tuple] = {}
        self._chan_level = spec.levels[spec.bottleneck_index()].name

    # ------------------------------------------------------------- helpers
    def _job_coeffs(self, algo: str, kind: str) -> tuple[float, float]:
        key = (algo, kind)
        cd = self._coeffs.get(key)
        if cd is None:
            cd = comm_coeffs(self.spec, algo, kind)
            self._coeffs[key] = cd
        return cd

    def _job_steps(self, job: CommJob) -> list[tuple[str, int, float]]:
        key = (job.algo, job.kind)
        ph = self._steps.get(key)
        if ph is None:
            ph = phases(self.spec, job.algo, job.kind)
            self._steps[key] = ph
        return [(p.kind, p.level, p.c * job.nbytes + p.d) for p in ph]

    # ----------------------------------------------------------------- run
    def run(self, jobs: list[CommJob],
            timeline: list | None = None) -> tuple[float, float]:
        # each run is an independent schedule starting at t=0: utilisation
        # segments must not accumulate across runs
        self.level_load = []
        if self.streams == 1:
            return self._run_serialized(jobs, timeline)
        return self._run_phased(jobs, timeline)

    def _run_serialized(self, jobs: list[CommJob],
                        timeline: list | None) -> tuple[float, float]:
        # the seed's comm pass: buckets transfer in order of readiness
        # (ties by index), serialized on one channel.  Arithmetic must stay
        # bit-identical: one c*x + d per job, start = max(chan_free, ready).
        chan_free = 0.0
        busy = 0.0
        finish = 0.0
        for job in sorted(jobs, key=lambda j: (j.ready, j.bucket)):
            if job.nbytes <= 0.0:
                continue  # nothing to transfer: no latency D charged
            c, d = self._job_coeffs(job.algo, job.kind)
            t = c * job.nbytes + d
            start = max(chan_free, job.ready)
            chan_free = start + t
            busy += t
            finish = chan_free
            if timeline is not None:
                kind = "allreduce" if job.kind == KIND_AR else KIND_RS_AG
                timeline.append((kind, job.bucket, job.algo,
                                 self._chan_level, start, chan_free))
        return busy, finish

    def _run_phased(self, jobs: list[CommJob],
                    timeline: list | None) -> tuple[float, float]:
        pending = sorted((j for j in jobs if j.nbytes > 0.0),
                         key=lambda j: (j.ready, j.bucket), reverse=True)
        active: list[_Active] = []
        t = 0.0
        busy = 0.0
        finish = 0.0
        names = [l.name for l in self.spec.levels]
        while pending or active:
            while pending and len(active) < self.streams \
                    and pending[-1].ready <= t:
                job = pending.pop()
                a = _Active(job, self._job_steps(job))
                if a.advance(t):
                    active.append(a)
                else:
                    finish = max(finish, t)  # all-empty phase list
            if not active:
                t = pending[-1].ready
                continue
            counts: dict[int, int] = {}
            for a in active:
                counts[a.level] = counts.get(a.level, 0) + 1
            # next event: earliest phase completion under the current
            # fair-share rates, or the next admissible arrival
            dt = min(a.remaining * counts[a.level] for a in active)
            if pending and len(active) < self.streams:
                dt = min(dt, pending[-1].ready - t)
            dt = max(dt, 0.0)
            t1 = t + dt
            progressed: dict[int, float] = {}
            for a in active:
                step = dt / counts[a.level]
                a.remaining -= step
                if self.record_load:
                    progressed[a.level] = progressed.get(a.level, 0.0) + step
            if self.record_load and dt > 0.0:
                # record the *observed* seconds of work each level advanced
                # during [t, t1] — the capacity test divides by the segment
                # span, so a rate bug cannot hide behind the prescription
                for lvl, w in progressed.items():
                    self.level_load.append((lvl, t, t1, w))
            t = t1
            still: list[_Active] = []
            for a in active:
                if a.remaining <= 1e-12 * a.work:
                    busy += a.work
                    if timeline is not None:
                        timeline.append((a.kind, a.bucket, a.algo,
                                         names[a.level], a.phase_start, t))
                    if a.advance(t):
                        still.append(a)
                    else:
                        finish = max(finish, t)
                else:
                    still.append(a)
            active = still
        return busy, finish

"""Dependency-aware event engine over link levels *and* compute streams
(DESIGN.md Sec. 8-9, 11).

The seed simulator priced communication as one serialized channel: each
bucket's collective was a single opaque interval, FIFO in readiness order.
PR 3 replaced that with a phase-level engine — collectives decompose into
per-link-level phases, concurrent phases on one level share its bandwidth —
but jobs were still a flat list of independent transfers, and compute was a
separate hand-rolled loop inside the simulator.  This revision makes the
engine a general dependency-aware scheduler over *both* resource kinds:

* **Compute jobs** (:class:`ComputeJob`) occupy a serialized compute
  stream (``stream{i}``) for ``duration`` seconds; their ``deps`` are the
  quotient predecessors (or, for pipeline schedules, the previous unit on
  the stream plus the stage-boundary p2p transfer).  Compute job-ids are
  negative (``~gid``) so they can never collide with comm job-ids, which
  stay non-negative.  :meth:`EventEngine.run_unified` schedules a compute
  job list and a comm job list as one dependency graph and returns a
  :class:`UnifiedResult`; when no compute job depends on a comm job the
  two resource kinds decouple and the engine runs the exact seed
  arithmetic (serialized compute pop-order loop, then the comm pass).

* **Jobs** (:class:`CommJob`) carry ``deps`` — job-ids that must *finish*
  before the job may start — and a ``traffic_class`` (``dp`` gradient
  bucket / ``tp`` tensor-parallel / ``pp`` pipeline-parallel), so
  non-gradient collectives extracted from the compiled HLO can contend with
  gradient buckets on the same link levels (:class:`BackgroundTraffic`
  turns a recurring TP/PP collective into concrete jobs over a horizon).
* **Chunked store-and-forward** — a job may name an ``after`` predecessor
  (the previous chunk of the same bucket): it may not *start phase p*
  before the predecessor has *finished its phase p*.  Chunks of one fused
  bucket thereby pipeline through the link levels (chunk 1's intra-host
  leg under chunk 0's inter-host leg) without ever overtaking each other —
  the CoCoNet-style dependency-ordered chunk schedule.  Per-chunk phase
  coefficients (:func:`repro.cluster.collectives.chunk_phases`) sum exactly
  to the unchunked ones, so chunking conserves channel work and wins only
  by scheduling.
* **Per-level discipline** — each level serves its contenders either
  **fair-share** (``k`` active phases progress at rate ``1/k`` each; the
  PR-3 fluid model, still the default and bit-identical to it) or **FIFO**
  (one phase at a time, arrival order, full rate).  ``discipline`` is a
  single mode or a ``{level_index: mode}`` mapping.
* ``streams`` bounds how many **distinct DP buckets** are in flight
  (NCCL-channel style); chunks of one bucket share their bucket's slot and
  TP/PP background traffic bypasses the bound (it is not issued by the
  gradient hook).  ``streams=1`` with dependency-free jobs is the
  **serialized channel**, bit-identical to the seed's ``_comm_pass``.

Timeline records are 8-tuples
``(kind, bucket, chunk, traffic_class, algo, level, start, end)``
(``--timeline`` output; see DESIGN.md Sec. 9 for the field semantics).
``record_load=True`` additionally keeps per-level utilisation segments
``(level, t0, t1, work_seconds)`` so tests can assert no oversubscription
from observed progress, not from the prescribed shares.  After ``run()``
the engine exposes ``job_finish`` (jid -> finish time) and per-class
``class_busy`` / ``class_finish`` tallies so callers can gate on gradient
traffic alone while background traffic keeps contending.
"""
from __future__ import annotations

import dataclasses
import heapq
import math

from ..cluster import ClusterSpec
from ..cluster.collectives import (KIND_AR, KIND_RS_AG, chunk_phases,
                                   comm_coeffs, fused_phases,
                                   level_chunk_phases)

# traffic classes a job can belong to
TC_DP = "dp"    # data-parallel gradient bucket (the searched dimension)
TC_TP = "tp"    # tensor-parallel activation collective
TC_PP = "pp"    # pipeline-parallel stage-boundary transfer
TRAFFIC_CLASSES = (TC_DP, TC_TP, TC_PP)
# compute jobs carry their own class so per-class tallies separate device
# occupancy from channel occupancy; deliberately NOT in TRAFFIC_CLASSES,
# which enumerates the *communication* classes background traffic may use
TC_COMPUTE = "compute"

# per-level service disciplines
DISC_FAIR = "fair"
DISC_FIFO = "fifo"
DISCIPLINES = (DISC_FAIR, DISC_FIFO)


@dataclasses.dataclass(frozen=True)
class CommJob:
    """One collective transfer: ready time, volume, how to run it, and its
    position in the dependency structure.

    ``job_id`` defaults to ``bucket`` (the PR-3 identity); chunk jobs and
    background jobs need explicit distinct ids.  ``deps`` are job-ids that
    must have *finished all phases* before this job may start.  ``after``
    is the store-and-forward predecessor: this job may not start its phase
    ``p`` before ``after`` has completed its phase ``p`` (chunks of one
    bucket share a phase sequence, so positions align)."""
    bucket: int
    ready: float
    nbytes: float
    algo: str = "ring"
    kind: str = KIND_AR
    job_id: int | None = None
    deps: tuple[int, ...] = ()
    after: int | None = None
    chunk: int = 0
    chunks: int = 1
    traffic_class: str = TC_DP
    # in-kernel fusion overlap discount (DESIGN.md Sec. 13): > 0 lets this
    # job's effective ready reach ``discount x dep_duration`` back into the
    # tail of each *compute* dep — the fused kernel streams chunks onto the
    # wire before the producer retires.  Link work stays full; only the
    # start moves.  0.0 (every non-fused job) changes nothing.
    discount: float = 0.0

    @property
    def jid(self) -> int:
        return self.bucket if self.job_id is None else self.job_id


@dataclasses.dataclass(frozen=True)
class ComputeJob:
    """One fused-op group (or pipeline fwd/bwd unit) on a serialized
    compute stream.

    ``ref`` is the display identity (the group id, or an encoded
    microbatch/chunk for pipeline units); ``job_id`` must be negative
    (``~gid`` by convention) so compute ids and comm ids share one
    ``deps`` namespace without collisions.  ``key`` orders the serialized
    ready heap and must be unique across one run's compute jobs (the
    simulator passes ``(_group_key, gid)`` — min member pid with the
    seed's ascending-gid tie-break, since duplication-allowed fusion lets
    min pids collide across groups).  ``deps`` may name both compute and
    comm job-ids; on a single stream with compute-only deps the engine
    reduces to the seed's serialized loop bit-exactly."""
    ref: int
    duration: float
    job_id: int
    stream: int = 0
    key: tuple | int = 0
    deps: tuple[int, ...] = ()
    kind: str = "compute"          # "compute" | "fwd" | "bwd"
    ready: float = 0.0
    traffic_class: str = TC_COMPUTE

    @property
    def jid(self) -> int:
        return self.job_id

    # CommJob-shaped views so the phased scheduler handles both kinds
    # uniformly (sort keys, slot accounting, timeline bookkeeping)
    @property
    def bucket(self) -> int:
        return self.ref

    @property
    def chunk(self) -> int:
        return 0

    @property
    def chunks(self) -> int:
        return 1

    @property
    def algo(self) -> str:
        return ""

    @property
    def after(self) -> None:
        return None

    @property
    def nbytes(self) -> float:
        return self.duration


@dataclasses.dataclass
class UnifiedResult:
    """One unified schedule's outcome: per-resource-kind busy/finish plus
    the serialized compute schedule (pop order, cumulative busy, per-ref
    completion times) that the simulator's delta-resume substrate snapshots
    into a ``_SimState``."""
    compute_busy: float
    compute_finish: float
    comm_busy: float
    comm_finish: float
    finish: float                  # max finish over every job of any kind
    order: list                    # compute refs in pop order
    busy_after: list               # cumulative compute busy after each pop
    done_at: dict                  # compute ref -> completion time


@dataclasses.dataclass(frozen=True)
class BackgroundTraffic:
    """A recurring non-gradient collective: one TP activation AllReduce or
    PP boundary transfer issued every ``period`` seconds starting at
    ``offset``.  ``materialize`` expands it into concrete :class:`CommJob`s
    over a horizon (the iteration's compute span)."""
    traffic_class: str
    nbytes: float
    period: float
    algo: str = "ring"
    kind: str = KIND_AR
    offset: float = 0.0
    count: int | None = None

    # safety cap: a mis-sized period cannot explode the event loop
    MAX_JOBS = 512

    def materialize(self, horizon: float, base_id: int) -> list[CommJob]:
        if self.nbytes <= 0.0:
            return []
        if self.count is not None:
            n = int(self.count)
        elif self.period > 0.0:
            n = int(math.ceil(max(horizon - self.offset, 0.0) / self.period))
        else:
            n = 1
        n = max(min(n, self.MAX_JOBS), 0)
        return [
            CommJob(bucket=-1 - k, ready=self.offset + k * self.period,
                    nbytes=self.nbytes, algo=self.algo, kind=self.kind,
                    job_id=base_id + k, traffic_class=self.traffic_class)
            for k in range(n)
        ]


class _Active:
    """A job in flight: its phase worklist and current-phase progress."""
    __slots__ = ("bucket", "algo", "steps", "idx", "level", "kind",
                 "remaining", "work", "phase_start", "jid", "after",
                 "chunk", "tclass", "order", "started")

    def __init__(self, job: CommJob, steps: list[tuple[str, int, float]],
                 order: int):
        self.bucket = job.bucket
        self.algo = job.algo
        self.steps = steps     # [(phase_kind, level, work_seconds), ...]
        self.idx = -1
        self.jid = job.jid
        self.after = job.after
        self.chunk = job.chunk
        self.tclass = job.traffic_class
        self.order = order     # admission order (FIFO tie-break)

    def advance(self, now: float) -> bool:
        """Move to the next non-empty phase; False when the job is done."""
        while True:
            self.idx += 1
            if self.idx >= len(self.steps):
                return False
            kind, level, work = self.steps[self.idx]
            if work > 0.0:
                self.kind = kind
                self.level = level
                self.work = work
                self.remaining = work
                # queue-entry time; re-stamped at first service so FIFO-
                # queued / after-blocked waits are not reported as occupancy
                self.phase_start = now
                self.started = False
                return True


def bucket_jobs(bucket: int, ready: float, nbytes: float, algo: str,
                kind: str, chunks: int, next_id: int,
                deps: tuple[int, ...] = (),
                discount: float = 0.0) -> tuple[list[CommJob], int]:
    """The canonical job decomposition of one gradient bucket: a single
    job when ``chunks <= 1``, else ``chunks`` store-and-forward chunk jobs
    (each ``nbytes/chunks``, ``after``-chained, ids allocated from
    ``next_id``).  ``deps`` (e.g. the bucket's provider compute jobs) are
    stamped onto every chunk, as is the in-kernel fusion ``discount`` of a
    fused bucket (0.0 otherwise).  Shared by the simulator's comm pass and
    ``repro.plan.Plan.comm_jobs`` so plan pricing can never drift from
    search pricing.  Returns ``(jobs, next_id)``."""
    deps = tuple(deps)
    if chunks <= 1:
        return [CommJob(bucket=bucket, ready=ready, nbytes=nbytes,
                        algo=algo, kind=kind, deps=deps,
                        discount=discount)], next_id
    jobs = []
    prev = None
    for c in range(chunks):
        jobs.append(CommJob(bucket=bucket, ready=ready,
                            nbytes=nbytes / chunks, algo=algo, kind=kind,
                            job_id=next_id, after=prev, chunk=c,
                            chunks=chunks, deps=deps, discount=discount))
        prev = next_id
        next_id += 1
    return jobs, next_id


class EventEngine:
    """Schedules one iteration's jobs on the link levels of a
    :class:`ClusterSpec` plus any compute streams the job list names.

    ``run`` is the comm-only entry point (returns ``(busy_seconds,
    finish_time)``; bit-identical to the PR-3 ``CommEngine``);
    ``run_unified`` schedules compute and comm jobs as one dependency
    graph."""

    def __init__(self, spec: ClusterSpec, streams: int = 1,
                 record_load: bool = False,
                 discipline: str | dict[int, str] = DISC_FAIR,
                 level_chunks: bool = False):
        self.spec = spec
        self.streams = max(int(streams), 1)
        self.record_load = record_load
        # per-level chunk sizing (DESIGN.md Sec. 14): fat link levels
        # coalesce chunk cohorts into bigger transfers.  Off by default —
        # uniform chunk_phases schedules stay bit-identical.
        self.level_chunks = bool(level_chunks)
        if isinstance(discipline, str):
            if discipline not in DISCIPLINES:
                raise ValueError(f"unknown discipline {discipline!r}; "
                                 f"expected one of {DISCIPLINES}")
            self._disc = [discipline] * len(spec.levels)
        else:
            self._disc = [DISC_FAIR] * len(spec.levels)
            for lvl, d in discipline.items():
                if d not in DISCIPLINES:
                    raise ValueError(f"unknown discipline {d!r}; "
                                     f"expected one of {DISCIPLINES}")
                if not 0 <= lvl < len(spec.levels):
                    raise ValueError(
                        f"discipline level {lvl} out of range for "
                        f"{len(spec.levels)}-level spec {spec.name!r}")
                self._disc[lvl] = d
        self.level_load: list[tuple[int, float, float, float]] = []
        self.job_finish: dict[int, float] = {}
        self.class_busy: dict[str, float] = {}
        self.class_finish: dict[str, float] = {}
        self._coeffs: dict[tuple[str, str], tuple[float, float]] = {}
        self._steps: dict[tuple[str, str, int, float, int], tuple] = {}
        self._chan_level = spec.levels[spec.bottleneck_index()].name

    # ------------------------------------------------------------- helpers
    def _job_coeffs(self, algo: str, kind: str) -> tuple[float, float]:
        key = (algo, kind)
        cd = self._coeffs.get(key)
        if cd is None:
            cd = comm_coeffs(self.spec, algo, kind)
            self._coeffs[key] = cd
        return cd

    def _job_steps(self, job) -> list[tuple[str, int, float]]:
        if isinstance(job, ComputeJob):
            # one phase on the job's compute stream; stream resources are
            # indexed past the link levels (see _run_phased's names/disc)
            return [(job.kind, len(self.spec.levels) + job.stream,
                     job.duration)]
        # with per-level chunk sizing on, undiscounted chunked jobs get a
        # per-chunk-index decomposition (carrier vs zero-work phases), so
        # the memo key gains the chunk index; fused jobs keep fused_phases
        # (their early comm start already prices the fat-level advantage)
        lc = (self.level_chunks and job.discount <= 0.0 and job.chunks > 1)
        key = (job.algo, job.kind, job.chunks, job.discount,
               job.chunk if lc else -1)
        ph = self._steps.get(key)
        if ph is None:
            if job.discount > 0.0:
                # fused_* phase kinds tag the timeline; (c, d) are the
                # chunk_phases ones unchanged (link work is conserved)
                ph = fused_phases(self.spec, job.algo, job.kind,
                                  job.chunks, job.discount)
            elif lc:
                ph = level_chunk_phases(self.spec, job.algo, job.kind,
                                        job.chunks, job.chunk)
            else:
                ph = chunk_phases(self.spec, job.algo, job.kind, job.chunks)
            self._steps[key] = ph
        return [(p.kind, p.level, p.c * job.nbytes + p.d) for p in ph]

    def _account(self, tclass: str, work: float) -> None:
        self.class_busy[tclass] = self.class_busy.get(tclass, 0.0) + work

    def _finish_job(self, jid: int, tclass: str, t: float) -> None:
        self.job_finish[jid] = t
        if t > self.class_finish.get(tclass, 0.0):
            self.class_finish[tclass] = t

    # ----------------------------------------------------------------- run
    def run(self, jobs: list[CommJob],
            timeline: list | None = None) -> tuple[float, float]:
        # each run is an independent schedule starting at t=0: utilisation
        # segments and per-job/per-class tallies must not accumulate
        self.level_load = []
        self.job_finish = {}
        self.class_busy = {}
        self.class_finish = {}
        # zero-byte jobs transfer nothing: free, and they satisfy deps
        # immediately (a dep on an empty chunk must not deadlock the chain)
        for job in jobs:
            if job.nbytes <= 0.0:
                self._finish_job(job.jid, job.traffic_class, 0.0)
        if self.streams == 1:
            return self._run_serialized(jobs, timeline)
        return self._run_phased(jobs, timeline)

    # ---------------------------------------------------------- unified run
    def run_unified(self, compute: list[ComputeJob], comm: list[CommJob],
                    timeline: list | None = None, background: tuple = (),
                    bg_base_id: int = 0) -> UnifiedResult:
        """Schedule compute and comm jobs as one dependency graph.

        When no compute job depends on a comm job (the default DP training
        iteration: comm depends on compute, never the reverse) the two
        resource kinds decouple and the engine runs the exact seed
        arithmetic: the serialized compute pop-order loop first, then comm
        job readiness is resolved from the finished compute deps and the
        comm pass runs as before — bit-identical to the split schedulers.
        With a cyclic coupling (pipeline schedules: fwd units wait on p2p
        transfers that wait on upstream fwd units) everything runs in the
        phased fluid scheduler with compute streams as extra FIFO
        resources.

        ``background`` traffic is materialized over the compute-finish
        horizon with job ids from ``bg_base_id``; as in the comm-only path,
        when background is present the comm busy/finish reported are the
        DP-class tallies (iteration time gates on gradient sync)."""
        self.level_load = []
        self.job_finish = {}
        self.class_busy = {}
        self.class_finish = {}
        comm_ids = {j.jid for j in comm}
        coupled = any(d in comm_ids
                      for j in compute for d in j.deps)
        if coupled:
            return self._run_coupled(compute, comm, timeline, background,
                                     bg_base_id)
        c_busy, c_fin, order, busy_after, done = \
            self._run_compute_serial(compute, timeline)
        dur: dict[int, float] | None = None  # compute durations, on demand
        jobs = []
        for j in comm:
            if j.deps:
                r = j.ready
                left = []
                for d in j.deps:
                    t = self.job_finish.get(d)
                    if t is None:
                        if d in comm_ids:
                            left.append(d)   # comm-on-comm dep: keep it
                    else:
                        if j.discount > 0.0:
                            # in-kernel fusion: the collective is issued
                            # from inside the producing kernel, so it may
                            # start discount x duration into the dep's tail
                            # (never before the dep started: discount < 1)
                            if dur is None:
                                dur = {cj.job_id: cj.duration
                                       for cj in compute}
                            t -= j.discount * dur.get(d, 0.0)
                        if t > r:
                            r = t
                if r != j.ready or len(left) != len(j.deps):
                    j = dataclasses.replace(j, ready=r, deps=tuple(left))
            jobs.append(j)
        for tr in background:
            made = tr.materialize(c_fin, bg_base_id)
            bg_base_id += len(made)
            jobs.extend(made)
        # zero-byte comm jobs transfer nothing: free, deps satisfied at 0
        for job in jobs:
            if job.nbytes <= 0.0:
                self._finish_job(job.jid, job.traffic_class, 0.0)
        if self.streams == 1:
            m_busy, m_fin = self._run_serialized(jobs, timeline)
        else:
            m_busy, m_fin = self._run_phased(jobs, timeline)
        if background:
            m_busy = self.class_busy.get(TC_DP, 0.0)
            m_fin = self.class_finish.get(TC_DP, 0.0)
        return UnifiedResult(
            compute_busy=c_busy, compute_finish=c_fin,
            comm_busy=m_busy, comm_finish=m_fin,
            finish=max(self.job_finish.values(), default=0.0),
            order=order, busy_after=busy_after, done_at=done)

    def _run_compute_serial(self, jobs: list[ComputeJob],
                            timeline: list | None):
        """Serialized compute stream(s): a ready heap ordered by ``key``
        pops jobs whose deps have finished.  On a single stream this is
        the seed simulator's compute loop bit-exactly: the pop order is
        independent of durations (``key`` is total), every dep of a popped
        job finished at or before ``stream_free`` (ends are
        non-decreasing), so ``start == stream_free`` and the busy sum
        accumulates in pop order."""
        by_id = {j.job_id: j for j in jobs}
        indeg: dict[int, int] = {}
        succs: dict[int, list[int]] = {}
        for j in jobs:
            c = 0
            for d in j.deps:
                if d in by_id:
                    succs.setdefault(d, []).append(j.job_id)
                    c += 1
            indeg[j.job_id] = c
        ready = [(j.key, j.job_id) for j in jobs if indeg[j.job_id] == 0]
        heapq.heapify(ready)
        free: dict[int, float] = {}
        busy = 0.0
        finish = 0.0
        order: list[int] = []
        busy_after: list[float] = []
        done: dict[int, float] = {}
        while ready:
            _, jid = heapq.heappop(ready)
            j = by_id[jid]
            start = free.get(j.stream, 0.0)
            for d in j.deps:     # cross-stream deps (no-op on one stream)
                t = self.job_finish.get(d)
                if t is not None and t > start:
                    start = t
            end = start + j.duration
            free[j.stream] = end
            busy += j.duration
            done[j.ref] = end
            order.append(j.ref)
            busy_after.append(busy)
            if end > finish:
                finish = end
            self._account(j.traffic_class, j.duration)
            self._finish_job(jid, j.traffic_class, end)
            if timeline is not None:
                timeline.append((j.kind, j.ref, start, end, j.traffic_class,
                                 f"stream{j.stream}", start, end))
            for d in succs.get(jid, ()):
                indeg[d] -= 1
                if indeg[d] == 0:
                    heapq.heappush(ready, (by_id[d].key, d))
        if len(order) != len(jobs):
            raise RuntimeError("cyclic dependency among compute jobs")
        return busy, finish, order, busy_after, done

    def _run_coupled(self, compute: list[ComputeJob], comm: list[CommJob],
                     timeline: list | None, background: tuple,
                     bg_base_id: int) -> UnifiedResult:
        """Compute and comm in one phased fluid schedule (pipeline path).

        Per-stream serialization is the lowering's responsibility: every
        compute job must dep on its stream predecessor, so at most one
        compute phase is active per stream and its share is always 1.
        The background horizon is the whole-model serialized compute span
        (an upper-bound proxy — the coupled makespan is unknown until the
        schedule runs)."""
        jobs: list = list(compute) + list(comm)
        horizon = sum(j.duration for j in compute)
        for tr in background:
            made = tr.materialize(horizon, bg_base_id)
            bg_base_id += len(made)
            jobs.extend(made)
        for job in jobs:
            if not isinstance(job, ComputeJob) and job.nbytes <= 0.0:
                self._finish_job(job.jid, job.traffic_class, 0.0)
        self._run_phased(jobs, timeline)
        done = {j.ref: self.job_finish[j.job_id] for j in compute}
        order = sorted(done, key=lambda r: (done[r], r))
        return UnifiedResult(
            compute_busy=self.class_busy.get(TC_COMPUTE, 0.0),
            compute_finish=self.class_finish.get(TC_COMPUTE, 0.0),
            comm_busy=self.class_busy.get(TC_DP, 0.0),
            comm_finish=self.class_finish.get(TC_DP, 0.0),
            finish=max(self.job_finish.values(), default=0.0),
            order=order, busy_after=[], done_at=done)

    # ------------------------------------------------------ serialized path
    def _run_serialized(self, jobs: list[CommJob],
                        timeline: list | None) -> tuple[float, float]:
        # the seed's comm pass: buckets transfer in order of readiness
        # (ties by index), serialized on one channel.  Arithmetic must stay
        # bit-identical: one c*x + d per job, start = max(chan_free, ready).
        if any(j.deps or j.after is not None for j in jobs):
            return self._run_serialized_deps(jobs, timeline)
        chan_free = 0.0
        busy = 0.0
        finish = 0.0
        for job in sorted(jobs, key=lambda j: (j.ready, j.bucket, j.chunk)):
            if job.nbytes <= 0.0:
                continue  # nothing to transfer: no latency D charged
            t = self._opaque_interval(job)
            start = max(chan_free, job.ready)
            chan_free = start + t
            busy += t
            finish = chan_free
            self._account(job.traffic_class, t)
            self._finish_job(job.jid, job.traffic_class, chan_free)
            if timeline is not None:
                kind = "allreduce" if job.kind == KIND_AR else job.kind
                timeline.append((kind, job.bucket, job.chunk,
                                 job.traffic_class, job.algo,
                                 self._chan_level, start, chan_free))
        return busy, finish

    def _opaque_interval(self, job: CommJob) -> float:
        """Serialized (single-channel) cost of one job: ``c*x + d`` with
        the phase latency split across the bucket's chunks (``d / 1 == d``
        bit-exactly, so unchunked jobs keep the seed arithmetic)."""
        c, d = self._job_coeffs(job.algo, job.kind)
        return c * job.nbytes + d / max(job.chunks, 1)

    def _run_serialized_deps(self, jobs: list[CommJob],
                             timeline: list | None) -> tuple[float, float]:
        """Serialized channel with finish-first ordering: the next job run
        is the earliest-(ready, bucket, chunk) job whose ``deps`` (and
        ``after`` predecessor — on one channel store-and-forward degenerates
        to whole-job ordering) have all finished."""
        ids = {j.jid for j in jobs}
        pending = sorted((j for j in jobs if j.nbytes > 0.0),
                         key=lambda j: (j.ready, j.bucket, j.chunk))
        chan_free = 0.0
        busy = 0.0
        finish = 0.0
        while pending:
            picked = None
            for i, job in enumerate(pending):
                need = list(job.deps)
                if job.after is not None:
                    need.append(job.after)
                if all(d not in ids or d in self.job_finish for d in need):
                    picked = i
                    break
            if picked is None:
                raise RuntimeError("dependency cycle in comm jobs")
            job = pending.pop(picked)
            t = self._opaque_interval(job)
            dep_ready = max((self.job_finish[x] for x in job.deps
                             if x in ids), default=0.0)
            if job.after is not None and job.after in ids:
                dep_ready = max(dep_ready, self.job_finish[job.after])
            start = max(chan_free, job.ready, dep_ready)
            chan_free = start + t
            busy += t
            finish = max(finish, chan_free)
            self._account(job.traffic_class, t)
            self._finish_job(job.jid, job.traffic_class, chan_free)
            if timeline is not None:
                kind = "allreduce" if job.kind == KIND_AR else job.kind
                timeline.append((kind, job.bucket, job.chunk,
                                 job.traffic_class, job.algo,
                                 self._chan_level, start, chan_free))
        return busy, finish

    # ---------------------------------------------------------- phased path
    def _runnable(self, a: _Active, by_id: dict[int, "_Active"],
                  ids: set[int]) -> bool:
        """Store-and-forward gate: a chunk may run its phase ``idx`` only
        once its ``after`` predecessor has completed that phase."""
        if a.after is None or a.after not in ids:
            return True
        if a.after in self.job_finish:
            return True
        pred = by_id.get(a.after)
        # a predecessor still waiting in the pending queue blocks the chain
        return pred is not None and pred.idx > a.idx

    def _run_phased(self, jobs: list,
                    timeline: list | None) -> tuple[float, float]:
        ids = {j.jid for j in jobs}
        # zero-duration compute jobs stay in the queue (they must wait for
        # their deps before "finishing"); zero-byte comm jobs were already
        # pre-finished by the caller
        pending = sorted((j for j in jobs
                          if isinstance(j, ComputeJob) or j.nbytes > 0.0),
                         key=lambda j: (j.ready, j.bucket, j.chunk))
        active: list[_Active] = []
        by_id: dict[int, _Active] = {}
        # slot accounting: distinct DP buckets in flight (chunks share their
        # bucket's slot; TP/PP background traffic bypasses the bound)
        inflight: dict[int, int] = {}
        t = 0.0
        busy = 0.0
        finish = 0.0
        order = 0
        names = [l.name for l in self.spec.levels]
        disc = self._disc
        # compute streams are extra serialized resources past the link
        # levels; FIFO is nominal — the lowering chains each stream's jobs
        # by deps, so at most one compute phase is active per stream
        n_streams = 0
        for j in jobs:
            if isinstance(j, ComputeJob) and j.stream >= n_streams:
                n_streams = j.stream + 1
        if n_streams:
            names = names + [f"stream{i}" for i in range(n_streams)]
            disc = disc + [DISC_FIFO] * n_streams
        while pending or active:
            # ---- admission: ready, deps finished, slot available
            i = 0
            while i < len(pending):
                job = pending[i]
                if job.ready > t:
                    break
                if any(d in ids and d not in self.job_finish
                       for d in job.deps):
                    i += 1
                    continue
                if (job.traffic_class == TC_DP
                        and job.bucket not in inflight
                        and len(inflight) >= self.streams):
                    i += 1
                    continue
                del pending[i]
                a = _Active(job, self._job_steps(job), order)
                order += 1
                if a.advance(t):
                    active.append(a)
                    by_id[a.jid] = a
                    if job.traffic_class == TC_DP:
                        inflight[job.bucket] = inflight.get(job.bucket, 0) + 1
                else:
                    finish = max(finish, t)  # all-empty phase list
                    self._finish_job(job.jid, job.traffic_class, t)
            if not active:
                if not pending:
                    break  # admission drained everything as zero-work jobs
                later = [j.ready for j in pending if j.ready > t]
                if not later:
                    raise RuntimeError("dependency cycle in comm jobs")
                t = min(later)
                continue
            runnable = [a for a in active if self._runnable(a, by_id, ids)]
            if not runnable:
                later = [j.ready for j in pending if j.ready > t]
                if not later:
                    raise RuntimeError("store-and-forward cycle in comm jobs")
                t = min(later)
                continue
            counts: dict[int, int] = {}
            for a in runnable:
                counts[a.level] = counts.get(a.level, 0) + 1
            # per-level discipline: fair-share divides a level's rate over
            # its contenders; FIFO serves them one at a time in admission /
            # phase-arrival order at full rate.  ``share`` is the divisor a
            # running phase's progress rate pays (None: not served now).
            share: dict[int, int] = {}
            heads: dict[int, _Active] = {}
            for a in runnable:
                if disc[a.level] == DISC_FAIR:
                    share[id(a)] = counts[a.level]
                else:
                    h = heads.get(a.level)
                    if h is None or (a.phase_start, a.order) < \
                            (h.phase_start, h.order):
                        heads[a.level] = a
            for a in heads.values():
                share[id(a)] = 1
            # next event: earliest phase completion under the current
            # rates, or the next admissible arrival
            dt = min(a.remaining * share[id(a)] for a in runnable
                     if id(a) in share)
            arrival = self._next_admissible_arrival(pending, inflight, t)
            if arrival is not None:
                dt = min(dt, arrival - t)
            dt = max(dt, 0.0)
            t1 = t + dt
            progressed: dict[int, float] = {}
            for a in runnable:
                s = share.get(id(a))
                if s is None:
                    continue
                if not a.started:
                    a.phase_start = t
                    a.started = True
                step = dt / s
                a.remaining -= step
                if self.record_load:
                    progressed[a.level] = progressed.get(a.level, 0.0) + step
            if self.record_load and dt > 0.0:
                # record the *observed* seconds of work each level advanced
                # during [t, t1] — the capacity test divides by the segment
                # span, so a rate bug cannot hide behind the prescription
                for lvl, w in progressed.items():
                    self.level_load.append((lvl, t, t1, w))
            t = t1
            still: list[_Active] = []
            for a in active:
                if a.remaining <= 1e-12 * a.work:
                    busy += a.work
                    self._account(a.tclass, a.work)
                    if timeline is not None:
                        if a.tclass == TC_COMPUTE:
                            # compute layout: spans at both (2,3) — legacy
                            # consumers — and (6,7) — the unified schema
                            timeline.append((a.kind, a.bucket,
                                             a.phase_start, t, a.tclass,
                                             names[a.level],
                                             a.phase_start, t))
                        else:
                            timeline.append((a.kind, a.bucket, a.chunk,
                                             a.tclass, a.algo,
                                             names[a.level],
                                             a.phase_start, t))
                    if a.advance(t):
                        still.append(a)
                    else:
                        finish = max(finish, t)
                        del by_id[a.jid]
                        self._finish_job(a.jid, a.tclass, t)
                        if a.tclass == TC_DP:
                            inflight[a.bucket] -= 1
                            if not inflight[a.bucket]:
                                del inflight[a.bucket]
                else:
                    still.append(a)
            active = still
        return busy, finish

    def _next_admissible_arrival(self, pending: list[CommJob],
                                 inflight: dict[int, int],
                                 now: float) -> float | None:
        """Earliest *future* ready time among pending jobs that could be
        admitted when they arrive (slot free, or slot-exempt, given the
        current in-flight set).  Jobs already ready but held back by a
        dependency or a full slot table are not arrival events — their
        admission is retried at the finish event that unblocks them."""
        slot_free = len(inflight) < self.streams
        best = None
        for j in pending:
            if j.ready <= now:
                continue
            if (j.traffic_class == TC_DP and not slot_free
                    and j.bucket not in inflight):
                continue
            if best is None or j.ready < best:
                best = j.ready
        return best


# the PR-3..5 comm-only name; same class, kept so existing callers and
# pickled references keep working
CommEngine = EventEngine

"""Dependency-aware, phase-level communication event engine (DESIGN.md
Sec. 8-9).

The seed simulator priced communication as one serialized channel: each
bucket's collective was a single opaque interval, FIFO in readiness order.
PR 3 replaced that with a phase-level engine — collectives decompose into
per-link-level phases, concurrent phases on one level share its bandwidth —
but jobs were still a flat list of independent transfers.  This revision
makes the engine a general dependency-aware scheduler:

* **Jobs** (:class:`CommJob`) carry ``deps`` — job-ids that must *finish*
  before the job may start — and a ``traffic_class`` (``dp`` gradient
  bucket / ``tp`` tensor-parallel / ``pp`` pipeline-parallel), so
  non-gradient collectives extracted from the compiled HLO can contend with
  gradient buckets on the same link levels (:class:`BackgroundTraffic`
  turns a recurring TP/PP collective into concrete jobs over a horizon).
* **Chunked store-and-forward** — a job may name an ``after`` predecessor
  (the previous chunk of the same bucket): it may not *start phase p*
  before the predecessor has *finished its phase p*.  Chunks of one fused
  bucket thereby pipeline through the link levels (chunk 1's intra-host
  leg under chunk 0's inter-host leg) without ever overtaking each other —
  the CoCoNet-style dependency-ordered chunk schedule.  Per-chunk phase
  coefficients (:func:`repro.cluster.collectives.chunk_phases`) sum exactly
  to the unchunked ones, so chunking conserves channel work and wins only
  by scheduling.
* **Per-level discipline** — each level serves its contenders either
  **fair-share** (``k`` active phases progress at rate ``1/k`` each; the
  PR-3 fluid model, still the default and bit-identical to it) or **FIFO**
  (one phase at a time, arrival order, full rate).  ``discipline`` is a
  single mode or a ``{level_index: mode}`` mapping.
* ``streams`` bounds how many **distinct DP buckets** are in flight
  (NCCL-channel style); chunks of one bucket share their bucket's slot and
  TP/PP background traffic bypasses the bound (it is not issued by the
  gradient hook).  ``streams=1`` with dependency-free jobs is the
  **serialized channel**, bit-identical to the seed's ``_comm_pass``.

Timeline records are 8-tuples
``(kind, bucket, chunk, traffic_class, algo, level, start, end)``
(``--timeline`` output; see DESIGN.md Sec. 9 for the field semantics).
``record_load=True`` additionally keeps per-level utilisation segments
``(level, t0, t1, work_seconds)`` so tests can assert no oversubscription
from observed progress, not from the prescribed shares.  After ``run()``
the engine exposes ``job_finish`` (jid -> finish time) and per-class
``class_busy`` / ``class_finish`` tallies so callers can gate on gradient
traffic alone while background traffic keeps contending.
"""
from __future__ import annotations

import dataclasses
import math

from ..cluster import ClusterSpec
from ..cluster.collectives import (KIND_AR, KIND_RS_AG, chunk_phases,
                                   comm_coeffs)

# traffic classes a job can belong to
TC_DP = "dp"    # data-parallel gradient bucket (the searched dimension)
TC_TP = "tp"    # tensor-parallel activation collective
TC_PP = "pp"    # pipeline-parallel stage-boundary transfer
TRAFFIC_CLASSES = (TC_DP, TC_TP, TC_PP)

# per-level service disciplines
DISC_FAIR = "fair"
DISC_FIFO = "fifo"
DISCIPLINES = (DISC_FAIR, DISC_FIFO)


@dataclasses.dataclass(frozen=True)
class CommJob:
    """One collective transfer: ready time, volume, how to run it, and its
    position in the dependency structure.

    ``job_id`` defaults to ``bucket`` (the PR-3 identity); chunk jobs and
    background jobs need explicit distinct ids.  ``deps`` are job-ids that
    must have *finished all phases* before this job may start.  ``after``
    is the store-and-forward predecessor: this job may not start its phase
    ``p`` before ``after`` has completed its phase ``p`` (chunks of one
    bucket share a phase sequence, so positions align)."""
    bucket: int
    ready: float
    nbytes: float
    algo: str = "ring"
    kind: str = KIND_AR
    job_id: int | None = None
    deps: tuple[int, ...] = ()
    after: int | None = None
    chunk: int = 0
    chunks: int = 1
    traffic_class: str = TC_DP

    @property
    def jid(self) -> int:
        return self.bucket if self.job_id is None else self.job_id


@dataclasses.dataclass(frozen=True)
class BackgroundTraffic:
    """A recurring non-gradient collective: one TP activation AllReduce or
    PP boundary transfer issued every ``period`` seconds starting at
    ``offset``.  ``materialize`` expands it into concrete :class:`CommJob`s
    over a horizon (the iteration's compute span)."""
    traffic_class: str
    nbytes: float
    period: float
    algo: str = "ring"
    kind: str = KIND_AR
    offset: float = 0.0
    count: int | None = None

    # safety cap: a mis-sized period cannot explode the event loop
    MAX_JOBS = 512

    def materialize(self, horizon: float, base_id: int) -> list[CommJob]:
        if self.nbytes <= 0.0:
            return []
        if self.count is not None:
            n = int(self.count)
        elif self.period > 0.0:
            n = int(math.ceil(max(horizon - self.offset, 0.0) / self.period))
        else:
            n = 1
        n = max(min(n, self.MAX_JOBS), 0)
        return [
            CommJob(bucket=-1 - k, ready=self.offset + k * self.period,
                    nbytes=self.nbytes, algo=self.algo, kind=self.kind,
                    job_id=base_id + k, traffic_class=self.traffic_class)
            for k in range(n)
        ]


class _Active:
    """A job in flight: its phase worklist and current-phase progress."""
    __slots__ = ("bucket", "algo", "steps", "idx", "level", "kind",
                 "remaining", "work", "phase_start", "jid", "after",
                 "chunk", "tclass", "order", "started")

    def __init__(self, job: CommJob, steps: list[tuple[str, int, float]],
                 order: int):
        self.bucket = job.bucket
        self.algo = job.algo
        self.steps = steps     # [(phase_kind, level, work_seconds), ...]
        self.idx = -1
        self.jid = job.jid
        self.after = job.after
        self.chunk = job.chunk
        self.tclass = job.traffic_class
        self.order = order     # admission order (FIFO tie-break)

    def advance(self, now: float) -> bool:
        """Move to the next non-empty phase; False when the job is done."""
        while True:
            self.idx += 1
            if self.idx >= len(self.steps):
                return False
            kind, level, work = self.steps[self.idx]
            if work > 0.0:
                self.kind = kind
                self.level = level
                self.work = work
                self.remaining = work
                # queue-entry time; re-stamped at first service so FIFO-
                # queued / after-blocked waits are not reported as occupancy
                self.phase_start = now
                self.started = False
                return True


def bucket_jobs(bucket: int, ready: float, nbytes: float, algo: str,
                kind: str, chunks: int,
                next_id: int) -> tuple[list[CommJob], int]:
    """The canonical job decomposition of one gradient bucket: a single
    job when ``chunks <= 1``, else ``chunks`` store-and-forward chunk jobs
    (each ``nbytes/chunks``, ``after``-chained, ids allocated from
    ``next_id``).  Shared by the simulator's comm pass and
    ``repro.plan.Plan.comm_jobs`` so plan pricing can never drift from
    search pricing.  Returns ``(jobs, next_id)``."""
    if chunks <= 1:
        return [CommJob(bucket=bucket, ready=ready, nbytes=nbytes,
                        algo=algo, kind=kind)], next_id
    jobs = []
    prev = None
    for c in range(chunks):
        jobs.append(CommJob(bucket=bucket, ready=ready,
                            nbytes=nbytes / chunks, algo=algo, kind=kind,
                            job_id=next_id, after=prev, chunk=c,
                            chunks=chunks))
        prev = next_id
        next_id += 1
    return jobs, next_id


class CommEngine:
    """Schedules one iteration's communication jobs on the link levels of a
    :class:`ClusterSpec`; returns ``(busy_seconds, finish_time)``."""

    def __init__(self, spec: ClusterSpec, streams: int = 1,
                 record_load: bool = False,
                 discipline: str | dict[int, str] = DISC_FAIR):
        self.spec = spec
        self.streams = max(int(streams), 1)
        self.record_load = record_load
        if isinstance(discipline, str):
            if discipline not in DISCIPLINES:
                raise ValueError(f"unknown discipline {discipline!r}; "
                                 f"expected one of {DISCIPLINES}")
            self._disc = [discipline] * len(spec.levels)
        else:
            self._disc = [DISC_FAIR] * len(spec.levels)
            for lvl, d in discipline.items():
                if d not in DISCIPLINES:
                    raise ValueError(f"unknown discipline {d!r}; "
                                     f"expected one of {DISCIPLINES}")
                if not 0 <= lvl < len(spec.levels):
                    raise ValueError(
                        f"discipline level {lvl} out of range for "
                        f"{len(spec.levels)}-level spec {spec.name!r}")
                self._disc[lvl] = d
        self.level_load: list[tuple[int, float, float, float]] = []
        self.job_finish: dict[int, float] = {}
        self.class_busy: dict[str, float] = {}
        self.class_finish: dict[str, float] = {}
        self._coeffs: dict[tuple[str, str], tuple[float, float]] = {}
        self._steps: dict[tuple[str, str, int], tuple] = {}
        self._chan_level = spec.levels[spec.bottleneck_index()].name

    # ------------------------------------------------------------- helpers
    def _job_coeffs(self, algo: str, kind: str) -> tuple[float, float]:
        key = (algo, kind)
        cd = self._coeffs.get(key)
        if cd is None:
            cd = comm_coeffs(self.spec, algo, kind)
            self._coeffs[key] = cd
        return cd

    def _job_steps(self, job: CommJob) -> list[tuple[str, int, float]]:
        key = (job.algo, job.kind, job.chunks)
        ph = self._steps.get(key)
        if ph is None:
            ph = chunk_phases(self.spec, job.algo, job.kind, job.chunks)
            self._steps[key] = ph
        return [(p.kind, p.level, p.c * job.nbytes + p.d) for p in ph]

    def _account(self, tclass: str, work: float) -> None:
        self.class_busy[tclass] = self.class_busy.get(tclass, 0.0) + work

    def _finish_job(self, jid: int, tclass: str, t: float) -> None:
        self.job_finish[jid] = t
        if t > self.class_finish.get(tclass, 0.0):
            self.class_finish[tclass] = t

    # ----------------------------------------------------------------- run
    def run(self, jobs: list[CommJob],
            timeline: list | None = None) -> tuple[float, float]:
        # each run is an independent schedule starting at t=0: utilisation
        # segments and per-job/per-class tallies must not accumulate
        self.level_load = []
        self.job_finish = {}
        self.class_busy = {}
        self.class_finish = {}
        # zero-byte jobs transfer nothing: free, and they satisfy deps
        # immediately (a dep on an empty chunk must not deadlock the chain)
        for job in jobs:
            if job.nbytes <= 0.0:
                self._finish_job(job.jid, job.traffic_class, 0.0)
        if self.streams == 1:
            return self._run_serialized(jobs, timeline)
        return self._run_phased(jobs, timeline)

    # ------------------------------------------------------ serialized path
    def _run_serialized(self, jobs: list[CommJob],
                        timeline: list | None) -> tuple[float, float]:
        # the seed's comm pass: buckets transfer in order of readiness
        # (ties by index), serialized on one channel.  Arithmetic must stay
        # bit-identical: one c*x + d per job, start = max(chan_free, ready).
        if any(j.deps or j.after is not None for j in jobs):
            return self._run_serialized_deps(jobs, timeline)
        chan_free = 0.0
        busy = 0.0
        finish = 0.0
        for job in sorted(jobs, key=lambda j: (j.ready, j.bucket, j.chunk)):
            if job.nbytes <= 0.0:
                continue  # nothing to transfer: no latency D charged
            t = self._opaque_interval(job)
            start = max(chan_free, job.ready)
            chan_free = start + t
            busy += t
            finish = chan_free
            self._account(job.traffic_class, t)
            self._finish_job(job.jid, job.traffic_class, chan_free)
            if timeline is not None:
                kind = "allreduce" if job.kind == KIND_AR else job.kind
                timeline.append((kind, job.bucket, job.chunk,
                                 job.traffic_class, job.algo,
                                 self._chan_level, start, chan_free))
        return busy, finish

    def _opaque_interval(self, job: CommJob) -> float:
        """Serialized (single-channel) cost of one job: ``c*x + d`` with
        the phase latency split across the bucket's chunks (``d / 1 == d``
        bit-exactly, so unchunked jobs keep the seed arithmetic)."""
        c, d = self._job_coeffs(job.algo, job.kind)
        return c * job.nbytes + d / max(job.chunks, 1)

    def _run_serialized_deps(self, jobs: list[CommJob],
                             timeline: list | None) -> tuple[float, float]:
        """Serialized channel with finish-first ordering: the next job run
        is the earliest-(ready, bucket, chunk) job whose ``deps`` (and
        ``after`` predecessor — on one channel store-and-forward degenerates
        to whole-job ordering) have all finished."""
        ids = {j.jid for j in jobs}
        pending = sorted((j for j in jobs if j.nbytes > 0.0),
                         key=lambda j: (j.ready, j.bucket, j.chunk))
        chan_free = 0.0
        busy = 0.0
        finish = 0.0
        while pending:
            picked = None
            for i, job in enumerate(pending):
                need = list(job.deps)
                if job.after is not None:
                    need.append(job.after)
                if all(d not in ids or d in self.job_finish for d in need):
                    picked = i
                    break
            if picked is None:
                raise RuntimeError("dependency cycle in comm jobs")
            job = pending.pop(picked)
            t = self._opaque_interval(job)
            dep_ready = max((self.job_finish[x] for x in job.deps
                             if x in ids), default=0.0)
            if job.after is not None and job.after in ids:
                dep_ready = max(dep_ready, self.job_finish[job.after])
            start = max(chan_free, job.ready, dep_ready)
            chan_free = start + t
            busy += t
            finish = max(finish, chan_free)
            self._account(job.traffic_class, t)
            self._finish_job(job.jid, job.traffic_class, chan_free)
            if timeline is not None:
                kind = "allreduce" if job.kind == KIND_AR else job.kind
                timeline.append((kind, job.bucket, job.chunk,
                                 job.traffic_class, job.algo,
                                 self._chan_level, start, chan_free))
        return busy, finish

    # ---------------------------------------------------------- phased path
    def _runnable(self, a: _Active, by_id: dict[int, "_Active"],
                  ids: set[int]) -> bool:
        """Store-and-forward gate: a chunk may run its phase ``idx`` only
        once its ``after`` predecessor has completed that phase."""
        if a.after is None or a.after not in ids:
            return True
        if a.after in self.job_finish:
            return True
        pred = by_id.get(a.after)
        # a predecessor still waiting in the pending queue blocks the chain
        return pred is not None and pred.idx > a.idx

    def _run_phased(self, jobs: list[CommJob],
                    timeline: list | None) -> tuple[float, float]:
        ids = {j.jid for j in jobs}
        pending = sorted((j for j in jobs if j.nbytes > 0.0),
                         key=lambda j: (j.ready, j.bucket, j.chunk))
        active: list[_Active] = []
        by_id: dict[int, _Active] = {}
        # slot accounting: distinct DP buckets in flight (chunks share their
        # bucket's slot; TP/PP background traffic bypasses the bound)
        inflight: dict[int, int] = {}
        t = 0.0
        busy = 0.0
        finish = 0.0
        order = 0
        names = [l.name for l in self.spec.levels]
        disc = self._disc
        while pending or active:
            # ---- admission: ready, deps finished, slot available
            i = 0
            while i < len(pending):
                job = pending[i]
                if job.ready > t:
                    break
                if any(d in ids and d not in self.job_finish
                       for d in job.deps):
                    i += 1
                    continue
                if (job.traffic_class == TC_DP
                        and job.bucket not in inflight
                        and len(inflight) >= self.streams):
                    i += 1
                    continue
                del pending[i]
                a = _Active(job, self._job_steps(job), order)
                order += 1
                if a.advance(t):
                    active.append(a)
                    by_id[a.jid] = a
                    if job.traffic_class == TC_DP:
                        inflight[job.bucket] = inflight.get(job.bucket, 0) + 1
                else:
                    finish = max(finish, t)  # all-empty phase list
                    self._finish_job(job.jid, job.traffic_class, t)
            if not active:
                if not pending:
                    break  # admission drained everything as zero-work jobs
                later = [j.ready for j in pending if j.ready > t]
                if not later:
                    raise RuntimeError("dependency cycle in comm jobs")
                t = min(later)
                continue
            runnable = [a for a in active if self._runnable(a, by_id, ids)]
            if not runnable:
                later = [j.ready for j in pending if j.ready > t]
                if not later:
                    raise RuntimeError("store-and-forward cycle in comm jobs")
                t = min(later)
                continue
            counts: dict[int, int] = {}
            for a in runnable:
                counts[a.level] = counts.get(a.level, 0) + 1
            # per-level discipline: fair-share divides a level's rate over
            # its contenders; FIFO serves them one at a time in admission /
            # phase-arrival order at full rate.  ``share`` is the divisor a
            # running phase's progress rate pays (None: not served now).
            share: dict[int, int] = {}
            heads: dict[int, _Active] = {}
            for a in runnable:
                if disc[a.level] == DISC_FAIR:
                    share[id(a)] = counts[a.level]
                else:
                    h = heads.get(a.level)
                    if h is None or (a.phase_start, a.order) < \
                            (h.phase_start, h.order):
                        heads[a.level] = a
            for a in heads.values():
                share[id(a)] = 1
            # next event: earliest phase completion under the current
            # rates, or the next admissible arrival
            dt = min(a.remaining * share[id(a)] for a in runnable
                     if id(a) in share)
            arrival = self._next_admissible_arrival(pending, inflight, t)
            if arrival is not None:
                dt = min(dt, arrival - t)
            dt = max(dt, 0.0)
            t1 = t + dt
            progressed: dict[int, float] = {}
            for a in runnable:
                s = share.get(id(a))
                if s is None:
                    continue
                if not a.started:
                    a.phase_start = t
                    a.started = True
                step = dt / s
                a.remaining -= step
                if self.record_load:
                    progressed[a.level] = progressed.get(a.level, 0.0) + step
            if self.record_load and dt > 0.0:
                # record the *observed* seconds of work each level advanced
                # during [t, t1] — the capacity test divides by the segment
                # span, so a rate bug cannot hide behind the prescription
                for lvl, w in progressed.items():
                    self.level_load.append((lvl, t, t1, w))
            t = t1
            still: list[_Active] = []
            for a in active:
                if a.remaining <= 1e-12 * a.work:
                    busy += a.work
                    self._account(a.tclass, a.work)
                    if timeline is not None:
                        timeline.append((a.kind, a.bucket, a.chunk,
                                         a.tclass, a.algo, names[a.level],
                                         a.phase_start, t))
                    if a.advance(t):
                        still.append(a)
                    else:
                        finish = max(finish, t)
                        del by_id[a.jid]
                        self._finish_job(a.jid, a.tclass, t)
                        if a.tclass == TC_DP:
                            inflight[a.bucket] -= 1
                            if not inflight[a.bucket]:
                                del inflight[a.bucket]
                else:
                    still.append(a)
            active = still
        return busy, finish

    def _next_admissible_arrival(self, pending: list[CommJob],
                                 inflight: dict[int, int],
                                 now: float) -> float | None:
        """Earliest *future* ready time among pending jobs that could be
        admitted when they arrive (slot free, or slot-exempt, given the
        current in-flight set).  Jobs already ready but held back by a
        dependency or a full slot table are not arrival events — their
        admission is retried at the finish event that unblocks them."""
        slot_free = len(inflight) < self.streams
        best = None
        for j in pending:
            if j.ready <= now:
                continue
            if (j.traffic_class == TC_DP and not slot_free
                    and j.bucket not in inflight):
                continue
            if best is None or j.ready < best:
                best = j.ready
        return best

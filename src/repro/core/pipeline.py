"""Pipeline-parallel stage schedules lowered to unified engine jobs
(DESIGN.md Sec. 11).

A :class:`PipelineSchedule` describes a Megatron-style 1F1B (or
interleaved-1F1B) schedule: ``n_stages`` devices, ``n_microbatches``
microbatches per iteration, optionally ``interleave`` virtual-stage chunks
per device.  :func:`lower_schedule` turns it into the job graph the
:class:`~repro.core.events.EventEngine` prices:

* one :class:`~repro.core.events.ComputeJob` per (stage, chunk,
  microbatch, fwd/bwd) unit, placed on compute stream ``s`` and
  dep-chained in the device's 1F1B issue order (warmup fwds, steady
  fwd/bwd pairs, cooldown bwds — warmup depth ``S-1-s``, or
  ``2*(S-1-s) + (v-1)*S`` interleaved);
* one :class:`~repro.core.events.CommJob` of kind ``p2p`` / class ``pp``
  per crossed stage boundary and microbatch (forward activations and
  backward activation-gradients), dep'd on the producing unit and feeding
  the consuming unit's deps — so stage-boundary transfers contend with
  gradient buckets on the shared link levels instead of being modeled as
  blind background noise.

The simulator derives the per-stage unit durations by bisecting its own
serialized single-device schedule into ``n_stages`` contiguous,
busy-balanced spans (``Simulator._run_pipeline``); this module is pure
schedule structure and stays import-light (no jax, loadable by the search
worker pool).

With uniform stage times ``f + b`` and free p2p, the lowered 1F1B
schedule's makespan is the textbook ``(M + S - 1) * (f + b)`` and its
bubble fraction ``(S - 1) / (M + S - 1)`` — asserted by the property
tests.  Compute units display as ``ref = microbatch * REF_MB + chunk``
(the stage is the stream name in the timeline record).
"""
from __future__ import annotations

import dataclasses

from ..cluster.collectives import KIND_P2P
from .events import CommJob, ComputeJob, TC_PP

SCHED_1F1B = "1f1b"
SCHED_INTERLEAVED = "interleaved_1f1b"
SCHEDULES = (SCHED_1F1B, SCHED_INTERLEAVED)

# compute-unit display encoding: ref = microbatch * REF_MB + chunk
REF_MB = 1000


@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    """The searched-over PP knobs: stage count, microbatch count, schedule
    family, interleaving depth, and the fwd share of a stage's time.
    ``p2p_bytes`` overrides the simulator's activation-size estimate for
    stage-boundary transfers (bytes per boundary per microbatch)."""
    n_stages: int
    n_microbatches: int
    schedule: str = SCHED_1F1B
    interleave: int = 1
    fwd_bwd_ratio: float = 0.5     # fwd_time / bwd_time
    p2p_bytes: float | None = None

    def __post_init__(self):
        if self.n_stages < 1:
            raise ValueError(f"n_stages must be >= 1, got {self.n_stages}")
        if self.n_microbatches < 1:
            raise ValueError(
                f"n_microbatches must be >= 1, got {self.n_microbatches}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"expected one of {SCHEDULES}")
        if self.interleave < 1:
            raise ValueError(
                f"interleave must be >= 1, got {self.interleave}")
        if not 0.0 < self.fwd_bwd_ratio:
            raise ValueError("fwd_bwd_ratio must be positive")
        if (self.chunks_per_stage > 1
                and self.n_microbatches % self.n_stages != 0):
            # Megatron's interleaved schedule requires microbatch groups of
            # exactly n_stages to keep the chunk rotation aligned
            raise ValueError("interleaved 1F1B needs n_microbatches divisible"
                             " by n_stages")

    @property
    def chunks_per_stage(self) -> int:
        return self.interleave if self.schedule == SCHED_INTERLEAVED else 1

    # ------------------------------------------------- plan serialization
    def to_tuple(self) -> tuple:
        return (self.n_stages, self.n_microbatches, self.schedule,
                self.interleave, self.fwd_bwd_ratio, self.p2p_bytes)

    @staticmethod
    def from_tuple(t) -> "PipelineSchedule":
        n_stages, n_microbatches, schedule, interleave, ratio, p2p = t
        return PipelineSchedule(
            n_stages=int(n_stages), n_microbatches=int(n_microbatches),
            schedule=str(schedule), interleave=int(interleave),
            fwd_bwd_ratio=float(ratio),
            p2p_bytes=None if p2p is None else float(p2p))


def resolve_schedule(base: PipelineSchedule | None, knobs,
                     n_groups: int) -> PipelineSchedule | None:
    """Apply a graph's searched pipeline-knob overrides onto the
    simulator's base schedule (DESIGN.md Sec. 14).

    ``knobs`` is :attr:`FusionGraph.pp_knobs` — ``None`` or a partial
    ``(n_stages, n_microbatches, interleave)`` tuple whose ``None`` slots
    inherit from ``base``.  Resolution is *total*: rather than rejecting
    invalid combinations mid-search it clamps them to the nearest valid
    schedule —

    * ``n_stages`` is clamped to ``[1, n_groups]`` (the stage bisection
      needs at least one fused group per stage);
    * ``interleave > 1`` requires ``n_microbatches`` divisible by
      ``n_stages`` (Megatron's chunk rotation); otherwise the interleave
      override collapses to 1;
    * the schedule family follows the interleave: ``interleaved_1f1b``
      iff the resolved interleave exceeds 1.

    ``fwd_bwd_ratio`` and ``p2p_bytes`` always come from ``base`` — they
    are measurements, not searched knobs.  With ``knobs=None`` the base is
    returned untouched (bit-identity for every pre-existing caller)."""
    if base is None or not knobs:
        return base
    S, M, v = knobs
    S = base.n_stages if S is None else int(S)
    M = base.n_microbatches if M is None else int(M)
    v = base.chunks_per_stage if v is None else int(v)
    S = max(1, min(S, int(n_groups))) if n_groups >= 1 else 1
    M = max(1, M)
    v = max(1, v)
    if v > 1 and M % S != 0:
        v = 1
    schedule = SCHED_INTERLEAVED if v > 1 else SCHED_1F1B
    if (S == base.n_stages and M == base.n_microbatches
            and v == base.chunks_per_stage and schedule == base.schedule):
        return base
    return dataclasses.replace(base, n_stages=S, n_microbatches=M,
                               schedule=schedule, interleave=v)


def _unit_sequence(sched: PipelineSchedule, s: int):
    """Device ``s``'s issue order as ``(kind, unit_index)`` pairs, kind in
    {"f", "b"}: warmup forwards, steady one-fwd-one-bwd pairs, cooldown
    backwards.  Unit indices count each kind separately, 0..M*v-1."""
    S, M, v = sched.n_stages, sched.n_microbatches, sched.chunks_per_stage
    total = M * v
    if v == 1:
        w = min(S - 1 - s, total)
    else:
        w = min((S - 1 - s) * 2 + (v - 1) * S, total)
    seq = [("f", k) for k in range(w)]
    for k in range(total - w):
        seq.append(("f", w + k))
        seq.append(("b", k))
    for k in range(total - w, total):
        seq.append(("b", k))
    return seq


def _unit_chunk_mb(sched: PipelineSchedule, kind: str,
                   k: int) -> tuple[int, int]:
    """Map device-local unit index ``k`` to (chunk, microbatch).  v == 1 is
    the identity; interleaved rotates through the device's chunks in
    microbatch groups of ``S`` (Megatron), backwards in reverse chunk
    order."""
    S, v = sched.n_stages, sched.chunks_per_stage
    if v == 1:
        return 0, k
    c = (k // S) % v
    if kind == "b":
        c = v - 1 - c
    mb = (k // (S * v)) * S + k % S
    return c, mb


def lower_schedule(sched: PipelineSchedule, stage_fwd: list[float],
                   stage_bwd: list[float], p2p_bytes: float, *,
                   next_id: int = 0):
    """Lower a schedule to engine jobs.

    ``stage_fwd`` / ``stage_bwd``: per-stage whole-stage durations per
    microbatch (split across ``interleave`` chunks).  ``p2p_bytes``: bytes
    per stage-boundary transfer per microbatch.  ``next_id`` allocates the
    (non-negative) p2p comm job ids; compute job ids are negative.

    Returns ``(compute_jobs, p2p_jobs, last_bwd, next_id)`` where
    ``last_bwd[s]`` is the job id of stage ``s``'s final backward unit —
    the point its gradient accumulation completes, which DP bucket jobs
    dep on."""
    S, M, v = sched.n_stages, sched.n_microbatches, sched.chunks_per_stage
    unit_f = [stage_fwd[s] / v for s in range(S)]
    unit_b = [stage_bwd[s] / v for s in range(S)]

    # pass 1: allocate unit job ids in each device's issue order, chained
    # so every stream is serialized
    jid_of: dict[tuple, int] = {}       # (kind, stage, chunk, mb) -> jid
    units: list[dict] = []
    last_bwd = [0] * S
    n = 0
    for s in range(S):
        prev = None
        for kind, k in _unit_sequence(sched, s):
            c, mb = _unit_chunk_mb(sched, kind, k)
            jid = ~n
            n += 1
            jid_of[(kind, s, c, mb)] = jid
            units.append({
                "jid": jid, "kind": kind, "stage": s, "chunk": c, "mb": mb,
                "key": n, "deps": [] if prev is None else [prev],
            })
            prev = jid
            if kind == "b":
                last_bwd[s] = jid

    # pass 2: cross virtual-stage deps — p2p transfers between devices,
    # direct deps within one (S == 1 degenerates to chunk chaining)
    V = S * v
    p2p: list[CommJob] = []

    def cross(src_key, dst_key, boundary):
        nonlocal next_id
        src = jid_of[src_key]
        dst = jid_of[dst_key]
        # same device — or a free transfer: a zero-byte comm job would be
        # pre-finished at t=0 by the engine and sever the chain, so free
        # p2p becomes a direct (instantaneous) dependency instead
        if src_key[1] == dst_key[1] or p2p_bytes <= 0.0:
            _unit(dst)["deps"].append(src)
            return
        job = CommJob(bucket=boundary, ready=0.0, nbytes=p2p_bytes,
                      algo="ring", kind=KIND_P2P, job_id=next_id,
                      deps=(src,), traffic_class=TC_PP)
        next_id += 1
        p2p.append(job)
        _unit(dst)["deps"].append(job.job_id)

    by_jid = {u["jid"]: u for u in units}

    def _unit(jid):
        return by_jid[jid]

    for vs in range(V - 1):
        src_s, src_c = vs % S, vs // S
        dst_s, dst_c = (vs + 1) % S, (vs + 1) // S
        for mb in range(M):
            # forward activations flow up the virtual-stage chain
            cross(("f", src_s, src_c, mb), ("f", dst_s, dst_c, mb), vs)
            # backward activation-gradients flow down it
            cross(("b", dst_s, dst_c, mb), ("b", src_s, src_c, mb), V - 1 + vs)
    # loss turnaround: the top virtual stage's backward needs its forward
    top_s, top_c = (V - 1) % S, (V - 1) // S
    for mb in range(M):
        _unit(jid_of[("b", top_s, top_c, mb)])["deps"].append(
            jid_of[("f", top_s, top_c, mb)])

    compute = [
        ComputeJob(ref=u["mb"] * REF_MB + u["chunk"],
                   duration=(unit_f if u["kind"] == "f" else unit_b)[u["stage"]],
                   job_id=u["jid"], stream=u["stage"], key=u["key"],
                   deps=tuple(u["deps"]),
                   kind="fwd" if u["kind"] == "f" else "bwd")
        for u in units
    ]
    return compute, p2p, last_bwd, next_id


def bubble_stats(sched: PipelineSchedule, stage_busy: list[float],
                 makespan: float) -> dict:
    """Per-stage idle (bubble) time within the compute makespan and the
    aggregate bubble fraction ``1 - sum(busy) / (S * makespan)``."""
    S = sched.n_stages
    bubbles = [max(makespan - b, 0.0) for b in stage_busy]
    denom = S * makespan
    frac = (sum(bubbles) / denom) if denom > 0.0 else 0.0
    return {"per_stage_s": bubbles, "fraction": frac}

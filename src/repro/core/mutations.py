"""Declarative mutation registry for the backtracking search.

The paper's three optimisation methods and the three extension dimensions
used to live as scattered ``METHOD_*`` string constants in
:mod:`repro.core.search` plus per-simulator drop rules hard-coded inside
``backtracking_search``.  This module makes each searched dimension a
first-class :class:`Mutation` — a name, a single random application, and an
``applicable(sim)`` predicate saying on which simulator configurations the
dimension can matter — registered in one place (``MUTATIONS``).  New
searched dimensions register here and the search, the Plan artifact and the
docs all pick them up (DESIGN.md Sec. 10).

Applicability encodes the pricing-model facts that used to be drop rules:

* ``algo`` — the flat back-compat spec is algorithm-blind (every collective
  model degenerates to the legacy formula), so algorithm flips can never
  improve on it; sims exposing no cluster at all are treated the same so
  their trajectories match the flat default.
* ``comm`` / ``chunk`` — on a serialized channel the ZeRO-3 RS+AG split
  prices identically to the fused AllReduce (RS + AG == AR term by term)
  and chunking conserves total channel work exactly, so both only matter
  once the event engine can pipeline phases (``streams > 1``).

The per-application bodies reproduce the seed ``random_apply`` draws
verbatim, so search trajectories (which are RNG-stream-identical by
construction) are unchanged by the refactor.

Import-light on purpose (no jax): the search worker pool and the Plan
artifact load this from bare interpreters.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Sequence

from ..cluster import BUCKET_COMM_KINDS, COLLECTIVE_ALGOS
from .graph import FusionGraph

METHOD_NONDUP = "nondup"
METHOD_DUP = "dup"
METHOD_TENSOR = "tensor"
METHOD_ALGO = "algo"
METHOD_COMM = "comm"
METHOD_CHUNK = "chunk"
METHOD_FUSED = "fused"
METHOD_PP_SPLIT = "pp_split"
METHOD_PP_MICROBATCH = "pp_microbatch"
METHOD_PP_INTERLEAVE = "pp_interleave"

# store-and-forward chunk counts METHOD_CHUNK draws from (1 restores the
# whole-bucket collective; powers of two mirror NCCL's chunk granularity)
CHUNK_CHOICES = (1, 2, 4, 8)

# pipeline-knob draws (DESIGN.md Sec. 14).  Overrides are resolved against
# the simulator's base PipelineSchedule at pricing time
# (repro.core.pipeline.resolve_schedule), which clamps n_stages to the
# graph's group count and collapses interleave where the Megatron
# divisibility constraint fails — so every draw is a valid candidate.
PP_SPLIT_CHOICES = (1, 2, 4, 8)
PP_MICROBATCH_CHOICES = (4, 8, 16, 32)
PP_INTERLEAVE_CHOICES = (1, 2)

# serving-plan knob draws (DESIGN.md Sec. 15).  These mutate a
# ``repro.serving.plan.ServingState`` (duck-typed through the same
# clone()/fast_signature() protocol as FusionGraph) and are applicable
# only on ``is_serving`` simulators — training sims never see them, so
# every PR 1-9 trajectory and cache key stays bit-identical.
METHOD_SERVE_SLOTS = "serve_slots"
METHOD_SERVE_BATCH = "serve_batch"
METHOD_SERVE_KV = "serve_kv"
METHOD_SERVE_ALGO = "serve_algo"
METHOD_SERVE_STREAMS = "serve_streams"

SERVE_SLOT_CHOICES = (4, 8, 16, 32, 64)
SERVE_BATCH_CHOICES = (1, 2, 4, 8, 16, 32, 64)
SERVE_KV_LAYOUTS = ("replicated", "head", "sequence")
SERVE_STREAM_CHOICES = (1, 2)

# the explicit method tuple compile_serving() passes: the training
# mutations' applies would crash on a ServingState (their applicability
# defaults to True), so serving searches never use methods=None
SERVING_METHODS = (METHOD_SERVE_SLOTS, METHOD_SERVE_BATCH, METHOD_SERVE_KV,
                   METHOD_SERVE_ALGO, METHOD_SERVE_STREAMS)


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One searched dimension: ``apply(g, rng)`` performs a single random
    application (mutating ``g``, returning True iff the graph changed);
    ``applicable(sim)`` says whether the dimension can improve candidates
    priced by ``sim`` (inapplicable mutations are dropped by the search
    instead of burning candidate evaluations)."""
    name: str
    apply: Callable[[FusionGraph, random.Random], bool]
    applicable: Callable[[object], bool] = lambda sim: True
    doc: str = ""


# ------------------------------------------------------------ applicability
def _cluster_of(sim) -> object | None:
    return getattr(sim, "cluster", None)


def _algo_applicable(sim) -> bool:
    cluster = _cluster_of(sim)
    return cluster is not None and not cluster.is_flat_compat


def _engine_applicable(sim) -> bool:
    return _algo_applicable(sim) and getattr(sim, "streams", 1) > 1


def _fused_applicable(sim) -> bool:
    # in-kernel fusion only matters where the engine can price the early
    # comm start (multi-stream) AND the cluster has a calibrated overlap
    # discount — an undiscounted fused bucket prices exactly as its base
    # kind, so searching the flag would burn candidate evaluations
    return (_engine_applicable(sim)
            and getattr(sim, "overlap_discount", 0.0) > 0.0)


def _pp_applicable(sim) -> bool:
    # pipeline knobs only price on a pipeline-enabled sim: everywhere else
    # pp_knobs is inert graph state, so offering the mutations would burn
    # candidate evaluations — and, worse, change legacy RNG streams.  The
    # registry gate is what keeps PR 1-8 trajectories bit-identical.
    return getattr(sim, "pipeline", None) is not None


# ------------------------------------------------------------- applications
def _apply_fuse(method: str):
    def apply(g: FusionGraph, rng: random.Random) -> bool:
        gids = list(g.groups)
        # a handful of attempts to find a valid (consumer, producer) pair
        for _attempt in range(4):
            c = rng.choice(gids)
            preds = list(g.group_preds(c))
            if not preds:
                continue
            p = rng.choice(preds)
            ok = g.fuse_nondup(c, p) if method == METHOD_NONDUP \
                else g.fuse_dup(c, p)
            if ok:
                return True
        return False

    return apply


def _apply_tensor(g: FusionGraph, rng: random.Random) -> bool:
    if len(g.buckets) < 2:
        return False
    i = rng.randrange(len(g.buckets) - 1)
    return g.merge_buckets(i, i + 1)


def _apply_algo(g: FusionGraph, rng: random.Random) -> bool:
    if not g.buckets:
        return False
    i = rng.randrange(len(g.buckets))
    return g.set_bucket_algo(i, rng.choice(COLLECTIVE_ALGOS))


def _apply_comm(g: FusionGraph, rng: random.Random) -> bool:
    if not g.buckets:
        return False
    i = rng.randrange(len(g.buckets))
    return g.set_bucket_comm(i, rng.choice(BUCKET_COMM_KINDS))


def _apply_chunk(g: FusionGraph, rng: random.Random) -> bool:
    if not g.buckets:
        return False
    i = rng.randrange(len(g.buckets))
    return g.set_bucket_chunks(i, rng.choice(CHUNK_CHOICES))


def _apply_fused(g: FusionGraph, rng: random.Random) -> bool:
    if not g.buckets:
        return False
    i = rng.randrange(len(g.buckets))
    return g.set_bucket_fused(i, rng.choice((False, True)))


def _apply_pp_split(g: FusionGraph, rng: random.Random) -> bool:
    return g.set_pp_knobs(n_stages=rng.choice(PP_SPLIT_CHOICES))


def _apply_pp_microbatch(g: FusionGraph, rng: random.Random) -> bool:
    return g.set_pp_knobs(n_microbatches=rng.choice(PP_MICROBATCH_CHOICES))


def _apply_pp_interleave(g: FusionGraph, rng: random.Random) -> bool:
    return g.set_pp_knobs(interleave=rng.choice(PP_INTERLEAVE_CHOICES))


def _serving_applicable(sim) -> bool:
    # serving knobs only exist on a ServingState priced by a
    # ServingSimulator; everywhere else offering them would crash the
    # apply (FusionGraph has no set_slots) and change legacy RNG streams
    return bool(getattr(sim, "is_serving", False))


def _apply_serve_slots(g, rng: random.Random) -> bool:
    return g.set_slots(rng.choice(SERVE_SLOT_CHOICES))


def _apply_serve_batch(g, rng: random.Random) -> bool:
    return g.set_decode_batch(rng.choice(SERVE_BATCH_CHOICES))


def _apply_serve_kv(g, rng: random.Random) -> bool:
    return g.set_kv_layout(rng.choice(SERVE_KV_LAYOUTS))


def _apply_serve_algo(g, rng: random.Random) -> bool:
    return g.set_algo(rng.choice(COLLECTIVE_ALGOS))


def _apply_serve_streams(g, rng: random.Random) -> bool:
    return g.set_streams(rng.choice(SERVE_STREAM_CHOICES))


# ------------------------------------------------------------------ registry
MUTATIONS: dict[str, Mutation] = {}


def register_mutation(m: Mutation, *, replace: bool = False) -> Mutation:
    """Register a searched dimension.  ``replace=True`` overrides an
    existing registration (tests / experimental estimator-specific drop
    rules); otherwise duplicate names are an error."""
    if not replace and m.name in MUTATIONS:
        raise ValueError(f"mutation {m.name!r} is already registered")
    MUTATIONS[m.name] = m
    return m


register_mutation(Mutation(
    METHOD_NONDUP, _apply_fuse(METHOD_NONDUP),
    doc="paper method (i): merge a producer group into a consumer group"))
register_mutation(Mutation(
    METHOD_DUP, _apply_fuse(METHOD_DUP),
    doc="paper method (ii): duplicate a producer group into a consumer"))
register_mutation(Mutation(
    METHOD_TENSOR, _apply_tensor,
    doc="paper method (iii): merge two neighbouring AllReduce buckets"))
register_mutation(Mutation(
    METHOD_ALGO, _apply_algo, _algo_applicable,
    doc="cluster method (iv): per-bucket collective algorithm "
        "(ring/tree/hier; flat specs are algorithm-blind)"))
register_mutation(Mutation(
    METHOD_COMM, _apply_comm, _engine_applicable,
    doc="event-engine method (v): fused AllReduce vs ZeRO-3 RS+AG "
        "(identical pricing on a serialized channel)"))
register_mutation(Mutation(
    METHOD_CHUNK, _apply_chunk, _engine_applicable,
    doc="event-engine method (vi): store-and-forward chunk count "
        "(pure scheduling; needs a multi-stream engine to matter)"))
register_mutation(Mutation(
    METHOD_FUSED, _apply_fused, _fused_applicable,
    doc="kernel method (vii): in-kernel fused compute+comm per bucket "
        "(CoCoNet-style; needs a multi-stream engine and a calibrated "
        "overlap discount)"))
register_mutation(Mutation(
    METHOD_PP_SPLIT, _apply_pp_split, _pp_applicable,
    doc="pipeline method (viii): searched stage count override "
        "(needs a pipeline-enabled sim; clamped to the group count)"))
register_mutation(Mutation(
    METHOD_PP_MICROBATCH, _apply_pp_microbatch, _pp_applicable,
    doc="pipeline method (ix): searched microbatch count override "
        "(needs a pipeline-enabled sim)"))
register_mutation(Mutation(
    METHOD_PP_INTERLEAVE, _apply_pp_interleave, _pp_applicable,
    doc="pipeline method (x): searched interleaved-1F1B chunk depth "
        "(needs a pipeline-enabled sim; collapses to 1 where Megatron's "
        "divisibility constraint fails)"))
register_mutation(Mutation(
    METHOD_SERVE_SLOTS, _apply_serve_slots, _serving_applicable,
    doc="serving method (xi): decode slot count (KV memory vs occupancy)"))
register_mutation(Mutation(
    METHOD_SERVE_BATCH, _apply_serve_batch, _serving_applicable,
    doc="serving method (xii): decode dispatch batch (weight-stream "
        "amortization vs padding waste and per-token TP payload)"))
register_mutation(Mutation(
    METHOD_SERVE_KV, _apply_serve_kv, _serving_applicable,
    doc="serving method (xiii): KV-shard layout "
        "(replicated / head / sequence)"))
register_mutation(Mutation(
    METHOD_SERVE_ALGO, _apply_serve_algo, _serving_applicable,
    doc="serving method (xiv): decode-collective algorithm "
        "(ring/tree/hier)"))
register_mutation(Mutation(
    METHOD_SERVE_STREAMS, _apply_serve_streams, _serving_applicable,
    doc="serving method (xv): prefill lane allocation (threaded into the "
        "decode chain vs a dedicated stream bought with HBM)"))

# METHOD_FUSED (and the pp_* methods after it) are deliberately NOT in
# ALL_METHODS: this tuple keys the
# RNG streams of seed-era benchmarks/tests (perf_search.py throughput,
# trajectory-identity assertions), so it is frozen — ``active_methods``
# appends registered extras after it, which is how default searches pick
# the fused dimension up.
ALL_METHODS = (METHOD_NONDUP, METHOD_DUP, METHOD_TENSOR, METHOD_ALGO,
               METHOD_COMM, METHOD_CHUNK)


def get_mutation(name: str) -> Mutation:
    try:
        return MUTATIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown search method {name!r}; registered: "
            f"{', '.join(sorted(MUTATIONS))}") from None


def active_methods(sim, methods: Sequence[str] | None = None) -> tuple[str, ...]:
    """The subset of ``methods`` (default: every registered mutation, in
    ``ALL_METHODS``-first order) whose ``applicable(sim)`` holds — the
    single source of the search's per-simulator drop rules."""
    if methods is None:
        extra = tuple(n for n in MUTATIONS if n not in ALL_METHODS)
        methods = ALL_METHODS + extra
    return tuple(m for m in methods if get_mutation(m).applicable(sim))


def random_apply(g: FusionGraph, method: str, n: int,
                 rng: random.Random) -> bool:
    """Apply ``method`` up to n times with random operands (the paper's
    ``RandomApply``).  Mutates ``g``; returns True if at least one
    application changed the graph.  Draw-for-draw identical to the seed's
    inline dispatch."""
    apply = get_mutation(method).apply
    changed = False
    for _ in range(n):
        changed |= apply(g, rng)
    return changed

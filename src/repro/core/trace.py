"""jaxpr -> FusionGraph tracer.

Extracts the primitive-level DAG of a real JAX computation (the per-device
forward+backward of a training step), estimates per-primitive FLOPs/bytes
from avals, and attaches one AllReduce instruction per parameter-gradient
output — the input representation DisCo searches over.

``pjit`` / ``custom_vjp`` / ``remat`` sub-jaxprs are inlined so the graph is
flat (JAX groups the whole step into a single HLO module — paper Sec. 5).
``scan``/``while`` stay as single OPAQUE nodes with body-cost x trip-count.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import numpy as np
from jax.extend import core as jcore

from .graph import DOT, EW, FusionGraph, LAYOUT, OPAQUE, PrimOp, REDUCE

_EW_PRIMS = {
    "add", "sub", "mul", "div", "rem", "pow", "integer_pow", "neg", "sign",
    "abs", "exp", "exp2", "expm1", "log", "log1p", "tanh", "logistic", "erf",
    "erf_inv", "erfc", "rsqrt", "sqrt", "cbrt", "sin", "cos", "floor", "ceil",
    "round", "clamp", "max", "min", "and", "or", "xor", "not", "select_n",
    "eq", "ne", "lt", "le", "gt", "ge", "is_finite", "nextafter", "square",
    "shift_left", "shift_right_logical", "shift_right_arithmetic", "stop_gradient",
    "copy", "real", "imag", "complex", "conj", "add_any", "atan2", "tan",
    "asin", "acos", "atan", "sinh", "cosh", "asinh", "acosh", "atanh",
    "population_count", "clz", "igamma", "igammac", "lgamma", "digamma",
}
_REDUCE_PRIMS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin", "cumsum", "cumprod",
    "cummax", "cummin", "cumlogsumexp", "reduce_precision",
}
_LAYOUT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "rev", "slice", "concatenate", "pad", "convert_element_type",
    "bitcast_convert_type", "gather", "scatter", "scatter_add", "scatter_max",
    "scatter_min", "scatter_mul", "dynamic_slice", "dynamic_update_slice",
    "iota", "split",
}
_SUBJAXPR_INLINE = {
    "pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
    "custom_vjp_call_jaxpr", "remat", "remat2", "checkpoint", "custom_lin",
}


def _nbytes(v) -> float:
    aval = v.aval if hasattr(v, "aval") else v
    if not hasattr(aval, "shape"):
        return 0.0
    try:
        return float(np.prod(aval.shape, dtype=np.float64) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _classify(prim_name: str) -> str:
    if prim_name in ("dot_general", "conv_general_dilated", "ragged_dot"):
        return DOT
    if prim_name in _EW_PRIMS:
        return EW
    if prim_name in _REDUCE_PRIMS:
        return REDUCE
    if prim_name in _LAYOUT_PRIMS:
        return LAYOUT
    return OPAQUE


def _dot_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    dnums = eqn.params["dimension_numbers"]
    (lhs_c, _), _ = dnums
    lhs = eqn.invars[0].aval
    k = float(np.prod([lhs.shape[i] for i in lhs_c], dtype=np.float64)) if lhs_c else 1.0
    return 2.0 * float(np.prod(out.shape, dtype=np.float64)) * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    groups = eqn.params.get("feature_group_count", 1)
    k_elems = float(np.prod(rhs.shape, dtype=np.float64)) / max(rhs.shape[-1], 1)
    return 2.0 * float(np.prod(out.shape, dtype=np.float64)) * k_elems / max(groups, 1)


def _eqn_cost(eqn, scale: float = 1.0) -> tuple[str, float, float, float]:
    """(category, flops, in_bytes, out_bytes) for a flat eqn."""
    name = eqn.primitive.name
    cat = _classify(name)
    in_b = sum(_nbytes(v) for v in eqn.invars if hasattr(v, "aval")) * scale
    out_b = sum(_nbytes(v) for v in eqn.outvars) * scale
    out_elems = sum(
        float(np.prod(v.aval.shape, dtype=np.float64))
        for v in eqn.outvars
        if hasattr(v.aval, "shape")
    )
    if cat == DOT:
        flops = (_conv_flops(eqn) if name == "conv_general_dilated" else _dot_flops(eqn)) * scale
    elif cat == EW:
        flops = out_elems * scale
    elif cat == REDUCE:
        flops = sum(_nbytes(v) for v in eqn.invars if hasattr(v, "aval")) / 4.0 * scale
    elif cat == LAYOUT:
        flops = 0.0
        in_b = min(in_b, out_b * 2 + 64)  # slices/gathers read ~what they emit
    else:
        flops = out_elems * scale
    return cat, flops, in_b, out_b


class _Builder:
    def __init__(self):
        self.prims: list[PrimOp] = []
        self.edges: set[tuple[int, int]] = set()

    def add(self, op_type, category, flops, in_b, out_b, dep_pids) -> int:
        pid = len(self.prims)
        self.prims.append(
            PrimOp(pid=pid, op_type=op_type, category=category, flops=flops,
                   in_bytes=in_b, out_bytes=out_b, time=0.0)
        )
        for d in dep_pids:
            if d is not None and d != pid:
                self.edges.add((d, pid))
        return pid


def _subjaxpr_totals(jaxpr) -> tuple[float, float, float]:
    """Total (flops, in_bytes, out_bytes) of a sub-jaxpr body (for OPAQUE
    scan/while nodes)."""
    fl = ib = ob = 0.0
    for eqn in jaxpr.eqns:
        sub = _find_subjaxpr(eqn)
        if sub is not None:
            n = float(eqn.params.get("length", eqn.params.get("num_carry", 1)) or 1)
            f2, i2, o2 = _subjaxpr_totals(sub)
            fl += f2 * n
            ib += i2 * n
            ob += o2 * n
        else:
            _, f, i, o = _eqn_cost(eqn)
            fl += f
            ib += i
            ob += o
    return fl, ib, ob


def _find_subjaxpr(eqn):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in eqn.params:
            j = eqn.params[key]
            return j.jaxpr if hasattr(j, "jaxpr") else j
    return None


def _walk(jaxpr, env: dict, b: _Builder) -> None:
    """env maps jaxpr Var -> producing pid (or None for graph inputs)."""
    def rd(v):
        if isinstance(v, jcore.Literal):
            return None
        return env.get(v)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        sub = _find_subjaxpr(eqn)
        if name in _SUBJAXPR_INLINE and sub is not None:
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            ienv = dict(zip(inner.invars, [rd(v) for v in eqn.invars]))
            # constvars: treat as inputs
            for cv in inner.constvars:
                ienv[cv] = None
            saved = dict(env)
            env.update(ienv)
            _walk(inner, env, b)
            for ov, iv in zip(eqn.outvars, inner.outvars):
                env[ov] = rd(iv) if not isinstance(iv, jcore.Literal) else None
            # restore outer bindings that inner shadowed is unnecessary:
            # jaxpr vars are unique objects
            continue
        if sub is not None:  # scan / while / cond -> one OPAQUE node
            trips = float(eqn.params.get("length", 1) or 1)
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            f, i_, o_ = _subjaxpr_totals(inner)
            in_b = sum(_nbytes(v) for v in eqn.invars if hasattr(v, "aval"))
            out_b = sum(_nbytes(v) for v in eqn.outvars)
            pid = b.add(name, OPAQUE, f * trips, max(in_b, i_), max(out_b, o_),
                        {rd(v) for v in eqn.invars if not isinstance(v, jcore.Literal)})
            for ov in eqn.outvars:
                env[ov] = pid
            continue
        cat, flops, in_b, out_b = _eqn_cost(eqn)
        deps = {rd(v) for v in eqn.invars if not isinstance(v, jcore.Literal)}
        pid = b.add(name, cat, flops, in_b, out_b, deps)
        for ov in eqn.outvars:
            env[ov] = pid


def graph_from_jaxpr(
    closed_jaxpr,
    grad_out_indices: Sequence[int],
    grad_bytes: Sequence[float],
    grad_sigs: Sequence[str] | None = None,
) -> FusionGraph:
    """Build a FusionGraph from a closed jaxpr whose outputs at
    ``grad_out_indices`` are the parameter gradients."""
    jaxpr = closed_jaxpr.jaxpr
    b = _Builder()
    env: dict = {v: None for v in list(jaxpr.invars) + list(jaxpr.constvars)}
    _walk(jaxpr, env, b)
    # attach gradient markers; insert identity prims on collision
    sigs = list(grad_sigs) if grad_sigs is not None else ["" for _ in grad_out_indices]
    marked: set[int] = set()
    for gi, (oi, gb) in enumerate(zip(grad_out_indices, grad_bytes)):
        ov = jaxpr.outvars[oi]
        pid = env.get(ov) if not isinstance(ov, jcore.Literal) else None
        if pid is None or pid in marked:
            pid = b.add("grad_identity", EW, 0.0, gb, gb,
                        {pid} if pid is not None else set())
        marked.add(pid)
        p = b.prims[pid]
        b.prims[pid] = PrimOp(
            pid=p.pid, op_type=p.op_type, category=p.category, flops=p.flops,
            in_bytes=p.in_bytes, out_bytes=p.out_bytes, time=p.time,
            grad_param=gi, grad_bytes=float(gb), grad_sig=sigs[gi],
        )
    return FusionGraph(b.prims, b.edges)


def trace_grad_graph(
    loss_fn: Callable,
    params,
    batch,
    grad_sig_fn: Callable[[int, object], str] | None = None,
) -> FusionGraph:
    """Trace ``jax.grad(loss_fn)`` (w.r.t. params) into a FusionGraph with one
    AllReduce per parameter-gradient leaf — the per-device data-parallel
    training graph DisCo optimises."""
    grad_fn = jax.grad(lambda p, bt: loss_fn(p, bt))
    closed = jax.make_jaxpr(grad_fn)(params, batch)
    leaves = jax.tree_util.tree_leaves(params)
    n = len(leaves)
    gbytes = [float(np.prod(l.shape, dtype=np.float64) * l.dtype.itemsize)
              if hasattr(l, "shape") else 8.0 for l in leaves]
    sigs = None
    if grad_sig_fn is not None:
        sigs = [grad_sig_fn(i, l) for i, l in enumerate(leaves)]
    else:
        sigs = [str(getattr(l, "dtype", "f32")) for l in leaves]
    return graph_from_jaxpr(closed, list(range(n)), gbytes, sigs)

"""Analytic per-device FLOP / HBM-byte / ICI-byte model per (arch x shape).

Why this exists: XLA's ``compiled.cost_analysis()`` counts ``while``-loop
bodies ONCE, not body x trip-count (verified experimentally — see
EXPERIMENTS.md "HLO cost-analysis caveat").  With scanned-layer models and
grad-accumulation scans, the raw HLO numbers undercount by the layer count.
The roofline table therefore reports *both* the raw HLO numbers and this
analytic model; the terms use the analytic values.

Conventions: "per device" divides batch over the data axes and model-width
over the ``model`` axis; remat recompute adds one forward; attention is
causal (S/2 average context; window-clamped when sliding-window).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..models.config import ModelConfig
from .hw import Hardware, TPU_V5E


@dataclasses.dataclass
class CostBreakdown:
    flops: float            # per device, per step
    hbm_bytes: float        # per device, per step
    ici_bytes: float        # per device, per step (link traffic)
    model_flops: float      # 6*N*D convention (global, for MFU-style ratio)
    notes: str = ""


def _attn_ctx(cfg: ModelConfig, S: int) -> float:
    """Average attended context per token (causal; window-clamped)."""
    if cfg.window:
        return min(S / 2.0, float(cfg.window))
    return S / 2.0


def _per_token_forward_flops(cfg: ModelConfig, S: int, decode: bool) -> float:
    """Matmul+attention forward FLOPs per token (whole model, unsharded)."""
    d = cfg.d_model
    f = 0.0
    ctx = float(S) if decode else _attn_ctx(cfg, S)
    for li in range(cfg.n_layers):
        kind = cfg.block_kind(li)
        if kind == "attn":
            if cfg.block == "mla" and cfg.mla:
                m = cfg.mla
                qdim = cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                f += 2 * d * ((m.q_lora_rank or 0) + m.kv_lora_rank
                              + m.qk_rope_head_dim)
                f += 2 * (m.q_lora_rank or d) * qdim
                f += 2 * m.kv_lora_rank * cfg.n_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                f += 2 * cfg.n_heads * m.v_head_dim * d
                hd_eff = m.qk_nope_head_dim + m.qk_rope_head_dim
                f += 2 * cfg.n_heads * (hd_eff + m.v_head_dim) * ctx
            else:
                hd = cfg.hd
                w = (min(ctx, cfg.window) if cfg.window else ctx)
                f += 2 * d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
                f += 2 * cfg.n_heads * hd * d
                f += 4 * cfg.n_heads * hd * w      # qk^T + pv
        elif kind == "rec":
            L = cfg.recurrent.lru_width
            f += 2 * d * L * 2 + 2 * L * L * 2 + 2 * L * d + 10 * L
        elif kind == "rwkv":
            hd = cfg.hd
            f += 2 * d * d * 5 + 2 * d * 64 * 2   # r,k,v,g,o + decay lora
            f += 6 * cfg.n_heads * hd * hd        # wkv rank-1 recurrence
        # FFN
        if cfg.is_moe_layer(li):
            e = cfg.moe
            nff = 3 if cfg.glu else 2
            f += 2 * nff * d * e.d_expert * (e.top_k + e.n_shared)
            f += 2 * d * e.n_routed                # router
        elif kind == "rwkv":
            f += 2 * d * cfg.d_ff * 2 + 2 * d * d  # channel mix
        else:
            f += 2 * (3 if cfg.glu else 2) * d * cfg.d_ff
    f += 2 * d * cfg.vocab                          # unembed
    if cfg.encdec is not None:
        # encoder runs once per sequence; amortise per decoder token
        enc = cfg.encdec
        per_enc_tok = (2 * 4 * d * cfg.hd * cfg.n_heads
                       + 2 * (3 if cfg.glu else 2) * d * cfg.d_ff
                       + 4 * cfg.n_heads * cfg.hd * enc.enc_seq / 2)
        f += per_enc_tok * enc.n_enc_layers * (enc.enc_seq / max(S, 1))
        # cross attention per decoder layer
        f += cfg.n_layers * (2 * 2 * d * cfg.hd * cfg.n_heads
                             + 4 * cfg.n_heads * cfg.hd * enc.enc_seq)
    return f


def train_cost(cfg: ModelConfig, batch: int, S: int, mesh_shape: dict,
               hw: Hardware = TPU_V5E, fsdp: bool = False,
               remat: bool = True) -> CostBreakdown:
    tp = mesh_shape.get("model", 1)
    dp = int(np.prod([v for k, v in mesh_shape.items() if k != "model"]))
    n_dev = tp * dp
    tokens = batch * S
    tokens_local = tokens / dp
    fwd = _per_token_forward_flops(cfg, S, decode=False)
    mult = 2.0 + 2.0 * 2.0 if remat else 1.0 + 2.0   # fwd + bwd(2x) + remat
    flops_pd = fwd * mult * tokens_local / tp

    n_params = cfg.param_count()
    n_local = n_params / tp / (dp if fsdp else 1)
    dtype = 2  # bf16
    w_traffic = n_local * dtype * (3 if remat else 2)      # fwd+bwd(+remat)
    opt_traffic = n_local * 22.0                            # adam f32 m,v,p,g
    d = cfg.d_model
    act_per_tok = cfg.n_layers * (8 * d + 4 * cfg.d_ff) * dtype
    kv_traffic = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.hd * dtype * 2
    hbm_pd = (w_traffic + opt_traffic
              + tokens_local * (act_per_tok + kv_traffic) / tp)

    # collectives (ring factors)
    gd = dp
    rf_d = 2 * (gd - 1) / gd if gd > 1 else 0.0
    rf_m = 2 * (tp - 1) / tp if tp > 1 else 0.0
    ici = 0.0
    if fsdp:
        # ZeRO-3: allgather weights fwd+bwd + reduce-scatter grads
        ici += n_params / tp * dtype * 2 * (gd - 1) / gd * 2
        ici += n_params / tp * 4 * (gd - 1) / gd
    else:
        # DisCo bucketed psum of f32 local TP shards over data axes
        ici += n_params / tp * 4 * rf_d
    # TP activation psums: ~2 per layer, fwd+bwd
    ici += cfg.n_layers * 2 * tokens_local * d * dtype * rf_m * 2
    if cfg.moe is not None:
        e = cfg.moe
        ici += tokens_local * d * dtype * e.top_k * 2   # a2a fwd+bwd approx
    model_flops = 6.0 * cfg.active_param_count() * tokens
    return CostBreakdown(flops_pd, hbm_pd, ici, model_flops, "train")


def prefill_cost(cfg: ModelConfig, batch: int, S: int, mesh_shape: dict,
                 hw: Hardware = TPU_V5E) -> CostBreakdown:
    tp = mesh_shape.get("model", 1)
    dp = int(np.prod([v for k, v in mesh_shape.items() if k != "model"]))
    tokens = batch * S
    tokens_local = tokens / dp
    fwd = _per_token_forward_flops(cfg, S, decode=False)
    flops_pd = fwd * tokens_local / tp
    n_local = cfg.param_count() / tp
    d = cfg.d_model
    act_per_tok = cfg.n_layers * (6 * d + 2 * cfg.d_ff) * 2
    hbm_pd = n_local * 2 + tokens_local * act_per_tok / tp
    rf_m = 2 * (tp - 1) / tp if tp > 1 else 0.0
    ici = cfg.n_layers * 2 * tokens_local * d * 2 * rf_m
    model_flops = 2.0 * cfg.active_param_count() * tokens
    return CostBreakdown(flops_pd, hbm_pd, ici, model_flops, "prefill")


def decode_cost(cfg: ModelConfig, batch: int, S: int, mesh_shape: dict,
                hw: Hardware = TPU_V5E) -> CostBreakdown:
    """One decode step (1 new token/sequence, cache length S)."""
    tp = mesh_shape.get("model", 1)
    dp = int(np.prod([v for k, v in mesh_shape.items() if k != "model"]))
    b_local = max(batch / dp, batch / dp)
    fwd = _per_token_forward_flops(cfg, min(S, cfg.window or S), decode=True)
    flops_pd = fwd * b_local / tp

    n_local = cfg.param_count() / tp
    # cache bytes per sequence
    if cfg.block == "mla" and cfg.mla:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        cache = cfg.n_layers * min(S, cfg.window or S) * per_tok * 2
    elif cfg.block == "rwkv":
        cache = cfg.n_layers * cfg.n_heads * cfg.hd * cfg.hd * 4
    elif cfg.recurrent is not None:
        n_att = sum(1 for i in range(cfg.n_layers)
                    if cfg.block_kind(i) == "attn")
        cache = (n_att * min(S, cfg.window or S)
                 * 2 * cfg.n_kv_heads * cfg.hd * 2
                 + (cfg.n_layers - n_att) * cfg.recurrent.lru_width * 4)
    else:
        cache = (cfg.n_layers * min(S, cfg.window or S)
                 * 2 * cfg.n_kv_heads * cfg.hd * 2)
    hbm_pd = n_local * 2 + b_local * cache / max(tp, 1) * 1.05
    rf_m = 2 * (tp - 1) / tp if tp > 1 else 0.0
    ici = cfg.n_layers * 2 * b_local * cfg.d_model * 2 * rf_m
    model_flops = 2.0 * cfg.active_param_count() * batch
    return CostBreakdown(flops_pd, hbm_pd, ici, model_flops, "decode")


def shape_cost(cfg: ModelConfig, shape: str, mesh_shape: dict,
               fsdp: bool = False) -> CostBreakdown:
    from ..launch.shapes import SHAPES

    info = SHAPES[shape]
    if info["kind"] == "train":
        return train_cost(cfg, info["batch"], info["seq"], mesh_shape,
                          fsdp=fsdp)
    if info["kind"] == "prefill":
        return prefill_cost(cfg, info["batch"], info["seq"], mesh_shape)
    return decode_cost(cfg, info["batch"], info["seq"], mesh_shape)

"""Backtracking search over the joint op/tensor-fusion space (paper Alg. 1).

Faithful reproduction: a priority queue of candidate HLO modules ordered by
Cost(.); each step dequeues the cheapest candidate and applies each of the
three optimisation methods ``RandomApply``-style n ~ U[0, beta] times;
candidates within ``alpha x Cost(H_opt)`` are re-enqueued for backtracking;
the search stops when the queue empties or H_opt is unchanged for
``unchanged_limit`` steps (paper: 1000; default reduced for CPU budget —
see DESIGN.md Sec. 6).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
import time as _time
from typing import Callable, Sequence

from .graph import FusionGraph
from .simulator import Simulator

METHOD_NONDUP = "nondup"
METHOD_DUP = "dup"
METHOD_TENSOR = "tensor"
ALL_METHODS = (METHOD_NONDUP, METHOD_DUP, METHOD_TENSOR)


@dataclasses.dataclass
class SearchResult:
    best: FusionGraph
    best_cost: float
    initial_cost: float
    steps: int
    simulations: int
    wall_time: float
    history: list  # (step, best_cost)


def random_apply(g: FusionGraph, method: str, n: int, rng: random.Random) -> bool:
    """Apply ``method`` up to n times with random operands.  Mutates ``g``;
    returns True if at least one application changed the graph."""
    changed = False
    for _ in range(n):
        if method == METHOD_TENSOR:
            if len(g.buckets) < 2:
                break
            i = rng.randrange(len(g.buckets) - 1)
            changed |= g.merge_buckets(i, i + 1)
            continue
        gids = list(g.groups)
        # a handful of attempts to find a valid (consumer, producer) pair
        for _attempt in range(4):
            c = rng.choice(gids)
            preds = list(g.group_preds(c))
            if not preds:
                continue
            p = rng.choice(preds)
            ok = g.fuse_nondup(c, p) if method == METHOD_NONDUP else g.fuse_dup(c, p)
            if ok:
                changed = True
                break
    return changed


def backtracking_search(
    g0: FusionGraph,
    sim: Simulator,
    *,
    alpha: float = 1.05,
    beta: int = 10,
    unchanged_limit: int = 200,
    methods: Sequence[str] = ALL_METHODS,
    seed: int = 0,
    max_queue: int = 512,
    max_steps: int | None = None,
    on_step: Callable | None = None,
) -> SearchResult:
    rng = random.Random(seed)
    tick = itertools.count()
    cost_cache: dict = {}
    sims = 0

    def cost(g: FusionGraph) -> float:
        nonlocal sims
        key = g.signature()
        c = cost_cache.get(key)
        if c is None:
            c = sim.cost(g)
            cost_cache[key] = c
            sims += 1
        return c

    t0 = _time.perf_counter()
    c0 = cost(g0)
    best, best_cost = g0, c0
    q: list = [(c0, next(tick), g0)]
    unchanged = 0
    steps = 0
    history = [(0, c0)]

    while q and unchanged < unchanged_limit:
        if max_steps is not None and steps >= max_steps:
            break
        c_h, _, h = heapq.heappop(q)
        steps += 1
        for s in methods:
            n = rng.randint(0, beta)
            if n == 0:
                unchanged += 1
                continue
            h2 = h.clone()
            if not random_apply(h2, s, n, rng):
                unchanged += 1
                continue
            c2 = cost(h2)  # validity is enforced inside the mutations
            if c2 < best_cost:
                best, best_cost = h2, c2
                unchanged = 0
                history.append((steps, best_cost))
            else:
                unchanged += 1
            if c2 <= alpha * best_cost and len(q) < max_queue:
                heapq.heappush(q, (c2, next(tick), h2))
        if on_step is not None:
            on_step(steps, best_cost)
    return SearchResult(
        best=best,
        best_cost=best_cost,
        initial_cost=c0,
        steps=steps,
        simulations=sims,
        wall_time=_time.perf_counter() - t0,
        history=history,
    )

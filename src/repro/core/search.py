"""Backtracking search over the joint op/tensor-fusion space (paper Alg. 1).

Faithful reproduction: a priority queue of candidate HLO modules ordered by
Cost(.); each step dequeues the cheapest candidate and applies each of the
optimisation methods ``RandomApply``-style n ~ U[0, beta] times — the
paper's three (non-duplicate fusion, duplicate fusion, tensor fusion) plus
the cluster extension's per-bucket collective-algorithm choice
(``METHOD_ALGO``, DESIGN.md Sec. 7) and the event-engine extension's
per-bucket comm-kind choice (``METHOD_COMM``: fused AllReduce vs ZeRO-3
reduce-scatter + all-gather) and per-bucket chunk-count choice
(``METHOD_CHUNK``: store-and-forward chunks pipelined through the link
levels; both active on multi-stream sims — DESIGN.md Sec. 8-9), making
the search joint over op fusion x tensor fusion x algorithm x comm kind
x chunking;
candidates within ``alpha x Cost(H_opt)`` are re-enqueued for backtracking;
the search stops when the queue empties or H_opt is unchanged for
``unchanged_limit`` steps (paper: 1000; default reduced for CPU budget —
see DESIGN.md Sec. 6).

Per Alg. 1, "unchanged" is counted **once per dequeued step** that fails to
improve H_opt — not once per method draw, which would make the effective
patience depend on ``len(methods)``.

The methods themselves live in the declarative registry
:mod:`repro.core.mutations`: each searched dimension is a
``Mutation(name, apply, applicable)`` and the per-simulator drop rules
(flat specs are algorithm-blind; comm-kind and chunk flips only matter on a
multi-stream engine) are its ``applicable(sim)`` predicate —
``active_methods(sim, methods)`` below replaces the hard-coded filters.
New dimensions register there once and the search picks them up.

Candidate evaluation can optionally be spread over a process pool
(``workers=N``): candidates are still *generated* sequentially (the RNG
stream, and therefore the search trajectory, is identical to the serial
path), but their simulations run concurrently, each worker holding its own
estimator cache.  Cost memoisation uses the graph's O(1) rolling
``fast_signature`` instead of the full O(V log V) sorted fingerprint.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import pickle
import random
import time as _time
from typing import Callable, Sequence

from .costs import OracleEstimator
from .graph import FusionGraph
from .mutations import (ALL_METHODS, CHUNK_CHOICES, METHOD_ALGO,
                        METHOD_CHUNK, METHOD_COMM, METHOD_DUP,
                        METHOD_FUSED, METHOD_NONDUP, METHOD_TENSOR,
                        MUTATIONS, Mutation, active_methods, random_apply)
from .simulator import Simulator

__all__ = [
    "ALL_METHODS", "CHUNK_CHOICES", "METHOD_ALGO", "METHOD_CHUNK",
    "METHOD_COMM", "METHOD_DUP", "METHOD_FUSED", "METHOD_NONDUP",
    "METHOD_TENSOR", "MUTATIONS", "Mutation", "SearchResult",
    "active_methods", "backtracking_search", "random_apply",
]


@dataclasses.dataclass
class SearchResult:
    best: FusionGraph
    best_cost: float
    initial_cost: float
    steps: int
    simulations: int
    wall_time: float
    history: list  # (step, best_cost)
    # (simulations-so-far, best_cost) at every improvement — the
    # simulations-to-quality curve the plan-cache warm-start benchmark
    # gates on (DESIGN.md Sec. 12)
    quality_history: list = dataclasses.field(default_factory=list)


# --------------------------------------------------------- worker-pool eval
_WORKER_CTX = None


def _pool_init(payload: bytes) -> None:
    global _WORKER_CTX
    (prims, psuccs, ppreds, grad_prim, family, hw, n_devices,
     cluster, streams, background, overlap_discount,
     pipeline, tp) = pickle.loads(payload)
    sim = Simulator(hw=hw, n_devices=n_devices, incremental=False,
                    cluster=cluster, streams=streams, background=background,
                    overlap_discount=overlap_discount,
                    pipeline=pipeline, tp=tp)
    _WORKER_CTX = (prims, psuccs, ppreds, grad_prim, family, sim)


def _pool_cost(state: tuple) -> float:
    (groups, provider, next_gid, buckets, bucket_algos, bucket_comm,
     bucket_chunks, bucket_fused, pp_knobs) = state
    prims, psuccs, ppreds, grad_prim, family, sim = _WORKER_CTX
    g = FusionGraph._from_parts(prims, psuccs, ppreds, groups, provider,
                                next_gid, grad_prim, buckets, family=family,
                                bucket_algos=bucket_algos,
                                bucket_comm=bucket_comm,
                                bucket_chunks=bucket_chunks,
                                bucket_fused=bucket_fused,
                                pp_knobs=pp_knobs)
    return sim.cost(g)


class _CandidatePool:
    """Process pool evaluating candidate costs; each worker keeps its own
    estimator cache keyed to the shared prim family."""

    def __init__(self, sim: Simulator, base: FusionGraph, workers: int):
        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor

        payload = pickle.dumps(
            (base.prims, base.psuccs, base.ppreds, base.grad_prim,
             base.family_token(), sim.hw, sim.n_devices,
             getattr(sim, "cluster", None), getattr(sim, "streams", 1),
             getattr(sim, "background", ()),
             getattr(sim, "overlap_discount", 0.0),
             getattr(sim, "pipeline", None), getattr(sim, "tp", None))
        )
        # spawn: workers only import repro.core (pure python, no jax), and
        # forking a process that already holds jax's thread pools can hang
        self._ex = ProcessPoolExecutor(
            max_workers=workers, initializer=_pool_init, initargs=(payload,),
            mp_context=multiprocessing.get_context("spawn"),
        )

    def evaluate(self, graphs: Sequence[FusionGraph]) -> list[float]:
        futs = [
            self._ex.submit(
                _pool_cost, (g.groups, g.provider, g._next_gid, g.buckets,
                             g.bucket_algos, g.bucket_comm, g.bucket_chunks,
                             g.bucket_fused, g.pp_knobs)
            )
            for g in graphs
        ]
        return [f.result() for f in futs]

    def close(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


def _make_pool(sim, g0, workers) -> _CandidatePool | None:
    if not workers or workers < 2:
        return None
    if not isinstance(getattr(sim, "estimator", None), OracleEstimator):
        return None  # GNN/custom estimators are not shippable to workers
    try:
        return _CandidatePool(sim, g0, workers)
    except Exception:
        return None


def backtracking_search(
    g0: FusionGraph,
    sim: Simulator,
    *,
    alpha: float = 1.05,
    beta: int = 10,
    unchanged_limit: int = 200,
    methods: Sequence[str] | None = None,
    seed: int = 0,
    max_queue: int = 512,
    max_steps: int | None = None,
    on_step: Callable | None = None,
    workers: int | None = None,
    initial: FusionGraph | None = None,
) -> SearchResult:
    """``initial`` injects a warm start state (e.g. a cached plan's
    strategy re-applied onto ``g0`` — see :mod:`repro.plan.cache`): it is
    costed and enqueued alongside ``g0``, and since the incumbent starts
    at the cheaper of the two, the search can never return a plan worse
    than its own start state.  ``initial_cost`` still reports ``g0``'s
    cost (the trivial baseline), so speedup-vs-initial stays comparable
    between warm and cold runs.  ``initial=None`` draws the identical RNG
    stream as before — cold trajectories are unchanged."""
    rng = random.Random(seed)
    tick = itertools.count()
    cost_cache: dict = {}
    sims = 0
    # methods=None searches every *registered* mutation (new dimensions
    # register once in repro.core.mutations and are picked up here); either
    # way, dimensions that cannot improve candidates priced by this sim
    # (flat specs are algorithm-blind, comm/chunk flips need a multi-stream
    # engine) are dropped instead of burning candidate evaluations — the
    # rules are the mutations' applicable(sim) predicates.
    methods = active_methods(sim, methods)
    pool = _make_pool(sim, g0, workers)

    def cost(g: FusionGraph) -> float:
        nonlocal sims
        key = g.fast_signature()
        c = cost_cache.get(key)
        if c is None:
            c = sim.cost(g)
            cost_cache[key] = c
            sims += 1
        return c

    t0 = _time.perf_counter()
    c0 = cost(g0)
    best, best_cost = g0, c0
    q: list = [(c0, next(tick), g0)]
    unchanged = 0
    steps = 0
    history = [(0, c0)]
    quality_history = [(sims, c0)]
    if initial is not None and initial.fast_signature() != g0.fast_signature():
        ci = cost(initial)
        if ci < best_cost:
            best, best_cost = initial, ci
            history.append((0, ci))
        quality_history.append((sims, best_cost))
        heapq.heappush(q, (ci, next(tick), initial))

    try:
        while q and unchanged < unchanged_limit:
            if max_steps is not None and steps >= max_steps:
                break
            c_h, _, h = heapq.heappop(q)
            steps += 1
            # generate all of this step's candidates first — the RNG stream
            # (and thus the trajectory) is independent of how they are costed
            cands: list[FusionGraph] = []
            for s in methods:
                n = rng.randint(0, beta)
                if n == 0:
                    continue
                h2 = h.clone()
                if random_apply(h2, s, n, rng):
                    cands.append(h2)
            if pool is not None and len(cands) > 1:
                fresh = {}
                for h2 in cands:
                    kk = h2.fast_signature()
                    if kk not in cost_cache and kk not in fresh:
                        fresh[kk] = h2
                if fresh:
                    try:
                        costs = pool.evaluate(list(fresh.values()))
                    except Exception:
                        pool.close()
                        pool = None
                    else:
                        for kk, c2 in zip(fresh, costs):
                            cost_cache[kk] = c2
                            sims += 1
            improved = False
            for h2 in cands:
                c2 = cost(h2)  # validity is enforced inside the mutations
                if c2 < best_cost:
                    best, best_cost = h2, c2
                    improved = True
                    history.append((steps, best_cost))
                    quality_history.append((sims, best_cost))
                if c2 <= alpha * best_cost and len(q) < max_queue:
                    heapq.heappush(q, (c2, next(tick), h2))
            # Alg. 1: H_opt "unchanged" is per dequeued step, not per method
            if not improved:
                unchanged += 1
            else:
                unchanged = 0
            if on_step is not None:
                on_step(steps, best_cost)
    finally:
        if pool is not None:
            pool.close()
    return SearchResult(
        best=best,
        best_cost=best_cost,
        initial_cost=c0,
        steps=steps,
        simulations=sims,
        wall_time=_time.perf_counter() - t0,
        history=history,
        quality_history=quality_history,
    )

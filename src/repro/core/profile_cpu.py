"""Profiling substrate: GNN training-sample generation and the CPU-measured
ground-truth tier.

Tier A (default, TPU-target): fused-op samples are drawn from traced model
graphs by replaying the paper's sample generator — "randomly select an op and
fuse it with one of its predecessors, repeat" (Sec. 5.2) — labelled by the
detailed analytic oracle.

Tier B (CPU-measured): synthetic fused ops are materialised as real jnp
functions, jit-compiled and *timed on this machine*; used by Fig. 9 / Table 2
benchmarks so the estimator is validated against genuinely measured times.
"""
from __future__ import annotations

import random
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .costs import group_time_oracle, prim_time
from .graph import DOT, EW, FusionGraph, LAYOUT, PrimOp, REDUCE
from .gnn import group_features
from .hw import Hardware


# ----------------------------------------------------------- tier A samples
def sample_fused_groups(
    g: FusionGraph,
    n_samples: int,
    rng: random.Random,
    max_members: int = 32,
    hw: Hardware | None = None,
):
    """Yield (feat, adj, mask, oracle_time) samples of random fused groups."""
    out = []
    for _ in range(n_samples):
        trial = g.clone()
        n_fuse = rng.randint(1, max_members - 1)
        target = None
        for _ in range(n_fuse):
            gids = [x for x in trial.groups if target is None or x == target]
            ok = False
            for _attempt in range(6):
                c = target if target is not None and target in trial.groups \
                    else rng.choice(list(trial.groups))
                preds = list(trial.group_preds(c))
                if not preds:
                    target = None
                    continue
                p = rng.choice(preds)
                before = set(trial.groups)
                if trial.fuse_nondup(c, p):
                    new = (set(trial.groups) - before).pop()
                    target = new
                    ok = True
                    break
                target = None
            if not ok:
                break
        if target is None or target not in trial.groups:
            continue
        if len(trial.groups[target]) < 2:
            continue
        t = group_time_oracle(trial, target, hw) if hw else group_time_oracle(trial, target)
        feat, adj, mask = group_features(trial, target, max_nodes=48)
        out.append((feat, adj, mask, t))
    return out


# ---------------------------------------------------------- tier B (CPU-run)
_UNARY = [jnp.tanh, jnp.exp, jax.nn.relu, jax.lax.logistic, jnp.sqrt]
_UNARY_NAMES = ["tanh", "exp", "max", "logistic", "sqrt"]
_BINARY = [jnp.add, jnp.multiply, jnp.subtract, jnp.maximum]
_BINARY_NAMES = ["add", "mul", "sub", "max"]


def synth_fused_op(rng: random.Random, max_nodes: int = 20, dim: int = 256):
    """Build a random executable fused-op DAG.

    Returns (fn, example_inputs, prims, edges) where prims/edges describe the
    node-level graph for GNN features.
    """
    n_ops = rng.randint(2, max_nodes)
    n_inputs = rng.randint(1, 3)
    shapes = [(dim, dim)] * n_inputs
    recipe = []  # (kind, idx_args, name)
    avail = list(range(n_inputs))  # value slots (inputs first)
    slot_shape = {i: shapes[i] for i in range(n_inputs)}
    next_slot = n_inputs
    for _ in range(n_ops):
        kind = rng.random()
        if kind < 0.15 and len(avail) >= 2:
            a, b = rng.sample(avail, 2)
            if slot_shape[a][-1] == slot_shape[b][0]:
                recipe.append(("dot", (a, b), "dot_general"))
                slot_shape[next_slot] = (slot_shape[a][0], slot_shape[b][-1])
            else:
                op = rng.randrange(len(_BINARY))
                recipe.append(("bin", (a, b, op), _BINARY_NAMES[op]))
                slot_shape[next_slot] = slot_shape[a]
        elif kind < 0.5 and len(avail) >= 2:
            a, b = rng.sample(avail, 2)
            if slot_shape[a] != slot_shape[b]:
                a, b = a, a
            op = rng.randrange(len(_BINARY))
            recipe.append(("bin", (a, b, op), _BINARY_NAMES[op]))
            slot_shape[next_slot] = slot_shape[a]
        else:
            a = rng.choice(avail)
            op = rng.randrange(len(_UNARY))
            recipe.append(("un", (a, op), _UNARY_NAMES[op]))
            slot_shape[next_slot] = slot_shape[a]
        avail.append(next_slot)
        next_slot += 1

    def fn(*inputs):
        slots = list(inputs)
        for kind, args, _ in recipe:
            if kind == "dot":
                v = slots[args[0]] @ slots[args[1]]
            elif kind == "bin":
                v = _BINARY[args[2]](slots[args[0]], slots[args[1]])
            else:
                v = _UNARY[args[1]](jnp.abs(slots[args[0]]) + 1e-3)
            slots.append(v)
        return slots[-1]

    # node-level graph (inputs are not nodes; edges between ops only)
    prims, edges = [], []
    for i, (kind, args, name) in enumerate(recipe):
        shape = slot_shape[n_inputs + i]
        nel = float(np.prod(shape))
        if kind == "dot":
            flops = 2.0 * shape[0] * shape[1] * slot_shape[args[0]][1]
            cat = DOT
        else:
            flops = nel
            cat = EW
        in_b = sum(
            float(np.prod(slot_shape[a])) * 4
            for a in args[: 2 if kind != "un" else 1]
        )
        prims.append(PrimOp(pid=i, op_type=name, category=cat, flops=flops,
                            in_bytes=in_b, out_bytes=nel * 4, time=0.0))
        for a in args[: 2 if kind != "un" else 1]:
            if a >= n_inputs:
                edges.append((a - n_inputs, i))
    example = [jnp.asarray(np.random.default_rng(0).standard_normal(s),
                           jnp.float32) for s in shapes]
    return fn, example, prims, edges


def time_callable(fn: Callable, args, repeats: int = 5) -> float:
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measured_fused_samples(n_samples: int, seed: int = 0, max_nodes: int = 16,
                           dim: int = 192):
    """Tier-B corpus: (feat, adj, mask, measured_seconds) samples."""
    rng = random.Random(seed)
    out = []
    for _ in range(n_samples):
        fn, example, prims, edges = synth_fused_op(rng, max_nodes, dim)
        t = time_callable(fn, example)
        # profiled standalone times for node features: CPU-calibrated roofline
        hw = CPU_HW
        prims = [
            PrimOp(pid=p.pid, op_type=p.op_type, category=p.category,
                   flops=p.flops, in_bytes=p.in_bytes, out_bytes=p.out_bytes,
                   time=prim_time(p, hw))
            for p in prims
        ]
        fg = FusionGraph(prims, edges)
        # single group containing everything
        gid = next(iter(fg.groups))
        while len(fg.groups) > 1:
            gids = list(fg.groups)
            done = False
            for c in gids:
                for p in fg.group_preds(c):
                    if fg.fuse_nondup(c, p):
                        done = True
                        break
                if done:
                    break
            if not done:  # disconnected components: merge artificially
                break
        gid = max(fg.groups, key=lambda g_: len(fg.groups[g_]))
        feat, adj, mask = group_features(fg, gid, max_nodes=48)
        out.append((feat, adj, mask, t))
    return out


# --------------------------------------------------------- CPU calibration
def calibrate_cpu_hw(dim: int = 512) -> Hardware:
    """Fit a Hardware() for *this* CPU from two microbenchmarks, so the
    simulator can be compared against real measured step times (Table 2)."""
    a = jnp.asarray(np.random.default_rng(0).standard_normal((dim, dim)),
                    jnp.float32)
    t_mm = time_callable(lambda x: x @ x, (a,))
    flops = 2.0 * dim**3
    peak = flops / max(t_mm, 1e-9)
    big = jnp.asarray(np.random.default_rng(1).standard_normal(4_000_000),
                      jnp.float32)
    t_cp = time_callable(lambda x: x * 1.0001 + 1.0, (big,))
    bw = (2 * big.size * 4) / max(t_cp, 1e-9)
    t_tiny = time_callable(lambda x: x + 1.0, (jnp.ones((8,)),))
    return Hardware(name="cpu-calibrated", peak_flops=peak, hbm_bw=bw,
                    ici_bw=bw / 4, vmem_bytes=32 * 2**20,
                    launch_overhead=max(t_tiny, 1e-6),
                    allreduce_latency=20e-6, efficiency=1.0)


CPU_HW = Hardware(name="cpu-nominal", peak_flops=5e10, hbm_bw=1e10,
                  ici_bw=2.5e9, vmem_bytes=32 * 2**20, launch_overhead=5e-6,
                  allreduce_latency=20e-6, efficiency=1.0)

"""Fusion IR — the joint op/tensor-fusion strategy state DisCo searches over.

The IR has two levels:

* **Primitive level** (immutable): ``PrimOp`` nodes and dependency edges, as
  extracted from a jaxpr by :mod:`repro.core.trace` (or built synthetically).
  A prim that produces a parameter gradient carries ``grad_param >= 0`` and
  ``grad_bytes > 0`` — its tensor must be AllReduced in data-parallel training.

* **Fusion state** (mutable): a partition of prims into *groups* (fused ops).
  Duplicate fusion (paper Fig. 1(iii)) lets a prim be a member of several
  groups; exactly one group is its *provider* — the occurrence whose
  completion makes the prim's output available to external consumers.
  AllReduce instructions are partitioned into *buckets* (tensor fusion);
  each bucket additionally carries a *collective algorithm* choice
  (``bucket_algos``: ring / tree / hier, priced by :mod:`repro.cluster`)
  and a *communication kind* (``bucket_comm``: one fused AllReduce, or
  ZeRO-3-style reduce-scatter + all-gather priced per link level by the
  event engine — DESIGN.md Sec. 8).

Mutations (`fuse_nondup`, `fuse_dup`, `merge_buckets`) are the paper's three
optimisation methods (Sec. 4.5); each validates DAG-ness of the quotient
graph and op fusibility before committing.  ``set_bucket_algo`` is the
cluster extension's fourth method, ``set_bucket_comm`` the event-engine
extension's fifth, and ``set_bucket_chunks`` (store-and-forward chunk
count, ``bucket_chunks``) the sixth: the search is joint over op fusion x
tensor fusion x collective algorithm x comm kind x chunking (DESIGN.md
Sec. 7-9).  Each dimension is registered as a declarative
:class:`repro.core.mutations.Mutation` (name, random application,
per-simulator applicability) — the searched strategy state here plus that
registry is everything :class:`repro.plan.Plan` serializes (DESIGN.md
Sec. 10).

Incremental invariants
----------------------

The quotient DAG (``_qsuccs``/``_qpreds``) is maintained *incrementally*
across mutations rather than rebuilt from the prim DAG per candidate:

* An op-fusion mutation merging groups ``c``/``p`` into a fresh gid ``G``
  patches only the neighbourhoods of ``c``, ``p`` and ``G``: out-edges are
  renamed ``c/p -> G``, and ``G``'s in-edges are recomputed by scanning the
  merged members' external predecessors (the only part whose edge set can
  *shrink* — a prim consumed from another group may become internal to ``G``
  under duplicate fusion).
* All updates are copy-on-write: modified adjacency sets are replaced, never
  mutated in place, so ``clone()`` can share the quotient structures between
  a graph and its descendants.
* Acyclicity is enforced with a targeted DFS: a mutation can only create a
  cycle through the new group ``G``, so we search ``G``'s successors for a
  path back to ``G`` instead of re-checking the whole DAG.
* ``_group_key`` (min member pid, the simulator tie-break), ``_provided``
  (pids each group provides) and the rolling signature hash are updated in
  O(|merged group|) at commit time.

Every committed mutation appends a record to ``_journal`` (relative to
``_base_token``, the id of the last simulator state computed for an ancestor
of this graph).  :class:`repro.core.simulator.Simulator` uses the journal to
re-simulate only the suffix of the schedule a mutation can affect; see the
module docstring there for the exact divergence-bound argument.

``signature()`` is the seed's full sorted fingerprint (kept for tests and
strategy serialization); ``fast_signature()`` is the rolling 64-bit hash
maintained by the mutations, used for search memoisation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable

# Op-type categories used for fusibility and the XLA-like baseline heuristic.
EW = "ew"            # elementwise / injective
REDUCE = "reduce"
DOT = "dot"
LAYOUT = "layout"    # reshape/transpose/broadcast/convert
OPAQUE = "opaque"    # scan/while/custom-call/sort/rng — never fused

FUSIBLE = {EW, REDUCE, DOT, LAYOUT}

_MASK64 = (1 << 64) - 1

# Distinguishes graph "families" (trace/profile lineages) so estimator caches
# keyed on group membership cannot alias across graphs whose prims carry
# different flops/bytes for the same pids.
_family_counter = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class PrimOp:
    pid: int
    op_type: str          # primitive name, e.g. "dot_general", "mul"
    category: str         # one of EW/REDUCE/DOT/LAYOUT/OPAQUE
    flops: float
    in_bytes: float       # bytes read from its inputs (standalone)
    out_bytes: float      # bytes written (standalone)
    time: float           # profiled standalone execution time (seconds)
    grad_param: int = -1  # index of the gradient leaf it produces, or -1
    grad_bytes: float = 0.0
    # partition signature of the gradient (tensor fusion may only merge
    # gradients reduced over the same axes / of the same dtype family).
    grad_sig: str = ""

    @property
    def fusible(self) -> bool:
        return self.category in FUSIBLE


def _group_hash(members: frozenset[int], provided: frozenset[int]) -> int:
    return hash((tuple(sorted(members)), tuple(sorted(provided)))) & _MASK64


class FusionGraph:
    """Mutable joint fusion state over an immutable prim DAG."""

    def __init__(self, prims: list[PrimOp], edges: Iterable[tuple[int, int]]):
        self.prims = list(prims)
        n = len(self.prims)
        self.psuccs: list[set[int]] = [set() for _ in range(n)]
        self.ppreds: list[set[int]] = [set() for _ in range(n)]
        for s, d in edges:
            self.psuccs[s].add(d)
            self.ppreds[d].add(s)
        # fusion state: every prim starts as a singleton group (gid == pid)
        self.groups: dict[int, frozenset[int]] = {
            p.pid: frozenset([p.pid]) for p in self.prims
        }
        self.provider: dict[int, int] = {p.pid: p.pid for p in self.prims}
        self._next_gid = n
        # tensor-fusion state: list of buckets; each bucket is an ordered
        # tuple of param indices.  Initially one bucket per gradient, in
        # topological production order.
        grads = sorted(
            (p for p in self.prims if p.grad_param >= 0), key=lambda p: p.pid
        )
        self.grad_prim: dict[int, int] = {p.grad_param: p.pid for p in grads}
        self.buckets: list[tuple[int, ...]] = [(p.grad_param,) for p in grads]
        # per-bucket collective algorithm ("ring" reproduces the seed model)
        self.bucket_algos: list[str] = ["ring"] * len(self.buckets)
        # per-bucket communication kind: fused AllReduce ("ar", the seed
        # model) or ZeRO-3-style reduce-scatter + all-gather ("rs_ag")
        self.bucket_comm: list[str] = ["ar"] * len(self.buckets)
        # per-bucket chunk count: >1 splits the fused tensor into chunks
        # that store-and-forward through the event engine's phase pipeline
        # (1, the seed model, is one whole-bucket collective)
        self.bucket_chunks: list[int] = [1] * len(self.buckets)
        # per-bucket in-kernel fusion flag: True issues the bucket's
        # collective from inside the producing kernel, reaching back into
        # the producer's tail by the cluster's calibrated overlap discount
        # (DESIGN.md Sec. 13); False is scheduled overlap (the seed model)
        self.bucket_fused: list[bool] = [False] * len(self.buckets)
        # searched pipeline-knob overrides: None (use the simulator's base
        # PipelineSchedule verbatim) or a partial (n_stages, n_microbatches,
        # interleave) tuple where None slots inherit from the base schedule
        # (resolved by repro.core.pipeline.resolve_schedule).  Only priced
        # on pipeline-enabled simulators — inert state everywhere else.
        self.pp_knobs: tuple | None = None
        self._rebuild_derived()

    @classmethod
    def _from_parts(cls, prims, psuccs, ppreds, groups, provider, next_gid,
                    grad_prim, buckets, family: int | None = None,
                    bucket_algos=None, bucket_comm=None,
                    bucket_chunks=None, bucket_fused=None,
                    pp_knobs=None) -> "FusionGraph":
        """Assemble a graph from explicit state (see ``profile_graph``);
        derived structures are rebuilt from scratch.  ``family`` pins the
        estimator-cache lineage when the prims are shared with an existing
        graph (search worker pools)."""
        g = object.__new__(cls)
        g.prims = prims
        g.psuccs = psuccs
        g.ppreds = ppreds
        g.groups = dict(groups)
        g.provider = dict(provider)
        g._next_gid = next_gid
        g.grad_prim = dict(grad_prim)
        g.buckets = list(buckets)
        g.bucket_algos = (list(bucket_algos) if bucket_algos is not None
                          else ["ring"] * len(g.buckets))
        g.bucket_comm = (list(bucket_comm) if bucket_comm is not None
                         else ["ar"] * len(g.buckets))
        g.bucket_chunks = (list(bucket_chunks) if bucket_chunks is not None
                           else [1] * len(g.buckets))
        g.bucket_fused = (list(bucket_fused) if bucket_fused is not None
                          else [False] * len(g.buckets))
        g.pp_knobs = None if pp_knobs is None else tuple(pp_knobs)
        g._rebuild_derived()
        if family is not None:
            g._family = family
        return g

    # -------------------------------------------------- derived structures
    def _rebuild_derived(self) -> None:
        """(Re)compute every derived structure from (prims, edges, groups,
        provider).  O(total membership x degree) — used only at construction;
        mutations keep the structures up to date incrementally."""
        self._qsuccs, self._qpreds = self._quotient_from_scratch()
        self._group_key: dict[int, int] = {
            gid: min(m) for gid, m in self.groups.items()
        }
        provided: dict[int, set[int]] = {gid: set() for gid in self.groups}
        for pid, gid in self.provider.items():
            provided[gid].add(pid)
        self._provided: dict[int, frozenset[int]] = {
            gid: frozenset(s) for gid, s in provided.items()
        }
        self._group_hash: dict[int, int] = {
            gid: _group_hash(m, self._provided[gid])
            for gid, m in self.groups.items()
        }
        self._ghash: int = sum(self._group_hash.values()) & _MASK64
        self._bucket_bytes_cache: dict[tuple[int, ...], float] = {}
        self._family: int = next(_family_counter)
        self._journal: list[tuple] = []
        self._base_token: int | None = None

    def _quotient_from_scratch(
        self,
    ) -> tuple[dict[int, set[int]], dict[int, set[int]]]:
        """The seed's full O(membership x degree) quotient rebuild.  Kept as
        the reference implementation: construction uses it, and the golden
        equivalence tests cross-check the incrementally maintained quotient
        against it after every mutation."""
        succs: dict[int, set[int]] = {g: set() for g in self.groups}
        preds: dict[int, set[int]] = {g: set() for g in self.groups}
        for gid, members in self.groups.items():
            for pid in members:
                for q in self.ppreds[pid]:
                    if q not in members:
                        src = self.provider[q]
                        if src != gid:
                            succs[src].add(gid)
                            preds[gid].add(src)
        return succs, preds

    # ------------------------------------------------------------------ util
    def clone(self) -> "FusionGraph":
        g = object.__new__(FusionGraph)
        g.prims = self.prims                  # immutable, shared
        g.psuccs = self.psuccs
        g.ppreds = self.ppreds
        g.groups = dict(self.groups)
        g.provider = dict(self.provider)
        g._next_gid = self._next_gid
        g.grad_prim = self.grad_prim
        g.buckets = list(self.buckets)
        g.bucket_algos = list(self.bucket_algos)
        g.bucket_comm = list(self.bucket_comm)
        g.bucket_chunks = list(self.bucket_chunks)
        g.bucket_fused = list(self.bucket_fused)
        g.pp_knobs = self.pp_knobs            # immutable tuple or None
        # quotient structures are shared: mutations are copy-on-write (they
        # replace modified adjacency sets, never mutate them in place)
        g._qsuccs = self._qsuccs
        g._qpreds = self._qpreds
        g._group_key = dict(self._group_key)
        g._provided = dict(self._provided)
        g._group_hash = dict(self._group_hash)
        g._ghash = self._ghash
        g._bucket_bytes_cache = self._bucket_bytes_cache  # content-keyed
        g._family = self._family
        g._journal = list(self._journal)
        g._base_token = self._base_token
        return g

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    def group_key(self, gid: int) -> frozenset[int]:
        return self.groups[gid]

    def family_token(self) -> int:
        """Identity of this graph's prim/edge lineage (shared by clones,
        fresh after re-profiling) — estimator cache-key component."""
        return self._family

    def provided_set(self, gid: int) -> frozenset[int]:
        """Members of ``gid`` whose outputs this group provides externally."""
        return self._provided[gid]

    # --------------------------------------------------------- quotient DAG
    def quotient(self) -> tuple[dict[int, set[int]], dict[int, set[int]]]:
        """Edges between groups: provider(q) -> G for q consumed by G from
        outside G.  Returns (succs, preds) keyed by gid.  Maintained
        incrementally by the mutations — this accessor is O(1)."""
        return self._qsuccs, self._qpreds

    @staticmethod
    def _cycle_through(succs: dict[int, set[int]], preds: dict[int, set[int]],
                       gs: set[int], gp: set[int], new_gid: int) -> bool:
        """Targeted cycle probe: after the merge, a cycle must pass through
        ``new_gid``, i.e. some successor in ``gs`` must reach some
        predecessor in ``gp``.  Bidirectional search with exhaustion stop —
        whichever of the downstream cone of ``gs`` / upstream cone of ``gp``
        is smaller bounds the work (a merge near either end of the DAG
        probes only the short side)."""
        seen_f = set(gs)
        seen_b = set(gp)
        if seen_f & seen_b:
            return True
        stack_f = list(gs)
        stack_b = list(gp)
        while stack_f and stack_b:
            if len(stack_f) <= len(stack_b):
                x = stack_f.pop()
                for d in succs[x]:
                    if d in seen_b:
                        return True
                    if d not in seen_f and d != new_gid:
                        seen_f.add(d)
                        stack_f.append(d)
            else:
                x = stack_b.pop()
                for d in preds[x]:
                    if d in seen_f:
                        return True
                    if d not in seen_b and d != new_gid:
                        seen_b.add(d)
                        stack_b.append(d)
        # one side exhausted without meeting the other: no gs ~> gp path
        return False

    def topo_groups(self) -> list[int]:
        succs, preds = self.quotient()
        indeg = {g: len(ps) for g, ps in preds.items()}
        # deterministic: prefer smaller min-member pid first
        import heapq

        key = self._group_key
        heap = [(key[g], g) for g, k in indeg.items() if k == 0]
        heapq.heapify(heap)
        order = []
        while heap:
            _, g = heapq.heappop(heap)
            order.append(g)
            for d in succs[g]:
                indeg[d] -= 1
                if indeg[d] == 0:
                    heapq.heappush(heap, (key[d], d))
        if len(order) != len(self.groups):
            raise RuntimeError("quotient graph is cyclic")
        return order

    # ----------------------------------------------------------- mutations
    def _fusible_group(self, gid: int) -> bool:
        return all(self.prims[p].fusible for p in self.groups[gid])

    def group_preds(self, gid: int) -> set[int]:
        return self._qpreds[gid]

    def group_succs(self, gid: int) -> set[int]:
        return self._qsuccs[gid]

    def _merged_quotient(
        self, removed: tuple[int, ...], merged: frozenset[int], new_gid: int
    ) -> tuple[dict, dict, set[int]] | None:
        """Copy-on-write quotient after replacing ``removed`` groups with the
        group ``new_gid`` = ``merged``.  Returns (succs, preds, preds_of_new)
        or None when the merge would create a cycle."""
        rm = set(removed)
        new_succs = dict(self._qsuccs)
        new_preds = dict(self._qpreds)
        # out-edges of the removed groups now originate from new_gid
        gs: set[int] = set()
        for r in removed:
            gs |= self._qsuccs[r]
        gs -= rm
        for d in gs:
            new_preds[d] = (new_preds[d] - rm) | {new_gid}
        # removed groups vanish from their predecessors' succ sets
        ps: set[int] = set()
        for r in removed:
            ps |= self._qpreds[r]
        ps -= rm
        for s in ps:
            new_succs[s] = new_succs[s] - rm
        # in-edges of the merged group: scan member externals — a prim that
        # used to be consumed across groups may now be internal to the merge
        gp: set[int] = set()
        provider = self.provider
        ppreds = self.ppreds
        for pid in merged:
            for q in ppreds[pid]:
                if q not in merged:
                    gp.add(provider[q])
        # no member of rm can appear in gp: provider[q] is a group containing
        # q, and q lies outside the merge while rm's members are all inside
        for s in gp:
            new_succs[s] = new_succs[s] | {new_gid}
        new_succs[new_gid] = gs
        new_preds[new_gid] = gp
        for r in removed:
            del new_succs[r], new_preds[r]
        # a new cycle must pass through new_gid: targeted reachability probe
        if self._cycle_through(new_succs, new_preds, gs, gp, new_gid):
            return None
        return new_succs, new_preds, gp

    def _commit_merge(self, removed: tuple[int, ...], merged: frozenset[int],
                      new_gid: int, new_succs: dict, new_preds: dict) -> None:
        self._qsuccs = new_succs
        self._qpreds = new_preds
        prov: set[int] = set()
        for r in removed:
            prov |= self._provided[r]
            del self.groups[r], self._provided[r], self._group_key[r]
            self._ghash = (self._ghash - self._group_hash.pop(r)) & _MASK64
        self.groups[new_gid] = merged
        provided = frozenset(prov)
        self._provided[new_gid] = provided
        for pid in provided:
            self.provider[pid] = new_gid
        self._group_key[new_gid] = min(merged)
        h = _group_hash(merged, provided)
        self._group_hash[new_gid] = h
        self._ghash = (self._ghash + h) & _MASK64
        self._next_gid = new_gid + 1
        self._journal.append(("fuse", removed, new_gid, frozenset(new_preds[new_gid])))

    def fuse_nondup(self, consumer: int, producer: int) -> bool:
        """Paper method (i): merge producer group into consumer group.
        Returns False (state unchanged) if invalid."""
        if consumer == producer:
            return False
        if consumer not in self.groups or producer not in self.groups:
            return False
        if not (self._fusible_group(consumer) and self._fusible_group(producer)):
            return False
        if producer not in self._qpreds[consumer]:
            return False
        merged = self.groups[consumer] | self.groups[producer]
        q = self._merged_quotient((consumer, producer), merged, self._next_gid)
        if q is None:
            return False
        new_succs, new_preds, _ = q
        self._commit_merge((consumer, producer), merged, self._next_gid,
                           new_succs, new_preds)
        return True

    def fuse_dup(self, consumer: int, producer: int) -> bool:
        """Paper method (ii): copy producer group's members into consumer
        group; the original producer group remains and keeps providing the
        outputs to its other successors (duplicate fusion, Fig. 1(iii))."""
        if consumer == producer:
            return False
        if consumer not in self.groups or producer not in self.groups:
            return False
        if not (self._fusible_group(consumer) and self._fusible_group(producer)):
            return False
        if producer not in self._qpreds[consumer]:
            return False
        merged = self.groups[consumer] | self.groups[producer]
        if merged == self.groups[consumer]:
            return False
        # Only the consumer group is replaced; the producer group remains and
        # its members keep their provider (duplicate copies are internal).
        q = self._merged_quotient((consumer,), merged, self._next_gid)
        if q is None:
            return False
        new_succs, new_preds, _ = q
        self._commit_merge((consumer,), merged, self._next_gid,
                           new_succs, new_preds)
        return True

    def merge_buckets(self, i: int, j: int) -> bool:
        """Paper method (iii): combine two *neighbouring* AllReduce buckets.
        Buckets are kept in gradient-production (topo) order; neighbours are
        adjacent buckets whose gradients share a compatible partition
        signature."""
        if i == j or not (0 <= i < len(self.buckets) and 0 <= j < len(self.buckets)):
            return False
        if abs(i - j) != 1:
            return False
        a, b = self.buckets[min(i, j)], self.buckets[max(i, j)]
        sig_a = self.prims[self.grad_prim[a[0]]].grad_sig
        sig_b = self.prims[self.grad_prim[b[0]]].grad_sig
        if sig_a != sig_b:
            return False
        lo = min(i, j)
        self.buckets[lo : lo + 2] = [a + b]
        # the merged bucket keeps the leading bucket's algorithm, comm kind,
        # chunk count and in-kernel fusion flag
        self.bucket_algos[lo : lo + 2] = [self.bucket_algos[lo]]
        self.bucket_comm[lo : lo + 2] = [self.bucket_comm[lo]]
        self.bucket_chunks[lo : lo + 2] = [self.bucket_chunks[lo]]
        self.bucket_fused[lo : lo + 2] = [self.bucket_fused[lo]]
        self._journal.append(("bucket", lo))
        return True

    def set_bucket_algo(self, i: int, algo: str) -> bool:
        """Cluster-extension method (iv): pick the collective algorithm for
        bucket ``i`` (see :mod:`repro.cluster.collectives`).  A no-op choice
        returns False so the search does not re-enqueue identical states."""
        from ..cluster import COLLECTIVE_ALGOS

        if algo not in COLLECTIVE_ALGOS:
            # fail at the call site, not as a KeyError deep in a (possibly
            # remote worker-pool) simulation
            raise ValueError(f"unknown collective algorithm {algo!r}; "
                             f"expected one of {COLLECTIVE_ALGOS}")
        if not 0 <= i < len(self.buckets):
            return False
        if self.bucket_algos[i] == algo:
            return False
        self.bucket_algos[i] = algo
        self._journal.append(("algo", i))
        return True

    def set_bucket_comm(self, i: int, kind: str) -> bool:
        """Event-engine method (v): pick bucket ``i``'s communication kind —
        one fused AllReduce (``"ar"``) or ZeRO-3-style reduce-scatter +
        all-gather (``"rs_ag"``), priced per link level by the event engine
        (DESIGN.md Sec. 8).  A no-op choice returns False."""
        from ..cluster import BUCKET_COMM_KINDS

        if kind not in BUCKET_COMM_KINDS:
            raise ValueError(f"unknown bucket comm kind {kind!r}; "
                             f"expected one of {BUCKET_COMM_KINDS}")
        if not 0 <= i < len(self.buckets):
            return False
        if self.bucket_comm[i] == kind:
            return False
        self.bucket_comm[i] = kind
        self._journal.append(("comm", i))
        return True

    def set_bucket_chunks(self, i: int, chunks: int) -> bool:
        """Event-engine method (vi): split bucket ``i`` into ``chunks``
        store-and-forward chunks pipelined through the link-level phases
        (DESIGN.md Sec. 9).  ``chunks=1`` is the whole-bucket collective;
        per-chunk phase coefficients sum to the unchunked ones, so the
        choice is pure scheduling.  A no-op choice returns False."""
        chunks = int(chunks)
        if chunks < 1:
            raise ValueError(f"bucket chunk count must be >= 1, got {chunks}")
        if not 0 <= i < len(self.buckets):
            return False
        if self.bucket_chunks[i] == chunks:
            return False
        self.bucket_chunks[i] = chunks
        self._journal.append(("chunk", i))
        return True

    def set_bucket_fused(self, i: int, flag: bool) -> bool:
        """Kernel method (vii): toggle in-kernel compute+comm fusion for
        bucket ``i`` (DESIGN.md Sec. 13).  A fused bucket's collective is
        issued from inside the producing kernel, so it may start
        ``discount x producer_duration`` before the producer finishes; link
        work is conserved (never a volume discount).  A no-op choice
        returns False."""
        flag = bool(flag)
        if not 0 <= i < len(self.buckets):
            return False
        if self.bucket_fused[i] == flag:
            return False
        self.bucket_fused[i] = flag
        self._journal.append(("fused", i))
        return True

    def set_pp_knobs(self, *, n_stages: int | None = None,
                     n_microbatches: int | None = None,
                     interleave: int | None = None) -> bool:
        """Pipeline method (viii): override slots of the simulator's base
        :class:`~repro.core.pipeline.PipelineSchedule`.  The override is a
        partial ``(n_stages, n_microbatches, interleave)`` tuple — passing
        a slot overwrites it, omitted slots keep their current override (or
        stay inherited from the base schedule).  Resolution against the
        base — clamping, interleave divisibility — happens at pricing time
        in :func:`repro.core.pipeline.resolve_schedule`, so the mutation is
        total.  Only pipeline-enabled simulators price this state; on any
        other sim it is inert (and the mutation registry never offers it
        there).  A no-op choice returns False."""
        vals = (n_stages, n_microbatches, interleave)
        for v in vals:
            if v is not None and int(v) < 1:
                raise ValueError(
                    f"pipeline knobs must be >= 1, got {vals}")
        cur = self.pp_knobs if self.pp_knobs is not None else (None,) * 3
        new = tuple(cur[k] if vals[k] is None else int(vals[k])
                    for k in range(3))
        if new == (None,) * 3 or new == self.pp_knobs:
            return False
        self.pp_knobs = new
        self._journal.append(("pp",))
        return True

    def reset_pp_knobs(self) -> bool:
        """Drop every pipeline-knob override (back to the simulator's base
        schedule).  Used by cache warm-start when the target simulator
        cannot price the pipeline dimensions.  Returns False if already
        clear."""
        if self.pp_knobs is None:
            return False
        self.pp_knobs = None
        self._journal.append(("pp",))
        return True

    # ------------------------------------------------------------ accessors
    def group_external_io(self, gid: int) -> tuple[float, float]:
        """(external input bytes, external output bytes) of a fused group —
        intermediates that stay inside the group are elided (the fusion
        memory saving of paper Sec. 2.2)."""
        members = self.groups[gid]
        in_b = 0.0
        out_b = 0.0
        for pid in members:
            p = self.prims[pid]
            ext_preds = [q for q in self.ppreds[pid] if q not in members]
            if self.ppreds[pid]:
                frac = len(ext_preds) / len(self.ppreds[pid])
                # matmul operands must be (re)streamed even when produced
                # in-group: internal elision is only partial for DOT inputs.
                if p.category == "dot":
                    frac = frac + 0.5 * (1.0 - frac)
                in_b += p.in_bytes * frac
            else:
                in_b += p.in_bytes
            # Output leaves the group iff some consumer is external (or it is
            # a graph output / gradient) AND this group is the prim's
            # provider.  A duplicated copy's output stays in-group.
            needs_out = (
                p.grad_param >= 0
                or not self.psuccs[pid]
                or any(q not in members for q in self.psuccs[pid])
            )
            if needs_out and self.provider[pid] == gid:
                out_b += p.out_bytes
        return in_b, out_b

    def group_flops(self, gid: int) -> float:
        return sum(self.prims[p].flops for p in self.groups[gid])

    def bucket_bytes(self, bucket: tuple[int, ...]) -> float:
        # content-keyed memo shared across clones (same prim lineage);
        # summation order matches the seed's left-to-right element sum
        t = self._bucket_bytes_cache.get(bucket)
        if t is None:
            t = sum(self.prims[self.grad_prim[g]].grad_bytes for g in bucket)
            self._bucket_bytes_cache[bucket] = t
        return t

    def bucket_ready_groups(self, bucket: tuple[int, ...]) -> set[int]:
        return {self.provider[self.grad_prim[g]] for g in bucket}

    def bucket_deps(self) -> list[tuple[int, ...]]:
        """Per-bucket provider groups as sorted tuples — the dependency
        edges of each bucket's comm job in the unified event engine
        (bucket ``i`` may start once every group in ``bucket_deps()[i]``
        has finished).  Index-aligned with ``self.buckets``; sorted so the
        dep tuples are deterministic regardless of set iteration order."""
        gp = self.grad_prim
        prov = self.provider
        return [tuple(sorted({prov[gp[g]] for g in b})) for b in self.buckets]

    def signature(self) -> tuple:
        """Hashable fingerprint of the strategy (for serialization-grade
        identity; ``fast_signature`` is the O(1) search-memo variant)."""
        gs = tuple(sorted(tuple(sorted(m)) for m in self.groups.values()))
        pv = tuple(sorted(self.provider.items()))
        bk = tuple(self.buckets)
        return (gs, pv, bk, tuple(self.bucket_algos),
                tuple(self.bucket_comm), tuple(self.bucket_chunks),
                tuple(self.bucket_fused), self.pp_knobs)

    def fast_signature(self) -> tuple[int, int]:
        """Order-independent rolling hash of (groups, provider, buckets,
        bucket algos, comm kinds, chunk counts), maintained by the
        mutations — O(#buckets) instead of O(V log V)."""
        return (self._ghash,
                hash((tuple(self.buckets), tuple(self.bucket_algos),
                      tuple(self.bucket_comm), tuple(self.bucket_chunks),
                      tuple(self.bucket_fused), self.pp_knobs)))

    # --------------------------------------------------------------- stats
    def describe(self) -> dict:
        return {
            "prims": len(self.prims),
            "groups": len(self.groups),
            "fused_groups": sum(1 for m in self.groups.values() if len(m) > 1),
            "duplicated_prims": sum(
                1
                for pid in range(len(self.prims))
                for gid, m in self.groups.items()
                if pid in m and self.provider[pid] != gid
            ),
            "allreduce_buckets": len(self.buckets),
            "grad_tensors": len(self.grad_prim),
            "bucket_algos": {
                a: self.bucket_algos.count(a) for a in set(self.bucket_algos)
            },
            "bucket_comm": {
                k: self.bucket_comm.count(k) for k in set(self.bucket_comm)
            },
            "bucket_chunks": {
                k: self.bucket_chunks.count(k)
                for k in set(self.bucket_chunks)
            },
            "fused_comm_buckets": sum(1 for f in self.bucket_fused if f),
            "pp_knobs": self.pp_knobs,
        }

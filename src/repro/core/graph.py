"""Fusion IR — the joint op/tensor-fusion strategy state DisCo searches over.

The IR has two levels:

* **Primitive level** (immutable): ``PrimOp`` nodes and dependency edges, as
  extracted from a jaxpr by :mod:`repro.core.trace` (or built synthetically).
  A prim that produces a parameter gradient carries ``grad_param >= 0`` and
  ``grad_bytes > 0`` — its tensor must be AllReduced in data-parallel training.

* **Fusion state** (mutable): a partition of prims into *groups* (fused ops).
  Duplicate fusion (paper Fig. 1(iii)) lets a prim be a member of several
  groups; exactly one group is its *provider* — the occurrence whose
  completion makes the prim's output available to external consumers.
  AllReduce instructions are partitioned into *buckets* (tensor fusion).

Mutations (`fuse_nondup`, `fuse_dup`, `merge_buckets`) are the paper's three
optimisation methods (Sec. 4.5); each validates DAG-ness of the quotient
graph and op fusibility before committing.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

# Op-type categories used for fusibility and the XLA-like baseline heuristic.
EW = "ew"            # elementwise / injective
REDUCE = "reduce"
DOT = "dot"
LAYOUT = "layout"    # reshape/transpose/broadcast/convert
OPAQUE = "opaque"    # scan/while/custom-call/sort/rng — never fused

FUSIBLE = {EW, REDUCE, DOT, LAYOUT}


@dataclasses.dataclass(frozen=True)
class PrimOp:
    pid: int
    op_type: str          # primitive name, e.g. "dot_general", "mul"
    category: str         # one of EW/REDUCE/DOT/LAYOUT/OPAQUE
    flops: float
    in_bytes: float       # bytes read from its inputs (standalone)
    out_bytes: float      # bytes written (standalone)
    time: float           # profiled standalone execution time (seconds)
    grad_param: int = -1  # index of the gradient leaf it produces, or -1
    grad_bytes: float = 0.0
    # partition signature of the gradient (tensor fusion may only merge
    # gradients reduced over the same axes / of the same dtype family).
    grad_sig: str = ""

    @property
    def fusible(self) -> bool:
        return self.category in FUSIBLE


class FusionGraph:
    """Mutable joint fusion state over an immutable prim DAG."""

    def __init__(self, prims: list[PrimOp], edges: Iterable[tuple[int, int]]):
        self.prims = list(prims)
        n = len(self.prims)
        self.psuccs: list[set[int]] = [set() for _ in range(n)]
        self.ppreds: list[set[int]] = [set() for _ in range(n)]
        for s, d in edges:
            self.psuccs[s].add(d)
            self.ppreds[d].add(s)
        # fusion state: every prim starts as a singleton group (gid == pid)
        self.groups: dict[int, frozenset[int]] = {
            p.pid: frozenset([p.pid]) for p in self.prims
        }
        self.provider: dict[int, int] = {p.pid: p.pid for p in self.prims}
        self._next_gid = n
        # tensor-fusion state: list of buckets; each bucket is an ordered
        # tuple of param indices.  Initially one bucket per gradient, in
        # topological production order.
        grads = sorted(
            (p for p in self.prims if p.grad_param >= 0), key=lambda p: p.pid
        )
        self.grad_prim: dict[int, int] = {p.grad_param: p.pid for p in grads}
        self.buckets: list[tuple[int, ...]] = [(p.grad_param,) for p in grads]
        self._quotient_cache: tuple | None = None

    # ------------------------------------------------------------------ util
    def clone(self) -> "FusionGraph":
        g = object.__new__(FusionGraph)
        g.prims = self.prims                  # immutable, shared
        g.psuccs = self.psuccs
        g.ppreds = self.ppreds
        g.groups = dict(self.groups)
        g.provider = dict(self.provider)
        g._next_gid = self._next_gid
        g.grad_prim = self.grad_prim
        g.buckets = list(self.buckets)
        g._quotient_cache = self._quotient_cache
        return g

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    def group_key(self, gid: int) -> frozenset[int]:
        return self.groups[gid]

    # --------------------------------------------------------- quotient DAG
    def quotient(self) -> tuple[dict[int, set[int]], dict[int, set[int]]]:
        """Edges between groups: provider(q) -> G for q consumed by G from
        outside G.  Returns (succs, preds) keyed by gid."""
        if self._quotient_cache is not None:
            return self._quotient_cache
        succs: dict[int, set[int]] = {g: set() for g in self.groups}
        preds: dict[int, set[int]] = {g: set() for g in self.groups}
        for gid, members in self.groups.items():
            for pid in members:
                for q in self.ppreds[pid]:
                    if q not in members:
                        src = self.provider[q]
                        if src != gid:
                            succs[src].add(gid)
                            preds[gid].add(src)
        self._quotient_cache = (succs, preds)
        return self._quotient_cache

    def _acyclic(self, succs: dict[int, set[int]]) -> bool:
        indeg = {g: 0 for g in succs}
        for g, ss in succs.items():
            for d in ss:
                indeg[d] += 1
        stack = [g for g, k in indeg.items() if k == 0]
        seen = 0
        while stack:
            g = stack.pop()
            seen += 1
            for d in succs[g]:
                indeg[d] -= 1
                if indeg[d] == 0:
                    stack.append(d)
        return seen == len(succs)

    def topo_groups(self) -> list[int]:
        succs, preds = self.quotient()
        indeg = {g: len(ps) for g, ps in preds.items()}
        # deterministic: prefer smaller min-member pid first
        import heapq

        key = {g: min(m) for g, m in self.groups.items()}
        heap = [(key[g], g) for g, k in indeg.items() if k == 0]
        heapq.heapify(heap)
        order = []
        while heap:
            _, g = heapq.heappop(heap)
            order.append(g)
            for d in succs[g]:
                indeg[d] -= 1
                if indeg[d] == 0:
                    heapq.heappush(heap, (key[d], d))
        if len(order) != len(self.groups):
            raise RuntimeError("quotient graph is cyclic")
        return order

    # ----------------------------------------------------------- mutations
    def _fusible_group(self, gid: int) -> bool:
        return all(self.prims[p].fusible for p in self.groups[gid])

    def group_preds(self, gid: int) -> set[int]:
        return self.quotient()[1][gid]

    def group_succs(self, gid: int) -> set[int]:
        return self.quotient()[0][gid]

    def fuse_nondup(self, consumer: int, producer: int) -> bool:
        """Paper method (i): merge producer group into consumer group.
        Returns False (state unchanged) if invalid."""
        if consumer == producer:
            return False
        if consumer not in self.groups or producer not in self.groups:
            return False
        if not (self._fusible_group(consumer) and self._fusible_group(producer)):
            return False
        if producer not in self.group_preds(consumer):
            return False
        merged = self.groups[consumer] | self.groups[producer]
        trial = self.clone()
        gid = trial._next_gid
        trial._next_gid += 1
        del trial.groups[consumer], trial.groups[producer]
        trial.groups[gid] = merged
        for pid, prov in list(trial.provider.items()):
            if prov in (consumer, producer):
                trial.provider[pid] = gid
        trial._quotient_cache = None
        succs, _ = trial.quotient()
        if not trial._acyclic(succs):
            return False
        self._commit(trial)
        return True

    def fuse_dup(self, consumer: int, producer: int) -> bool:
        """Paper method (ii): copy producer group's members into consumer
        group; the original producer group remains and keeps providing the
        outputs to its other successors (duplicate fusion, Fig. 1(iii))."""
        if consumer == producer:
            return False
        if consumer not in self.groups or producer not in self.groups:
            return False
        if not (self._fusible_group(consumer) and self._fusible_group(producer)):
            return False
        if producer not in self.group_preds(consumer):
            return False
        # Gradient-producing prims must not be duplicated (their output is
        # consumed by AllReduce; recomputing is fine but provider stays put —
        # allowed).  Disallow duplicating OPAQUE already covered by fusible.
        trial = self.clone()
        merged = self.groups[consumer] | self.groups[producer]
        if merged == self.groups[consumer]:
            return False
        gid = trial._next_gid
        trial._next_gid += 1
        del trial.groups[consumer]
        trial.groups[gid] = merged
        for pid, prov in list(trial.provider.items()):
            if prov == consumer:
                trial.provider[pid] = gid
        # provider of producer's members unchanged (duplicate).
        trial._quotient_cache = None
        succs, _ = trial.quotient()
        if not trial._acyclic(succs):
            return False
        self._commit(trial)
        return True

    def merge_buckets(self, i: int, j: int) -> bool:
        """Paper method (iii): combine two *neighbouring* AllReduce buckets.
        Buckets are kept in gradient-production (topo) order; neighbours are
        adjacent buckets whose gradients share a compatible partition
        signature."""
        if i == j or not (0 <= i < len(self.buckets) and 0 <= j < len(self.buckets)):
            return False
        if abs(i - j) != 1:
            return False
        a, b = self.buckets[min(i, j)], self.buckets[max(i, j)]
        sig_a = self.prims[self.grad_prim[a[0]]].grad_sig
        sig_b = self.prims[self.grad_prim[b[0]]].grad_sig
        if sig_a != sig_b:
            return False
        lo = min(i, j)
        self.buckets[lo : lo + 2] = [a + b]
        return True

    def _commit(self, trial: "FusionGraph") -> None:
        self.groups = trial.groups
        self.provider = trial.provider
        self._next_gid = trial._next_gid
        self._quotient_cache = trial._quotient_cache

    # ------------------------------------------------------------ accessors
    def group_external_io(self, gid: int) -> tuple[float, float]:
        """(external input bytes, external output bytes) of a fused group —
        intermediates that stay inside the group are elided (the fusion
        memory saving of paper Sec. 2.2)."""
        members = self.groups[gid]
        in_b = 0.0
        out_b = 0.0
        for pid in members:
            p = self.prims[pid]
            ext_preds = [q for q in self.ppreds[pid] if q not in members]
            if self.ppreds[pid]:
                frac = len(ext_preds) / len(self.ppreds[pid])
                # matmul operands must be (re)streamed even when produced
                # in-group: internal elision is only partial for DOT inputs.
                if p.category == "dot":
                    frac = frac + 0.5 * (1.0 - frac)
                in_b += p.in_bytes * frac
            else:
                in_b += p.in_bytes
            # Output leaves the group iff some consumer is external (or it is
            # a graph output / gradient) AND this group is the prim's
            # provider.  A duplicated copy's output stays in-group.
            needs_out = (
                p.grad_param >= 0
                or not self.psuccs[pid]
                or any(q not in members for q in self.psuccs[pid])
            )
            if needs_out and self.provider[pid] == gid:
                out_b += p.out_bytes
        return in_b, out_b

    def group_flops(self, gid: int) -> float:
        return sum(self.prims[p].flops for p in self.groups[gid])

    def bucket_bytes(self, bucket: tuple[int, ...]) -> float:
        return sum(self.prims[self.grad_prim[g]].grad_bytes for g in bucket)

    def bucket_ready_groups(self, bucket: tuple[int, ...]) -> set[int]:
        return {self.provider[self.grad_prim[g]] for g in bucket}

    def signature(self) -> tuple:
        """Hashable fingerprint of the strategy (for memoisation)."""
        gs = tuple(sorted(tuple(sorted(m)) for m in self.groups.values()))
        pv = tuple(sorted(self.provider.items()))
        bk = tuple(self.buckets)
        return (gs, pv, bk)

    # --------------------------------------------------------------- stats
    def describe(self) -> dict:
        return {
            "prims": len(self.prims),
            "groups": len(self.groups),
            "fused_groups": sum(1 for m in self.groups.values() if len(m) > 1),
            "duplicated_prims": sum(
                1
                for pid in range(len(self.prims))
                for gid, m in self.groups.items()
                if pid in m and self.provider[pid] != gid
            ),
            "allreduce_buckets": len(self.buckets),
            "grad_tensors": len(self.grad_prim),
        }

"""End-to-end HLO execution-time simulator (paper Sec. 4.4).

Replays a :class:`FusionGraph` on one device:

* one serialized **compute stream** — a FIFO ready queue of fused ops; a
  ready op starts at ``max(device_free, preds done)``;
* one serialized **communication channel** — AllReduce buckets start when
  (a) every gradient in the bucket has been produced (its provider group is
  done) and (b) the channel is clear; communication overlaps compute.

Per-iteration time = max(last compute completion, last AllReduce completion).
The FO (full-overlap) bound is ``max(total_compute, total_comm)`` — maximal
overlap ignoring dependencies (paper Sec. 6.2).

The communication channel is priced by the phase-level event engine
(:mod:`repro.core.events`): with the default ``streams=1`` it is the
serialized channel above, bit-identical to the seed; with ``streams > 1``
buckets pipeline their per-link-level phases concurrently under fair-share
bandwidth division (DESIGN.md Sec. 8).

Incremental (delta) cost evaluation
-----------------------------------

``Simulator`` memoises the full schedule of every graph it replays (pop
order, per-group completion times, running busy time) in an LRU keyed by a
state token stamped onto the graph.  A mutated clone carries a *journal* of
mutations relative to its ancestor's token (see :mod:`repro.core.graph`),
and ``run()`` re-simulates only the suffix of the schedule the journal can
affect:

* The compute stream is serialized and the pop order is independent of op
  times, so the schedule prefix up to the *divergence bound* ``k`` is reused
  verbatim.  ``k`` is the earliest position at which any group removed by
  the journal was popped, or at which a journal-created group could first
  have been popped (one past the max position of its quotient
  predecessors) — before ``k`` the old and new ready heaps pop identically.
* From ``k`` the replay continues with the maintained quotient: remaining
  in-degrees are counted against the already-popped prefix, completion
  times accumulate from the cached prefix sums, and AllReduce bucket
  readiness is re-derived as the max completion over each bucket's provider
  groups.  Floating-point accumulation order matches the full replay, so
  delta results are **bit-identical** to a from-scratch run.
* Tensor-fusion (bucket) and collective-algorithm mutations never perturb
  the compute stream: only the O(B log B) communication pass is recomputed.

The delta path **falls back to full replay** whenever it would not be
exact: no cached ancestor state (evicted or never simulated), a journal
longer than ``max_journal``, a timeline request, or any inconsistency
detected while replaying (missing groups, cyclic quotient).  ``Simulator.stats``
counts full/delta/fallback evaluations.  Construct with
``incremental=False`` for the seed full-replay-only behaviour (the golden
equivalence tests run both paths and assert identical results).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import OrderedDict

from ..cluster import (COLLECTIVE_ALGOS, ClusterSpec, KIND_AR, KIND_RS_AG,
                       comm_coeffs, overlap_discount_for, phases)
from .costs import OracleEstimator, total_comm_time, total_compute_time
from .events import (BackgroundTraffic, CommJob, ComputeJob, EventEngine,
                     TC_COMPUTE, TC_DP, TC_PP, TC_TP, bucket_jobs)
from .graph import FusionGraph
from .hw import Hardware, TPU_V5E
from .tp_traffic import (TPTraffic, balanced_spans, couple_tp,
                         couple_tp_pipeline)

_token_counter = itertools.count(1)


@dataclasses.dataclass
class SimResult:
    iteration_time: float
    compute_time: float          # sum of fused-op times (busy compute)
    comm_time: float             # sum of AllReduce times (busy channel)
    compute_finish: float
    comm_finish: float
    overlap_ratio: float         # (compute_time+comm_time)/iteration_time
    timeline: list | None = None
    # pipeline-schedule runs only: bubble / per-stage occupancy stats
    # (None for the default single-device replay)
    pipeline: dict | None = None
    # dep-coupled TP-traffic runs only (Simulator(tp=...)): lowering mode,
    # per-layer volumes and tp-class busy/finish tallies (DESIGN.md Sec. 14)
    tp: dict | None = None


@dataclasses.dataclass
class _SimState:
    """Cached schedule of one full/delta replay (delta-resume substrate)."""
    order: list            # gids in pop order
    done_at: dict          # gid -> completion time
    busy_after: list       # cumulative compute-busy after each pop
    times: dict            # gid -> fused-op time (gids are never reused
    #                        across a state's descendants, so these stay
    #                        valid for every journal that resumes from it)
    result: SimResult
    _pos: dict | None = None

    @property
    def pos(self) -> dict:
        # built lazily: about half of all states are never resumed from
        if self._pos is None:
            self._pos = {gid: i for i, gid in enumerate(self.order)}
        return self._pos


class Simulator:
    """Cost model Cost(H) driving the backtracking search."""

    def __init__(self, estimator=None, hw: Hardware = TPU_V5E, n_devices: int = 256,
                 keep_timeline: bool = False, incremental: bool = True,
                 state_cache_size: int = 64, max_journal: int = 24,
                 cluster: ClusterSpec | None = None, streams: int = 1,
                 background: tuple = (), pipeline=None,
                 overlap_discount: float | None = None,
                 tp: TPTraffic | None = None,
                 level_chunks: bool = False):
        self.estimator = estimator or OracleEstimator(hw)
        self.hw = hw
        # legacy (hw, n_devices) maps to the flat back-compat spec — comm
        # times stay bit-identical to the seed's allreduce_time model.  A
        # real ClusterSpec overrides n_devices and prices each bucket by its
        # chosen collective algorithm (DESIGN.md Sec. 7).
        if cluster is None:
            cluster = ClusterSpec.flat(hw, n_devices)
        else:
            n_devices = cluster.n_devices
        self.cluster = cluster
        self.n_devices = n_devices
        # the comm pass is the phase-level event engine; streams=1 is the
        # serialized channel, bit-identical to the seed (DESIGN.md Sec. 8).
        # Every collective model is linear in bytes: resolve the (C, D)
        # pairs per (algo, comm-kind) once so the hot serialized pass stays
        # a dict hit + multiply-add (no per-bucket job objects).
        self.streams = max(int(streams), 1)
        # recurring TP/PP collectives (BackgroundTraffic) injected alongside
        # the gradient buckets on multi-stream sims: searched strategies are
        # priced under fabric contention from non-gradient traffic
        # (DESIGN.md Sec. 9).  Ignored on the serialized channel, which is
        # the seed model and must stay bit-identical.
        self.background: tuple[BackgroundTraffic, ...] = tuple(background)
        # a PipelineSchedule routes run() through the coupled engine path
        # (_run_pipeline): fused groups are split into stages, lowered to
        # 1F1B compute+p2p job graphs, and priced together with the
        # gradient buckets (DESIGN.md Sec. 11).  None = the paper's
        # single-device replay.
        self.pipeline = pipeline
        # a TPTraffic promotes tensor-parallel activation collectives from
        # periodic BackgroundTraffic averages to first-class scheduled jobs
        # dep-coupled to the compute that produces and consumes them
        # (DESIGN.md Sec. 14): span-lowered on the single-device replay
        # (_run_tp), per-1F1B-unit under a pipeline schedule.  Ignored on
        # the serialized channel (streams=1), like background traffic —
        # the seed model stays bit-identical.
        self.tp = tp
        # in-kernel fusion overlap discount (DESIGN.md Sec. 13): how far a
        # fused bucket's collective reaches back into its producing compute
        # job's tail, as a fraction of the producer's duration.  Resolved
        # from the per-preset calibration table (0.0 on flat/uncalibrated
        # specs, where fused buckets price exactly as their base kind and
        # METHOD_FUSED drops out of the search).
        if overlap_discount is None:
            overlap_discount = overlap_discount_for(cluster)
        self.overlap_discount = float(overlap_discount)
        # per-level chunk sizing (DESIGN.md Sec. 14): opt-in, off keeps
        # uniform chunk_phases schedules bit-identical to PR 1-8
        self.level_chunks = bool(level_chunks)
        self._engine = EventEngine(cluster, streams=self.streams,
                                   level_chunks=self.level_chunks)
        self._ar_coeffs = {
            algo: comm_coeffs(cluster, algo, KIND_AR)
            for algo in COLLECTIVE_ALGOS
        }
        self._rs_ag_coeffs = {
            algo: comm_coeffs(cluster, algo, KIND_RS_AG)
            for algo in COLLECTIVE_ALGOS
        }
        self.keep_timeline = keep_timeline
        self.incremental = incremental
        self.max_journal = max_journal
        self._states: OrderedDict[int, _SimState] = OrderedDict()
        self._state_cache_size = state_cache_size
        self.stats = {"full": 0, "delta": 0, "cached": 0, "fallback": 0}

    def cost(self, g: FusionGraph) -> float:
        return self.run(g).iteration_time

    def run(self, g: FusionGraph) -> SimResult:
        if self.pipeline is not None:
            # multi-stream coupled schedule: the pop-order prefix argument
            # behind delta resume does not hold, so pipeline pricing is
            # always a full (non-incremental) replay
            self.stats["full"] += 1
            return self._run_pipeline(g)
        if self.tp is not None and self.streams > 1:
            # dep-coupled TP jobs add comm->compute edges, so the pop-order
            # prefix argument behind delta resume does not hold either:
            # always a full replay
            self.stats["full"] += 1
            return self._run_tp(g)
        if not self.incremental:
            return self._run_full(g, record=False).result
        base = None
        if g._base_token is not None:
            base = self._states.get(g._base_token)
            if base is not None:
                self._states.move_to_end(g._base_token)
        if base is not None and not g._journal:
            # a keep_timeline sim only ever remembers timeline-carrying
            # states, so the cached result can be returned as-is
            self.stats["cached"] += 1
            return base.result
        state = None
        if base is not None and len(g._journal) <= self.max_journal:
            state = self._run_delta(g, base)
            if state is None:
                self.stats["fallback"] += 1
        if state is None:
            state = self._run_full(g, record=True)
            self.stats["full"] += 1
        else:
            self.stats["delta"] += 1
        self._remember(g, state)
        return state.result

    # ------------------------------------------------------------ full path
    def _compute_jobs(self, g: FusionGraph):
        """Fused groups as engine compute jobs: ``job_id = ~gid`` (compute
        ids are negative by convention), ``key`` the serialized pop-order
        tie-break, ``deps`` the quotient predecessors.  Returns
        ``(jobs, times)`` with ``times`` the per-gid durations (the
        ``_SimState.times`` cache)."""
        _, preds = g.quotient()
        key = g._group_key
        group_time = self.estimator.group_time
        times: dict[int, float] = {}
        jobs = []
        for gid in g.groups:
            t = group_time(g, gid)
            times[gid] = t
            # (group key, gid): duplication-allowed fusion means min member
            # pids can tie across groups — the gid component restores the
            # seed heap's ascending-gid tie-break
            jobs.append(ComputeJob(
                ref=gid, duration=t, job_id=~gid, key=(key[gid], gid),
                deps=tuple(~p for p in preds[gid])))
        return jobs, times

    def _grad_jobs(self, g: FusionGraph):
        """Gradient buckets as dependency-carrying comm jobs: bucket ``i``
        deps on the compute jobs of its provider groups (the engine derives
        readiness — no ``bucket_waiting`` side-channel).  Zero-byte buckets
        are skipped in both channel models: nothing transfers, so no
        latency is charged (streams=1 parity with the seed comm pass).
        ``streams=1`` keeps whole-bucket jobs (the serialized channel
        ignores chunking, as the seed did); ``streams > 1`` applies the
        chunk decomposition.  Returns ``(jobs, next_id)``."""
        algos = g.bucket_algos
        kinds = g.bucket_comm
        buckets = g.buckets
        deps_of = g.bucket_deps()
        jobs = []
        next_id = len(buckets)
        if self.streams == 1:
            for i in range(len(buckets)):
                nbytes = g.bucket_bytes(buckets[i])
                if nbytes <= 0.0:
                    continue
                jobs.append(CommJob(
                    bucket=i, ready=0.0, nbytes=nbytes, algo=algos[i],
                    kind=kinds[i], deps=tuple(~p for p in deps_of[i])))
            return jobs, next_id
        chunks = g.bucket_chunks
        fused = g.bucket_fused
        disc = self.overlap_discount
        for i in range(len(buckets)):
            nbytes = g.bucket_bytes(buckets[i])
            if nbytes <= 0.0:
                continue
            js, next_id = bucket_jobs(i, 0.0, nbytes, algos[i], kinds[i],
                                      chunks[i], next_id,
                                      deps=tuple(~p for p in deps_of[i]),
                                      discount=disc if fused[i] else 0.0)
            jobs.extend(js)
        return jobs, next_id

    def _run_full(self, g: FusionGraph, record: bool) -> _SimState:
        compute, times = self._compute_jobs(g)
        comm, next_id = self._grad_jobs(g)
        timeline = [] if self.keep_timeline else None
        bg = self.background if self.streams > 1 else ()
        try:
            u = self._engine.run_unified(compute, comm, timeline,
                                         background=bg, bg_base_id=next_id)
        except RuntimeError as e:
            raise RuntimeError("cyclic fusion graph in simulator") from e
        result = self._make_result(u.compute_busy, u.comm_busy,
                                   u.compute_finish, u.comm_finish, timeline)
        if not record:
            return _SimState(order=[], done_at={}, busy_after=[], times={},
                             result=result)
        return _SimState(order=u.order, done_at=u.done_at,
                         busy_after=u.busy_after, times=times, result=result)

    # -------------------------------------------------------- pipeline path
    def pipeline_inputs(self, g: FusionGraph) -> dict:
        """Derive the 1F1B lowering's inputs from the fused graph: the
        serialized single-device schedule is bisected into ``n_stages``
        contiguous, busy-balanced spans; each span's time splits into
        per-microbatch fwd/bwd unit durations by ``fwd_bwd_ratio``; the
        stage-boundary p2p volume defaults to the mean activation
        (out_bytes) of the groups at the stage cuts, per microbatch.

        The schedule is the base ``self.pipeline`` with the graph's
        searched ``pp_knobs`` overrides resolved onto it
        (:func:`repro.core.pipeline.resolve_schedule`)."""
        sched = self._resolve_pipeline(g)
        compute, _ = self._compute_jobs(g)
        u = self._engine.run_unified(compute, [])
        S = sched.n_stages
        if S > len(u.order):
            raise ValueError(f"n_stages={S} exceeds {len(u.order)} fused "
                             "groups — nothing to split")
        ends = balanced_spans(u.busy_after, S)
        group_stage: dict[int, int] = {}
        stage_busy = []
        stage_groups = []
        prev = 0
        for s in range(S):
            hi = ends[s]
            for gid in u.order[prev:hi]:
                group_stage[gid] = s
            lo_busy = u.busy_after[prev - 1] if prev else 0.0
            stage_busy.append(u.busy_after[hi - 1] - lo_busy)
            stage_groups.append(hi - prev)
            prev = hi
        M = sched.n_microbatches
        r = sched.fwd_bwd_ratio
        stage_fwd = [b / M * (r / (1.0 + r)) for b in stage_busy]
        stage_bwd = [b / M - f for b, f in zip(stage_busy, stage_fwd)]
        if sched.p2p_bytes is not None:
            pbytes = sched.p2p_bytes
        else:
            outs = []
            for s in range(S - 1):
                boundary_gid = u.order[ends[s] - 1]
                outs.append(sum(g.prims[p].out_bytes
                                for p in g.groups[boundary_gid]))
            pbytes = (sum(outs) / len(outs) / M) if outs else 0.0
        return {"group_stage": group_stage, "stage_busy": stage_busy,
                "stage_groups": stage_groups, "stage_fwd": stage_fwd,
                "stage_bwd": stage_bwd, "p2p_bytes": pbytes}

    def _resolve_pipeline(self, g: FusionGraph):
        """The base schedule with ``g.pp_knobs`` overrides applied (clamped
        to this graph's group count — the stage bisection needs at least
        one group per stage)."""
        from .pipeline import resolve_schedule
        return resolve_schedule(self.pipeline, getattr(g, "pp_knobs", None),
                                len(g.groups))

    def _run_pipeline(self, g: FusionGraph) -> SimResult:
        from .pipeline import bubble_stats, lower_schedule
        sched = self._resolve_pipeline(g)
        pi = self.pipeline_inputs(g)
        buckets = g.buckets
        chunks = g.bucket_chunks
        nb = [g.bucket_bytes(b) for b in buckets]
        # id layout: buckets 0..B-1, then chunk jobs, then p2p, then
        # background — count the chunk ids before lowering allocates p2p's
        cid = len(buckets)
        for i in range(len(buckets)):
            if nb[i] > 0.0 and chunks[i] > 1:
                cid += chunks[i]
        cjobs, p2p, last_bwd, bg_base = lower_schedule(
            sched, pi["stage_fwd"], pi["stage_bwd"], pi["p2p_bytes"],
            next_id=cid)
        # dep-coupled TP activation traffic (DESIGN.md Sec. 14): each
        # (stage, microbatch, fwd/bwd) unit carries its share of the
        # per-layer collectives; synchronous TP blocks the device's next
        # unit, and the last backward unit's collective replaces
        # last_bwd[s] as the stage's gradient gate
        tp_jobs: list = []
        if self.tp is not None:
            cjobs, tp_jobs, grad_gate, bg_base = couple_tp_pipeline(
                cjobs, sched, self.tp, bg_base)
            if grad_gate is not None:
                last_bwd = [grad_gate[s] if grad_gate[s] is not None
                            else last_bwd[s] for s in range(sched.n_stages)]
        # gradient buckets dep on the *last backward unit* of every stage
        # that provides them: that is when the stage's gradient
        # accumulation over all microbatches completes
        group_stage = pi["group_stage"]
        deps_of = g.bucket_deps()
        algos = g.bucket_algos
        kinds = g.bucket_comm
        comm = []
        next_id = len(buckets)
        for i in range(len(buckets)):
            if nb[i] <= 0.0:
                continue
            stages = sorted({group_stage[p] for p in deps_of[i]})
            bdeps = tuple(last_bwd[s] for s in stages)
            # fused buckets are priced conservatively (no overlap discount)
            # under a pipeline schedule: the coupled fluid scheduler cannot
            # know a dep's finish ahead of service, so early-ready has no
            # exact seam there (DESIGN.md Sec. 13)
            js, next_id = bucket_jobs(i, 0.0, nb[i], algos[i], kinds[i],
                                      chunks[i], next_id, deps=bdeps)
            comm.extend(js)
        timeline = [] if self.keep_timeline else None
        u = self._engine.run_unified(cjobs, comm + p2p + tp_jobs, timeline,
                                     background=self.background,
                                     bg_base_id=bg_base)
        info = {
            "schedule": sched.schedule,
            "n_stages": sched.n_stages,
            "n_microbatches": sched.n_microbatches,
            "interleave": sched.chunks_per_stage,
            "stage_busy_s": pi["stage_busy"],
            "stage_groups": pi["stage_groups"],
            "bubble": bubble_stats(sched, pi["stage_busy"],
                                   u.compute_finish),
            "p2p_bytes": pi["p2p_bytes"],
            "p2p_busy_s": self._engine.class_busy.get(TC_PP, 0.0),
            "pp_knobs": g.pp_knobs,
        }
        tp_info = None
        if self.tp is not None:
            tp_info = {
                "mode": "pipeline-unit",
                "n_layers": self.tp.n_layers,
                "fwd_bytes": self.tp.fwd_bytes,
                "bwd_bytes": self.tp.bwd,
                "jobs": len(tp_jobs),
                "tp_busy_s": self._engine.class_busy.get(TC_TP, 0.0),
                "tp_finish_s": self._engine.class_finish.get(TC_TP, 0.0),
            }
        it = u.finish
        return SimResult(
            iteration_time=it,
            # per-device busy sums: with S stages compute_time can exceed
            # the iteration (distinct devices are busy concurrently)
            compute_time=u.compute_busy,
            comm_time=u.comm_busy,
            compute_finish=u.compute_finish,
            comm_finish=u.comm_finish,
            overlap_ratio=(u.compute_busy + u.comm_busy) / it if it > 0
            else 1.0,
            timeline=timeline,
            pipeline=info,
            tp=tp_info,
        )

    # ------------------------------------------------------------- TP path
    def _run_tp(self, g: FusionGraph) -> SimResult:
        """Price the graph under dep-coupled TP activation traffic
        (DESIGN.md Sec. 14).

        The serialized schedule is re-emitted as an explicitly chained job
        list (the coupled engine's per-stream serialization contract: pop
        order is a linear extension of the quotient deps, so chaining it
        preserves the schedule), split into ``tp.n_layers`` busy-balanced
        spans by the same bisection the pipeline stage split uses, and
        per-span collectives are coupled in: forward TP jobs gate the next
        span's first compute job, backward TP jobs gate the gradient
        buckets of the groups their span provides.  Iteration time keeps
        the background-model convention — gated by compute and gradient
        sync; TP traffic matters through the contention and compute delays
        it causes (tallies reported in ``SimResult.tp``).  Fused buckets
        are priced conservatively (no overlap discount) on the coupled
        scheduler, as under a pipeline schedule."""
        tp = self.tp
        compute, times = self._compute_jobs(g)
        u = self._engine.run_unified(compute, [])
        order = u.order
        L = max(1, min(tp.n_layers, len(order)))
        ends = balanced_spans(u.busy_after, L)
        chained = []
        prev = None
        for idx, gid in enumerate(order):
            chained.append(ComputeJob(
                ref=gid, duration=times[gid], job_id=~gid, key=(idx,),
                deps=() if prev is None else (prev,)))
            prev = ~gid
        # id layout: buckets 0..B-1, then chunk jobs, then TP jobs, then
        # background (mirrors _run_pipeline)
        buckets = g.buckets
        chunks = g.bucket_chunks
        nb = [g.bucket_bytes(b) for b in buckets]
        cid = len(buckets)
        for i in range(len(buckets)):
            if nb[i] > 0.0 and chunks[i] > 1:
                cid += chunks[i]
        chained, fwd_jobs, bwd_jobs, bg_base = couple_tp(chained, ends, tp,
                                                         cid)
        # provider group -> span, for backward gating of the buckets
        span_of: dict[int, int] = {}
        prev_e = 0
        for s, e in enumerate(ends):
            for gid in order[prev_e:e]:
                span_of[gid] = s
            prev_e = e
        deps_of = g.bucket_deps()
        algos = g.bucket_algos
        kinds = g.bucket_comm
        comm = []
        next_id = len(buckets)
        for i in range(len(buckets)):
            if nb[i] <= 0.0:
                continue
            bdeps = [~p for p in deps_of[i]]
            if bwd_jobs:
                # gradients are ready only once the producing spans'
                # backward TP collectives completed
                bdeps.extend(bwd_jobs[s].job_id for s in
                             sorted({span_of[p] for p in deps_of[i]}))
            js, next_id = bucket_jobs(i, 0.0, nb[i], algos[i], kinds[i],
                                      chunks[i], next_id, deps=tuple(bdeps))
            comm.extend(js)
        timeline = [] if self.keep_timeline else None
        u2 = self._engine.run_unified(chained, comm + fwd_jobs + bwd_jobs,
                                      timeline, background=self.background,
                                      bg_base_id=bg_base)
        result = self._make_result(u2.compute_busy, u2.comm_busy,
                                   u2.compute_finish, u2.comm_finish,
                                   timeline)
        result.tp = {
            "mode": "span",
            "n_layers": L,
            "fwd_bytes": tp.fwd_bytes,
            "bwd_bytes": tp.bwd,
            "jobs": len(fwd_jobs) + len(bwd_jobs),
            "tp_busy_s": self._engine.class_busy.get(TC_TP, 0.0),
            "tp_finish_s": self._engine.class_finish.get(TC_TP, 0.0),
        }
        return result

    # ----------------------------------------------------------- delta path
    def _run_delta(self, g: FusionGraph, base: _SimState) -> _SimState | None:
        """Exact suffix replay from the journal's divergence bound; returns
        None when the delta is invalid (caller falls back to full replay)."""
        if getattr(self.estimator, "comm_sensitive", False) \
                and any(rec[0] != "fuse" for rec in g._journal):
            # bucket-dimension mutations (algo/comm/chunk/merge) change a
            # comm-sensitive estimator's fused-op predictions, so cached
            # group times from the ancestor schedule are stale
            return None
        n_base = len(base.order)
        k = n_base
        pos = base.pos
        for rec in g._journal:
            if rec[0] != "fuse":
                continue
            _, removed, _new_gid, new_preds = rec
            for x in removed:
                p = pos.get(x)
                if p is not None:
                    k = min(k, p)
            known = [pos[x] for x in new_preds if x in pos]
            k = min(k, (max(known) + 1) if known else 0)

        succs, preds = g.quotient()
        prefix = base.order[:k]
        popped = set(prefix)
        groups = g.groups
        for gid in prefix:
            if gid not in groups:
                return None  # journal/state mismatch
        done_at = dict(base.done_at)
        remaining = [gid for gid in groups if gid not in popped]
        indeg: dict[int, int] = {}
        for gid in remaining:
            c = 0
            for x in preds[gid]:
                if x not in popped:
                    c += 1
            indeg[gid] = c
        key = g._group_key
        ready = [(key[gid], gid) for gid in remaining if indeg[gid] == 0]
        heapq.heapify(ready)
        device_free = done_at[prefix[-1]] if k > 0 else 0.0
        compute_busy = base.busy_after[k - 1] if k > 0 else 0.0
        order = list(prefix)
        busy_after = base.busy_after[:k]
        times = dict(base.times)
        group_time = self.estimator.group_time
        while ready:
            _, gid = heapq.heappop(ready)
            # a surviving gid always denotes the same fused group, so its
            # cached time from the base schedule is still exact
            t = times.get(gid)
            if t is None:
                t = group_time(g, gid)
                times[gid] = t
            end = device_free + t
            done_at[gid] = end
            device_free = end
            compute_busy += t
            order.append(gid)
            busy_after.append(compute_busy)
            for d in succs[gid]:
                if d in indeg:
                    indeg[d] -= 1
                    if indeg[d] == 0:
                        heapq.heappush(ready, (key[d], d))
        if len(order) != len(groups):
            return None  # cyclic or inconsistent — let the full path decide

        bucket_ready_at: dict[int, float] = {}
        fused = g.bucket_fused
        disc = self.overlap_discount if self.streams > 1 else 0.0
        for i, b in enumerate(g.buckets):
            provs = g.bucket_ready_groups(b)
            try:
                if disc > 0.0 and fused[i]:
                    # in-kernel fusion: ready reaches discount x duration
                    # back into each provider's tail — same subtraction the
                    # unified engine applies per dep, so delta stays
                    # bit-identical to the full path (max is arithmetic-free)
                    bucket_ready_at[i] = max(done_at[x] - disc * times[x]
                                             for x in provs)
                else:
                    bucket_ready_at[i] = max(done_at[x] for x in provs)
            except KeyError:
                return None
        timeline = None
        if self.keep_timeline:
            # reconstruct the serialized compute records the full path
            # would emit: on one stream each pop starts where the previous
            # ended, so the chained starts are bit-exact (never derived by
            # subtraction, which would not be)
            timeline = []
            prev = 0.0
            for gid in order:
                end = done_at[gid]
                timeline.append(("compute", gid, prev, end, TC_COMPUTE,
                                 "stream0", prev, end))
                prev = end
        comm_busy, comm_finish = self._comm_pass(g, bucket_ready_at, timeline,
                                                 horizon=device_free)
        compute_finish = device_free if order else 0.0
        result = self._make_result(compute_busy, comm_busy, compute_finish,
                                   comm_finish, timeline)
        # stale (removed-gid) entries are harmless — gids are never reused
        # within a lineage — but prune once they dominate the dicts
        if len(done_at) > 2 * len(groups):
            done_at = {gid: done_at[gid] for gid in groups}
            times = {gid: times[gid] for gid in groups}
        return _SimState(order=order, done_at=done_at,
                         busy_after=busy_after, times=times, result=result)

    # -------------------------------------------------------------- shared
    def _comm_pass(self, g: FusionGraph, bucket_ready_at: dict[int, float],
                   timeline: list | None,
                   horizon: float = 0.0) -> tuple[float, float]:
        # communication: buckets transfer in order of readiness (paper: "in
        # order of production of their respective gradient tensors").
        algos = g.bucket_algos
        kinds = g.bucket_comm
        buckets = g.buckets
        if self.streams > 1:
            # phase-level event engine: per-link-level pipelining with
            # fair-share contention (DESIGN.md Sec. 8).  A bucket with
            # chunks > 1 becomes a store-and-forward chain of chunk jobs
            # (chunk c may not start a phase before chunk c-1 finished it);
            # recurring TP/PP background traffic contends on the same
            # levels over the compute horizon (DESIGN.md Sec. 9).
            chunks = g.bucket_chunks
            fused = g.bucket_fused
            disc = self.overlap_discount
            jobs = []
            next_id = len(buckets)
            for i, r in bucket_ready_at.items():
                nbytes = g.bucket_bytes(buckets[i])
                if nbytes <= 0.0:
                    continue  # nothing to transfer: no latency D charged
                # a fused bucket's ready was already discounted into the
                # producer tail by the caller; the discount is re-stamped
                # on the jobs so their phases carry the fused_* tags (the
                # deps are resolved, so no second subtraction happens)
                js, next_id = bucket_jobs(i, r, nbytes,
                                          algos[i], kinds[i], chunks[i],
                                          next_id,
                                          discount=disc if fused[i] else 0.0)
                jobs.extend(js)
            if self.background:
                for traffic in self.background:
                    bjobs = traffic.materialize(horizon, next_id)
                    next_id += len(bjobs)
                    jobs.extend(bjobs)
                self._engine.run(jobs, timeline)
                # iteration time is gated by gradient sync; background
                # traffic only matters through the contention it causes
                return (self._engine.class_busy.get(TC_DP, 0.0),
                        self._engine.class_finish.get(TC_DP, 0.0))
            return self._engine.run(jobs, timeline)
        # streams=1 hot path: the serialized channel inline, identical to
        # CommEngine(streams=1) without per-bucket job objects — and
        # bit-identical to the seed's comm pass for all-AllReduce buckets
        chan_free = 0.0
        comm_busy = 0.0
        comm_finish = 0.0
        order = sorted(bucket_ready_at.items(), key=lambda kv: (kv[1], kv[0]))
        ar_coeffs = self._ar_coeffs
        rs_ag_coeffs = self._rs_ag_coeffs
        for i, ready_t in order:
            nbytes = g.bucket_bytes(buckets[i])
            if nbytes <= 0.0:
                continue  # nothing to transfer: no latency D charged
            kind = kinds[i]
            c, d = (ar_coeffs if kind == KIND_AR else rs_ag_coeffs)[algos[i]]
            t = c * nbytes + d
            start = max(chan_free, ready_t)
            chan_free = start + t
            comm_busy += t
            comm_finish = chan_free
            if timeline is not None:
                timeline.append((
                    "allreduce" if kind == KIND_AR else KIND_RS_AG, i, 0,
                    TC_DP, algos[i], self._engine._chan_level, start,
                    chan_free))
        return comm_busy, comm_finish

    @staticmethod
    def _make_result(compute_busy, comm_busy, compute_finish, comm_finish,
                     timeline) -> SimResult:
        it = max(compute_finish, comm_finish)
        return SimResult(
            iteration_time=it,
            compute_time=compute_busy,
            comm_time=comm_busy,
            compute_finish=compute_finish,
            comm_finish=comm_finish,
            overlap_ratio=(compute_busy + comm_busy) / it if it > 0 else 1.0,
            timeline=timeline,
        )

    def _remember(self, g: FusionGraph, state: _SimState) -> None:
        tok = next(_token_counter)
        self._states[tok] = state
        if len(self._states) > self._state_cache_size:
            self._states.popitem(last=False)
        g._base_token = tok
        g._journal = []

    # ------------------------------------------------------------- FO bound
    def full_overlap_bound(self, g: FusionGraph) -> float:
        """Lower bound on iteration time under maximal overlap.

        The comm floor depends on the channel model: serialized (streams=1)
        communication cannot finish before the sum of all bucket times (the
        seed's ``total_comm_time``, bit-identical); the multi-stream engine
        can pipeline buckets across link levels, but every level still has
        to advance its total phase work at capacity 1 — the floor is the
        busiest level's work sum.  Chunking conserves per-level work
        exactly (per-chunk coefficients sum to the unchunked ones), so the
        unchunked phase sums below stay an exact floor for chunked
        schedules; background TP/PP traffic is excluded (the bound is on
        the gradient traffic the search controls).  In-kernel fused buckets
        conserve link work too — the overlap discount moves a job's start
        earlier, never shrinks its phases — so the same floor holds."""
        comp = total_compute_time(g, self.estimator, self.hw)
        if self.streams == 1:
            comm = total_comm_time(g, cluster=self.cluster)
        else:
            level_work = [0.0] * len(self.cluster.levels)
            for i, b in enumerate(g.buckets):
                nb = g.bucket_bytes(b)
                if nb <= 0.0:
                    continue
                for p in phases(self.cluster, g.bucket_algos[i],
                                g.bucket_comm[i]):
                    level_work[p.level] += p.c * nb + p.d
            comm = max(level_work, default=0.0)
        return max(comp, comm)

"""End-to-end HLO execution-time simulator (paper Sec. 4.4).

Replays a :class:`FusionGraph` on one device:

* one serialized **compute stream** — a FIFO ready queue of fused ops; a
  ready op starts at ``max(device_free, preds done)``;
* one serialized **communication channel** — AllReduce buckets start when
  (a) every gradient in the bucket has been produced (its provider group is
  done) and (b) the channel is clear; communication overlaps compute.

Per-iteration time = max(last compute completion, last AllReduce completion).
The FO (full-overlap) bound is ``max(total_compute, total_comm)`` — maximal
overlap ignoring dependencies (paper Sec. 6.2).
"""
from __future__ import annotations

import dataclasses
import heapq

from .costs import OracleEstimator, total_comm_time, total_compute_time
from .graph import FusionGraph
from .hw import Hardware, TPU_V5E, allreduce_time


@dataclasses.dataclass
class SimResult:
    iteration_time: float
    compute_time: float          # sum of fused-op times (busy compute)
    comm_time: float             # sum of AllReduce times (busy channel)
    compute_finish: float
    comm_finish: float
    overlap_ratio: float         # (compute_time+comm_time)/iteration_time
    timeline: list | None = None


class Simulator:
    """Cost model Cost(H) driving the backtracking search."""

    def __init__(self, estimator=None, hw: Hardware = TPU_V5E, n_devices: int = 256,
                 keep_timeline: bool = False):
        self.estimator = estimator or OracleEstimator(hw)
        self.hw = hw
        self.n_devices = n_devices
        self.keep_timeline = keep_timeline

    def cost(self, g: FusionGraph) -> float:
        return self.run(g).iteration_time

    def run(self, g: FusionGraph) -> SimResult:
        succs, preds = g.quotient()
        indeg = {gid: len(ps) for gid, ps in preds.items()}
        key = {gid: min(m) for gid, m in g.groups.items()}
        done_at: dict[int, float] = {}
        ready = [(key[gid], gid) for gid, k in indeg.items() if k == 0]
        heapq.heapify(ready)
        device_free = 0.0
        timeline = [] if self.keep_timeline else None
        compute_busy = 0.0
        # bucket i becomes ready when all provider groups of its grads done
        bucket_waiting = {
            i: set(g.bucket_ready_groups(b)) for i, b in enumerate(g.buckets)
        }
        bucket_ready_at: dict[int, float] = {
            i: 0.0 for i, w in bucket_waiting.items() if not w
        }
        group_to_buckets: dict[int, list[int]] = {}
        for i, w in bucket_waiting.items():
            for gid in w:
                group_to_buckets.setdefault(gid, []).append(i)

        while ready:
            _, gid = heapq.heappop(ready)
            t = self.estimator.group_time(g, gid)
            start = max(device_free, max((done_at[p] for p in preds[gid]), default=0.0))
            end = start + t
            done_at[gid] = end
            device_free = end
            compute_busy += t
            if timeline is not None:
                timeline.append(("compute", gid, start, end))
            for i in group_to_buckets.get(gid, ()):
                bucket_waiting[i].discard(gid)
                if not bucket_waiting[i]:
                    bucket_ready_at[i] = end
            for d in succs[gid]:
                indeg[d] -= 1
                if indeg[d] == 0:
                    heapq.heappush(ready, (key[d], d))
        if len(done_at) != len(g.groups):
            raise RuntimeError("cyclic fusion graph in simulator")

        # communication channel: buckets transfer in order of readiness
        # (paper: "in order of production of their respective gradient
        # tensors"), serialized on one channel, overlapping compute.
        chan_free = 0.0
        comm_busy = 0.0
        comm_finish = 0.0
        order = sorted(bucket_ready_at.items(), key=lambda kv: (kv[1], kv[0]))
        for i, ready_t in order:
            t = allreduce_time(g.bucket_bytes(g.buckets[i]), self.hw, self.n_devices)
            start = max(chan_free, ready_t)
            chan_free = start + t
            comm_busy += t
            comm_finish = chan_free
            if timeline is not None:
                timeline.append(("allreduce", i, start, chan_free))

        compute_finish = device_free
        it = max(compute_finish, comm_finish)
        return SimResult(
            iteration_time=it,
            compute_time=compute_busy,
            comm_time=comm_busy,
            compute_finish=compute_finish,
            comm_finish=comm_finish,
            overlap_ratio=(compute_busy + comm_busy) / it if it > 0 else 1.0,
            timeline=timeline,
        )

    # ------------------------------------------------------------- FO bound
    def full_overlap_bound(self, g: FusionGraph) -> float:
        comp = total_compute_time(g, self.estimator, self.hw)
        comm = total_comm_time(g, self.hw, self.n_devices)
        return max(comp, comm)

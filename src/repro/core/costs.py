"""Cost substrate: per-op and fused-op execution-time oracles.

Two roles (paper Sec. 4.2):

* **Profiler** — standalone time of every original op (paper: measured with
  ``--xla_hlo_profile``; here: analytic TPU-v5e roofline, since the container
  is CPU-only and the *target* is TPU).
* **Fused-op ground truth** — the detailed oracle used (a) to label GNN
  training samples in tier A and (b) as the ``--estimator oracle`` option.
  It includes the non-linear "hardware texture" the paper argues makes fused
  op time hard to predict analytically from *op lists alone*: MXU-alignment
  padding, VMEM working-set spill, overhead amortisation, and a saturation
  term for deep elementwise chains.

A second, CPU-measured ground truth (tier B) lives in
:mod:`repro.core.profile_cpu` and actually jit-executes fused subgraphs.
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence

from ..cluster import ClusterSpec, comm_time
from .graph import DOT, EW, FusionGraph, LAYOUT, OPAQUE, PrimOp, REDUCE
from .hw import Hardware, TPU_V5E


# --------------------------------------------------------------------- prims
def prim_time(p: PrimOp, hw: Hardware = TPU_V5E) -> float:
    """Standalone execution time of one primitive (the Profiler's output)."""
    bytes_total = p.in_bytes + p.out_bytes
    flops_t = p.flops / (hw.peak_flops * hw.efficiency)
    mem_t = bytes_total / hw.hbm_bw
    if p.category == OPAQUE:
        # opaque ops (scan/sort/custom) run at a discount to peak
        flops_t *= 2.0
    return max(flops_t, mem_t) + hw.launch_overhead


def profile_graph(g: FusionGraph, hw: Hardware = TPU_V5E) -> FusionGraph:
    """Fill in ``time`` for every prim (returns a new graph sharing edges)."""
    prims = [
        PrimOp(
            pid=p.pid,
            op_type=p.op_type,
            category=p.category,
            flops=p.flops,
            in_bytes=p.in_bytes,
            out_bytes=p.out_bytes,
            time=prim_time(p, hw),
            grad_param=p.grad_param,
            grad_bytes=p.grad_bytes,
            grad_sig=p.grad_sig,
        )
        for p in g.prims
    ]
    return FusionGraph._from_parts(
        prims, g.psuccs, g.ppreds, g.groups, g.provider, g._next_gid,
        g.grad_prim, g.buckets, bucket_algos=g.bucket_algos,
        bucket_comm=g.bucket_comm, bucket_chunks=g.bucket_chunks,
    )


# ----------------------------------------------------------------- fused ops
def _align_penalty(p: PrimOp, hw: Hardware) -> float:
    """Deterministic MXU-padding texture: dots whose FLOP volume is not a
    multiple of a full MXU tile pass waste cycles on padding."""
    if p.category != DOT or p.flops <= 0:
        return 1.0
    tile_flops = 2.0 * hw.mxu_dim**3
    waste = (-p.flops) % tile_flops
    return 1.0 + 0.35 * (waste / tile_flops) * min(1.0, tile_flops / max(p.flops, 1.0) * 8)


def fused_time_oracle(
    members: Sequence[PrimOp],
    external_in_bytes: float,
    external_out_bytes: float,
    hw: Hardware = TPU_V5E,
    n_internal_edges: int = 0,
) -> float:
    """Detailed fused-op execution time (tier-A ground truth).

    flops: all member flops (duplicate-fused copies included by the caller).
    bytes: only the group's external traffic — fusion's memory saving.
    """
    flops = sum(p.flops * _align_penalty(p, hw) for p in members)
    bytes_total = external_in_bytes + external_out_bytes
    flops_t = flops / (hw.peak_flops * hw.efficiency)
    mem_t = bytes_total / hw.hbm_bw
    # VMEM working-set spill: intermediates elided from HBM must live in
    # VMEM; once the aggregate working set exceeds VMEM the compiler spills
    # them back to HBM (round trip).
    internal_bytes = max(sum(p.out_bytes for p in members) - external_out_bytes, 0.0)
    ws = max((p.out_bytes for p in members), default=0.0) + internal_bytes
    spill = max(0.0, ws - hw.vmem_bytes) * 2.0 / hw.hbm_bw
    # deep fused loop nests lose ILP/pipelining: superlinear in member count
    n = len(members)
    chain_penalty = 1.0 + 0.03 * math.log1p(max(n - 8, 0))
    # single dispatch for the whole fused op
    return max(flops_t, mem_t) * chain_penalty + spill + hw.launch_overhead


def group_time_oracle(g: FusionGraph, gid: int, hw: Hardware = TPU_V5E) -> float:
    members = [g.prims[p] for p in g.groups[gid]]
    if len(members) == 1 and members[0].category == OPAQUE:
        return members[0].time if members[0].time > 0 else prim_time(members[0], hw)
    in_b, out_b = g.group_external_io(gid)
    return fused_time_oracle(members, in_b, out_b, hw)


class OracleEstimator:
    """Estimator interface backed by the analytic oracle (with memoisation).

    The GNN estimator in :mod:`repro.core.gnn` exposes the same interface.
    """

    def __init__(self, hw: Hardware = TPU_V5E):
        self.hw = hw
        self._cache: dict = {}

    def group_time(self, g: FusionGraph, gid: int) -> float:
        # The fused time depends on (a) the member set, (b) which members
        # this group provides (external-output accounting), and (c) the prim
        # lineage — the same pids carry different flops/bytes across traced /
        # re-profiled graphs, so the family token keeps one shared estimator
        # from returning stale times across graphs.
        key = (g.family_token(), g.groups[gid], g.provided_set(gid))
        t = self._cache.get(key)
        if t is None:
            t = group_time_oracle(g, gid, self.hw)
            self._cache[key] = t
        return t


def total_compute_time(g: FusionGraph, estimator, hw: Hardware = TPU_V5E) -> float:
    return sum(estimator.group_time(g, gid) for gid in g.groups)


def total_comm_time(g: FusionGraph, hw: Hardware = TPU_V5E,
                    n_devices: int = 256,
                    cluster: ClusterSpec | None = None) -> float:
    """Busy time of the communication channel: each bucket priced by its
    chosen collective algorithm and comm kind (AllReduce or ZeRO-3 RS+AG)
    on ``cluster`` (a legacy ``(hw, n_devices)`` call maps to the flat
    back-compat spec — bit-identical to the seed's per-bucket
    ``allreduce_time`` sum).  Empty/zero-byte buckets transfer nothing and
    are skipped (no fixed latency D charged)."""
    if cluster is None:
        cluster = ClusterSpec.flat(hw, n_devices)
    total = 0.0
    for i, b in enumerate(g.buckets):
        nb = g.bucket_bytes(b)
        if nb <= 0.0:
            continue
        total += comm_time(nb, cluster, g.bucket_algos[i], g.bucket_comm[i])
    return total

"""First-class tensor-parallel activation traffic (DESIGN.md Sec. 14).

PR 4 modeled TP activation collectives as :class:`BackgroundTraffic` — a
periodic average over the compute horizon.  That prices *statistical*
contention: the search schedules gradient buckets into windows that are
quiet on average, not windows that are actually quiet.  This module
promotes the tp class to first-class scheduled jobs, dep-coupled to the
compute that produces and consumes them, the same promotion PR 6 gave the
pp class:

* :class:`TPTraffic` — the declarative description: ``n_layers`` per-layer
  collectives of ``fwd_bytes`` (forward activations) and ``bwd_bytes``
  (backward activation-gradients) each, of a given collective
  ``algo``/``kind``.  ``to_tuple``/``from_tuple`` round-trip it through the
  Plan artifact (schema v3) and the search worker pool.
* :func:`balanced_spans` — the busy-balanced contiguous bisection of a
  serialized schedule shared with the pipeline stage split
  (``Simulator.pipeline_inputs`` delegates here so the two lowerings can
  never drift).
* :func:`couple_tp` — the single-device lowering: the serialized schedule
  is split into ``n_layers`` spans; each span's **forward** TP job deps on
  the span's last compute job and *gates the next span's first compute
  job* (forward activations block downstream compute); each span's
  **backward** TP job deps on the same producer and is handed back to the
  caller to gate the gradient buckets that span provides (backward
  collectives gate gradient readiness).
* :func:`couple_tp_pipeline` — the 1F1B lowering: every (stage,
  microbatch, fwd/bwd) unit carries its share of the per-layer collectives
  (``n_layers / (S * v * M)`` layers per unit, so total tp bytes are
  conserved exactly against the legacy background model); the unit's TP
  job deps on the unit and gates the device's *next* unit — synchronous TP
  blocks the device until its collective completes — and the last backward
  unit's TP job replaces ``last_bwd[s]`` as the stage's gradient gate.

Zero-byte legs follow PR 6's p2p rule: a free TP collective is never
emitted as a job (a zero-byte comm job would be pre-finished at t=0 and
carry no scheduling information) — the compute chain *is* the direct
dependency, so the lowering degenerates bit-exactly to the un-TP'd
schedule.

:meth:`TPTraffic.to_background` is the fallback the tentpole keeps: when
no layer mapping is available (serialized channel, legacy callers) the
same description lowers to the PR-4 periodic averages — ``n_layers``
forward jobs phase-offset from ``n_layers`` backward jobs, total bytes
identical to the dep-coupled lowering by construction.

Import-light on purpose (no jax): loadable by the search worker pool and
the Plan artifact from bare interpreters.
"""
from __future__ import annotations

import bisect
import dataclasses

from ..cluster.collectives import KIND_AR
from .events import BackgroundTraffic, CommJob, ComputeJob, TC_TP


@dataclasses.dataclass(frozen=True)
class TPTraffic:
    """Per-layer tensor-parallel activation collectives.

    ``fwd_bytes`` / ``bwd_bytes`` are bytes per layer per iteration (the
    pipeline lowering divides them over microbatches and virtual stages so
    totals conserve).  ``bwd_bytes=None`` mirrors the forward volume — the
    usual Megatron pattern where the backward all-reduce moves the same
    activation-gradient bytes."""
    n_layers: int
    fwd_bytes: float
    bwd_bytes: float | None = None
    algo: str = "ring"
    kind: str = KIND_AR

    def __post_init__(self):
        if self.n_layers < 1:
            raise ValueError(f"n_layers must be >= 1, got {self.n_layers}")
        if self.fwd_bytes < 0.0:
            raise ValueError("fwd_bytes must be >= 0")
        if self.bwd_bytes is not None and self.bwd_bytes < 0.0:
            raise ValueError("bwd_bytes must be >= 0")

    @property
    def bwd(self) -> float:
        return self.fwd_bytes if self.bwd_bytes is None else self.bwd_bytes

    @property
    def total_bytes(self) -> float:
        """Per-iteration tp volume: every lowering (span, pipeline-unit,
        background fallback) moves exactly this many bytes."""
        return self.n_layers * (self.fwd_bytes + self.bwd)

    # ------------------------------------------------- plan serialization
    def to_tuple(self) -> tuple:
        return (self.n_layers, self.fwd_bytes, self.bwd_bytes,
                self.algo, self.kind)

    @staticmethod
    def from_tuple(t) -> "TPTraffic":
        n_layers, fwd, bwd, algo, kind = t
        return TPTraffic(
            n_layers=int(n_layers), fwd_bytes=float(fwd),
            bwd_bytes=None if bwd is None else float(bwd),
            algo=str(algo), kind=str(kind))

    # -------------------------------------------------- legacy fallback
    def to_background(self, horizon: float) -> tuple[BackgroundTraffic, ...]:
        """The PR-4 periodic-average model of the same traffic: one
        forward job per layer spread evenly over ``horizon``, one backward
        job per layer half a period out of phase.  Total bytes equal the
        dep-coupled lowering exactly (``count`` pins the job count, so the
        engine's own horizon estimate cannot change the volume)."""
        period = horizon / self.n_layers if horizon > 0.0 else 0.0
        out = []
        if self.fwd_bytes > 0.0:
            out.append(BackgroundTraffic(
                TC_TP, self.fwd_bytes, period, algo=self.algo,
                kind=self.kind, offset=0.0, count=self.n_layers))
        if self.bwd > 0.0:
            out.append(BackgroundTraffic(
                TC_TP, self.bwd, period, algo=self.algo, kind=self.kind,
                offset=0.5 * period, count=self.n_layers))
        return tuple(out)


def balanced_spans(busy_after: list, n: int) -> list[int]:
    """Split a serialized pop order into ``n`` contiguous, busy-balanced
    spans; returns the exclusive end index of each span.

    ``busy_after`` is the cumulative compute-busy vector of the serialized
    schedule (``UnifiedResult.busy_after``).  This is the pipeline stage
    bisection extracted verbatim from the PR-6 ``pipeline_inputs`` (which
    now delegates here): bisect the cumulative busy at each ``total*(s+1)/n``
    cut, then clamp so every span keeps at least one job, in order.
    Precondition: ``1 <= n <= len(busy_after)``."""
    size = len(busy_after)
    total = busy_after[-1] if busy_after else 0.0
    ends = []
    for s in range(n - 1):
        cut = total * (s + 1) / n
        ends.append(bisect.bisect_left(busy_after, cut) + 1)
    ends.append(size)
    # every span keeps at least one job, in order
    for s in range(n):
        lo = (ends[s - 1] if s else 0) + 1
        hi = size - (n - 1 - s)
        ends[s] = min(max(ends[s], lo), hi)
    return ends


def couple_tp(compute: list[ComputeJob], ends: list[int], tp: TPTraffic,
              next_id: int):
    """Dep-couple per-span TP collectives into a chained compute job list.

    ``compute`` must already be dep-chained in execution order (job ``i+1``
    deps on job ``i`` — the coupled engine's per-stream serialization
    contract); ``ends`` are the span end indices from
    :func:`balanced_spans` (one span per modeled layer).

    Per span ``s``: a forward TP job deps on the span's last compute job
    and the *next* span's first compute job gains a dep on it (forward
    activations block downstream compute); a backward TP job deps on the
    same producer and is returned for the caller to attach to the gradient
    buckets the span provides.  Zero-byte legs are never emitted (PR 6's
    rule: the compute chain is already the direct dependency).

    Returns ``(compute, fwd_jobs, bwd_jobs, next_id)`` where
    ``bwd_jobs[s]`` is span ``s``'s backward job (lists are empty when the
    respective volume is zero).
    """
    fwd_jobs: list[CommJob] = []
    bwd_jobs: list[CommJob] = []
    if not compute or (tp.fwd_bytes <= 0.0 and tp.bwd <= 0.0):
        return compute, fwd_jobs, bwd_jobs, next_id
    out = list(compute)
    for s, e in enumerate(ends):
        producer = out[e - 1].job_id
        if tp.fwd_bytes > 0.0:
            job = CommJob(bucket=s, ready=0.0, nbytes=tp.fwd_bytes,
                          algo=tp.algo, kind=tp.kind, job_id=next_id,
                          deps=(producer,), traffic_class=TC_TP)
            next_id += 1
            fwd_jobs.append(job)
            if e < len(out):
                nxt = out[e]
                out[e] = dataclasses.replace(
                    nxt, deps=nxt.deps + (job.job_id,))
        if tp.bwd > 0.0:
            job = CommJob(bucket=s, ready=0.0, nbytes=tp.bwd,
                          algo=tp.algo, kind=tp.kind, job_id=next_id,
                          deps=(producer,), traffic_class=TC_TP)
            next_id += 1
            bwd_jobs.append(job)
    return out, fwd_jobs, bwd_jobs, next_id


def couple_tp_pipeline(compute: list[ComputeJob], sched, tp: TPTraffic,
                       next_id: int):
    """Dep-couple per-unit TP collectives into a lowered 1F1B job list.

    Every (stage, microbatch, fwd/bwd) unit covers ``n_layers / (S * v)``
    layers for one microbatch, so its TP job carries
    ``layer_bytes * n_layers / (S * v * M)`` — summed over all units the
    total tp volume equals :attr:`TPTraffic.total_bytes` exactly (byte
    conservation against the background fallback).  Synchronous TP blocks
    the device until the collective completes: each unit's TP job deps on
    the unit and the device's *next* unit in 1F1B issue order deps on the
    TP job.  The last backward unit's TP job per stage is returned in
    ``grad_gate`` — it replaces ``last_bwd[s]`` as the stage's
    gradient-readiness gate.  Zero-byte legs are never emitted.

    Returns ``(compute, tp_jobs, grad_gate, next_id)``; ``grad_gate`` is
    ``None`` when there is no backward volume (buckets keep their
    ``last_bwd`` gates).
    """
    S = sched.n_stages
    M = sched.n_microbatches
    v = sched.chunks_per_stage
    per_unit = tp.n_layers / float(S * v * M)
    fb = tp.fwd_bytes * per_unit
    bb = tp.bwd * per_unit
    if fb <= 0.0 and bb <= 0.0:
        return compute, [], None, next_id
    tp_jobs: list[CommJob] = []
    tp_of: dict[int, int] = {}   # unit job_id -> its TP job id
    grad_gate: list | None = [None] * S if bb > 0.0 else None
    for u in compute:
        nb = fb if u.kind == "fwd" else bb
        if nb <= 0.0:
            continue
        job = CommJob(bucket=u.stream, ready=0.0, nbytes=nb, algo=tp.algo,
                      kind=tp.kind, job_id=next_id, deps=(u.job_id,),
                      traffic_class=TC_TP)
        next_id += 1
        tp_jobs.append(job)
        tp_of[u.job_id] = job.job_id
        if u.kind == "bwd" and grad_gate is not None:
            # units arrive in issue order, so the last write per stage is
            # the stage's final backward — the gradient gate
            grad_gate[u.stream] = job.job_id
    # the device cannot start its next unit before the previous unit's
    # collective completed (synchronous TP occupies the device)
    out: list[ComputeJob] = []
    prev_tp: dict[int, int | None] = {}
    for u in compute:
        d = prev_tp.get(u.stream)
        if d is not None:
            u = dataclasses.replace(u, deps=u.deps + (d,))
        prev_tp[u.stream] = tp_of.get(u.job_id)
        out.append(u)
    return out, tp_jobs, grad_gate, next_id

"""The paper's comparison baselines, re-implemented as graph rewrites
(Sec. 6.1): XLA-style post-order heuristic op fusion, XLA AllReduce-combiner
threshold tensor fusion, PyTorch-DDP-style reverse-order bucketing, and the
full-overlap (FO) bound.  On a non-flat :class:`repro.cluster.ClusterSpec`,
``evaluate_baselines`` adds topology-aware rows (Horovod-style hierarchical
AllReduce, NCCL-style per-bucket algorithm auto-tuning) and three
overlap-aware rows priced by the multi-stream event engine (DESIGN.md
Sec. 8-9): an NCCL-channels-style 4-stream pipelined schedule, a ZeRO-3
reduce-scatter + all-gather schedule, and a chunked variant whose large
buckets store-and-forward 4 chunks through the link-level phase pipeline.
"""
from __future__ import annotations

from ..cluster import ClusterSpec, best_algo
from .graph import DOT, EW, FusionGraph, LAYOUT, REDUCE
from .simulator import Simulator

# stream count of the overlap-aware baseline rows (NCCL channels default is
# harder to pin; 4 is enough for the phase pipeline to express itself)
OVERLAP_STREAMS = 4

# XLA GPU AllReduce combiner default threshold (bytes).
XLA_COMBINE_THRESHOLD = 30 * 2**20
# PyTorch DDP default bucket cap.
DDP_BUCKET_CAP = 25 * 2**20


def xla_post_order_op_fusion(g: FusionGraph, max_group: int = 64) -> FusionGraph:
    """XLA-like heuristic: visit ops in a fixed post order; fuse an op with
    its producer whenever both are fusible kinds and the fusion saves device
    memory traffic (paper Sec. 2.2: "ops are chosen according to a
    pre-defined post order")."""
    g = g.clone()
    # post order = reverse topological order of prims
    order = sorted(range(len(g.prims)), reverse=True)
    fusible_producer = {EW, LAYOUT}
    fusible_consumer = {EW, LAYOUT, REDUCE, DOT}
    for pid in order:
        p = g.prims[pid]
        if p.category not in fusible_consumer:
            continue
        cgid = next((gid for gid, m in g.groups.items() if pid in m
                     and g.provider[pid] == gid), None)
        if cgid is None or len(g.groups[cgid]) >= max_group:
            continue
        # try each producer group, best-effort greedy
        for prod in sorted(g.group_preds(cgid)):
            if len(g.groups[prod]) + len(g.groups[cgid]) > max_group:
                continue
            if all(g.prims[q].category in fusible_producer for q in g.groups[prod]):
                g.fuse_nondup(cgid, prod)
                break
    return g


def threshold_tensor_fusion(g: FusionGraph, threshold: int = XLA_COMBINE_THRESHOLD,
                            reverse: bool = False) -> FusionGraph:
    """XLA AllReduce-combiner style: greedily merge neighbouring buckets while
    the fused tensor stays under ``threshold`` bytes.  ``reverse=True`` packs
    from the end of the production order (PyTorch DDP registers buckets in
    reverse gradient order)."""
    g = g.clone()
    i = len(g.buckets) - 2 if reverse else 0
    step = -1 if reverse else 0  # after a merge at i, the next pair is (i, i+1) again
    while 0 <= i < len(g.buckets) - 1:
        a, b = g.buckets[i], g.buckets[i + 1]
        if g.bucket_bytes(a) + g.bucket_bytes(b) <= threshold and g.merge_buckets(i, i + 1):
            if reverse:
                i -= 1
            continue
        i += -1 if reverse else 1
    return g


def jax_no_fusion(g: FusionGraph) -> FusionGraph:
    return g.clone()


def jax_op_fusion(g: FusionGraph) -> FusionGraph:
    return xla_post_order_op_fusion(g)


def jax_allreduce_fusion(g: FusionGraph) -> FusionGraph:
    return threshold_tensor_fusion(g)


def jax_default(g: FusionGraph) -> FusionGraph:
    return threshold_tensor_fusion(xla_post_order_op_fusion(g))


def pytorch_ddp(g: FusionGraph) -> FusionGraph:
    """DDP: no op fusion; 25 MB buckets packed in reverse production order."""
    return threshold_tensor_fusion(g, threshold=DDP_BUCKET_CAP, reverse=True)


def assign_bucket_algos(g: FusionGraph, cluster: ClusterSpec,
                        algo: str = "auto") -> FusionGraph:
    """Set every bucket's collective algorithm: a fixed one, or per-bucket
    ``best_algo`` when ``algo="auto"`` (NCCL-tuner style)."""
    g = g.clone()
    for i, b in enumerate(g.buckets):
        nb = g.bucket_bytes(b)
        if nb <= 0.0:
            continue
        g.set_bucket_algo(i, best_algo(nb, cluster)[0] if algo == "auto"
                          else algo)
    return g


def assign_bucket_comm(g: FusionGraph, kind: str = "rs_ag") -> FusionGraph:
    """Set every non-empty bucket's communication kind (ZeRO-3-style
    ``"rs_ag"`` or the default fused AllReduce ``"ar"``)."""
    g = g.clone()
    for i, b in enumerate(g.buckets):
        if g.bucket_bytes(b) <= 0.0:
            continue
        g.set_bucket_comm(i, kind)
    return g


def assign_bucket_chunks(g: FusionGraph, chunks: int = 4,
                         min_bytes: float = 1 << 20) -> FusionGraph:
    """Split every bucket of at least ``min_bytes`` into ``chunks``
    store-and-forward chunks (NCCL-style chunked pipelining, DESIGN.md
    Sec. 9).  Small buckets keep the whole-bucket collective — chunking
    them only fragments the fixed latency."""
    g = g.clone()
    for i, b in enumerate(g.buckets):
        if g.bucket_bytes(b) < min_bytes:
            continue
        g.set_bucket_chunks(i, chunks)
    return g


BASELINES = {
    "JAX_no_fusion": jax_no_fusion,
    "JAX_op_fusion": jax_op_fusion,
    "JAX_AllReduce_fusion": jax_allreduce_fusion,
    "JAX_default": jax_default,
    "PyTorch_DDP": pytorch_ddp,
}


def evaluate_baselines(g: FusionGraph, sim: Simulator) -> dict[str, float]:
    out = {name: sim.cost(fn(g)) for name, fn in BASELINES.items()}
    # FO is per-strategy (paper Sec. 6.2): the seed row bounds JAX_default
    out["FO"] = sim.full_overlap_bound(jax_default(g))
    # topology-aware rows only make sense on a real cluster spec; the flat
    # back-compat shim keeps the seed baseline set (and values) unchanged
    cluster = getattr(sim, "cluster", None)
    if cluster is not None and not cluster.is_flat_compat:
        hier = assign_bucket_algos(jax_default(g), cluster, "hier")
        tuned = assign_bucket_algos(jax_default(g), cluster, "auto")
        out["Horovod_hierarchical"] = sim.cost(hier)
        out["NCCL_auto_algo"] = sim.cost(tuned)
        # overlap-aware rows: the same tuned strategy priced by the
        # multi-stream event engine (pipelined phases), with and without
        # the ZeRO-3 RS+AG split.  A fresh non-incremental simulator shares
        # the estimator so fused-op times come from the same cache.
        sim_ms = Simulator(estimator=sim.estimator, hw=sim.hw,
                           cluster=cluster, streams=OVERLAP_STREAMS,
                           incremental=False,
                           background=getattr(sim, "background", ()))
        zero3 = assign_bucket_comm(tuned, "rs_ag")
        chunked = assign_bucket_chunks(tuned, 4)
        out[f"NCCL_{OVERLAP_STREAMS}stream"] = sim_ms.cost(tuned)
        out["ZeRO3_rs_ag"] = sim_ms.cost(zero3)
        out[f"NCCL_{OVERLAP_STREAMS}stream_chunked"] = sim_ms.cost(chunked)
        # keep the FO row a floor for *every* reported row: the extra rows
        # price different strategies (algo/comm assignments) and a
        # different channel model, so extend the bound to the min over the
        # (strategy, channel) pairs actually priced
        out["FO"] = min(out["FO"],
                        sim.full_overlap_bound(hier),
                        sim.full_overlap_bound(tuned),
                        sim_ms.full_overlap_bound(tuned),
                        sim_ms.full_overlap_bound(zero3),
                        sim_ms.full_overlap_bound(chunked))
    return out

"""Serving: batched decode engine + searched serving plans.

``repro.serving.plan`` / ``repro.serving.workload`` are import-light (no
jax) so the plan cache and search pool can load serving artifacts from
bare interpreters; the engine pulls in jax, so it is exposed lazily.
"""
from .workload import TraceRequest, VirtualClock, Workload, replay

__all__ = ["Request", "ServeEngine", "TraceRequest", "VirtualClock",
           "Workload", "replay", "ServingPlan", "compile_serving"]

_ENGINE = {"Request", "ServeEngine"}
_PLAN = {"ServingPlan", "compile_serving"}


def __getattr__(name):
    if name in _ENGINE:
        from . import engine
        return getattr(engine, name)
    if name in _PLAN:
        from . import plan
        return getattr(plan, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Batched serving engine (continuous-batching-lite).

Static-shape slot model, the standard TPU serving pattern: a fixed number
of decode slots with a shared static-capacity cache; requests are admitted
into free slots via single-sequence prefill (right-aligned write into the
slot's cache region), every decode step advances ALL active slots with one
jit'd call, finished slots are retired and refilled — prefill and decode
interleave without recompilation (all shapes static).

This is the substrate the ``decode_32k`` / ``long_500k`` dry-run shapes
lower; on the production mesh the same engine runs with the sharded
params/cache shardings from :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import stacked as ST
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine (None until the lifecycle event happened, so an
    # unfinished request reports None instead of a nonsense 0/negative)
    output: list = dataclasses.field(default_factory=list)
    submitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None or self.submitted_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def latency(self) -> Optional[float]:
        if self.done_at is None or self.submitted_at is None:
            return None
        return self.done_at - self.submitted_at


class ServeEngine:
    """max_slots concurrent sequences, cache capacity ``cache_len`` each."""

    def __init__(self, params, cfg: ModelConfig, *,
                 max_slots: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 sampler: Optional[Callable] = None,
                 clock: Callable[[], float] = time.monotonic,
                 plan=None, decode_batch: Optional[int] = None):
        # a serving plan (repro.serving.plan.ServingPlan, duck-typed)
        # enacts the searched slot/batch/shard choices; explicit kwargs
        # still win over the plan's fields (e.g. to clamp a pod-sized
        # plan onto a small host)
        if max_slots is None:
            max_slots = int(plan.slots) if plan is not None else 8
        if cache_len is None:
            cache_len = int(plan.cache_len) if plan is not None else 256
        if decode_batch is None and plan is not None:
            decode_batch = int(plan.decode_batch)
        self.plan = plan
        self.kv_layout = getattr(plan, "kv_layout", "replicated")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.clock = clock
        # decode dispatch width: < max_slots decodes the active slots in
        # gathered chunks of this many lanes (the searched batch knob)
        self.decode_batch = (max_slots if decode_batch is None
                             else max(1, min(int(decode_batch), max_slots)))
        self.sampler = sampler or (lambda logits, rng: jnp.argmax(
            logits, axis=-1).astype(jnp.int32))
        # slot state
        self.caches = ST.init_cache(cfg, max_slots, cache_len)
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.slot_pos = np.zeros(max_slots, np.int32)      # next write pos
        self.slot_last = np.zeros(max_slots, np.int32)     # last sampled tok
        self.slot_budget = np.zeros(max_slots, np.int32)
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []
        self._steps = 0

        # jit'd engine kernels (static shapes)
        self._decode = jax.jit(self._decode_impl)
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     static_argnames=("n_valid",))
        self._prefill_one = jax.jit(self._prefill_impl,
                                    static_argnames=("plen",))

    # ------------------------------------------------------------- kernels
    def _decode_impl(self, params, caches, tokens, positions):
        """Advance all slots one token.  tokens: (S,), positions: (S,)."""
        # per-slot positions: run decode with per-slot rope positions by
        # vmapping over the slot dim? decode_step uses a single scalar pos;
        # we batch with the max-consistent trick: positions differ per slot,
        # so rope/cache writes must be per-slot — use vmap over slots.
        def one(p, cache, tok, pos):
            # vmap strips the slot axis (axis 1 of stacked caches); decode
            # expects a batch dim there — reinsert a singleton
            c = jax.tree.map(lambda a: jnp.expand_dims(a, 1), cache)
            logits, nc = ST.decode_step(p, self.cfg, c, tok[None], pos)
            nc = jax.tree.map(lambda a: jnp.squeeze(a, 1), nc)
            return logits[0], nc

        logits, new_caches = jax.vmap(
            one, in_axes=(None, _slot_axes(caches), 0, 0),
            out_axes=(0, _slot_axes(caches)))(
                params, caches, tokens, positions)
        return logits, new_caches

    def _decode_chunk_impl(self, params, caches, tokens, positions, idx,
                           *, n_valid):
        """Advance a gathered chunk of slots one token: gather the chunk's
        cache columns (slot axis 1), decode at the chunk width, scatter
        only the ``n_valid`` real lanes back (padding lanes duplicate a
        real slot for the gather and are discarded — the scatter indices
        stay distinct, so the update is deterministic)."""
        sub = jax.tree.map(lambda a: jnp.take(a, idx, axis=1), caches)
        logits, new_sub = self._decode_impl(params, sub, tokens, positions)
        idx_v = idx[:n_valid]
        new_caches = jax.tree.map(
            lambda full, new: full.at[:, idx_v].set(
                jax.lax.slice_in_dim(new, 0, n_valid, axis=1).astype(
                    full.dtype)),
            caches, new_sub)
        return logits, new_caches

    def _prefill_impl(self, params, tokens, *, plen):
        """Single-sequence prefill into a fresh cache region."""
        logits, cache = ST.prefill(params, self.cfg, tokens[None],
                                   self.cache_len)
        return logits[0], cache

    # ------------------------------------------------------------- control
    def submit(self, req: Request) -> None:
        req.submitted_at = self.clock()
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            plen = len(req.prompt)
            assert plen < self.cache_len
            logits, cache = self._prefill_impl(
                self.params, jnp.asarray(req.prompt, jnp.int32), plen=plen)
            # install the prefilled single-sequence cache into this slot
            self.caches = jax.tree.map(
                lambda full, new: _install_slot(full, new, slot),
                self.caches, cache)
            tok = int(np.argmax(np.asarray(logits)))
            req.first_token_at = self.clock()
            req.output.append(tok)
            self.slot_req[slot] = req
            self.slot_pos[slot] = plen
            self.slot_last[slot] = tok
            self.slot_budget[slot] = req.max_new_tokens - 1

    def step(self) -> int:
        """One engine iteration: admit waiting requests, decode all active
        slots (in gathered dispatches of ``decode_batch`` lanes when the
        batch knob is below the slot count).  Returns the number of active
        slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        if self.decode_batch >= self.max_slots:
            # full-width dispatch: the original (default) path, unchanged
            tokens = jnp.asarray(self.slot_last, jnp.int32)
            positions = jnp.asarray(self.slot_pos, jnp.int32)
            logits, self.caches = self._decode(self.params, self.caches,
                                               tokens, positions)
            full = np.asarray(self.sampler(logits, None))
            nxt = {slot: int(full[slot]) for slot in active}
        else:
            nxt = {}
            width = self.decode_batch
            for c0 in range(0, len(active), width):
                chunk = active[c0:c0 + width]
                # pad the gather with a duplicate of a real lane; only the
                # first len(chunk) (distinct) lanes are scattered back
                idx = chunk + [chunk[-1]] * (width - len(chunk))
                idx_arr = jnp.asarray(idx, jnp.int32)
                tokens = jnp.asarray(self.slot_last[idx], jnp.int32)
                positions = jnp.asarray(self.slot_pos[idx], jnp.int32)
                logits, self.caches = self._decode_chunk(
                    self.params, self.caches, tokens, positions, idx_arr,
                    n_valid=len(chunk))
                got = np.asarray(self.sampler(logits, None))
                for j, slot in enumerate(chunk):
                    nxt[slot] = int(got[j])
        self._steps += 1
        for slot in active:
            req = self.slot_req[slot]
            tok = nxt[slot]
            req.output.append(tok)
            self.slot_pos[slot] += 1
            self.slot_last[slot] = tok
            self.slot_budget[slot] -= 1
            done = (self.slot_budget[slot] <= 0
                    or (req.eos_id is not None and tok == req.eos_id)
                    or self.slot_pos[slot] >= self.cache_len - 1)
            if done:
                req.done_at = self.clock()
                self.completed.append(req)
                self.slot_req[slot] = None
        return len(active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or any(r is not None for r in self.slot_req)):
            if self.step() == 0 and not self.queue:
                break
            max_steps -= 1
            if max_steps <= 0:
                raise RuntimeError("serve loop did not converge")
        return self.completed

    def stats(self) -> dict:
        lat = [r.latency for r in self.completed if r.latency is not None]
        ttft = [r.ttft for r in self.completed if r.ttft is not None]
        toks = sum(len(r.output) for r in self.completed)
        return {
            "completed": len(self.completed),
            "decode_steps": self._steps,
            "tokens": toks,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        }

    def metrics(self) -> dict:
        """Per-request latency summary over the completed set: TTFT /
        TPOT / end-to-end latency percentiles plus the aggregate decode
        throughput over the serving span (first submit to last finish).
        Consumed by ``benchmarks/fig_serving_sweep.py`` and printed by
        ``examples/serve_with_plan.py``."""
        done = self.completed
        ttfts = [r.ttft for r in done if r.ttft is not None]
        lats = [r.latency for r in done if r.latency is not None]
        tpots = [(r.latency - r.ttft) / (len(r.output) - 1)
                 for r in done
                 if r.latency is not None and r.ttft is not None
                 and len(r.output) > 1]
        toks = sum(len(r.output) for r in done)
        starts = [r.submitted_at for r in done if r.submitted_at is not None]
        ends = [r.done_at for r in done if r.done_at is not None]
        span = (max(ends) - min(starts)) if starts and ends else 0.0

        def pct(vals, q):
            return float(np.percentile(vals, q)) if vals else None

        return {
            "completed": len(done),
            "tokens": toks,
            "decode_steps": self._steps,
            "span_s": span,
            "tokens_per_s": toks / span if span > 0.0 else 0.0,
            "ttft_p50_s": pct(ttfts, 50), "ttft_p99_s": pct(ttfts, 99),
            "tpot_p50_s": pct(tpots, 50), "tpot_p99_s": pct(tpots, 99),
            "latency_p50_s": pct(lats, 50), "latency_p99_s": pct(lats, 99),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "mean_latency_s": float(np.mean(lats)) if lats else None,
        }


# ------------------------------------------------------------------ helpers
def _slot_axes(caches):
    """vmap in_axes tree: slot/batch axis is 1 for stacked cache leaves."""
    return jax.tree.map(lambda a: 1, caches)


def _install_slot(full, new, slot):
    """Write a single-sequence cache (batch==1 at axis 1) into slot
    ``slot`` of the engine cache (batch==max_slots at axis 1)."""
    return jax.lax.dynamic_update_slice_in_dim(full, new.astype(full.dtype),
                                               slot, axis=1)

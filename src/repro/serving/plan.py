"""``repro.serving.plan`` — searched decode-serving plans priced under
live request traffic (DESIGN.md Sec. 15).

Training went plan-aware in PR 5; serving still built its strategy ad hoc.
This module is the serving twin of :mod:`repro.plan`: a frozen,
schema-versioned :class:`ServingPlan` artifact (decode slot count, decode
dispatch batch, KV-shard layout, per-collective algorithm, prefill stream
allocation, cluster fingerprint, predicted tokens/sec) distinct from the
training ``Plan``, plus the :func:`compile_serving` facade that searches
the serving knobs with the *same* mutation-registry backtracking search
the training compiler uses.

The pricing model lowers one decode window into the unified
:class:`~repro.core.events.EventEngine`:

* **Decode compute** — ``rounds x dispatches x layer-spans`` dep-chained
  :class:`ComputeJob`\\ s on stream 0 (each span: weight streaming + KV
  reads vs matmul flops on the reference chip, whichever binds, plus a
  launch overhead; the last span of a dispatch adds the LM head).
  Dispatches are padded to the plan's ``decode_batch`` — padding waste is
  priced, which is exactly the batch-granularity tradeoff the search
  weighs.
* **Per-token TP collectives** — the PR 9 dep-coupled lowering
  (:func:`repro.core.tp_traffic.couple_tp`) applied at decode granularity:
  one latency-critical ``tp``-class job per span, gating the next span's
  compute (``bwd_bytes=0`` — there is no backward in decode).  The
  KV-shard layout decides the per-layer payload multiple and collective
  kind (``replicated`` -> one all-reduce, ``head`` -> two all-reduces,
  ``sequence`` -> gathered partial-attention traffic).
* **Prefill admissions** — a competing traffic class: the seeded
  :class:`~repro.serving.workload.Workload` trace's arrival pattern is
  scaled onto the decode horizon; each admission is a compute job (threaded
  into the decode chain when ``streams == 1``, on a dedicated prefill
  stream when ``streams == 2`` — bought with HBM for the prefill working
  set) plus a ``prefill``-class TP collective whose finish stamps that
  request's predicted TTFT.

Cost is **seconds per decoded token** under the trace; the search start
state *is* the default engine configuration, so the searched plan can
never price worse than the default (the same structural guarantee the
warm-started training search gives).  Serving mutations register outside
``ALL_METHODS`` and are applicable only on ``is_serving`` simulators, so
every PR 1–9 training trajectory and cache key stays bit-identical.

Import-light on purpose (no jax): plans must load/price from bare
interpreters and the plan-cache CLI.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time

from ..cluster import ClusterSpec, get_preset
from ..cluster.collectives import COLLECTIVE_ALGOS, KIND_AG, KIND_AR
from ..core.events import CommJob, ComputeJob, EventEngine, TC_TP
from ..core.hw import Hardware, TPU_V5E
from ..core.mutations import (SERVE_KV_LAYOUTS, SERVE_STREAM_CHOICES,
                              SERVING_METHODS)
from ..core.search import backtracking_search
from ..core.tp_traffic import TPTraffic, couple_tp
from ..plan.artifact import (ClusterMismatchError, PlanError,
                             PlanVersionError, _spec_from_fingerprint,
                             _tuplize, cluster_fingerprint,
                             cluster_fingerprint_diff)
from .workload import Workload

__all__ = [
    "SERVING_SCHEMA", "SERVING_PLAN_VERSION", "DEFAULT_HBM_BYTES",
    "KV_LAYOUTS", "TC_PREFILL", "DecodeModel", "ServingState",
    "ServingSimulator", "ServingPlan", "compile_serving",
    "serving_compile_key",
]

SERVING_SCHEMA = "repro.serving_plan"
SERVING_PLAN_VERSION = 1
SERVING_SUPPORTED_VERSIONS = (1,)

# serving memory budget per device (the Hardware dataclass carries no HBM
# capacity — this is the v5e-class default, overridable per compile)
DEFAULT_HBM_BYTES = 16e9

TC_PREFILL = "prefill"

# KV-shard layouts: (collective kind, per-layer payload multiple,
# KV memory/read shard factor).  ``replicated`` keeps the full cache on
# every device (one MLP all-reduce per layer, maximum HBM); ``head``
# shards over KV heads (attn + MLP all-reduces, sharding saturates at
# n_kv_heads — the GQA wall); ``sequence`` shards the cache over sequence
# (scales past the head count, pays gathered partial-attention traffic).
KV_LAYOUTS = SERVE_KV_LAYOUTS  # draw choices live with the mutations
_KV_KIND = {"replicated": KIND_AR, "head": KIND_AR, "sequence": KIND_AG}
_KV_PAYLOADS = {"replicated": 1.0, "head": 2.0, "sequence": 3.0}


def kv_shard_factor(layout: str, tp_degree: int, n_kv_heads: int) -> float:
    """Per-device fraction of the KV cache held (and read) under a
    layout.  ``head`` cannot shard beyond the model's KV-head count."""
    if layout == "head":
        return 1.0 / max(1, min(tp_degree, n_kv_heads))
    if layout == "sequence":
        return 1.0 / max(1, tp_degree)
    if layout != "replicated":
        raise ValueError(f"unknown KV layout {layout!r} "
                         f"(choices: {KV_LAYOUTS})")
    return 1.0


def default_tp_degree(spec: ClusterSpec) -> int:
    """The serving TP group: the innermost link level (flat specs: up to
    8-way) — decode collectives should never cross a pod boundary."""
    if spec.is_flat_compat:
        return max(1, min(8, spec.n_devices))
    return max(1, min(8, spec.levels[0].degree))


# --------------------------------------------------------------- the model
@dataclasses.dataclass(frozen=True)
class DecodeModel:
    """The decode-relevant slice of a :class:`ModelConfig` — just enough
    to price weight streaming, KV traffic and per-token activation
    collectives, serializable into the plan artifact."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    glu: bool = True
    dtype_bytes: int = 2

    @staticmethod
    def from_config(cfg) -> "DecodeModel":
        dt = {"float32": 4, "bfloat16": 2, "float16": 2}.get(cfg.dtype, 2)
        return DecodeModel(
            name=cfg.name, n_layers=int(cfg.n_layers),
            d_model=int(cfg.d_model), n_heads=int(cfg.n_heads),
            n_kv_heads=int(cfg.n_kv_heads), head_dim=int(cfg.hd),
            d_ff=int(cfg.d_ff), vocab=int(cfg.vocab), glu=bool(cfg.glu),
            dtype_bytes=dt)

    # ------------------------------------------------------ derived sizes
    @property
    def layer_weight_bytes(self) -> float:
        attn = self.d_model * self.head_dim * (self.n_heads
                                               + 2 * self.n_kv_heads) \
            + self.n_heads * self.head_dim * self.d_model
        ffn = (3 if self.glu else 2) * self.d_model * self.d_ff
        return float((attn + ffn) * self.dtype_bytes)

    @property
    def head_weight_bytes(self) -> float:
        return float(self.d_model * self.vocab * self.dtype_bytes)

    @property
    def params_bytes(self) -> float:
        # embedding + LM head ride along with the layer stack
        return self.n_layers * self.layer_weight_bytes \
            + 2 * self.head_weight_bytes

    @property
    def kv_bytes_per_token(self) -> float:
        """Full-cache bytes one token pins across all layers (K and V)."""
        return float(2 * self.n_kv_heads * self.head_dim * self.dtype_bytes
                     * self.n_layers)

    @property
    def act_bytes_per_token(self) -> float:
        return float(self.d_model * self.dtype_bytes)

    # ------------------------------------------------------ serialization
    def to_tuple(self) -> tuple:
        return ("decode_model.v1", self.name, self.n_layers, self.d_model,
                self.n_heads, self.n_kv_heads, self.head_dim, self.d_ff,
                self.vocab, self.glu, self.dtype_bytes)

    @staticmethod
    def from_tuple(t) -> "DecodeModel":
        if not t or t[0] != "decode_model.v1":
            raise ValueError(f"not a decode-model tuple: {t!r}")
        (_, name, nl, dm, nh, nkv, hd, dff, vocab, glu, db) = t
        return DecodeModel(name=str(name), n_layers=int(nl), d_model=int(dm),
                           n_heads=int(nh), n_kv_heads=int(nkv),
                           head_dim=int(hd), d_ff=int(dff), vocab=int(vocab),
                           glu=bool(glu), dtype_bytes=int(db))


# ------------------------------------------------------------ search state
SLOT_DEFAULT = 8
BATCH_DEFAULT = 8


@dataclasses.dataclass
class ServingState:
    """The searched serving knobs — the mutable state the backtracking
    search clones and mutates (the serving twin of ``FusionGraph``).  The
    default value *is* the default ``ServeEngine`` configuration, so a
    search started here can never return a worse plan."""
    slots: int = SLOT_DEFAULT
    decode_batch: int = BATCH_DEFAULT
    kv_layout: str = "replicated"
    algo: str = "ring"
    streams: int = 1

    @property
    def batch(self) -> int:
        """Effective dispatch width (a batch can never exceed the slots)."""
        return max(1, min(self.decode_batch, self.slots))

    # ------------------------------------------------- search-side protocol
    def clone(self) -> "ServingState":
        return dataclasses.replace(self)

    def signature(self) -> tuple:
        return ("serving", self.slots, self.decode_batch, self.kv_layout,
                self.algo, self.streams)

    def fast_signature(self) -> tuple:
        return self.signature()

    # ------------------------------------------------------------ mutators
    def set_slots(self, n: int) -> bool:
        n = int(n)
        if n < 1 or n == self.slots:
            return False
        self.slots = n
        return True

    def set_decode_batch(self, n: int) -> bool:
        n = int(n)
        if n < 1 or n == self.decode_batch:
            return False
        self.decode_batch = n
        return True

    def set_kv_layout(self, layout: str) -> bool:
        if layout not in KV_LAYOUTS:
            raise ValueError(f"unknown kv layout {layout!r}; "
                             f"known: {KV_LAYOUTS}")
        if layout == self.kv_layout:
            return False
        self.kv_layout = layout
        return True

    def set_algo(self, algo: str) -> bool:
        if algo not in COLLECTIVE_ALGOS:
            raise ValueError(f"unknown collective algo {algo!r}")
        if algo == self.algo:
            return False
        self.algo = algo
        return True

    def set_streams(self, n: int) -> bool:
        n = int(n)
        if n not in SERVE_STREAM_CHOICES:
            raise ValueError(f"streams must be one of "
                             f"{SERVE_STREAM_CHOICES}, got {n}")
        if n == self.streams:
            return False
        self.streams = n
        return True


def _pct(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1,
              max(0, math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[idx]


# -------------------------------------------------------------- simulator
class ServingSimulator:
    """Prices a :class:`ServingState` as seconds per decoded token under
    a :class:`Workload` trace on a cluster, by lowering one decode window
    into the unified event engine (module docstring has the job model).

    ``is_serving`` gates the serving mutations' applicability — training
    simulators never see them, serving simulators never see the
    graph-mutating training methods (``compile_serving`` passes
    ``methods=SERVING_METHODS`` explicitly)."""

    is_serving = True
    estimator = None  # no worker pool: candidate evals are engine-bound

    def __init__(self, model: DecodeModel, workload: Workload, cluster,
                 *, hw: Hardware = TPU_V5E, cache_len: int = 256,
                 tp_degree: int | None = None,
                 hbm_bytes: float = DEFAULT_HBM_BYTES,
                 max_spans: int = 6, rounds: int = 4, max_jobs: int = 240):
        self.model = model
        self.workload = workload
        self.cluster = (cluster if isinstance(cluster, ClusterSpec)
                        else get_preset(cluster))
        self.hw = hw
        self.cache_len = int(cache_len)
        self.tp_degree = (default_tp_degree(self.cluster)
                          if tp_degree is None else max(1, int(tp_degree)))
        self.hbm_bytes = float(hbm_bytes)
        self.max_spans = int(max_spans)
        self.rounds = int(rounds)
        self.max_jobs = int(max_jobs)
        self._memo: dict = {}

    # ----------------------------------------------------------- protocol
    def cost(self, state: ServingState) -> float:
        return self._run(state)["seconds_per_token"]

    def price(self, state: ServingState) -> dict:
        return dict(self._run(state))

    # ------------------------------------------------------------- sizing
    def _geometry(self, state: ServingState) -> tuple[int, int, int, int]:
        """(occupancy, dispatches, spans, rounds) for a state, bounded so
        one candidate evaluation never explodes the job count."""
        occ = max(1, min(state.slots, self.workload.concurrency))
        b = min(state.batch, occ)
        disp = -(-occ // b)
        spans = max(1, min(self.max_spans, self.model.n_layers,
                           self.max_jobs // (2 * disp)))
        rounds = max(2, min(self.rounds,
                            self.max_jobs // max(1, disp * spans)))
        return occ, disp, spans, rounds

    def mem_bytes(self, state: ServingState) -> float:
        """Per-device HBM the state pins: sharded weights, the slot KV
        cache under the layout's shard factor, and (with a dedicated
        prefill stream) the prefill working set."""
        m, tp = self.model, self.tp_degree
        shard = kv_shard_factor(state.kv_layout, tp, m.n_kv_heads)
        mem = m.params_bytes / tp \
            + state.slots * self.cache_len * m.kv_bytes_per_token * shard
        if state.streams > 1:
            max_prompt = self.workload.prompt_lens[1]
            mem += 2.0 * max_prompt * (m.d_model + m.d_ff) * m.dtype_bytes \
                + self.cache_len * m.kv_bytes_per_token * shard
        return mem

    def decode_tp(self, state: ServingState) -> TPTraffic:
        """The per-span TP traffic the decode lowering couples in — the
        byte-conservation anchor the tests compare against the training
        lowering (``couple_tp`` emits exactly ``total_bytes``)."""
        occ, disp, spans, rounds = self._geometry(state)
        b = min(state.batch, occ)
        lps = self.model.n_layers / spans
        per_span = 0.0
        if self.tp_degree > 1:
            per_span = (_KV_PAYLOADS[state.kv_layout] * lps * b
                        * self.model.act_bytes_per_token)
        return TPTraffic(n_layers=rounds * disp * spans,
                         fwd_bytes=per_span, bwd_bytes=0.0, algo=state.algo,
                         kind=_KV_KIND[state.kv_layout])

    # ------------------------------------------------------------ durations
    def _span_seconds(self, b: int, lps: float, with_head: bool) -> float:
        m, hw, tp = self.model, self.hw, self.tp_degree
        wb = m.layer_weight_bytes * lps / tp
        kv = b * 0.5 * self.cache_len * (m.kv_bytes_per_token / m.n_layers) \
            * lps * self._kv_read_shard
        fl = 2.0 * (m.layer_weight_bytes / m.dtype_bytes) * b * lps / tp
        t = max((wb + kv) / hw.hbm_bw,
                fl / (hw.peak_flops * hw.efficiency)) + hw.launch_overhead
        if with_head:
            hb = m.head_weight_bytes / tp
            hf = 2.0 * (m.head_weight_bytes / m.dtype_bytes) * b / tp
            t += max(hb / hw.hbm_bw, hf / (hw.peak_flops * hw.efficiency))
        return t

    def _prefill_seconds(self) -> float:
        m, hw, tp = self.model, self.hw, self.tp_degree
        P = self.workload.mean_prompt_len
        fl = 2.0 * (m.params_bytes / m.dtype_bytes) * P / tp
        return max(m.params_bytes / tp / hw.hbm_bw,
                   fl / (hw.peak_flops * hw.efficiency)) + hw.launch_overhead

    # ------------------------------------------------------------- lowering
    def _run(self, state: ServingState) -> dict:
        key = state.fast_signature()
        hit = self._memo.get(key)
        if hit is not None:
            return hit

        m, wl, tp = self.model, self.workload, self.tp_degree
        mem = self.mem_bytes(state)
        if mem > self.hbm_bytes:
            out = {"feasible": False,
                   "reason": f"needs {mem:.3e} B HBM > budget "
                             f"{self.hbm_bytes:.3e} B",
                   "mem_bytes": mem, "hbm_bytes": self.hbm_bytes,
                   "seconds_per_token": float("inf"),
                   "tokens_per_s": 0.0, "state": state.signature()}
            self._memo[key] = out
            return out

        occ, disp, spans, rounds = self._geometry(state)
        b = min(state.batch, occ)
        lps = m.n_layers / spans
        self._kv_read_shard = kv_shard_factor(state.kv_layout, tp,
                                              m.n_kv_heads)

        # decode chain: rounds x dispatches x spans dep-chained jobs
        chain: list[ComputeJob] = []
        jid = -1
        for r in range(rounds):
            for d in range(disp):
                for s in range(spans):
                    i = len(chain)
                    chain.append(ComputeJob(
                        ref=i,
                        duration=self._span_seconds(b, lps,
                                                    with_head=s == spans - 1),
                        job_id=jid, stream=0, key=i,
                        deps=(chain[-1].job_id,) if chain else ()))
                    jid -= 1
        horizon = sum(j.duration for j in chain)

        # per-span TP collectives, dep-coupled at decode granularity
        tpt = self.decode_tp(state)
        next_id = 1
        chain, fwd_jobs, _, next_id = couple_tp(
            chain, list(range(1, len(chain) + 1)), tpt, next_id)

        # prefill admissions from the trace's arrival pattern
        t_pref = self._prefill_seconds()
        n_pref = max(1, min(wl.n_requests, 2 * rounds * disp,
                            round(rounds * occ / wl.mean_new_tokens)))
        fr = wl.arrival_fractions()
        pref_bytes = 0.0
        if tp > 1:
            pref_bytes = (_KV_PAYLOADS[state.kv_layout] * m.n_layers
                          * wl.mean_prompt_len * m.act_bytes_per_token)
        comm: list[CommJob] = list(fwd_jobs)
        ttft_gates: list[tuple[int, float]] = []   # (gate job id, ready)
        prev_pref: int | None = None
        stream = 0 if state.streams == 1 else 1
        kcount = len(chain)
        admissions = []
        for k in range(n_pref):
            frac = fr[(k * len(fr)) // n_pref]
            admissions.append((min(len(chain) - 1, int(frac * len(chain))),
                               frac * horizon))
        admissions.sort()
        for pos, ready in admissions:
            deps = () if prev_pref is None else (prev_pref,)
            if stream == 0 and pos > 0:
                deps = deps + (chain[pos - 1].job_id,)
            pj = ComputeJob(ref=kcount, duration=t_pref, job_id=jid,
                            stream=stream, key=kcount, deps=deps,
                            kind="prefill", ready=ready,
                            traffic_class=TC_PREFILL)
            jid -= 1
            kcount += 1
            prev_pref = pj.job_id
            chain.append(pj)
            if stream == 0:
                # threaded into the decode chain: the next decode dispatch
                # waits for the admission (the PR 9 coupling pattern)
                nxt = chain[pos]
                chain[pos] = dataclasses.replace(
                    nxt, deps=nxt.deps + (pj.job_id,))
            if pref_bytes > 0.0:
                cj = CommJob(bucket=kcount, ready=0.0, nbytes=pref_bytes,
                             algo=state.algo, kind=_KV_KIND[state.kv_layout],
                             job_id=next_id, deps=(pj.job_id,),
                             traffic_class=TC_PREFILL)
                next_id += 1
                comm.append(cj)
                ttft_gates.append((cj.job_id, ready))
            else:
                ttft_gates.append((pj.job_id, ready))

        if not fwd_jobs:
            # tp_degree == 1 emits no TP jobs; force the coupled (phased)
            # path anyway so prefill ready times are honored — a zero-byte
            # sentinel is pre-finished at t=0 and costs nothing
            sentinel = CommJob(bucket=0, ready=0.0, nbytes=0.0,
                               job_id=next_id, traffic_class=TC_TP)
            next_id += 1
            comm.append(sentinel)
            first = chain[0]
            chain[0] = dataclasses.replace(
                first, deps=first.deps + (sentinel.job_id,))

        eng = EventEngine(self.cluster, streams=1)
        u = eng.run_unified(chain, comm)

        decode_ids = [j.job_id for j in chain
                      if j.traffic_class != TC_PREFILL] \
            + [j.job_id for j in fwd_jobs]
        decode_finish = max(eng.job_finish[i] for i in decode_ids)
        tokens = rounds * occ
        spt = decode_finish / tokens
        ttfts = sorted(max(0.0, eng.job_finish[g] - ready)
                       for g, ready in ttft_gates)
        out = {
            "feasible": True,
            "seconds_per_token": spt,
            "tokens_per_s": tokens / decode_finish,
            "decode_finish_s": decode_finish,
            "finish_s": u.finish,
            "ttft_p50_s": _pct(ttfts, 0.50),
            "ttft_p99_s": _pct(ttfts, 0.99),
            "occupancy": occ,
            "dispatch_batch": b,
            "dispatches": disp,
            "spans": spans,
            "rounds": rounds,
            "tokens": tokens,
            "n_prefills": n_pref,
            "prefill_s": t_pref,
            "tp_bytes_decode": sum(j.nbytes for j in fwd_jobs),
            "tp_bytes_total": tpt.total_bytes,
            "tp_busy_s": eng.class_busy.get(TC_TP, 0.0),
            "prefill_busy_s": eng.class_busy.get(TC_PREFILL, 0.0),
            "mem_bytes": mem,
            "hbm_bytes": self.hbm_bytes,
            "tp_degree": tp,
            "state": state.signature(),
        }
        self._memo[key] = out
        return out


# ---------------------------------------------------------------- artifact
def _atomic_json(path: str, obj) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=1)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """The frozen serving-strategy artifact: the searched knobs plus
    everything needed to rebuild the pricing context (model slice,
    workload, cluster fingerprint, reference chip) and re-verify the
    prediction.  Distinct schema from the training ``Plan`` — a serving
    plan loaded by ``Plan.load`` fails with ``PlanVersionError``, and vice
    versa, instead of silently mispricing."""
    slots: int
    decode_batch: int
    kv_layout: str
    algo: str
    streams: int
    cache_len: int
    tp_degree: int
    hbm_bytes: float
    model: tuple
    workload: tuple
    workload_digest: str
    cluster: tuple
    hw: tuple
    predicted_tokens_per_s: float
    predicted_ttft_p99_s: float
    version: int = SERVING_PLAN_VERSION
    provenance: dict = dataclasses.field(default_factory=dict, compare=False)

    # -------------------------------------------------------- construction
    @staticmethod
    def from_search(state: ServingState, sim: ServingSimulator,
                    price: dict, provenance: dict | None = None
                    ) -> "ServingPlan":
        return ServingPlan(
            slots=state.slots, decode_batch=state.decode_batch,
            kv_layout=state.kv_layout, algo=state.algo,
            streams=state.streams, cache_len=sim.cache_len,
            tp_degree=sim.tp_degree, hbm_bytes=sim.hbm_bytes,
            model=sim.model.to_tuple(),
            workload=sim.workload.to_tuple(),
            workload_digest=sim.workload.digest(),
            cluster=cluster_fingerprint(sim.cluster),
            hw=_tuplize(sorted(dataclasses.asdict(sim.hw).items())),
            predicted_tokens_per_s=float(price.get("tokens_per_s", 0.0)),
            predicted_ttft_p99_s=float(price.get("ttft_p99_s", 0.0)),
            provenance=dict(provenance or {}))

    # ------------------------------------------------------------ accessors
    def state(self) -> ServingState:
        return ServingState(slots=self.slots, decode_batch=self.decode_batch,
                            kv_layout=self.kv_layout, algo=self.algo,
                            streams=self.streams)

    @property
    def predicted_iteration_time(self) -> float | None:
        """Seconds per decoded token — the cache index's display metric
        (the serving analogue of a training plan's iteration time)."""
        if self.predicted_tokens_per_s > 0.0:
            return 1.0 / self.predicted_tokens_per_s
        return None

    def simulator(self, cluster: ClusterSpec | None = None
                  ) -> ServingSimulator:
        """Rebuild the pricing simulator.  An explicit ``cluster`` that
        does not match the recorded fingerprint raises
        :class:`ClusterMismatchError` (same contract as the training
        plan) — pass nothing to price on the recorded topology."""
        if cluster is not None:
            fp = cluster_fingerprint(cluster)
            if fp != self.cluster:
                diff = cluster_fingerprint_diff(self.cluster, fp)
                raise ClusterMismatchError(
                    f"plan was searched against a different cluster "
                    f"({len(diff)} field(s) differ; first: "
                    f"{diff[0] if diff else '?'})")
            spec = cluster
        else:
            spec = _spec_from_fingerprint(self.cluster)
        return ServingSimulator(
            DecodeModel.from_tuple(self.model),
            Workload.from_tuple(self.workload), spec,
            hw=Hardware(**dict(self.hw)), cache_len=self.cache_len,
            tp_degree=self.tp_degree, hbm_bytes=self.hbm_bytes)

    def price(self, cluster: ClusterSpec | None = None) -> dict:
        """Re-price the plan's knobs (on the recorded fingerprint, or an
        explicit matching/overriding cluster).  Unlike :meth:`simulator`,
        an override mismatch does not raise — it prices anyway and reports
        ``cluster_fingerprint_match: False`` (the dryrun CLI turns that
        into a field-by-field diff and a nonzero exit)."""
        match = True
        if cluster is not None:
            match = cluster_fingerprint(cluster) == self.cluster
            sim = ServingSimulator(
                DecodeModel.from_tuple(self.model),
                Workload.from_tuple(self.workload), cluster,
                hw=Hardware(**dict(self.hw)), cache_len=self.cache_len,
                tp_degree=self.tp_degree, hbm_bytes=self.hbm_bytes)
        else:
            sim = self.simulator()
        out = sim.price(self.state())
        out["cluster"] = {"name": sim.cluster.name,
                          "n_devices": sim.cluster.n_devices}
        out["cluster_fingerprint_match"] = match
        return out

    def describe(self) -> dict:
        return {
            "schema": SERVING_SCHEMA,
            "version": self.version,
            "arch": self.model[1],
            "slots": self.slots,
            "decode_batch": self.decode_batch,
            "kv_layout": self.kv_layout,
            "algo": self.algo,
            "streams": self.streams,
            "cache_len": self.cache_len,
            "tp_degree": self.tp_degree,
            "workload_digest": self.workload_digest,
            "predicted_tokens_per_s": self.predicted_tokens_per_s,
            "predicted_ttft_p99_s": self.predicted_ttft_p99_s,
        }

    # ---------------------------------------------------------------- JSON
    def _to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = SERVING_SCHEMA
        return d

    def fingerprint(self) -> str:
        import hashlib
        d = self._to_json()
        d.pop("provenance", None)
        return hashlib.sha256(
            json.dumps(d, sort_keys=True, default=repr).encode()
        ).hexdigest()[:16]

    def save(self, path: str) -> str:
        _atomic_json(path, self._to_json())
        return path

    @staticmethod
    def from_dict(d: dict) -> "ServingPlan":
        if not isinstance(d, dict) or d.get("schema") != SERVING_SCHEMA:
            raise PlanVersionError(
                f"not a {SERVING_SCHEMA} artifact "
                f"(schema={d.get('schema') if isinstance(d, dict) else '?'})")
        v = d.get("version")
        if v not in SERVING_SUPPORTED_VERSIONS:
            raise PlanVersionError(
                f"unsupported serving-plan version {v!r}; supported: "
                f"{SERVING_SUPPORTED_VERSIONS}")
        try:
            return ServingPlan(
                slots=int(d["slots"]), decode_batch=int(d["decode_batch"]),
                kv_layout=str(d["kv_layout"]), algo=str(d["algo"]),
                streams=int(d["streams"]), cache_len=int(d["cache_len"]),
                tp_degree=int(d["tp_degree"]),
                hbm_bytes=float(d["hbm_bytes"]),
                model=_tuplize(d["model"]),
                workload=_tuplize(d["workload"]),
                workload_digest=str(d["workload_digest"]),
                cluster=_tuplize(d["cluster"]),
                hw=_tuplize(d["hw"]),
                predicted_tokens_per_s=float(d["predicted_tokens_per_s"]),
                predicted_ttft_p99_s=float(d["predicted_ttft_p99_s"]),
                version=int(v),
                provenance=dict(d.get("provenance") or {}))
        except (KeyError, TypeError, ValueError) as e:
            raise PlanError(f"malformed serving plan: {e}") from e

    @staticmethod
    def load(path: str) -> "ServingPlan":
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise PlanError(f"cannot read serving plan {path}: {e}") from e
        return ServingPlan.from_dict(d)


# ----------------------------------------------------------------- facade
def serving_compile_key(model: DecodeModel, workload: Workload,
                        sim: ServingSimulator, knobs: str) -> str:
    """The plan-cache key of one serving compile point: model slice x
    workload digest x pricing context x search knobs (the serving twin of
    ``repro.plan.cache.compile_key`` — the workload digest is what keeps
    two traffic patterns from sharing a plan)."""
    from ..plan.cache import _sha
    return _sha({
        "schema": SERVING_SCHEMA,
        "model": model.to_tuple(),
        "workload": workload.digest(),
        "cache_len": sim.cache_len,
        "tp_degree": sim.tp_degree,
        "hbm_bytes": sim.hbm_bytes,
        "cluster": cluster_fingerprint(sim.cluster),
        "hw": sorted(dataclasses.asdict(sim.hw).items()),
        "knobs": knobs,
    })


def _cache_features(model: DecodeModel, workload: Workload,
                    sim: ServingSimulator, knobs: str) -> dict:
    """Index features in the training cache's key vocabulary so the
    ``ls``/``stats`` CLI and similarity ranking stay schema-agnostic
    (``graph`` is namespaced — a serving entry can never look like an
    exact trace match to a training request)."""
    from ..plan.cache import _sha
    spec = sim.cluster
    if spec.is_flat_compat:
        levels = ["flat"]
    else:
        levels = [l.name for l in spec.levels]
    return {
        "schema": SERVING_SCHEMA,
        "graph": f"serving:{workload.digest()}",
        "arch": model.name,
        "cluster": _sha(cluster_fingerprint(spec)),
        "cluster_name": spec.name,
        "n_devices": int(spec.n_devices),
        "levels": levels,
        "knobs": knobs,
    }


def compile_serving(arch, *, cluster="tpu_v5e_pod_16",
                    workload: Workload | None = None, cache_len: int = 256,
                    tp_degree: int | None = None, hw: Hardware = TPU_V5E,
                    hbm_bytes: float = DEFAULT_HBM_BYTES,
                    alpha: float = 1.05, beta: int = 10,
                    unchanged_limit: int = 60, max_steps: int | None = None,
                    methods=None, seed: int = 0, cache=None) -> ServingPlan:
    """Search a serving plan for ``arch`` (a config name, ``ModelConfig``
    or :class:`DecodeModel`) under ``workload`` traffic on ``cluster``.

    The search starts from the default :class:`ServingState` (the stock
    ``ServeEngine`` configuration), so the returned plan never prices
    worse than the default.  ``cache`` replays exact hits bit-identically
    through the shared :class:`~repro.plan.cache.PlanCache` (the workload
    digest joins the key)."""
    from ..plan.cache import knob_digest, open_cache

    if isinstance(arch, DecodeModel):
        model = arch
    elif isinstance(arch, str):
        from ..configs import get_config
        model = DecodeModel.from_config(get_config(arch))
    else:
        model = DecodeModel.from_config(arch)
    wl = workload if workload is not None else Workload()
    spec = get_preset(cluster) if isinstance(cluster, str) else cluster
    sim = ServingSimulator(model, wl, spec, hw=hw, cache_len=cache_len,
                           tp_degree=tp_degree, hbm_bytes=hbm_bytes)
    if methods is None:
        # explicit: the training mutations' applies would crash on a
        # ServingState, and their applicability defaults to True
        methods = SERVING_METHODS
    store = open_cache(cache)
    knobs = knob_digest(alpha=alpha, beta=beta,
                        unchanged_limit=unchanged_limit, max_steps=max_steps,
                        methods=methods, seed=seed)
    key = serving_compile_key(model, wl, sim, knobs)
    if store is not None:
        hit = store.get(key)
        if isinstance(hit, ServingPlan):
            hit.provenance["cache"] = {"outcome": "hit", "key": key}
            return hit

    t0 = time.perf_counter()
    res = backtracking_search(ServingState(), sim, alpha=alpha, beta=beta,
                              unchanged_limit=unchanged_limit,
                              max_steps=max_steps, methods=methods,
                              seed=seed)
    price = sim.price(res.best)
    plan = ServingPlan.from_search(res.best, sim, price, provenance={
        "arch": model.name,
        "cluster_name": spec.name,
        "initial_cost": res.initial_cost,
        "best_cost": res.best_cost,
        "steps": res.steps,
        "simulations": res.simulations,
        "search_wall_time": round(time.perf_counter() - t0, 3),
        "seed": seed,
        "cache": {"outcome": "miss" if store is not None else "disabled",
                  "key": key if store is not None else None},
    })
    if store is not None:
        store.put(key, plan, _cache_features(model, wl, sim, knobs))
    return plan

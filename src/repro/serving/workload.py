"""Seeded synthetic many-user decode request traces (DESIGN.md Sec. 15).

The serving search prices candidate plans under *live request traffic*:
a Poisson arrival process of (prompt length, decode budget) pairs standing
in for millions of concurrent users.  :class:`Workload` is the frozen,
hashable description — everything the trace generator needs and nothing it
derives — so the same value can (a) materialize a deterministic
:class:`TraceRequest` sequence for the simulator's prefill-admission model
and the engine replayer, and (b) digest into the plan-cache key (two
compiles under different traffic must not share a cached plan).

:class:`VirtualClock` + :func:`replay` drive a real
:class:`~repro.serving.engine.ServeEngine` through a trace on simulated
time: requests are submitted at their recorded arrivals, every decode step
advances the clock by a fixed ``step_time``, and the engine's injected
clock (satellite of this PR) stamps TTFT/latency deterministically —
tests and examples never race wall time.

Import-light on purpose (no jax, no numpy at module load): the search
worker pool and the plan artifact load this from bare interpreters; only
:func:`materialize_requests` (prompt token arrays for a real engine)
imports numpy, lazily.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import random

__all__ = ["TraceRequest", "Workload", "VirtualClock", "replay",
           "materialize_requests"]


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One synthetic user request: arrival time (seconds from trace
    start), prompt length in tokens, and decode budget in new tokens."""
    rid: int
    arrival_s: float
    prompt_len: int
    new_tokens: int


@dataclasses.dataclass(frozen=True)
class Workload:
    """Frozen trace-generator parameters.

    ``rate`` is the Poisson arrival intensity (requests/second);
    ``concurrency`` is the admission-window cap the serving simulator
    prices against (how many requests contend for decode slots at once —
    a property of the traffic, not of the searched plan).  ``prompt_lens``
    and ``new_tokens`` are inclusive uniform ranges."""
    n_requests: int = 64
    rate: float = 32.0
    concurrency: int = 48
    prompt_lens: tuple = (4, 48)
    new_tokens: tuple = (8, 48)
    seed: int = 0

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got {self.n_requests}")
        if not self.rate > 0.0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if self.concurrency < 1:
            raise ValueError(
                f"concurrency must be >= 1, got {self.concurrency}")
        for lo, hi in (self.prompt_lens, self.new_tokens):
            if not (1 <= lo <= hi):
                raise ValueError(
                    f"ranges must satisfy 1 <= lo <= hi, got ({lo}, {hi})")

    # --------------------------------------------------------- generation
    def requests(self) -> tuple[TraceRequest, ...]:
        """The materialized trace: deterministic in the Workload value
        (same seed -> bit-identical trace, across processes — the draws go
        through ``random.Random``, whose sequence is version-stable)."""
        return _materialize(self)

    # ------------------------------------------------------------ summary
    @property
    def mean_prompt_len(self) -> float:
        reqs = self.requests()
        return sum(r.prompt_len for r in reqs) / len(reqs)

    @property
    def mean_new_tokens(self) -> float:
        reqs = self.requests()
        return sum(r.new_tokens for r in reqs) / len(reqs)

    @property
    def total_new_tokens(self) -> int:
        return sum(r.new_tokens for r in self.requests())

    @property
    def duration_s(self) -> float:
        """Arrival span of the trace (time of the last arrival)."""
        return self.requests()[-1].arrival_s

    def arrival_fractions(self) -> tuple[float, ...]:
        """Each request's arrival as a fraction of the trace span — the
        simulator scales these onto its own decode horizon so prefill
        admissions land where the traffic actually bursts."""
        dur = self.duration_s
        if dur <= 0.0:
            return tuple(0.0 for _ in self.requests())
        return tuple(min(r.arrival_s / dur, 1.0) for r in self.requests())

    # ------------------------------------------------------ serialization
    def to_tuple(self) -> tuple:
        return ("workload.v1", self.n_requests, self.rate, self.concurrency,
                tuple(self.prompt_lens), tuple(self.new_tokens), self.seed)

    @staticmethod
    def from_tuple(t) -> "Workload":
        tag, n, rate, conc, pl, nt, seed = t
        if tag != "workload.v1":
            raise ValueError(f"not a workload tuple: {t!r}")
        return Workload(n_requests=int(n), rate=float(rate),
                        concurrency=int(conc),
                        prompt_lens=tuple(int(x) for x in pl),
                        new_tokens=tuple(int(x) for x in nt),
                        seed=int(seed))

    def digest(self) -> str:
        """Stable short digest of the generator parameters (and therefore
        of the trace) — joins the serving plan-cache key."""
        return hashlib.sha256(
            json.dumps(self.to_tuple(), sort_keys=True).encode()
        ).hexdigest()[:20]


@functools.lru_cache(maxsize=128)
def _materialize(wl: Workload) -> tuple[TraceRequest, ...]:
    rng = random.Random(wl.seed)
    t = 0.0
    out = []
    for rid in range(wl.n_requests):
        t += rng.expovariate(wl.rate)
        out.append(TraceRequest(
            rid=rid, arrival_s=t,
            prompt_len=rng.randint(*wl.prompt_lens),
            new_tokens=rng.randint(*wl.new_tokens)))
    return tuple(out)


def materialize_requests(workload: Workload, vocab: int) -> list:
    """Engine-level :class:`~repro.serving.engine.Request` objects for the
    trace, with deterministic synthetic prompt tokens (numpy imported
    lazily so the module stays jax/numpy-free for the search pool)."""
    import numpy as np

    from .engine import Request

    rng = np.random.default_rng(workload.seed)
    out = []
    for tr in workload.requests():
        prompt = rng.integers(0, vocab, size=tr.prompt_len).astype(np.int32)
        out.append(Request(rid=tr.rid, prompt=prompt,
                           max_new_tokens=tr.new_tokens))
    return out


class VirtualClock:
    """A monotonic clock the test/replay harness advances by hand.
    Callable (drop-in for ``time.monotonic``) so it plugs straight into
    ``ServeEngine(clock=...)``."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0.0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._now += dt
        return self._now


def replay(engine, workload: Workload, *, step_time: float = 1e-3,
           max_steps: int = 100_000) -> dict:
    """Drive a real engine through ``workload``'s trace on its virtual
    clock: submit requests at their recorded arrivals, advance the clock
    ``step_time`` per decode step (idle gaps jump to the next arrival),
    run to drain.  Returns ``engine.metrics()``.  The engine must have
    been built with a :class:`VirtualClock` — replaying on wall time would
    make TTFT depend on host load."""
    clock = engine.clock
    if not isinstance(clock, VirtualClock):
        raise TypeError("replay() needs an engine built with "
                        "clock=VirtualClock(); wall-clock replays are not "
                        "deterministic")
    items = materialize_requests(workload, engine.cfg.vocab)
    arrivals = [tr.arrival_s for tr in workload.requests()]
    i = 0
    for _ in range(max_steps):
        while i < len(items) and arrivals[i] <= clock() + 1e-12:
            engine.submit(items[i])
            i += 1
        n = engine.step()
        if n == 0 and not engine.queue:
            if i >= len(items):
                return engine.metrics()
            # idle: jump to the next arrival instead of spinning
            clock.advance(arrivals[i] - clock())
            continue
        clock.advance(step_time)
    raise RuntimeError(f"replay did not drain within {max_steps} steps")

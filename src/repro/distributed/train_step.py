"""Distributed training steps.

Two modes:

* ``ddp_tp`` — **the DisCo enactment path**: manual ``shard_map`` over the
  data axes; tensor parallelism stays GSPMD-auto over ``model``.  Gradient
  synchronisation is *explicit*: one ``psum`` per AllReduce bucket of the
  searched :class:`GradSyncStrategy`, with optional
  ``optimization_barrier`` fences pinning the bucket schedule.  The compiled
  HLO therefore carries exactly the collective schedule the search chose.

* ``fsdp_tp`` — GSPMD-auto ZeRO-3 for architectures whose replicated
  weights+optimizer do not fit one TP shard (DeepSeek-V2-236B,
  DeepSeek-Coder-33B).  Gradient reduce-scatters are inserted by XLA per
  tensor; DisCo bucket enactment is N/A here (DESIGN.md Sec. 4).
"""
from __future__ import annotations

import dataclasses
import json
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import compat
from ..compat import axis_size_compat, shard_map_compat
from ..models import model as M
from ..models.config import ModelConfig
from ..optim import adamw, apply_updates, clip_by_global_norm
from . import sharding as SH


# ----------------------------------------------------------------- strategy
@dataclasses.dataclass
class GradSyncStrategy:
    """Tensor-fusion strategy: a partition of parameter leaves into ordered
    buckets (leaf indices in ``jax.tree.leaves`` order), each synchronised
    by one fused collective.  ``comms[i]`` picks the collective kind of
    bucket ``i``: ``"ar"`` (one fused AllReduce, the paper's DDP path) or
    ``"rs_ag"`` (ZeRO-3-style reduce-scatter + all-gather — the searched
    ``FusionGraph.bucket_comm`` dimension, enacted for real).
    ``chunks[i] > 1`` splits bucket ``i``'s fused tensor into that many
    even byte ranges, each synchronised by its own collective — the
    searched ``FusionGraph.bucket_chunks`` store-and-forward dimension,
    enacted for real (identical numerics: a psum of disjoint slices is the
    sliced psum).  ``fused[i]`` truthy marks bucket ``i`` for the in-kernel
    compute+comm overlap path (the searched ``FusionGraph.bucket_fused``
    dimension): Pallas staging kernels pack straight into reduce-scatter
    layout and unpack straight out of the all-gather, with the same RS+AG
    wire arithmetic — loss-bit-identical to the psum path."""
    buckets: list[list[int]]
    barriers: bool = False      # fence buckets with optimization_barrier
    comms: Optional[list[str]] = None   # per-bucket "ar" | "rs_ag"
    chunks: Optional[list[int]] = None  # per-bucket collective count (>= 1)
    fused: Optional[list[int]] = None   # per-bucket in-kernel overlap flag

    def comm_kind(self, i: int) -> str:
        return self.comms[i] if self.comms else "ar"

    def chunk_count(self, i: int) -> int:
        return max(int(self.chunks[i]), 1) if self.chunks else 1

    def is_fused(self, i: int) -> bool:
        return bool(self.fused[i]) if self.fused else False

    @staticmethod
    def per_tensor(params) -> "GradSyncStrategy":
        n = len(jax.tree.leaves(params))
        return GradSyncStrategy([[i] for i in range(n)])

    @staticmethod
    def single_bucket(params) -> "GradSyncStrategy":
        n = len(jax.tree.leaves(params))
        return GradSyncStrategy([list(range(n))])

    @staticmethod
    def size_capped(params, cap_bytes: int = 25 * 2**20) -> "GradSyncStrategy":
        """DDP-style: consecutive leaves bucketed up to a byte cap."""
        leaves = jax.tree.leaves(params)
        buckets, cur, cur_b = [], [], 0
        for i, l in enumerate(leaves):
            b = l.size * l.dtype.itemsize
            if cur and cur_b + b > cap_bytes:
                buckets.append(cur)
                cur, cur_b = [], 0
            cur.append(i)
            cur_b += b
        if cur:
            buckets.append(cur)
        return GradSyncStrategy(buckets)

    @staticmethod
    def from_buckets(buckets, comms=None, chunks=None, params=None,
                     barriers: bool = False, fused=None) -> "GradSyncStrategy":
        """Build a strategy from explicit per-bucket state (the single
        implementation of the clip-to-leaves contract, shared by
        ``from_fusion_graph`` and ``repro.plan.Plan.grad_sync``).  With
        ``params``, bucket entries are clipped to the real leaf count and
        uncovered leaves get singleton unfused AllReduce buckets."""
        buckets = [list(b) for b in buckets]
        comms = (list(comms) if comms is not None
                 else ["ar"] * len(buckets))
        chunks = ([int(k) for k in chunks] if chunks is not None
                  else [1] * len(buckets))
        fused = ([int(bool(f)) for f in fused] if fused is not None
                 else [0] * len(buckets))
        if params is not None:
            n = len(jax.tree.leaves(params))
            seen: set = set()
            kept, kcomms, kchunks, kfused = [], [], [], []
            for b, kind, k, fz in zip(buckets, comms, chunks, fused):
                bk = [i for i in b if i < n]
                seen.update(bk)
                if bk:
                    kept.append(bk)
                    kcomms.append(kind)
                    kchunks.append(k)
                    kfused.append(fz)
            rest = [i for i in range(n) if i not in seen]
            kept.extend([[i] for i in rest])
            kcomms.extend(["ar"] * len(rest))
            kchunks.extend([1] * len(rest))
            kfused.extend([0] * len(rest))
            buckets, comms, chunks, fused = kept, kcomms, kchunks, kfused
        return GradSyncStrategy(buckets, barriers=barriers, comms=comms,
                                chunks=chunks, fused=fused)

    @staticmethod
    def from_fusion_graph(g, params) -> "GradSyncStrategy":
        """Lift the searched FusionGraph's bucket partition onto the real
        parameter leaves (grad_param indices == leaf indices), carrying the
        searched per-bucket comm kind and chunk count along so ``rs_ag``
        buckets lower to reduce-scatter + all-gather and chunked buckets
        to per-chunk collectives when enacted."""
        kinds = getattr(g, "bucket_comm", None) or ["ar"] * len(g.buckets)
        counts = getattr(g, "bucket_chunks", None) or [1] * len(g.buckets)
        flags = getattr(g, "bucket_fused", None)
        return GradSyncStrategy.from_buckets(g.buckets, kinds, counts,
                                             params=params, fused=flags)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"buckets": self.buckets, "barriers": self.barriers,
                       "comms": self.comms, "chunks": self.chunks,
                       "fused": self.fused}, f)

    @staticmethod
    def load(path: str) -> "GradSyncStrategy":
        with open(path) as f:
            d = json.load(f)
        return GradSyncStrategy(d["buckets"], d.get("barriers", False),
                                comms=d.get("comms"),
                                chunks=d.get("chunks"),
                                fused=d.get("fused"))


def _fused_bucket_sync(leaves, dp: int, chunks: int, dp_axes,
                       barrier_with=None):
    """In-kernel fused bucket sync: Pallas pack (grad leaves -> chunked,
    shard-tiled f32 staging, cast fused) -> per-chunk real reduce-scatter +
    mean + all-gather -> Pallas unpack (f32 -> grad dtype cast fused into
    the un-staging pass).  The wire arithmetic is exactly the ``rs_ag``
    lowering's, so numerics match the fused ``psum`` bit-for-bit.  Raises
    at trace time when Pallas cannot trace inside this shard_map region;
    the caller falls back to the jnp RS+AG lowering (same numerics)."""
    from ..kernels import ops as K
    total = sum(l.size for l in leaves)
    k = min(max(int(chunks), 1), max(total, 1))
    parts = K.fused_pack(leaves, total, dp, k)
    if barrier_with is not None:
        fenced = jax.lax.optimization_barrier(tuple(parts) + (barrier_with,))
        parts = list(fenced[:-1])
    cuts = [total * c // k for c in range(k + 1)]
    outs = []
    for c, part in enumerate(parts):
        shard = jax.lax.psum_scatter(part, tuple(dp_axes),
                                     scatter_dimension=0, tiled=True) / dp
        part = jax.lax.all_gather(shard, tuple(dp_axes), tiled=True)
        outs.append(part[:cuts[c + 1] - cuts[c]])
    f32 = jnp.concatenate(outs) if k > 1 else outs[0]
    return K.fused_unpack(f32, [l.shape for l in leaves],
                          [l.dtype for l in leaves]), f32


def sync_grads(grads, strategy: GradSyncStrategy, dp_axes: Sequence[str],
               mesh=None, pspecs=None, full_manual: bool = False):
    """Explicit bucketed gradient synchronisation (mean) — DisCo tensor
    fusion with the searched per-bucket comm kind enacted.

    Each bucket is flattened+concatenated into one fused tensor, reduced as
    a *single* collective over the data axes, and split back — exactly the
    paper's tensor fusion.  An ``"ar"`` bucket is one fused ``psum``; an
    ``"rs_ag"`` bucket lowers to ``psum_scatter`` + ``all_gather`` (the
    ZeRO-3-style split the event engine prices per link level), padded to a
    multiple of the data-parallel degree so the shards tile evenly — the
    compiled HLO carries reduce-scatter/all-gather ops instead of
    all-reduce, with identical numerics.

    A bucket with ``chunks > 1`` splits its fused tensor into that many
    even byte ranges and issues one collective per chunk (the same lowering
    path as above, applied per range) — the searched store-and-forward
    chunking, enacted so the compiled HLO carries exactly the collective
    count the event engine priced.  Numerics are bit-identical to the
    whole-bucket collective: each element's reduction is unchanged, only
    the op it rides in shrinks.

    A *fused* bucket (``strategy.fused[i]`` — the searched in-kernel
    compute+comm overlap dimension) routes through
    :func:`_fused_bucket_sync`: Pallas staging kernels pack the leaves
    straight into the reduce-scatter's chunked shard-tiled layout and
    unpack straight out of the all-gather with the dtype cast fused, with
    the identical RS+AG wire arithmetic in between.  Where Pallas or
    gather-type collectives cannot lower, the bucket falls down the same
    ladder as ``rs_ag`` (jnp RS+AG, then fused ``psum``) — numerics are
    preserved on every rung.

    Compat gate: stock JAX 0.4.x's bundled XLA aborts on gather-type
    collectives (``all_gather``/``all_to_all``/``ppermute``) inside a
    *partial*-manual shard_map region (reduce-type ops are fine); in a
    fully-manual region (``full_manual=True`` — no auto axes, e.g. the
    ``layout="dp"`` step or TP degree 1) and on modern JAX the real RS+AG
    pair lowers.  Where it cannot, ``rs_ag`` buckets fall back to the fused
    ``psum`` — same numerics, AllReduce-shaped traffic (the same class of
    0.4.x fallback as the vocab-parallel CE; see ``repro/compat.py``).

    Fusing must not destroy tensor-parallel sharding, so when ``mesh`` and
    ``pspecs`` are given the bucketing runs inside a nested ``shard_map``
    over the ``model`` axis: the fused buffer concatenates the *local TP
    shards* (Megatron-DDP style), keeping the collective 1/TP-sized and the
    HLO free of gather/reshard traffic.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)

    def fuse_and_reduce(leaves_local: list):
        dp = 1
        for a in dp_axes:
            dp *= axis_size_compat(a)
        out: list = [None] * len(leaves_local)
        prev_fused = None
        for bi, bucket in enumerate(strategy.buckets):
            gather_ok = (full_manual
                         or not compat.needs_partial_manual_workarounds())
            # searched in-kernel fused path: Pallas staging kernels around
            # a real RS+AG pair.  The ladder: Pallas kernel path -> (when
            # Pallas cannot trace in this region) the jnp RS+AG lowering
            # below -> (when gather-type ops cannot lower at all) the fused
            # psum — every rung loss-bit-identical.
            want_fused = strategy.is_fused(bi) and dp > 1 and gather_ok
            if want_fused:
                try:
                    outs, packed = _fused_bucket_sync(
                        [leaves_local[i] for i in bucket], dp,
                        strategy.chunk_count(bi), dp_axes,
                        barrier_with=(prev_fused if strategy.barriers
                                      else None))
                except Exception:
                    pass  # Pallas unavailable here -> jnp RS+AG below
                else:
                    for i, o in zip(bucket, outs):
                        out[i] = o
                    prev_fused = packed
                    continue
            flats = [leaves_local[i].reshape(-1) for i in bucket]
            fused = jnp.concatenate(flats) if len(flats) > 1 else flats[0]
            if strategy.barriers and prev_fused is not None:
                fused, _ = jax.lax.optimization_barrier((fused, prev_fused))
            # reduce in f32: gradient-accuracy standard practice, and works
            # around an XLA:CPU bf16 all-reduce miscompile in the dry-run.
            dt = fused.dtype
            f32 = fused.astype(jnp.float32)
            rs_ag = ((strategy.comm_kind(bi) == "rs_ag" or want_fused)
                     and dp > 1 and gather_ok)

            def reduce_one(part):
                if rs_ag:
                    n0 = part.shape[0]
                    pad = (-n0) % dp
                    if pad:
                        part = jnp.concatenate(
                            [part, jnp.zeros((pad,), jnp.float32)])
                    shard = jax.lax.psum_scatter(part, tuple(dp_axes),
                                                 scatter_dimension=0,
                                                 tiled=True) / dp
                    part = jax.lax.all_gather(shard, tuple(dp_axes),
                                              tiled=True)
                    if pad:
                        part = part[:n0]
                else:
                    part = jax.lax.psum(part, tuple(dp_axes)) / dp
                return part

            k = min(strategy.chunk_count(bi), max(f32.shape[0], 1))
            if k > 1:
                # even byte split; each chunk is its own collective
                cuts = [f32.shape[0] * c // k for c in range(k + 1)]
                f32 = jnp.concatenate(
                    [reduce_one(f32[cuts[c]:cuts[c + 1]]) for c in range(k)])
            else:
                f32 = reduce_one(f32)
            fused = f32.astype(dt)
            prev_fused = fused
            off = 0
            for i in bucket:
                n = leaves_local[i].size
                out[i] = fused[off:off + n].reshape(leaves_local[i].shape)
                off += n
        return tuple(out)

    if (mesh is None or "model" not in mesh.shape or mesh.shape["model"] == 1
            or not compat.supports_nested_partial_manual()):
        # flat path: psum over the data axes on the (model-auto-sharded)
        # gradients directly — also the 0.4.x route, which cannot nest a
        # partial-manual shard_map over `model` inside the data region
        return jax.tree_util.tree_unflatten(treedef, fuse_and_reduce(leaves))

    specs = tuple(jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P)))
    assert len(specs) == len(leaves)
    # nested shard_map picks up the ambient (partial-manual) mesh context on
    # modern JAX; 0.4.x nests with the explicit mesh instead
    synced = shard_map_compat(
        lambda *ls: fuse_and_reduce(list(ls)),
        mesh=mesh, in_specs=specs, out_specs=specs,
        axis_names={"model"}, check=False, use_ambient_mesh=True,
    )(*leaves)
    return jax.tree_util.tree_unflatten(treedef, list(synced))


# --------------------------------------------------------------- step build
def _split_batch(batch: dict, n_micro: int) -> dict:
    return {k: v.reshape(n_micro, v.shape[0] // n_micro, *v.shape[1:])
            for k, v in batch.items()}


def build_train_step(
    cfg: ModelConfig,
    mesh,
    *,
    mode: str = "ddp_tp",
    strategy: Optional[GradSyncStrategy] = None,
    optimizer=None,
    grad_accum: int = 1,
    remat: bool = True,
    clip_norm: float = 1.0,
    lr: float = 3e-4,
    loss_fn: Optional[Callable] = None,
    layout: str = "tp",
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics),
    jit-compiled with the mesh's shardings.  ``loss_fn(params, cfg, batch,
    remat=...)`` defaults to the scanned-layer implementation."""
    opt_init, opt_update = optimizer or adamw(lr, weight_decay=0.01)
    if layout == "dp":
        # pure data parallelism: the `model` axis carries batch too (small
        # models waste ICI on TP activation psums — see EXPERIMENTS.md Perf)
        dp_axes = tuple(a for a in ("pod", "data", "model")
                        if a in mesh.shape)
    else:
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if loss_fn is None:
        from ..models import stacked as ST
        loss_fn = ST.loss_fn

    # vocab-parallel CE crashes XLA:CPU's AllReducePromotion when the
    # shard_map is not nested inside a manual region (fsdp/auto mode);
    # the non-VP chunked CE is used there instead (see DESIGN.md).
    # In pure-DP layout everything is replicated: no vocab parallelism.
    # JAX 0.4.x cannot nest the partial-manual VP shard_map at all — the
    # same non-VP chunked CE fallback applies there.
    nested_ok = compat.supports_nested_partial_manual()
    vp_ce = mode == "ddp_tp" and layout != "dp" and nested_ok
    vp = None if (layout == "dp" or not nested_ok) else mesh

    def local_loss(params, batch):
        return loss_fn(params, cfg, batch, remat=remat, vp_mesh=vp,
                       vp_ce=vp_ce)

    def grads_of(params, batch):
        if grad_accum > 1:
            micro = _split_batch(batch, grad_accum)

            def body(carry, mb):
                l, g = jax.value_and_grad(local_loss)(params, mb)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = compat.scan_compat(body, zero, micro)
            scale = 1.0 / grad_accum
            return loss * scale, jax.tree.map(lambda g: g * scale, grads)
        return jax.value_and_grad(local_loss)(params, batch)

    def update(params, opt_state, loss, grads):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    if mode == "ddp_tp":
        strat = strategy  # captured; None -> per-tensor at first call site

        def local_step(params, opt_state, batch):
            loss, grads = grads_of(params, batch)
            if layout == "dp":
                # every mesh axis is a (manual) data axis here: the region
                # is fully manual, so RS+AG lowering is safe on 0.4.x too
                grads = sync_grads(
                    grads, strat or GradSyncStrategy.per_tensor(params),
                    dp_axes, mesh=None, full_manual=True)
            else:
                align = SH.head_alignment(cfg, mesh)
                pspecs = jax.tree_util.tree_map_with_path(
                    lambda pth, l: SH.param_spec(
                        pth, l, model_size=mesh.shape.get("model", 1),
                        dp_axes=(), fsdp=False, **align),
                    grads)
                grads = sync_grads(
                    grads, strat or GradSyncStrategy.per_tensor(params),
                    dp_axes, mesh=mesh, pspecs=pspecs,
                    full_manual=mesh.shape.get("model", 1) == 1)
            loss = jax.lax.pmean(loss, tuple(dp_axes))
            return update(params, opt_state, loss, grads)

        def make(batch_keys):
            bspec = {}
            for k in batch_keys:
                lead = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                bspec[k] = P(lead)
            fn = shard_map_compat(local_step, mesh=mesh,
                                  in_specs=(P(), P(), bspec),
                                  out_specs=(P(), P(), P()),
                                  axis_names=set(dp_axes),
                                  check=False)
            return fn

        def step(params, opt_state, batch):
            return make(tuple(sorted(batch)))(params, opt_state, batch)

        return step

    if mode == "fsdp_tp":
        def full_step(params, opt_state, batch):
            loss, grads = grads_of(params, batch)
            return update(params, opt_state, loss, grads)

        return full_step

    raise ValueError(f"unknown mode {mode!r}")


def jit_train_step(step_fn, cfg: ModelConfig, mesh, params_like, opt_like,
                   batch_specs: dict, *, fsdp: bool = False,
                   layout: str = "tp", zero1: bool = False):
    """jit with explicit in/out shardings.

    layout="dp": params replicated, batch over ALL mesh axes.
    zero1=True: optimizer moments additionally sharded over the data axes
    (largest divisible free dim) — ZeRO-1; XLA slices the update and
    all-gathers the applied deltas.
    """
    from ..optim import OptState

    rep = NamedSharding(mesh, P())
    if layout == "dp":
        pshard = jax.tree.map(lambda _: rep, params_like)
        bshard = {k: NamedSharding(mesh, P(tuple(mesh.axis_names),
                                           *([None] * (len(v.shape) - 1))))
                  for k, v in batch_specs.items()}
    else:
        pshard = SH.param_shardings(params_like, mesh, fsdp=fsdp, cfg=cfg)
        bshard = SH.batch_shardings(batch_specs, mesh)
    moment_shard = pshard
    if zero1:
        moment_shard = SH.zero1_shardings(params_like, mesh, pshard)
    oshard = OptState(mu=moment_shard, nu=moment_shard,
                      count=NamedSharding(mesh, P()))
    jf = jax.jit(
        step_fn,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, {"loss": rep, "grad_norm": rep}),
        donate_argnums=(0, 1),
    )
    return jf

"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs per arch.

Tensor parallelism shards the *flattened* projection output dims (always
multiples of 128, so they divide the 16-way ``model`` axis even when the
head count does not — e.g. PaliGemma's 8 heads x 256 = 2048).  MoE expert
tensors shard the expert dimension (expert parallelism).  ``fsdp=True``
additionally shards the largest remaining dim over the data axes (ZeRO-3
style) — used for the >16 GB/TP-shard architectures (DeepSeek-V2-236B,
DeepSeek-Coder-33B).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig

# leaf-name -> (model-sharded dim index) for 2D weights
_OUT_SHARDED = {"wq", "wk", "wv", "w_up", "w_gate", "w_uq", "w_uk", "w_uv",
                "w_x", "w_ri", "w_ii", "w_r", "w_k", "w_v", "w_g", "c_k",
                "c_r"}
_IN_SHARDED = {"wo", "w_down", "w_out", "w_o", "c_v"}
_EXPERT_LEAVES = {"w_up", "w_gate", "w_down"}  # under a "moe" subtree
_REPLICATED = {"router", "w_dq", "w_dkv", "w_kr", "conv_w", "conv_b", "lam",
               "w0", "wA", "wB", "bonus", "in_proj", "vision_proj"}


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
    return names


def _divisible(dim: int, n: int) -> bool:
    return dim % n == 0


_Q_LEAVES = {"wq", "bq"}
_KV_LEAVES = {"wk", "wv", "bk", "bv"}
_QO_LEAVES = {"wo"}


def param_spec(path, leaf, *, model_size: int, dp_axes: tuple,
               fsdp: bool, q_aligned: bool = True,
               kv_aligned: bool = True) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    in_moe = "moe" in names and "shared" not in names
    shape = tuple(leaf.shape)
    # stacked (scanned-layer) params carry a leading layer dim under
    # "groups"/"layers": apply the rules to the trailing dims.
    stacked = ("groups" in names or "layers" in names) and len(shape) >= 2 \
        and name not in ("embed", "lm_head")
    lead: tuple = ()
    if stacked:
        lead = (None,)
        shape = shape[1:]
    spec: list = [None] * len(shape)

    if in_moe and name in _EXPERT_LEAVES and _divisible(shape[0], model_size):
        if fsdp and dp_axes and _divisible(shape[0], _dp_size_cache[dp_axes]) \
                and _divisible(shape[-1], model_size):
            # full expert parallelism: experts over the data axes, per-expert
            # FFN dim over model -> weights 256/512-way sharded, no ZeRO
            # gather needed for the (dominant) expert tensors.
            spec[0] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            spec[-1] = "model"
            return P(*lead, *spec)
        spec[0] = "model"          # expert parallelism over the TP axis
    elif name == "embed" and _divisible(shape[0], model_size):
        spec[0] = "model"          # vocab-sharded embedding
    elif name == "lm_head" and _divisible(shape[-1], model_size):
        spec[-1] = "model"
    elif name in _REPLICATED or "ln" in name or "norm" in name \
            or name.startswith("mu") or name.startswith("cmu") \
            or name.startswith("b") or "scale" in name or "bias" in name:
        pass
    elif name in _Q_LEAVES or name in _KV_LEAVES or name in _QO_LEAVES:
        # Megatron head-alignment rule: never split an attention head
        # across TP ranks (mid-head splits force degenerate reshards —
        # and crash XLA:CPU's AllReducePromotion in the dry-run).
        aligned = q_aligned if name in (_Q_LEAVES | _QO_LEAVES) else kv_aligned
        if aligned:
            if len(shape) == 2 and name in _QO_LEAVES and _divisible(
                    shape[0], model_size):
                spec[0] = "model"
            elif len(shape) == 2 and name not in _QO_LEAVES and _divisible(
                    shape[1], model_size):
                spec[1] = "model"
            elif len(shape) == 1 and _divisible(shape[0], model_size):
                spec[0] = "model"
        elif len(shape) == 2 and name in (_Q_LEAVES | _QO_LEAVES):
            # unaligned heads: shard the NON-head dim (row/column parallel
            # without touching head boundaries) — memory-critical for e.g.
            # coder-33b's 56-head attention (6.4 GiB of q/o per layer group)
            if name in _Q_LEAVES and _divisible(shape[0], model_size):
                spec[0] = "model"
            elif name in _QO_LEAVES and _divisible(shape[1], model_size):
                spec[1] = "model"
    elif len(shape) == 2 and name in _OUT_SHARDED and _divisible(
            shape[1], model_size):
        spec[1] = "model"
    elif len(shape) == 2 and name in _IN_SHARDED and _divisible(
            shape[0], model_size):
        spec[0] = "model"

    if fsdp and dp_axes and name not in ("embed", "lm_head"):
        # shard the largest unsharded dim over the data axes (ZeRO-3).
        # embed/lm_head stay vocab-sharded only: the vocab-parallel
        # embedding/CE shard_map pins their specs to P("model", ...).
        dp_total = _dp_size_cache[dp_axes]
        free = sorted((i for i, s in enumerate(spec) if s is None),
                      key=lambda i: -shape[i])
        for i in free:
            if shape[i] % dp_total == 0:
                spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
    return P(*lead, *spec)


_dp_size_cache: dict = {}


def head_alignment(cfg, mesh) -> dict:
    """Whether q / kv attention projections may shard over ``model``
    without splitting a head."""
    m = mesh.shape.get("model", 1)
    return {"q_aligned": cfg is None or cfg.n_heads % m == 0,
            "kv_aligned": cfg is None or cfg.n_kv_heads % m == 0}


def param_shardings(params, mesh, *, fsdp: bool = False, cfg=None):
    """Tree of NamedShardings for a param/opt pytree."""
    model_size = mesh.shape.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    _dp_size_cache[dp_axes] = int(np.prod([mesh.shape[a] for a in dp_axes]))
    align = head_alignment(cfg, mesh)

    def one(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return jax.sharding.NamedSharding(mesh, P())
        return jax.sharding.NamedSharding(
            mesh, param_spec(path, leaf, model_size=model_size,
                             dp_axes=dp_axes, fsdp=fsdp, **align))

    return jax.tree_util.tree_map_with_path(one, params)


def param_pspecs(params, mesh, *, fsdp: bool = False):
    """Same as param_shardings but raw PartitionSpecs (for constraints)."""
    sh = param_shardings(params, mesh, fsdp=fsdp)
    return jax.tree.map(lambda s: s.spec, sh,
                        is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))


def batch_pspec(batch_dim_size: int, mesh, ndim: int) -> P:
    """Shard the leading batch dim over all data axes that divide it."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch_dim_size % total == 0:
        lead = tuple(axes) if len(axes) > 1 else axes[0]
        return P(lead, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def batch_shardings(specs: dict, mesh):
    return {
        k: jax.sharding.NamedSharding(
            mesh, batch_pspec(v.shape[0], mesh, len(v.shape)))
        for k, v in specs.items()
    }


def cache_shardings(caches, mesh, stacked: bool = True):
    """Decode-cache shardings.

    Stacked layout (scanned-layer models): leaves carry a leading layer dim,
    so batch is axis 1.  Batch shards over the data axes; KV-head / head /
    width dims shard over ``model`` when divisible.
    """
    model_size = mesh.shape.get("model", 1)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = leaf.ndim
        spec = [None] * nd
        b_ax = 1 if stacked else 0
        if nd > b_ax:
            spec[b_ax] = batch_pspec(leaf.shape[b_ax], mesh, 1)[0]
        if name in ("k", "v", "k_scale", "v_scale"):
            kv_ax = b_ax + 2
            if nd > kv_ax and _divisible(leaf.shape[kv_ax], model_size):
                spec[kv_ax] = "model"
            elif name in ("k", "v") and nd > kv_ax + 1 and _divisible(
                    leaf.shape[-1], model_size):
                # few KV heads (GQA kv < TP): shard head_dim instead — the
                # score contraction psums over `model`, tiny at decode
                spec[-1] = "model"
        elif name in ("c_kv", "k_rope") and _divisible(leaf.shape[-1],
                                                       model_size):
            spec[-1] = "model"     # MLA latent/rope dims
        elif name == "wkv":
            h_ax = b_ax + 1
            if nd > h_ax and _divisible(leaf.shape[h_ax], model_size):
                spec[h_ax] = "model"   # rwkv heads
        elif name in ("h", "conv", "prev") and _divisible(
                leaf.shape[-1], model_size):
            spec[-1] = "model"         # rg-lru width / rwkv hidden
        return jax.sharding.NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, caches)


def zero1_shardings(params_like, mesh, pshard):
    """ZeRO-1 optimizer-moment shardings: take each param's sharding and
    additionally shard the largest free dim over the data axes."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_total = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes \
        else 1

    def one(leaf, sh):
        if not hasattr(leaf, "shape") or leaf.ndim == 0 or dp_total == 1:
            return sh
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        free = sorted((i for i, s in enumerate(spec) if s is None),
                      key=lambda i: -leaf.shape[i])
        for i in free:
            if leaf.shape[i] % dp_total == 0:
                spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
        return jax.sharding.NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, params_like, pshard,
                        is_leaf=lambda x: hasattr(x, "shape"))

"""RWKV-6 WKV recurrence Pallas-TPU kernel.

State S is (hd, hd) per (batch, head); the recurrence
    out_t = r_t . (S + u * k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T
is a rank-1 update + vector-matrix product per step.  TPU mapping: keep S
resident in VMEM scratch (hd<=128 -> 64 KiB f32, trivially fits), march over
time chunks so r/k/v/w stream through VMEM once (bandwidth-optimal), with
the per-step rank-1 updates on the VPU (outer products are lane-parallel).

Grid: (B, H, n_time_chunks), time innermost (state persists across chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state, *, tc: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0, 0].astype(jnp.float32)   # (tc, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)      # (hd,)

    def step(t, S):
        kv = k[t][:, None] * v[t][None, :]            # (hd, hd) rank-1
        out = jnp.sum(r[t][:, None] * (S + u[:, None] * kv), axis=0)
        o_ref[0, 0, t, :] = out.astype(o_ref.dtype)
        return w[t][:, None] * S + kv

    state[...] = jax.lax.fori_loop(0, tc, step, state[...])


def rwkv6_wkv_kernel(r, k, v, w, u, *, tc: int = 128, interpret: bool = True):
    """r,k,v,w: (B, S, H, hd); u: (H, hd) -> out (B, S, H, hd)."""
    B, S, H, hd = r.shape
    tc = min(tc, S)
    assert S % tc == 0
    grid = (B, H, S // tc)
    # (B, H, S, hd) layout: one program owns one (b, h) stream
    rr, kk, vv, ww = (x.transpose(0, 2, 1, 3) for x in (r, k, v, w))
    out = pl.pallas_call(
        functools.partial(_kernel, tc=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tc, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, tc, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, tc, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, tc, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, hd), lambda b, h, t: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tc, hd), lambda b, h, t: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, u)
    return out.transpose(0, 2, 1, 3)

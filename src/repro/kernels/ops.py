"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs in Python via the Pallas interpreter — numerically
identical to the TPU lowering).  On a real TPU set
``REPRO_KERNEL_INTERPRET=0`` (or call ``set_interpret(False)``) to compile
the Mosaic kernels.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import rglru as _rg
from . import rwkv6 as _rk
from . import bucket_pack as _bp
from . import fused_grad_sync as _fg
from . import ref as _ref

_INTERPRET = os.environ.get("REPRO_KERNEL_INTERPRET", "1") != "0"


def set_interpret(flag: bool) -> None:
    global _INTERPRET
    _INTERPRET = flag


@partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None):
    """GQA flash attention.  q: (B,S,H,hd); k,v: (B,T,KV,hd)."""
    return _fa.flash_attention_kernel(q, k, v, causal=causal, window=window,
                                      interpret=_INTERPRET)


@jax.jit
def rglru_scan(x, r_gate, i_gate, lam, c: float = 8.0):
    """RG-LRU over (B,S,L): gate math in XLA (fuses), recurrence in the
    kernel."""
    log_a = -c * jax.nn.softplus(lam)[None, None, :] * r_gate
    a = jnp.exp(log_a)
    g = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i_gate * x)
    return _rg.rglru_scan_kernel(a, g, interpret=_INTERPRET)


@jax.jit
def rwkv6_wkv(r, k, v, w, u):
    """WKV-6.  r,k,v,w: (B,S,H,hd); u: (H,hd)."""
    return _rk.rwkv6_wkv_kernel(r, k, v, w, u, interpret=_INTERPRET)


def bucket_pack(leaves, total: int, out_dtype=jnp.float32):
    return _bp.bucket_pack_kernel(leaves, total, out_dtype,
                                  interpret=_INTERPRET)


def fused_pack(leaves, total: int, dp: int, chunks: int = 1):
    """Reduce-scatter-ready chunked staging of a fused bucket (the
    in-kernel compute+comm overlap path's pack half)."""
    return _fg.fused_pack_kernel(leaves, total, dp, chunks,
                                 interpret=_INTERPRET)


def fused_unpack(buf, shapes, dtypes):
    """All-gather epilogue: un-stage the gathered f32 bucket back into
    leaves with the dtype cast fused (the overlap path's unpack half)."""
    return _fg.fused_unpack_kernel(buf, shapes, dtypes,
                                   interpret=_INTERPRET)


# re-exported oracles (tests assert kernel == ref)
flash_attention_ref = _ref.flash_attention_ref
rglru_ref = _ref.rglru_ref
rwkv6_ref = _ref.rwkv6_ref
bucket_pack_ref = _ref.bucket_pack_ref
bucket_unpack_ref = _ref.bucket_unpack_ref
fused_pack_ref = _ref.fused_pack_ref
fused_unpack_ref = _ref.fused_unpack_ref

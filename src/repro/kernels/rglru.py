"""RG-LRU recurrence Pallas-TPU kernel.

The recurrence h_t = a_t * h_{t-1} + g_t is elementwise over the LRU width,
so the natural TPU mapping is: tile the width across the lane dimension
(blocks of 128 lanes x 8 sublanes) and keep the running state h in VMEM
scratch while marching over time chunks — one HBM read of (a, g) and one
write of h per element, with the sequential dependence handled by a
``fori_loop`` inside the kernel (VPU latency-bound, bandwidth-optimal).

Grid: (B, n_width_blocks, n_time_chunks), time innermost (state persists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, g_ref, h_ref, state, *, tc: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0].astype(jnp.float32)     # (tc, Lb)
    g = g_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + g[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    state[...] = jax.lax.fori_loop(0, tc, step, state[...])


def rglru_scan_kernel(a, g, *, tc: int = 128, lb: int = 512,
                      interpret: bool = True):
    """a, g: (B, S, L) decay and gated input; returns h: (B, S, L).

    h_t = a_t * h_{t-1} + g_t  (the caller precomputes a = exp(log_a) and
    g = sqrt(1-a^2) * i * x; those are elementwise and fuse in XLA).
    """
    B, S, L = a.shape
    tc = min(tc, S)
    lb = min(lb, L)
    assert S % tc == 0 and L % lb == 0
    grid = (B, L // lb, S // tc)
    return pl.pallas_call(
        functools.partial(_kernel, tc=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, lb), lambda b, l, t: (b, t, l)),
            pl.BlockSpec((1, tc, lb), lambda b, l, t: (b, t, l)),
        ],
        out_specs=pl.BlockSpec((1, tc, lb), lambda b, l, t: (b, t, l)),
        out_shape=jax.ShapeDtypeStruct((B, S, L), a.dtype),
        scratch_shapes=[pltpu.VMEM((lb,), jnp.float32)],
        interpret=interpret,
    )(a, g)

"""Pallas TPU kernels for the compute hot-spots.

Each kernel ships three artifacts:
  <name>.py — ``pl.pallas_call`` with explicit BlockSpec VMEM tiling (TPU
              target; validated with ``interpret=True`` on CPU),
  ops.py    — jit'd public wrappers that pick kernel vs reference path,
  ref.py    — pure-jnp oracles the tests assert against.

Kernels: flash_attention (GQA / causal / sliding-window), rglru (RG-LRU
chunked recurrence), rwkv6 (WKV-6 chunked recurrence), bucket_pack
(tensor-fusion gradient packing — the paper's fused-AllReduce staging copy),
fused_grad_sync (in-kernel compute+comm overlap: reduce-scatter-ready
chunked pack + all-gather unpack/cast halves around the wire collective).
"""

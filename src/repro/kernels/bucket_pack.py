"""Tensor-fusion bucket staging copy, as a Pallas-TPU kernel.

DisCo's tensor fusion stages many small gradient tensors into one fused
AllReduce buffer (and un-stages afterwards).  The copy is pure
HBM-bandwidth; the kernel tiles it through VMEM with an optional
bf16 -> f32 convert fused into the same pass (the dry-run reduces gradients
in f32), so staging + convert costs one HBM round-trip instead of two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(o_ref.dtype)


def convert_copy_kernel(x, out_dtype=jnp.float32, block: int = 65536,
                        interpret: bool = True):
    """Tiled convert-copy of a flat array (the per-leaf staging primitive).

    x: (N,) any float dtype; returns (N,) ``out_dtype``.  N is padded up to
    a block multiple internally.
    """
    n = x.shape[0]
    block = min(block, max(n, 8))
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    nb = x.shape[0] // block
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), out_dtype),
        interpret=interpret,
    )(x)
    return out[:n]


def bucket_pack_kernel(leaves, total: int, out_dtype=jnp.float32,
                       interpret: bool = True):
    """Stage a bucket of gradient leaves into one fused f32 buffer."""
    parts = [convert_copy_kernel(l.reshape(-1), out_dtype,
                                 interpret=interpret) for l in leaves]
    buf = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    if buf.shape[0] < total:
        buf = jnp.pad(buf, (0, total - buf.shape[0]))
    return buf

"""Flash attention Pallas-TPU kernel (GQA / causal / sliding-window).

Grid: (B, KV, n_q_blocks, n_kv_blocks), KV-block axis innermost so the
online-softmax state (m, l, acc) persists in VMEM scratch across KV steps
for one query block.  Each program instance covers all G = H/KV query heads
of one KV head — GQA reads each K/V block once per group, the kernel-level
arithmetic-intensity win over head-replicated attention.

Block sizes default to (128, 128): MXU-aligned (multiples of 128) and a
VMEM working set of G*qb*hd + 2*kb*hd + G*qb*kb floats — well under the
128 MiB v5e VMEM for hd <= 256, G <= 8.

Causal/window structure skips fully-masked KV blocks via ``pl.when`` (the
roofline-visible FLOP saving the XLA reference path does not get).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(np.finfo(np.float32).min)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            qb: int, kb: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * qb
    k_start = ki * kb
    # block-level structural skip: block fully above the diagonal, or fully
    # outside the sliding window
    live = True
    if causal:
        live = k_start <= q_start + qb - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + kb - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, qb, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (kb, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (kb, hd)
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                   # (G, qb, kb)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        ok = kpos <= qpos if causal else jnp.ones((qb, kb), bool)
        if window is not None:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok[None], s, NEG_INF)
        m_prev = m_scr[...]                             # (G, qb)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           qb: int = 128, kb: int = 128,
                           interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, T, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qb = min(qb, S)
    kb = min(kb, T)
    assert S % qb == 0 and T % kb == 0
    nq, nk = S // qb, T // kb
    # (B, KV, G, S, hd) layout so one program sees one (b, kv) slice
    qr = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)                        # (B, KV, T, hd)
    vr = v.transpose(0, 2, 1, 3)
    grid = (B, KV, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / np.sqrt(hd), causal=causal,
                          window=window, qb=qb, kb=kb, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, qb, hd), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, kb, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, kb, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, qb, hd),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, qb), jnp.float32),        # m
            pltpu.VMEM((G, qb), jnp.float32),        # l
            pltpu.VMEM((G, qb, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)

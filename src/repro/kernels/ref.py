"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) — dense softmax attention with GQA
    head grouping and optional causal/sliding-window mask."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    s = s / np.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = kpos <= qpos if causal else jnp.ones((S, T), bool)
    if window is not None:
        ok = ok & (kpos > qpos - window)
    s = jnp.where(ok[None, None, None], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def rglru_ref(x, r_gate, i_gate, lam, c: float = 8.0):
    """RG-LRU linear recurrence, sequential reference.

    x, r_gate, i_gate: (B,S,L); lam: (L,).
    h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t), a_t = exp(-c softplus(lam) r_t)
    """
    log_a = (-c * jax.nn.softplus(lam)[None, None, :]
             * r_gate.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i_gate.astype(jnp.float32) * x.astype(jnp.float32))

    def step(h, inp):
        at, gt = inp
        h = at * h + gt
        return h, h

    a_t = a.transpose(1, 0, 2)
    g_t = gated.transpose(1, 0, 2)
    _, hs = jax.lax.scan(step, jnp.zeros_like(g_t[0]), (a_t, g_t))
    return hs.transpose(1, 0, 2).astype(x.dtype)


def rwkv6_ref(r, k, v, w, u):
    """WKV-6 recurrence, sequential reference.

    r,k,v,w: (B,S,H,hd); u: (H,hd).
      out_t = r_t . (S + u kv_t);  S <- diag(w_t) S + kv_t,  kv_t = k_t v_t^T
    """
    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None][..., None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    B, S, H, hd = r.shape
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32)
               for a in (r, k, v, w))
    _, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype)


def bucket_pack_ref(leaves: list, sizes: list[int], total: int):
    """Flatten + concatenate gradient leaves into one fused AllReduce buffer
    (f32), padding to ``total``."""
    flat = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    buf = jnp.concatenate(flat)
    return jnp.pad(buf, (0, total - buf.shape[0]))


def bucket_unpack_ref(buf, shapes, dtypes):
    out = []
    off = 0
    for shape, dt in zip(shapes, dtypes):
        n = int(np.prod(shape))
        out.append(buf[off:off + n].reshape(shape).astype(dt))
        off += n
    return out


def fused_pack_ref(leaves: list, total: int, dp: int, chunks: int = 1):
    """Reduce-scatter-ready staging: fused f32 bucket (padded to ``total``)
    cut at even byte boundaries into ``chunks`` ranges, each zero-padded to
    a multiple of ``dp``."""
    flat = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    buf = jnp.concatenate(flat)
    buf = jnp.pad(buf, (0, total - buf.shape[0]))
    k = max(int(chunks), 1)
    cuts = [total * c // k for c in range(k + 1)]
    out = []
    for c in range(k):
        part = buf[cuts[c]:cuts[c + 1]]
        out.append(jnp.pad(part, (0, (-part.shape[0]) % max(int(dp), 1))))
    return out


def fused_unpack_ref(buf, shapes, dtypes):
    """All-gather epilogue: un-stage + cast back — same contract as
    ``bucket_unpack_ref``."""
    return bucket_unpack_ref(buf, shapes, dtypes)

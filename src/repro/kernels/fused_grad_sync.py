"""In-kernel fused compute+comm gradient sync — the Pallas kernel halves.

The searched ``FusionGraph.bucket_fused`` dimension prices a bucket whose
collective overlaps the producing compute's tail (the event engine's
early-ready model, DESIGN.md Sec. 13).  Enacted, the overlap comes from
fusing the communication's *local* memory halves into the staging copies
that surround it (CoCoNet-style):

* **pack side** — the reduce-scatter's input staging is fused into the
  bucket-pack epilogue: leaves are cast+copied straight into the
  chunk-major, shard-tiled f32 layout ``psum_scatter(tiled=True)``
  consumes, so the scatter needs no separate pad/copy pass and each
  chunk's collective can begin as soon as its staging block lands (the
  per-chunk early start the pricing layer discounts).
* **unpack side** — the all-gather's output buffer is un-staged back into
  the parameter leaves with the f32 -> grad-dtype cast fused into the same
  tiled pass, so gather + unpack + cast cost one HBM round trip.

The wire collectives themselves stay ``jax.lax`` ops between the two
kernel halves — the kernels own every local byte moved around them.  No
scaling happens inside the pack (f32 summation is non-associative:
``sum(x / dp) != sum(x) / dp`` bitwise); the mean divide rides on the
scattered shard, exactly like the plain ``rs_ag`` lowering, keeping the
fused path loss-bit-identical to the ``psum`` path.
"""
from __future__ import annotations

import jax.numpy as jnp

from .bucket_pack import convert_copy_kernel


def chunk_cuts(total: int, chunks: int) -> list[int]:
    """Even byte-range chunk boundaries — the same split convention as
    ``chunk_phases`` (pricing) and ``sync_grads`` (enactment)."""
    k = max(int(chunks), 1)
    return [total * c // k for c in range(k + 1)]


def fused_pack_kernel(leaves, total: int, dp: int, chunks: int = 1,
                      block: int = 65536, interpret: bool = True):
    """Stage a bucket of gradient leaves into reduce-scatter-ready chunks.

    Returns a list of ``chunks`` f32 buffers: chunk ``c`` covers byte range
    ``[cuts[c], cuts[c+1])`` of the fused bucket (padded to ``total``
    first), each zero-padded to a multiple of ``dp`` so
    ``psum_scatter(tiled=True)`` tiles it directly.  The grad-dtype -> f32
    convert is fused into the staging copy.
    """
    parts = [convert_copy_kernel(l.reshape(-1), jnp.float32, block=block,
                                 interpret=interpret) for l in leaves]
    buf = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    if buf.shape[0] < total:
        buf = jnp.pad(buf, (0, total - buf.shape[0]))
    cuts = chunk_cuts(total, chunks)
    out = []
    for c in range(len(cuts) - 1):
        part = buf[cuts[c]:cuts[c + 1]]
        pad = (-part.shape[0]) % max(int(dp), 1)
        if pad:
            part = jnp.pad(part, (0, pad))
        out.append(part)
    return out


def fused_unpack_kernel(buf, shapes, dtypes, block: int = 65536,
                        interpret: bool = True):
    """Un-stage the gathered f32 bucket back into leaves, the f32 ->
    grad-dtype cast fused into the same tiled pass (all-gather epilogue)."""
    out = []
    off = 0
    for shape, dt in zip(shapes, dtypes):
        n = 1
        for s in shape:
            n *= int(s)
        part = convert_copy_kernel(buf[off:off + n], dt, block=block,
                                   interpret=interpret)
        out.append(part.reshape(shape))
        off += n
    return out

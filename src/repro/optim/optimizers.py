"""Optimizers as pure pytree transforms (optax-style, but self-contained —
the container only ships jax/numpy).

Every optimizer is a pair ``(init_fn, update_fn)``:
    state = init_fn(params)
    updates, state = update_fn(grads, state, params, step)
    params = apply_updates(params, updates)
All functions are jit/pjit-safe and shard-transparent (pure tree maps), so
optimizer state inherits parameter sharding under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: object       # first moment (or momentum)
    nu: object       # second moment (empty tree for sgd)
    count: jnp.ndarray


def _zeros_like_tree(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p), params)


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step, total_steps) / max(total_steps, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.05):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        warm = base_lr * (step + 1) / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return fn


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(_zeros_like_tree(params), _zeros_like_tree(params),
                        jnp.zeros((), jnp.int32))

    def update(grads, state: OptState, params):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state.nu, grads)
        c = count.astype(jnp.float32)
        mu_hat_s = 1.0 / (1 - b1 ** c)
        nu_hat_s = 1.0 / (1 - b2 ** c)
        step_lr = lr_fn(state.count)
        updates = jax.tree.map(
            lambda m, v, p: -step_lr * (
                m * mu_hat_s / (jnp.sqrt(v * nu_hat_s) + eps) + weight_decay * p
            ),
            mu, nu, params,
        )
        return updates, OptState(mu, nu, count)

    return init, update


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = _zeros_like_tree(params) if momentum else jax.tree.map(
            lambda p: jnp.zeros((), p.dtype), params)
        return OptState(mu, jnp.zeros(()), jnp.zeros((), jnp.int32))

    def update(grads, state: OptState, params):
        count = state.count + 1
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        else:
            mu = state.mu
        vel = mu if momentum else grads
        step_lr = lr_fn(state.count)
        updates = jax.tree.map(lambda v: -step_lr * v, vel)
        return updates, OptState(mu, state.nu, count)

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)

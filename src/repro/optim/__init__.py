from .optimizers import (
    OptState,
    adamw,
    sgd,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    linear_warmup_cosine,
)

__all__ = [
    "OptState",
    "adamw",
    "sgd",
    "apply_updates",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup_cosine",
]

"""Least-squares calibration of per-level ``(alpha, beta)`` from measured
collective timings (ROADMAP: "calibrate per-level alpha/beta from measured
traces").

Every collective cost model in :mod:`repro.cluster.collectives` is, for a
fixed topology *structure* (degrees, straggler, contention), **positively
homogeneous of degree 1** in the vector of level betas (seconds/byte) and,
separately, in the vector of level alphas: the bandwidth coefficient is
``C = sum_l beta_l * wC_l`` and the latency term ``D = sum_l alpha_l *
wD_l``, where the weights depend only on the structure — except for the
flat ring, whose bottleneck selection makes ``C`` *piecewise* linear.  By
Euler's homogeneous-function theorem the exact per-level weights at a
reference point are the partial derivatives there, which we extract by
central finite differences.  A measured timing corpus

    t_i  =  sum_l beta_l * (wC_l[algo_i, kind_i] * nbytes_i)
          + sum_l alpha_l * wD_l[algo_i, kind_i]

is then an ordinary linear least-squares problem in ``(beta_l, alpha_l)``.
Because the ring's active bottleneck can move as the fit updates the betas,
:func:`fit_levels` re-extracts weights at the current iterate for a few
rounds (the fit is exact in one round when the bottleneck does not flip).

Levels no sample can see (zero weight in every row — e.g. degree-1 levels)
keep their datasheet values; fitted betas/alphas are clamped positive.

``samples_from_dryrun`` adapts the ``cluster`` block a
``repro.launch.dryrun`` JSON carries (per-algorithm AllReduce pricing, and
the RS/AG block when the compiled module contains reduce-scatter /
all-gather ops) into :class:`TimingSample` rows; with real-hardware
profiles the same entry point calibrates against measured wall times.

Import-light like the rest of ``repro.cluster``: numpy is imported lazily
inside the solver so worker-pool interpreters never pay for it.
"""
from __future__ import annotations

import dataclasses
import json

from .collectives import KIND_AR, _comm_coeffs_uncached
from .topology import ClusterSpec, LinkLevel


@dataclasses.dataclass(frozen=True)
class TimingSample:
    """One measured collective: ``time_s`` seconds to move ``nbytes`` under
    ``algo`` / ``kind`` on the cluster being calibrated."""
    nbytes: float
    time_s: float
    algo: str = "ring"
    kind: str = KIND_AR


@dataclasses.dataclass
class FitResult:
    spec: ClusterSpec          # spec0 with fitted bandwidth/alpha per level
    betas: list[float]         # fitted seconds/byte (slowest link) per level
    alphas: list[float]        # fitted seconds/step per level
    rel_rmse: float            # relative RMS residual over the samples
    identifiable: list[bool]   # per level: did any sample constrain it?
    clamped: list[bool] = dataclasses.field(default_factory=list)
    # per level: the solver produced a non-physical (<= 0) beta, so the
    # datasheet value was kept — treat the level's fit as unreliable


def _with_params(spec: ClusterSpec, betas, alphas) -> ClusterSpec:
    """Clone ``spec`` with per-level beta/alpha replaced (structure —
    degrees, straggler, contention — preserved; beta = straggler/bw)."""
    levels = tuple(
        # keep the exact original level when nothing moved (the beta ->
        # bandwidth inversion would otherwise round datasheet constants)
        l if (b == l.beta and a == l.alpha)
        else dataclasses.replace(l, bandwidth=l.straggler / b, alpha=a)
        for l, b, a in zip(spec.levels, betas, alphas)
    )
    return ClusterSpec(spec.name, levels, compat_hw=spec.compat_hw)


def _weights(spec: ClusterSpec, algo: str, kind: str):
    """Per-level partial derivatives (wC, wD) of the (C, D) coefficients at
    ``spec``'s current betas/alphas, by central differences."""
    betas = [l.beta for l in spec.levels]
    alphas = [l.alpha for l in spec.levels]
    wC, wD = [], []
    for i in range(len(betas)):
        h = max(abs(betas[i]), 1e-15) * 1e-6
        bp = list(betas); bp[i] += h
        bm = list(betas); bm[i] = max(bm[i] - h, 1e-30)
        cp, _ = _comm_coeffs_uncached(_with_params(spec, bp, alphas), algo, kind)
        cm, _ = _comm_coeffs_uncached(_with_params(spec, bm, alphas), algo, kind)
        wC.append((cp - cm) / (bp[i] - bm[i]))
        h = max(abs(alphas[i]), 1e-15) * 1e-6
        ap = list(alphas); ap[i] += h
        am = list(alphas); am[i] = max(am[i] - h, 0.0)
        _, dp = _comm_coeffs_uncached(_with_params(spec, betas, ap), algo, kind)
        _, dm = _comm_coeffs_uncached(_with_params(spec, betas, am), algo, kind)
        wD.append((dp - dm) / (ap[i] - am[i]) if ap[i] > am[i] else 0.0)
    return wC, wD


def fit_levels(samples: list[TimingSample], spec0: ClusterSpec,
               iters: int = 3) -> FitResult:
    """Fit per-level ``(beta, alpha)`` to the timing corpus by iterated
    linear least squares (re-extracting weights at each iterate so the
    ring's piecewise bottleneck selection can settle)."""
    import numpy as np

    if not samples:
        raise ValueError("fit_levels needs at least one timing sample")
    if spec0.is_flat_compat:
        raise ValueError("cannot calibrate the flat back-compat shim; "
                         "build a real ClusterSpec first")
    spec = spec0
    nlev = len(spec.levels)
    identifiable = [False] * nlev
    clamped = [False] * nlev
    for _ in range(max(iters, 1)):
        wcache: dict[tuple[str, str], tuple] = {}
        rows, y = [], []
        for s in samples:
            key = (s.algo, s.kind)
            if key not in wcache:
                wcache[key] = _weights(spec, s.algo, s.kind)
            wC, wD = wcache[key]
            rows.append([w * s.nbytes for w in wC] + list(wD))
            y.append(s.time_s)
        A = np.asarray(rows, dtype=float)
        b = np.asarray(y, dtype=float)
        # column scaling for conditioning; zero columns (level invisible to
        # every sample) are pinned to the current spec value
        colmax = np.max(np.abs(A), axis=0)
        betas = [l.beta for l in spec.levels]
        alphas = [l.alpha for l in spec.levels]
        current = np.asarray(betas + alphas)
        seen = colmax > 0.0
        identifiable = [bool(seen[i] or seen[nlev + i]) for i in range(nlev)]
        if not seen.any():
            break
        scale = np.where(seen, colmax, 1.0)
        As = A[:, seen] / scale[seen]
        x, *_ = np.linalg.lstsq(As, b, rcond=None)
        fitted = current.copy()
        fitted[seen] = x / scale[seen]
        # a non-physical (<= 0) beta means the corpus does not actually
        # constrain the level (noise, collinearity): keep the datasheet
        # value and flag it rather than silently pricing the level as
        # ~infinite bandwidth
        clamped = [False] * nlev  # judged afresh at each iterate
        betas, alphas = [], []
        for i in range(nlev):
            if fitted[i] > 0.0:
                betas.append(float(fitted[i]))
            else:
                betas.append(spec.levels[i].beta)
                clamped[i] = identifiable[i]
            alphas.append(max(float(fitted[nlev + i]), 0.0))
        spec = _with_params(spec, betas, alphas)
    cd = {}
    for s in samples:
        key = (s.algo, s.kind)
        if key not in cd:
            cd[key] = _comm_coeffs_uncached(spec, s.algo, s.kind)
    pred = np.asarray([
        cd[(s.algo, s.kind)][0] * s.nbytes + cd[(s.algo, s.kind)][1]
        for s in samples
    ])
    meas = np.asarray([s.time_s for s in samples])
    denom = max(float(np.sqrt(np.mean(meas ** 2))), 1e-30)
    rel_rmse = float(np.sqrt(np.mean((pred - meas) ** 2))) / denom
    return FitResult(spec=spec,
                     betas=[l.beta for l in spec.levels],
                     alphas=[l.alpha for l in spec.levels],
                     rel_rmse=rel_rmse, identifiable=identifiable,
                     clamped=clamped)


# ------------------------------------------------ in-kernel overlap discount
# Overlap discount delta of the fused compute+comm kernel path (DESIGN.md
# Sec. 13): a fused bucket's collective may start ``delta x
# producer_duration`` before its producing compute job finishes, because the
# kernel streams gradient chunks onto the wire from inside the producing
# matmul's epilogue instead of waiting for the whole bucket.
#
# Per-preset values are calibrated by ``benchmarks/micro_overlap.py``: a
# single-parameter grid fit of the engine's early-ready pricing against a
# fine-grained per-chunk reference schedule (chunk k of K ready at
# ``start + (k+1)/K x duration``), over a sweep of bucket sizes and chunk
# counts.  Regenerate with ``python benchmarks/micro_overlap.py --fit``;
# ``--check`` asserts the stored table still matches a fresh fit.
DEFAULT_OVERLAP_DISCOUNT = 0.0  # uncalibrated topologies never discount

OVERLAP_DISCOUNTS: dict[str, float] = {
    # regenerated by benchmarks/micro_overlap.py --fit (do not hand-edit).
    # The engine's single-bucket pricing is scale-free, so every preset
    # currently fits the same value (see the benchmark's docstring); the
    # table stays per-preset keyed so measured-kernel truths can
    # differentiate later without an interface change.
    "tpu_v5e_pod_16": 0.525,
    "tpu_v5e_pod_64": 0.525,
    "tpu_v5e_pod_256": 0.525,
    "a100_nvlink_ib": 0.525,
    "h100_superpod": 0.525,
    "cross_dc_2pod": 0.525,
    "a100_straggler_ib": 0.525,
}


def overlap_discount_for(spec) -> float:
    """Calibrated overlap discount for a cluster spec (0.0 when the spec is
    None, flat back-compat, or not in the calibrated table — an
    uncalibrated discount would be a fictitious speedup, so fused buckets
    there price exactly as their base comm kind and ``METHOD_FUSED`` drops
    out of the search)."""
    if spec is None or getattr(spec, "is_flat_compat", False):
        return 0.0
    return float(OVERLAP_DISCOUNTS.get(getattr(spec, "name", None),
                                       DEFAULT_OVERLAP_DISCOUNT))


def fit_overlap_discount(reference, model, grid=None) -> tuple[float, float]:
    """Grid-fit the single overlap-discount parameter: pick the ``delta``
    whose modelled makespans best match the fine-grained reference schedule
    (relative RMS over the sample configs).  ``reference`` is a list of
    reference makespans, ``model`` a callable ``delta -> list of modelled
    makespans`` in the same order.  Returns ``(delta, rel_rmse)``."""
    if grid is None:
        grid = [i / 40.0 for i in range(40)]  # 0.000 .. 0.975
    best_d, best_err = 0.0, float("inf")
    for d in grid:
        pred = model(d)
        err = sum(((p - r) / r) ** 2
                  for p, r in zip(pred, reference) if r > 0.0)
        if err < best_err:
            best_d, best_err = float(d), err
    n = sum(1 for r in reference if r > 0.0)
    return best_d, (best_err / max(n, 1)) ** 0.5


# --------------------------------------------------------- dryrun adapters
def spec_from_describe(d: dict) -> ClusterSpec:
    """Rebuild a ClusterSpec from ``ClusterSpec.describe()`` output (the
    ``cluster.spec`` block of a dryrun JSON)."""
    levels = tuple(
        LinkLevel(l["name"], int(l["degree"]), l["bandwidth_gbps"] * 1e9,
                  l["alpha_us"] * 1e-6, straggler=l.get("straggler", 1.0),
                  contention=l.get("contention", 1.0))
        for l in d["levels"]
    )
    return ClusterSpec(d["name"], levels)


def samples_from_dryrun(doc: dict) -> tuple[list[TimingSample], ClusterSpec]:
    """Extract (samples, spec) from one ``repro.launch.dryrun`` result dict:
    per-algorithm AllReduce timings (mean collective size, per-collective
    time) plus the RS/AG pricing block when present."""
    cl = doc.get("cluster")
    if not cl:
        raise ValueError("dryrun JSON has no 'cluster' block")
    spec = spec_from_describe(cl["spec"])
    samples: list[TimingSample] = []
    count = max(int(cl.get("allreduce_count", 0)), 0)
    if count > 0:
        mean = cl["allreduce_bytes"] / count
        for algo, total in cl.get("allreduce_time_s", {}).items():
            samples.append(TimingSample(mean, total / count, algo, KIND_AR))
    for op, kind in (("reduce-scatter", "rs"), ("all-gather", "ag")):
        blk = (cl.get("rs_ag") or {}).get(op)
        if not blk or not blk.get("count"):
            continue
        mean = blk["bytes"] / blk["count"]
        for algo, total in blk.get("time_s", {}).items():
            samples.append(TimingSample(mean, total / blk["count"], algo, kind))
    return samples, spec


def fit_from_dryrun(paths: list[str], iters: int = 3) -> FitResult:
    """Calibrate one spec from a set of dryrun JSONs (all priced on the same
    topology): pool every timing sample and fit."""
    samples: list[TimingSample] = []
    spec = None
    for p in paths:
        with open(p) as f:
            doc = json.load(f)
        s, sp = samples_from_dryrun(doc)
        samples.extend(s)
        if spec is None:
            spec = sp
        elif sp.describe()["levels"] != spec.describe()["levels"]:
            raise ValueError(f"{p}: priced on a different topology than "
                             f"the first file")
    if spec is None:
        raise ValueError("no dryrun files given")
    return fit_levels(samples, spec, iters=iters)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="fit per-level (alpha, beta) from dryrun collective "
                    "timings")
    ap.add_argument("paths", nargs="+", help="dryrun JSON files")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    res = fit_from_dryrun(args.paths, iters=args.iters)
    print(json.dumps({
        "spec": res.spec.describe(),
        "rel_rmse": res.rel_rmse,
        "identifiable": res.identifiable,
    }, indent=1))


if __name__ == "__main__":
    main()

"""Topology-aware cluster & collective-algorithm modeling (DESIGN.md Sec. 7).

``ClusterSpec`` describes hierarchical, heterogeneous interconnects;
``collectives`` prices ring / recursive-halving-doubling / hierarchical
AllReduce on them.  ``repro.core`` threads a spec through the cost substrate
and the backtracking search so the collective algorithm is a *searched*
dimension alongside op and tensor fusion.

Import-light on purpose: no jax, no repro.core at module load (the search
worker pool spawns bare interpreters that must import this cheaply; the
``from_mesh`` bridge lives in :mod:`repro.launch.mesh`).
"""
from .topology import (ClusterSpec, LinkLevel, PRESETS, dcn_level,
                       get_preset, list_presets, tpu_pod_levels)
from .collectives import (ALGO_HIER, ALGO_RING, ALGO_TREE, ALGORITHMS,
                          BUCKET_COMM_KINDS, COLLECTIVE_ALGOS, CommPhase,
                          DEFAULT_ALGO, DEFAULT_COMM_KIND, KIND_AG, KIND_AR,
                          KIND_FUSED, KIND_P2P, KIND_RS, KIND_RS_AG,
                          allreduce_coeffs, best_algo, bucket_time,
                          chunk_phases, comm_coeffs, comm_time, fused_phases,
                          hier_allreduce, level_chunk_phases, phases,
                          ring_allreduce, tree_allreduce)
from .calibrate import (DEFAULT_OVERLAP_DISCOUNT, OVERLAP_DISCOUNTS,
                        overlap_discount_for)

__all__ = [
    "ClusterSpec", "LinkLevel", "PRESETS", "dcn_level", "get_preset",
    "list_presets", "tpu_pod_levels",
    "ALGO_HIER", "ALGO_RING", "ALGO_TREE", "ALGORITHMS", "COLLECTIVE_ALGOS",
    "BUCKET_COMM_KINDS", "CommPhase", "DEFAULT_ALGO", "DEFAULT_COMM_KIND",
    "KIND_AG", "KIND_AR", "KIND_FUSED", "KIND_P2P", "KIND_RS", "KIND_RS_AG",
    "allreduce_coeffs", "best_algo", "bucket_time", "chunk_phases",
    "comm_coeffs", "comm_time", "fused_phases", "hier_allreduce",
    "level_chunk_phases", "phases", "ring_allreduce", "tree_allreduce",
    "DEFAULT_OVERLAP_DISCOUNT", "OVERLAP_DISCOUNTS", "overlap_discount_for",
]

"""Collective-algorithm cost models over a :class:`ClusterSpec`.

Three AllReduce algorithms, each a closed-form alpha-beta cost (the level of
detail DistIR shows a strategy-ranking simulator needs — per-topology, not
per-packet):

* ``ring`` — one flat ring over all N devices.  Bandwidth-optimal volume
  ``2 (N-1)/N x`` but every one of the ``2 (N-1)`` synchronous steps is
  gated by the slowest (bottleneck) level the ring crosses.  A ring
  confined to a *single* link level is neighbour-aligned by construction
  and pays no ``contention``; a ring spanning several levels fights the
  fabric at its bottleneck.  On the flat back-compat spec the coefficients
  come straight from ``repro.core.hw.ring_allreduce_coeffs`` so the cost is
  bit-identical to the paper's ``T = C x + D`` seed model.

* ``tree`` — recursive-halving reduce-scatter + recursive-doubling
  all-gather (Rabenseifner), scheduled inner-first so the large early
  exchanges stay on fast links and only ``x / N_below`` crosses each outer
  level.  ``2 log2(N)`` steps total; its long-haul pairwise exchanges are
  *not* adjacency-aligned (distance-``2^k`` partners on a torus axis, wide
  routes on an oversubscribed fat tree), so every level charges its
  ``contention`` factor, and non-power-of-two degrees pay one extra
  preparation exchange (the classic 2^k restriction).

* ``hier`` — two-level-style hierarchical AllReduce generalised to L
  levels: ring reduce-scatter inward level by level (shrinking the live
  shard by ``degree`` each time), a ring AllReduce of ``x / N_inner`` at the
  outermost level, then ring all-gathers back out.  Structured, rail-aligned
  rings are exempt from ``contention``; inter-host volume drops by the
  product of the inner degrees — why it wins whenever the outer link is the
  bottleneck (provably never worse than ``ring`` when inner levels are
  uniformly faster; see tests/test_cluster.py).  On a spec with no inner
  fan-out it degenerates to — and is priced exactly as — the flat ring.

The flat back-compat spec is **algorithm-blind**: the seed's fixed-``D``
linear model cannot distinguish algorithms, so all three degenerate to the
legacy formula there (and the search drops the algo mutation method).

Every model is linear in message size for a fixed (spec, algo), so
``allreduce_coeffs`` derives the ``(C, D)`` pair once per pair and memoises
it — ``bucket_time`` in the simulator's hot comm pass is then one
multiply-add, not a topology walk.  All models return 0.0 for empty
(<= 0 byte) transfers: an AllReduce that moves nothing costs nothing
(zero-byte-bucket fix, DESIGN.md Sec. 7).
"""
from __future__ import annotations

import functools
import math

from .topology import ClusterSpec

ALGO_RING = "ring"
ALGO_TREE = "tree"
ALGO_HIER = "hier"
# order matters: best_algo ties resolve to the earliest entry (ring, the
# legacy default, wins exact ties so flat specs keep seed behaviour)
COLLECTIVE_ALGOS = (ALGO_RING, ALGO_TREE, ALGO_HIER)

DEFAULT_ALGO = ALGO_RING


# ------------------------------------------------------------- coefficients
def _ring_coeffs(spec: ClusterSpec) -> tuple[float, float]:
    n = spec.n_devices
    spans = [l for l in spec.levels if l.degree > 1]
    if n <= 1 or not spans:
        return 0.0, 0.0
    b = spec.bottleneck()
    # a single-axis ring is neighbour traffic (dilation 1): no contention
    beta = b.beta_contended() if len(spans) > 1 else b.beta
    return (2.0 * (n - 1) / n) * beta, 2.0 * (n - 1) * b.alpha


def _tree_coeffs(spec: ClusterSpec) -> tuple[float, float]:
    if spec.n_devices <= 1:
        return 0.0, 0.0
    c = 0.0
    d_lat = 0.0
    below = 1
    for l in spec.levels:
        d = l.degree
        if d <= 1:
            continue
        beta = l.beta_contended()
        steps = math.ceil(math.log2(d))
        # volume crossing this level per device (reduce-scatter half; the
        # all-gather mirror doubles it)
        c += 2.0 * (1.0 / below - 1.0 / (below * d)) * beta
        d_lat += 2.0 * steps * l.alpha
        if d & (d - 1):  # non-power-of-two: one extra preparation exchange
            c += 2.0 * (1.0 / below) * beta
            d_lat += 2.0 * l.alpha
        below *= d
    return c, d_lat


def _hier_coeffs(spec: ClusterSpec) -> tuple[float, float]:
    if spec.n_devices <= 1:
        return 0.0, 0.0
    inner_fanout = 1
    for l in spec.levels[:-1]:
        inner_fanout *= l.degree
    if inner_fanout <= 1:
        # no inner hierarchy to exploit: "hierarchical" IS the flat ring
        # (same physical schedule, same contention) — never price it cheaper
        return _ring_coeffs(spec)
    c = 0.0
    d_lat = 0.0
    scale = 1.0  # live shard fraction after the inner reduce-scatters
    for l in spec.levels[:-1]:
        d = l.degree
        if d > 1:
            # ring reduce-scatter + all-gather at this level, (d-1) steps
            # and (d-1)/d of the live shard each way, rail-aligned
            c += 2.0 * ((d - 1) / d) * scale * l.beta
            d_lat += 2.0 * (d - 1) * l.alpha
        scale /= d
    outer = spec.levels[-1]
    h = outer.degree
    if h > 1:
        c += (2.0 * (h - 1) / h) * scale * outer.beta
        d_lat += 2.0 * (h - 1) * outer.alpha
    return c, d_lat


_COEFF_FNS = {
    ALGO_RING: _ring_coeffs,
    ALGO_TREE: _tree_coeffs,
    ALGO_HIER: _hier_coeffs,
}


@functools.lru_cache(maxsize=None)
def allreduce_coeffs(spec: ClusterSpec,
                     algo: str = DEFAULT_ALGO) -> tuple[float, float]:
    """``(C, D)`` of the linear cost ``T = C x + D`` for ``x > 0``.

    On the flat back-compat spec every algorithm returns the seed's
    ``ring_allreduce_coeffs`` pair — the legacy model is algorithm-blind,
    and ring cost stays bit-identical to ``hw.allreduce_time``."""
    if spec.compat_hw is not None:
        from repro.core.hw import ring_allreduce_coeffs

        return ring_allreduce_coeffs(spec.compat_hw, spec.n_devices)
    return _COEFF_FNS[algo](spec)


def bucket_time(nbytes: float, spec: ClusterSpec,
                algo: str = DEFAULT_ALGO) -> float:
    """Cost of AllReducing one fused gradient bucket of ``nbytes`` under
    ``algo``.  Empty buckets are free."""
    if nbytes <= 0.0:
        return 0.0
    c, d = allreduce_coeffs(spec, algo)
    return c * nbytes + d


def ring_allreduce(nbytes: float, spec: ClusterSpec) -> float:
    return bucket_time(nbytes, spec, ALGO_RING)


def tree_allreduce(nbytes: float, spec: ClusterSpec) -> float:
    return bucket_time(nbytes, spec, ALGO_TREE)


def hier_allreduce(nbytes: float, spec: ClusterSpec) -> float:
    return bucket_time(nbytes, spec, ALGO_HIER)


ALGORITHMS = {
    ALGO_RING: ring_allreduce,
    ALGO_TREE: tree_allreduce,
    ALGO_HIER: hier_allreduce,
}


def best_algo(nbytes: float, spec: ClusterSpec) -> tuple[str, float]:
    """Cheapest algorithm for this message size on this topology."""
    best_name, best_t = DEFAULT_ALGO, bucket_time(nbytes, spec, DEFAULT_ALGO)
    for name in COLLECTIVE_ALGOS:
        if name == DEFAULT_ALGO:
            continue
        t = bucket_time(nbytes, spec, name)
        if t < best_t:
            best_name, best_t = name, t
    return best_name, best_t

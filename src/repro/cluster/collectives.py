"""Collective-algorithm cost models over a :class:`ClusterSpec`.

Three AllReduce algorithms, each a closed-form alpha-beta cost (the level of
detail DistIR shows a strategy-ranking simulator needs — per-topology, not
per-packet):

* ``ring`` — one flat ring over all N devices.  Bandwidth-optimal volume
  ``2 (N-1)/N x`` but every one of the ``2 (N-1)`` synchronous steps is
  gated by the slowest (bottleneck) level the ring crosses.  A ring
  confined to a *single* link level is neighbour-aligned by construction
  and pays no ``contention``; a ring spanning several levels fights the
  fabric at its bottleneck.  On the flat back-compat spec the coefficients
  come straight from ``repro.core.hw.ring_allreduce_coeffs`` so the cost is
  bit-identical to the paper's ``T = C x + D`` seed model.

* ``tree`` — recursive-halving reduce-scatter + recursive-doubling
  all-gather (Rabenseifner), scheduled inner-first so the large early
  exchanges stay on fast links and only ``x / N_below`` crosses each outer
  level.  ``2 log2(N)`` steps total; its long-haul pairwise exchanges are
  *not* adjacency-aligned (distance-``2^k`` partners on a torus axis, wide
  routes on an oversubscribed fat tree), so every level charges its
  ``contention`` factor, and non-power-of-two degrees pay one extra
  preparation exchange (the classic 2^k restriction).

* ``hier`` — two-level-style hierarchical AllReduce generalised to L
  levels: ring reduce-scatter inward level by level (shrinking the live
  shard by ``degree`` each time), a ring AllReduce of ``x / N_inner`` at the
  outermost level, then ring all-gathers back out.  Structured, rail-aligned
  rings are exempt from ``contention``; inter-host volume drops by the
  product of the inner degrees — why it wins whenever the outer link is the
  bottleneck (provably never worse than ``ring`` when inner levels are
  uniformly faster; see tests/test_cluster.py).  On a spec with no inner
  fan-out it degenerates to — and is priced exactly as — the flat ring.

The flat back-compat spec is **algorithm-blind**: the seed's fixed-``D``
linear model cannot distinguish algorithms, so all three degenerate to the
legacy formula there (and the search drops the algo mutation method).

Every model is linear in message size for a fixed (spec, algo), so
``allreduce_coeffs`` derives the ``(C, D)`` pair once per pair and memoises
it — ``bucket_time`` in the simulator's hot comm pass is then one
multiply-add, not a topology walk.  All models return 0.0 for empty
(<= 0 byte) transfers: an AllReduce that moves nothing costs nothing
(zero-byte-bucket fix, DESIGN.md Sec. 7).

Phase decomposition (DESIGN.md Sec. 8)
--------------------------------------

``phases(spec, algo, kind)`` decomposes a collective into the sequence of
:class:`CommPhase` steps the event engine (:mod:`repro.core.events`)
schedules on per-link-level resources: hierarchical AllReduce becomes
intra-host reduce-scatter -> inter-host allreduce -> intra-host all-gather,
each phase tagged with the ``LinkLevel`` index it occupies and carrying its
own linear ``(c, d)`` pair.  Phase coefficients sum to the opaque-interval
coefficients (same physics, finer granularity), so the serialized engine
and the phase engine agree on total channel work.

Besides AllReduce (``kind="ar"``) the same machinery prices the ZeRO-3
gradient path: ``kind="rs"`` (reduce-scatter of a gradient bucket across
all devices) and ``kind="ag"`` (all-gather of the updated shard), each
exactly one half of the matching AllReduce — ring RS + ring AG equals ring
AR term by term, so the ``rs_ag`` bucket kind never gets a fictitious
discount.  ``BUCKET_COMM_KINDS`` lists the per-bucket choices the search
mutates (``FusionGraph.set_bucket_comm``).  ``kind="p2p"`` prices a
point-to-point transfer (pipeline-parallel stage boundary) as one phase on
the bottleneck level, for the event engine's ``pp`` traffic class.

``chunk_phases(spec, algo, kind, chunks)`` is the chunked variant
(DESIGN.md Sec. 9): each chunk carries the same per-byte coefficients and
``1/chunks`` of each phase latency, so per-chunk costs over ``nbytes /
chunks`` sum *exactly* to the unchunked collective — store-and-forward
chunk pipelining in the event engine is pure scheduling, never a cost-model
discount, and ``chunks=1`` returns the :func:`phases` tuple itself.
"""
from __future__ import annotations

import dataclasses
import functools
import math

from .topology import ClusterSpec

ALGO_RING = "ring"
ALGO_TREE = "tree"
ALGO_HIER = "hier"
# order matters: best_algo ties resolve to the earliest entry (ring, the
# legacy default, wins exact ties so flat specs keep seed behaviour)
COLLECTIVE_ALGOS = (ALGO_RING, ALGO_TREE, ALGO_HIER)

DEFAULT_ALGO = ALGO_RING

# communication-op kinds a gradient bucket can use: one fused AllReduce
# (the paper's DDP path) or ZeRO-3-style reduce-scatter + all-gather
KIND_AR = "ar"
KIND_RS = "rs"
KIND_AG = "ag"
KIND_RS_AG = "rs_ag"
# point-to-point transfer (pipeline-parallel stage boundary / HLO
# collective-permute): not a bucket kind, but priced by the same phase
# machinery so PP background traffic can contend in the event engine
KIND_P2P = "p2p"
BUCKET_COMM_KINDS = (KIND_AR, KIND_RS_AG)
DEFAULT_COMM_KIND = KIND_AR
# In-kernel fused compute+comm (CoCoNet-style, DESIGN.md Sec. 13).  NOT a
# BUCKET_COMM_KINDS member: the searched flag lives in
# ``FusionGraph.bucket_fused`` so the base kind (ar / rs_ag) keeps pricing
# the wire traffic — "fused" tags phases/timeline records of buckets whose
# collective is issued from inside the producing kernel.
KIND_FUSED = "fused"


# ------------------------------------------------------------- coefficients
def _ring_coeffs(spec: ClusterSpec) -> tuple[float, float]:
    n = spec.n_devices
    spans = [l for l in spec.levels if l.degree > 1]
    if n <= 1 or not spans:
        return 0.0, 0.0
    b = spec.bottleneck()
    # a single-axis ring is neighbour traffic (dilation 1): no contention
    beta = b.beta_contended() if len(spans) > 1 else b.beta
    return (2.0 * (n - 1) / n) * beta, 2.0 * (n - 1) * b.alpha


def _tree_coeffs(spec: ClusterSpec) -> tuple[float, float]:
    if spec.n_devices <= 1:
        return 0.0, 0.0
    c = 0.0
    d_lat = 0.0
    below = 1
    for l in spec.levels:
        d = l.degree
        if d <= 1:
            continue
        beta = l.beta_contended()
        steps = math.ceil(math.log2(d))
        # volume crossing this level per device (reduce-scatter half; the
        # all-gather mirror doubles it)
        c += 2.0 * (1.0 / below - 1.0 / (below * d)) * beta
        d_lat += 2.0 * steps * l.alpha
        if d & (d - 1):  # non-power-of-two: one extra preparation exchange
            c += 2.0 * (1.0 / below) * beta
            d_lat += 2.0 * l.alpha
        below *= d
    return c, d_lat


def _hier_coeffs(spec: ClusterSpec) -> tuple[float, float]:
    if spec.n_devices <= 1:
        return 0.0, 0.0
    inner_fanout = 1
    for l in spec.levels[:-1]:
        inner_fanout *= l.degree
    if inner_fanout <= 1:
        # no inner hierarchy to exploit: "hierarchical" IS the flat ring
        # (same physical schedule, same contention) — never price it cheaper
        return _ring_coeffs(spec)
    c = 0.0
    d_lat = 0.0
    scale = 1.0  # live shard fraction after the inner reduce-scatters
    for l in spec.levels[:-1]:
        d = l.degree
        if d > 1:
            # ring reduce-scatter + all-gather at this level, (d-1) steps
            # and (d-1)/d of the live shard each way, rail-aligned
            c += 2.0 * ((d - 1) / d) * scale * l.beta
            d_lat += 2.0 * (d - 1) * l.alpha
        scale /= d
    outer = spec.levels[-1]
    h = outer.degree
    if h > 1:
        c += (2.0 * (h - 1) / h) * scale * outer.beta
        d_lat += 2.0 * (h - 1) * outer.alpha
    return c, d_lat


_COEFF_FNS = {
    ALGO_RING: _ring_coeffs,
    ALGO_TREE: _tree_coeffs,
    ALGO_HIER: _hier_coeffs,
}


@functools.lru_cache(maxsize=None)
def allreduce_coeffs(spec: ClusterSpec,
                     algo: str = DEFAULT_ALGO) -> tuple[float, float]:
    """``(C, D)`` of the linear cost ``T = C x + D`` for ``x > 0``.

    On the flat back-compat spec every algorithm returns the seed's
    ``ring_allreduce_coeffs`` pair — the legacy model is algorithm-blind,
    and ring cost stays bit-identical to ``hw.allreduce_time``."""
    if spec.compat_hw is not None:
        from repro.core.hw import ring_allreduce_coeffs

        return ring_allreduce_coeffs(spec.compat_hw, spec.n_devices)
    return _COEFF_FNS[algo](spec)


def bucket_time(nbytes: float, spec: ClusterSpec,
                algo: str = DEFAULT_ALGO) -> float:
    """Cost of AllReducing one fused gradient bucket of ``nbytes`` under
    ``algo``.  Empty buckets are free."""
    if nbytes <= 0.0:
        return 0.0
    c, d = allreduce_coeffs(spec, algo)
    return c * nbytes + d


def ring_allreduce(nbytes: float, spec: ClusterSpec) -> float:
    return bucket_time(nbytes, spec, ALGO_RING)


def tree_allreduce(nbytes: float, spec: ClusterSpec) -> float:
    return bucket_time(nbytes, spec, ALGO_TREE)


def hier_allreduce(nbytes: float, spec: ClusterSpec) -> float:
    return bucket_time(nbytes, spec, ALGO_HIER)


ALGORITHMS = {
    ALGO_RING: ring_allreduce,
    ALGO_TREE: tree_allreduce,
    ALGO_HIER: hier_allreduce,
}


def best_algo(nbytes: float, spec: ClusterSpec) -> tuple[str, float]:
    """Cheapest algorithm for this message size on this topology."""
    best_name, best_t = DEFAULT_ALGO, bucket_time(nbytes, spec, DEFAULT_ALGO)
    for name in COLLECTIVE_ALGOS:
        if name == DEFAULT_ALGO:
            continue
        t = bucket_time(nbytes, spec, name)
        if t < best_t:
            best_name, best_t = name, t
    return best_name, best_t


# ------------------------------------------------------ phase decomposition
PHASE_RS = "reduce_scatter"
PHASE_AR = "allreduce"
PHASE_AG = "all_gather"
PHASE_P2P = "permute"


@dataclasses.dataclass(frozen=True)
class CommPhase:
    """One step of a collective: a linear-cost transfer occupying exactly one
    link level.  ``seconds(x)`` is the phase's duration at full level
    bandwidth; under fair-share contention the event engine stretches it."""
    kind: str     # PHASE_RS / PHASE_AR / PHASE_AG
    level: int    # index into spec.levels
    c: float      # seconds/byte at full bandwidth
    d: float      # fixed latency seconds
    # overlap discount of an in-kernel fused collective (DESIGN.md Sec. 13):
    # fraction of the *producing compute job* the transfer reaches back
    # into.  Link work (c, d) stays FULL — fusion never shrinks wire
    # traffic, it only starts it earlier — so coefficient conservation and
    # ``full_overlap_bound`` hold unchanged.  0.0 for ordinary phases.
    overlap: float = 0.0

    def seconds(self, nbytes: float) -> float:
        return self.c * nbytes + self.d


def _ring_phases(spec: ClusterSpec, kind: str) -> tuple[CommPhase, ...]:
    c, d = _ring_coeffs(spec)
    if c == 0.0 and d == 0.0:
        return ()
    b = spec.bottleneck_index()
    if kind == KIND_AR:
        return (CommPhase(PHASE_AR, b, c, d),)
    # ring reduce-scatter / all-gather: (N-1)/N volume and (N-1) steps —
    # exactly one half of the AllReduce, term by term
    pk = PHASE_RS if kind == KIND_RS else PHASE_AG
    return (CommPhase(pk, b, 0.5 * c, 0.5 * d),)


def _tree_phases(spec: ClusterSpec, kind: str) -> tuple[CommPhase, ...]:
    """Recursive-halving reduce-scatter inward / recursive-doubling
    all-gather outward; each level's contribution of ``_tree_coeffs`` splits
    half to the RS leg and half to the AG mirror."""
    if spec.n_devices <= 1:
        return ()
    rs: list[CommPhase] = []
    ag: list[CommPhase] = []
    below = 1
    for i, l in enumerate(spec.levels):
        deg = l.degree
        if deg <= 1:
            continue
        beta = l.beta_contended()
        steps = math.ceil(math.log2(deg))
        c_l = (1.0 / below - 1.0 / (below * deg)) * beta
        d_l = steps * l.alpha
        if deg & (deg - 1):
            c_l += (1.0 / below) * beta
            d_l += l.alpha
        rs.append(CommPhase(PHASE_RS, i, c_l, d_l))
        ag.append(CommPhase(PHASE_AG, i, c_l, d_l))
        below *= deg
    ag.reverse()
    if kind == KIND_RS:
        return tuple(rs)
    if kind == KIND_AG:
        return tuple(ag)
    return tuple(rs + ag)


def _hier_phases(spec: ClusterSpec, kind: str) -> tuple[CommPhase, ...]:
    """Per-level rings: reduce-scatter inward, the outermost level's
    collective on the residual shard, all-gather back outward (the phase
    sequence of ``_hier_coeffs``)."""
    if spec.n_devices <= 1:
        return ()
    inner_fanout = 1
    for l in spec.levels[:-1]:
        inner_fanout *= l.degree
    if inner_fanout <= 1:
        return _ring_phases(spec, kind)  # no inner hierarchy: IS the flat ring
    rs: list[CommPhase] = []
    ag: list[CommPhase] = []
    scale = 1.0
    for i, l in enumerate(spec.levels[:-1]):
        deg = l.degree
        if deg > 1:
            c_l = ((deg - 1) / deg) * scale * l.beta
            d_l = (deg - 1) * l.alpha
            rs.append(CommPhase(PHASE_RS, i, c_l, d_l))
            ag.append(CommPhase(PHASE_AG, i, c_l, d_l))
        scale /= deg
    ag.reverse()
    outer = spec.levels[-1]
    oi = len(spec.levels) - 1
    h = outer.degree
    mid: list[CommPhase] = []
    if h > 1:
        c_o = ((h - 1) / h) * scale * outer.beta
        d_o = (h - 1) * outer.alpha
        if kind == KIND_AR:
            mid = [CommPhase(PHASE_AR, oi, 2.0 * c_o, 2.0 * d_o)]
        elif kind == KIND_RS:
            mid = [CommPhase(PHASE_RS, oi, c_o, d_o)]
        else:
            mid = [CommPhase(PHASE_AG, oi, c_o, d_o)]
    if kind == KIND_RS:
        return tuple(rs + mid)
    if kind == KIND_AG:
        return tuple(mid + ag)
    return tuple(rs + mid + ag)


_PHASE_FNS = {
    ALGO_RING: _ring_phases,
    ALGO_TREE: _tree_phases,
    ALGO_HIER: _hier_phases,
}


def _p2p_phases(spec: ClusterSpec) -> tuple[CommPhase, ...]:
    """One point-to-point transfer (pipeline stage boundary): the full
    message crosses the bottleneck level once — ``c`` is that level's
    per-byte cost, ``d`` one hop latency.  Algorithm-independent."""
    if spec.n_devices <= 1:
        return ()
    b = spec.bottleneck_index()
    lvl = spec.levels[b]
    return (CommPhase(PHASE_P2P, b, lvl.beta, lvl.alpha),)


def _phases_uncached(spec: ClusterSpec, algo: str,
                     kind: str) -> tuple[CommPhase, ...]:
    if kind == KIND_RS_AG:
        return (_phases_uncached(spec, algo, KIND_RS)
                + _phases_uncached(spec, algo, KIND_AG))
    if kind == KIND_P2P:
        return _p2p_phases(spec)
    if spec.compat_hw is not None:
        # the legacy model is one opaque channel: a single phase carrying the
        # seed's exact (C, D); RS/AG are each half of it
        c, d = allreduce_coeffs(spec, algo)
        if kind == KIND_AR:
            return (CommPhase(PHASE_AR, 0, c, d),)
        pk = PHASE_RS if kind == KIND_RS else PHASE_AG
        return (CommPhase(pk, 0, 0.5 * c, 0.5 * d),)
    return _PHASE_FNS[algo](spec, kind)


@functools.lru_cache(maxsize=None)
def phases(spec: ClusterSpec, algo: str = DEFAULT_ALGO,
           kind: str = KIND_AR) -> tuple[CommPhase, ...]:
    """Phase decomposition of one collective of ``kind`` under ``algo`` —
    the schedule unit of the event engine (DESIGN.md Sec. 8)."""
    if kind not in (KIND_AR, KIND_RS, KIND_AG, KIND_RS_AG, KIND_P2P):
        raise ValueError(f"unknown comm kind {kind!r}")
    return _phases_uncached(spec, algo, kind)


@functools.lru_cache(maxsize=None)
def chunk_phases(spec: ClusterSpec, algo: str = DEFAULT_ALGO,
                 kind: str = KIND_AR, chunks: int = 1) -> tuple[CommPhase, ...]:
    """Phase decomposition of **one chunk** of a collective split ``chunks``
    ways (DESIGN.md Sec. 9).

    Each chunk moves ``nbytes / chunks`` of the payload through the same
    phase sequence; the per-phase latency is split evenly across chunks, so
    the per-chunk coefficients sum *exactly* to the unchunked ones —
    chunking conserves total channel work (no fictitious discount) and wins
    only by store-and-forward pipelining chunks through the link levels.
    ``chunks=1`` returns the :func:`phases` tuple unchanged (bit-identical
    schedules)."""
    if chunks <= 1:
        return phases(spec, algo, kind)
    return tuple(
        dataclasses.replace(p, d=p.d / chunks)
        for p in phases(spec, algo, kind)
    )


def _cohort_size(level_bw: float, bottleneck_bw: float, chunks: int) -> int:
    """Chunks coalesced into one transfer on a fat level: the largest power
    of two <= min(chunks, level_bw / bottleneck_bw) that divides
    ``chunks`` evenly (partial cohorts would break exact conservation).
    A level no faster than the bottleneck gets cohort 1 (no coalescing)."""
    if bottleneck_bw <= 0.0 or level_bw <= 0.0:
        return 1
    cap = min(float(chunks), level_bw / bottleneck_bw)
    m = 1
    while m * 2 <= cap:
        m *= 2
    while m > 1 and chunks % m:
        m //= 2
    return m


@functools.lru_cache(maxsize=None)
def level_chunk_phases(spec: ClusterSpec, algo: str = DEFAULT_ALGO,
                       kind: str = KIND_AR, chunks: int = 1,
                       chunk_index: int = 0) -> tuple[CommPhase, ...]:
    """Per-level chunk sizing (DESIGN.md Sec. 14): the phase decomposition
    of chunk ``chunk_index`` when fat link levels coalesce chunks into
    bigger transfers.

    Uniform chunking (:func:`chunk_phases`) sizes every phase's transfer
    for the bottleneck level, so a fat intra-host level pays its per-chunk
    latency ``chunks`` times for no pipelining benefit — real collectives
    (NCCL's proxy path) keep fine chunks only where the wire is slow.
    Here the **leading** phase coalesces each cohort of ``m`` consecutive
    chunks into the cohort's *first* chunk (every chunk's payload is
    resident at the source before the collective starts, so the carrier
    can ship the whole cohort causally-exactly) and the **trailing** phase
    coalesces into the cohort's *last* chunk (the delivery can only
    complete once the cohort's last chunk has arrived); ``m`` is
    :func:`_cohort_size` of that phase's level.  Non-carrier chunks get a
    zero-work phase — the event engine's positional after-gating and
    zero-phase skipping handle them untouched.

    Conservation is exact: per phase, ``chunks/m`` carriers each carry
    ``m x`` the per-chunk ``(c, d/chunks)``, summing to the unchunked
    ``(c, d)`` — coalescing is pure scheduling, never a cost discount.
    Interior phases, single-phase decompositions (nothing to pipeline
    through), flat compat specs and ``chunks <= 1`` are unchanged from
    :func:`chunk_phases`."""
    base = chunk_phases(spec, algo, kind, chunks)
    if chunks <= 1 or len(base) < 2 or spec.compat_hw is not None:
        return base
    bw_bottleneck = spec.bottleneck().bandwidth
    out = list(base)
    for pos, last in ((0, False), (len(base) - 1, True)):
        p = base[pos]
        m = _cohort_size(spec.levels[p.level].bandwidth, bw_bottleneck,
                         chunks)
        if m <= 1:
            continue
        carrier = (chunk_index % m) == (m - 1 if last else 0)
        out[pos] = (dataclasses.replace(p, c=p.c * m, d=p.d * m)
                    if carrier else dataclasses.replace(p, c=0.0, d=0.0))
    return tuple(out)


@functools.lru_cache(maxsize=None)
def fused_phases(spec: ClusterSpec, algo: str = DEFAULT_ALGO,
                 kind: str = KIND_AR, chunks: int = 1,
                 discount: float = 0.0) -> tuple[CommPhase, ...]:
    """Phase decomposition of one chunk of an **in-kernel fused** collective
    (DESIGN.md Sec. 13).

    ``discount`` is the calibrated overlap factor delta in ``[0, 1)``: the
    fused kernel issues the collective from inside the producing compute
    job, so the transfer's ready time reaches ``delta x producer_duration``
    back into that job's tail.  The per-chunk ``(c, d)`` coefficients are
    the :func:`chunk_phases` ones **unchanged** — fusion conserves link work
    exactly (the bytes still cross the wire; they just start earlier), so
    the coefficient-conservation property and the engine's
    ``full_overlap_bound`` floor hold with no special cases.  Phase kinds
    are tagged ``fused_*`` so event-engine timelines can tell in-kernel
    overlap apart from scheduled overlap.

    ``discount <= 0`` returns the :func:`chunk_phases` tuple itself
    (bit-identical schedules: an undiscounted fused bucket prices exactly
    as its base kind)."""
    if discount <= 0.0:
        return chunk_phases(spec, algo, kind, chunks)
    if not discount < 1.0:
        raise ValueError(f"overlap discount must be in [0, 1), "
                         f"got {discount!r}")
    return tuple(
        dataclasses.replace(p, kind=f"{KIND_FUSED}_{p.kind}",
                            overlap=discount)
        for p in chunk_phases(spec, algo, kind, chunks)
    )


def _comm_coeffs_uncached(spec: ClusterSpec, algo: str,
                          kind: str) -> tuple[float, float]:
    if kind == KIND_AR:
        # delegate so the AllReduce path stays bit-identical to the
        # memoised legacy coefficients
        if spec.compat_hw is not None:
            return allreduce_coeffs(spec, algo)
        return _COEFF_FNS[algo](spec)
    c = 0.0
    d = 0.0
    for p in _phases_uncached(spec, algo, kind):
        c += p.c
        d += p.d
    return c, d


@functools.lru_cache(maxsize=None)
def comm_coeffs(spec: ClusterSpec, algo: str = DEFAULT_ALGO,
                kind: str = KIND_AR) -> tuple[float, float]:
    """``(C, D)`` of the opaque-interval cost of one collective of ``kind``
    (``ar`` / ``rs`` / ``ag`` / ``rs_ag``) — ``kind="ar"`` is exactly
    :func:`allreduce_coeffs`."""
    if kind == KIND_AR:
        return allreduce_coeffs(spec, algo)
    if kind not in (KIND_RS, KIND_AG, KIND_RS_AG, KIND_P2P):
        raise ValueError(f"unknown comm kind {kind!r}")
    return _comm_coeffs_uncached(spec, algo, kind)


def comm_time(nbytes: float, spec: ClusterSpec, algo: str = DEFAULT_ALGO,
              kind: str = KIND_AR) -> float:
    """Serialized (single-channel) cost of one collective of ``kind``;
    empty transfers are free."""
    if nbytes <= 0.0:
        return 0.0
    c, d = comm_coeffs(spec, algo, kind)
    return c * nbytes + d

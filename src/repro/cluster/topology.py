"""Topology model: hierarchical cluster specs for collective cost modeling.

A :class:`ClusterSpec` describes the interconnect of a data-parallel cluster
as a tree of *link levels*, innermost (fastest, e.g. NVLink/ICI) to
outermost (slowest, e.g. IB/DCN).  Level ``l`` joins ``degree_l`` groups of
the levels below it, so ``n_devices = prod(degree_l)``.  Each level carries
an (alpha, beta) latency-bandwidth pair in the classic LogP/alpha-beta
sense, plus two heterogeneity knobs:

* ``straggler`` — slowest-link slowdown at this level (a flapping NIC, a
  cable running at half rate).  Synchronous collectives are gated by their
  slowest link, so it scales the bandwidth term of *every* algorithm that
  crosses the level.
* ``contention`` — penalty charged to traffic patterns that are not aligned
  with physical adjacency: recursive halving's distance-``2^k`` pairwise
  exchanges (link dilation on a torus axis, wide routes on an
  oversubscribed fat tree) and flat rings *spanning* the level from below.
  Rings confined to a single level, and hierarchical collectives' rail-
  aligned per-level rings, are exempt (the BlueConnect/Horovod-hierarchical
  argument).

The **back-compat shim**: :meth:`ClusterSpec.flat` maps the legacy
``(Hardware, n_devices)`` pair onto a one-level spec whose ring-AllReduce
cost is *bit-identical* to :func:`repro.core.hw.allreduce_time` (the paper's
``T = C x + D`` linear model) — the PR-1 golden equivalence tests and every
default-constructed :class:`repro.core.simulator.Simulator` see unchanged
numbers.  See DESIGN.md Sec. 7.

This module is intentionally jax-free and repro.core-free at import time so
the search worker pool (spawned bare interpreters) can load it cheaply.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class LinkLevel:
    """One level of the interconnect hierarchy.

    ``bandwidth`` is the per-device (per-rail) bandwidth through this
    level's links in bytes/s; ``alpha`` is the per-communication-step
    latency of one exchange crossing the level, in seconds.
    """
    name: str
    degree: int               # groups of the level below joined at this level
    bandwidth: float          # bytes/s per device stream
    alpha: float              # seconds per communication step
    straggler: float = 1.0    # slowest-link slowdown (>= 1)
    contention: float = 1.0   # oversubscription penalty for unstructured traffic

    @property
    def beta(self) -> float:
        """Seconds/byte of the slowest link at this level."""
        return self.straggler / self.bandwidth

    def beta_contended(self) -> float:
        """Effective seconds/byte for traffic that fights the fabric
        (flat rings / halving-doubling spanning this level)."""
        return self.straggler * self.contention / self.bandwidth


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Hierarchical cluster description (levels ordered inner -> outer).

    ``compat_hw`` marks the back-compat shim: a flat one-level spec created
    from a legacy ``(Hardware, n_devices)`` pair, whose ring cost delegates
    to ``repro.core.hw.allreduce_time`` for bit-identical results.
    """
    name: str
    levels: tuple[LinkLevel, ...]
    compat_hw: object | None = None   # repro.core.hw.Hardware, duck-typed

    def __post_init__(self):
        if not self.levels:
            raise ValueError("ClusterSpec needs at least one link level")
        for l in self.levels:
            if l.degree < 1:
                raise ValueError(f"level {l.name}: degree must be >= 1")

    @property
    def n_devices(self) -> int:
        n = 1
        for l in self.levels:
            n *= l.degree
        return n

    @property
    def is_flat_compat(self) -> bool:
        return self.compat_hw is not None

    @staticmethod
    def flat(hw, n_devices: int) -> "ClusterSpec":
        """Legacy ``(hw, n_devices)`` -> one homogeneous link level.  Ring
        cost on this spec is bit-identical to ``hw.allreduce_time`` (the
        level's alpha stores the paper's fixed negotiation overhead D)."""
        lvl = LinkLevel("ici", max(int(n_devices), 1), hw.ici_bw,
                        hw.allreduce_latency)
        return ClusterSpec(f"flat_{hw.name}_{n_devices}", (lvl,),
                           compat_hw=hw)

    # ------------------------------------------------------------- helpers
    def group_sizes(self) -> list[int]:
        """Cumulative device counts below/at each level: N_0=1, N_l =
        N_{l-1} * degree_l."""
        sizes = [1]
        for l in self.levels:
            sizes.append(sizes[-1] * l.degree)
        return sizes

    def bottleneck_index(self) -> int:
        """Index of the level a flat collective is gated by (max contended
        beta over levels with fan-out, outermost wins ties — long-haul
        links dominate)."""
        cands = [i for i, l in enumerate(self.levels) if l.degree > 1]
        if not cands:
            return len(self.levels) - 1
        best = cands[0]
        for i in cands[1:]:
            if (self.levels[i].beta_contended()
                    >= self.levels[best].beta_contended()):
                best = i
        return best

    def bottleneck(self) -> LinkLevel:
        return self.levels[self.bottleneck_index()]

    def describe(self) -> dict:
        return {
            "name": self.name,
            "n_devices": self.n_devices,
            "flat_compat": self.is_flat_compat,
            "levels": [
                {
                    "name": l.name, "degree": l.degree,
                    "bandwidth_gbps": l.bandwidth / 1e9,
                    "alpha_us": l.alpha * 1e6,
                    "straggler": l.straggler,
                    "contention": l.contention,
                }
                for l in self.levels
            ],
        }


# ------------------------------------------------------------------ presets
def _torus_dilation(degree: int) -> float:
    """Mean link dilation of recursive halving's distance-2^k exchanges on a
    bidirectional ring axis — neighbour traffic has dilation 1, a
    distance-d/2 exchange occupies d/2 links."""
    if degree <= 2:
        return 1.0
    hops = []
    k = 1
    while k < degree:
        hops.append(min(k, degree - k))
        k *= 2
    return max(1.0, sum(hops) / len(hops))


def _tpu_ici(name: str, degree: int, bw: float = 50e9,
             alpha: float = 1e-6, **kw) -> LinkLevel:
    kw.setdefault("contention", _torus_dilation(degree))
    return LinkLevel(name, degree, bw, alpha, **kw)


def tpu_pod_levels(n_chips: int, bw: float = 50e9,
                   alpha: float = 1e-6) -> tuple[LinkLevel, ...]:
    """ICI levels of a v5e-style pod: a fast 16-wide inner ring axis and,
    past 16 chips, a slower outer axis.  Shared by the presets and the
    ``cluster_from_mesh`` bridge (single source for the ICI constants)."""
    inner = min(int(n_chips), 16)
    if inner < 1 or n_chips % inner:
        return (_tpu_ici("ici", max(int(n_chips), 1), bw, alpha),)
    levels = [_tpu_ici("ici_x", inner, bw, alpha)]
    outer = n_chips // inner
    if outer > 1:
        levels.append(_tpu_ici("ici_y", outer, bw=bw / 2, alpha=2 * alpha))
    return tuple(levels)


def dcn_level(pods: int, bandwidth: float = 6.25e9, alpha: float = 250e-6,
              contention: float = 4.0) -> LinkLevel:
    """Inter-pod data-center-network level (single source for the DCN
    constants, used by the preset zoo and ``cluster_from_mesh``)."""
    return LinkLevel("dcn", pods, bandwidth, alpha, contention=contention)


# A 2D/3D torus is not literally a tree; the hierarchy below approximates a
# pod as "fast inner ring axis x slower outer ring axis" — good enough for
# ranking fusion strategies (the per-axis bandwidth ratio is what matters).
PRESETS: dict[str, ClusterSpec] = {
    # single ICI ring axis: the paper's homogeneous setting, per-hop latency
    "tpu_v5e_pod_16": ClusterSpec("tpu_v5e_pod_16", tpu_pod_levels(16)),
    "tpu_v5e_pod_64": ClusterSpec("tpu_v5e_pod_64", tpu_pod_levels(64)),
    "tpu_v5e_pod_256": ClusterSpec("tpu_v5e_pod_256", tpu_pod_levels(256)),
    # 4 x DGX-A100: 8 GPUs on NVLink, hosts on HDR IB (2:1 oversubscribed
    # fat tree), one IB rail per GPU
    "a100_nvlink_ib": ClusterSpec(
        "a100_nvlink_ib",
        (LinkLevel("nvlink", 8, 300e9, 3e-6),
         LinkLevel("ib_hdr", 4, 25e9, 15e-6, contention=2.0))),
    # 16 x DGX-H100 SuperPOD slice: NVLink4 + NDR IB rail-optimised
    "h100_superpod": ClusterSpec(
        "h100_superpod",
        (LinkLevel("nvlink4", 8, 450e9, 2e-6),
         LinkLevel("ib_ndr", 16, 50e9, 10e-6, contention=1.5))),
    # two TPU pods joined over the data-center network
    "cross_dc_2pod": ClusterSpec(
        "cross_dc_2pod", tpu_pod_levels(256) + (dcn_level(2),)),
    # heterogeneous variant: one flapping IB link running at 1/8 rate drags
    # every synchronous collective that crosses the inter-host level
    "a100_straggler_ib": ClusterSpec(
        "a100_straggler_ib",
        (LinkLevel("nvlink", 8, 300e9, 3e-6),
         LinkLevel("ib_hdr", 4, 25e9, 15e-6, straggler=8.0,
                   contention=2.0))),
}


def get_preset(name: str) -> ClusterSpec:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown cluster preset {name!r}; available: "
            f"{', '.join(sorted(PRESETS))}") from None


def list_presets() -> list[str]:
    return sorted(PRESETS)
